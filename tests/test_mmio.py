"""Matrix Market IO round-trips (paper section 3.1)."""
import numpy as np
import pytest

from repro.matrices.mmio import read_matrix_market, write_matrix_market


def test_roundtrip_real(tmp_path, rng):
    n = 20
    a = (rng.random((n, n)) < 0.2) * rng.standard_normal((n, n))
    r, c = np.nonzero(a)
    path = tmp_path / "m.mtx"
    write_matrix_market(path, r, c, a[r, c], (n, n))
    r2, c2, v2, shape = read_matrix_market(path)
    assert shape == (n, n)
    b = np.zeros((n, n))
    b[r2, c2] = v2
    np.testing.assert_allclose(b, a, atol=1e-12)


def test_roundtrip_complex(tmp_path, rng):
    vals = (rng.standard_normal(5) + 1j * rng.standard_normal(5))
    write_matrix_market(tmp_path / "c.mtx", [0, 1, 2, 3, 4],
                        [4, 3, 2, 1, 0], vals, (5, 5))
    _, _, v2, _ = read_matrix_market(tmp_path / "c.mtx")
    np.testing.assert_allclose(np.sort_complex(v2), np.sort_complex(vals))


def test_symmetric_expansion(tmp_path):
    with open(tmp_path / "s.mtx", "w") as f:
        f.write("%%MatrixMarket matrix coordinate real symmetric\n")
        f.write("% comment line\n")
        f.write("3 3 2\n1 1 5.0\n3 1 2.0\n")
    r, c, v, shape = read_matrix_market(tmp_path / "s.mtx")
    a = np.zeros((3, 3))
    a[r, c] = v
    assert a[0, 0] == 5.0 and a[2, 0] == 2.0 and a[0, 2] == 2.0


def test_pattern_field(tmp_path):
    with open(tmp_path / "p.mtx", "w") as f:
        f.write("%%MatrixMarket matrix coordinate pattern general\n")
        f.write("2 2 2\n1 1\n2 2\n")
    r, c, v, _ = read_matrix_market(tmp_path / "p.mtx")
    np.testing.assert_array_equal(v, [1.0, 1.0])


def test_rejects_array_format(tmp_path):
    with open(tmp_path / "bad.mtx", "w") as f:
        f.write("%%MatrixMarket matrix array real general\n2 2\n1\n2\n3\n4\n")
    with pytest.raises(ValueError):
        read_matrix_market(tmp_path / "bad.mtx")
