"""Matrix Market IO round-trips (paper section 3.1)."""
import numpy as np
import pytest

from repro.matrices.mmio import read_matrix_market, write_matrix_market


def test_roundtrip_real(tmp_path, rng):
    n = 20
    a = (rng.random((n, n)) < 0.2) * rng.standard_normal((n, n))
    r, c = np.nonzero(a)
    path = tmp_path / "m.mtx"
    write_matrix_market(path, r, c, a[r, c], (n, n))
    r2, c2, v2, shape = read_matrix_market(path)
    assert shape == (n, n)
    b = np.zeros((n, n))
    b[r2, c2] = v2
    np.testing.assert_allclose(b, a, atol=1e-12)


def test_roundtrip_complex(tmp_path, rng):
    vals = (rng.standard_normal(5) + 1j * rng.standard_normal(5))
    write_matrix_market(tmp_path / "c.mtx", [0, 1, 2, 3, 4],
                        [4, 3, 2, 1, 0], vals, (5, 5))
    _, _, v2, _ = read_matrix_market(tmp_path / "c.mtx")
    np.testing.assert_allclose(np.sort_complex(v2), np.sort_complex(vals))


def test_symmetric_expansion(tmp_path):
    with open(tmp_path / "s.mtx", "w") as f:
        f.write("%%MatrixMarket matrix coordinate real symmetric\n")
        f.write("% comment line\n")
        f.write("3 3 2\n1 1 5.0\n3 1 2.0\n")
    r, c, v, shape = read_matrix_market(tmp_path / "s.mtx")
    a = np.zeros((3, 3))
    a[r, c] = v
    assert a[0, 0] == 5.0 and a[2, 0] == 2.0 and a[0, 2] == 2.0


def test_pattern_field(tmp_path):
    with open(tmp_path / "p.mtx", "w") as f:
        f.write("%%MatrixMarket matrix coordinate pattern general\n")
        f.write("2 2 2\n1 1\n2 2\n")
    r, c, v, _ = read_matrix_market(tmp_path / "p.mtx")
    np.testing.assert_array_equal(v, [1.0, 1.0])


def test_rejects_array_format(tmp_path):
    with open(tmp_path / "bad.mtx", "w") as f:
        f.write("%%MatrixMarket matrix array real general\n2 2\n1\n2\n3\n4\n")
    with pytest.raises(ValueError):
        read_matrix_market(tmp_path / "bad.mtx")


# ---------------------------------------------------------------------------
# field/symmetry fidelity (integer parsing, headers, blank lines)
# ---------------------------------------------------------------------------

def _tri_vals(field, rng):
    """Strictly-lower + diagonal triplets legal for every symmetry."""
    r = np.array([0, 2, 3, 1, 3], np.int64)
    c = np.array([0, 1, 2, 1, 3], np.int64)
    if field == "integer":
        v = np.array([5, -7, 123456789012345, 9, 4], np.int64)
    elif field == "complex":
        v = (rng.standard_normal(5) + 1j * rng.standard_normal(5))
    elif field == "pattern":
        v = np.ones(5)
    else:
        v = rng.standard_normal(5)
    return r, c, v


def _expand(sym, r, c, v):
    off = r != c
    if sym == "general":
        return r, c, v
    v2 = {"symmetric": v[off], "skew-symmetric": -v[off],
          "hermitian": np.conj(v[off])}[sym]
    return (np.concatenate([r, c[off]]), np.concatenate([c, r[off]]),
            np.concatenate([v, v2]))


def _dense(r, c, v, n=4):
    a = np.zeros((n, n), v.dtype)
    a[r, c] = v
    return a


@pytest.mark.parametrize("field", ["real", "integer", "complex", "pattern"])
@pytest.mark.parametrize("sym", ["general", "symmetric", "skew-symmetric",
                                 "hermitian"])
def test_roundtrip_field_x_symmetry(tmp_path, rng, field, sym):
    if sym == "hermitian" and field != "complex":
        pytest.skip("hermitian requires a complex field")
    if sym == "skew-symmetric" and field == "pattern":
        pytest.skip("pattern carries no sign to negate")
    r, c, v = _tri_vals(field, rng)
    if sym == "skew-symmetric":
        keep = r != c                         # no stored diagonal
        r, c, v = r[keep], c[keep], v[keep]
    p1 = tmp_path / "a.mtx"
    write_matrix_market(p1, r, c, v, (4, 4), field=field, symmetry=sym)
    assert f"coordinate {field} {sym}" in p1.read_text().splitlines()[0]

    r1, c1, v1, shape = read_matrix_market(p1)
    assert shape == (4, 4)
    re, ce, ve = _expand(sym, r, c, v)
    np.testing.assert_allclose(_dense(r1, c1, v1), _dense(re, ce, ve),
                               atol=1e-14)
    # write->read->write->read keeps values AND dtype (integer stays
    # integer — the old writer re-emitted it as `real`)
    p2 = tmp_path / "b.mtx"
    write_matrix_market(p2, r1, c1, v1, shape)
    r2, c2, v2, _ = read_matrix_market(p2)
    assert v2.dtype == v1.dtype
    np.testing.assert_allclose(_dense(r2, c2, v2), _dense(r1, c1, v1),
                               atol=1e-14)


def test_integer_field_dtype_and_exactness(tmp_path):
    """int64 values survive exactly: float(...) parsing would truncate
    2**53 + 1, and the writer must emit an `integer` header."""
    big = 2 ** 53 + 1
    p = tmp_path / "i.mtx"
    write_matrix_market(p, [0, 1], [1, 0], np.array([big, -3], np.int64),
                        (2, 2))
    assert "coordinate integer general" in p.read_text().splitlines()[0]
    _, _, v, _ = read_matrix_market(p)
    assert v.dtype == np.int64
    assert v[0] == big                        # float round-trip gives 2**53


def test_integer_parse_is_exact(tmp_path):
    with open(tmp_path / "i.mtx", "w") as f:
        f.write("%%MatrixMarket matrix coordinate integer general\n")
        f.write(f"1 1 1\n1 1 {2 ** 53 + 1}\n")
    _, _, v, _ = read_matrix_market(tmp_path / "i.mtx")
    assert v[0] == 2 ** 53 + 1


def test_blank_lines_tolerated(tmp_path):
    with open(tmp_path / "b.mtx", "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n"
                "% a comment\n"
                "\n"
                "3 3 2\n"
                "\n"
                "1 1 1.5\n"
                "\n"
                "3 2 -2.5\n"
                "\n")
    r, c, v, shape = read_matrix_market(tmp_path / "b.mtx")
    assert shape == (3, 3)
    np.testing.assert_allclose(v, [1.5, -2.5])


def test_truncated_file_raises(tmp_path):
    with open(tmp_path / "t.mtx", "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n3 3 2\n1 1 1.0\n")
    with pytest.raises(ValueError, match="end of file"):
        read_matrix_market(tmp_path / "t.mtx")
