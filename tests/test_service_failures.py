"""Failure-injection tests: the scheduling edges where state could tear.

Each scenario forces one specific hazard — cancel landing mid-chunk (and
racing a convergence at the same boundary), a deadline expiring during a
refill on the column AND the block warm-restart path, admission
rejection at a full queue, and a whole queue expiring before its batch
ever initializes — then checks the service's counters, ``completed``
log, and batch state with the same invariant checker the property tests
use.  All on the virtual clock: every scenario is exact and repeatable.
"""
import numpy as np
import pytest

from repro.matrices import laplace3d
from repro.runtime import MatrixRegistry
from service_harness import ServiceHarness, assert_consistent


@pytest.fixture(scope="module")
def lap():
    r, c, v, n = laplace3d(6)
    return r, c, v, n


@pytest.fixture()
def reg(lap):
    r, c, v, n = lap
    registry = MatrixRegistry()
    registry.register("lap", rows=r, cols=c, vals=v, shape=(n, n), C=16,
                      sigma=32, w_align=4, dtype=np.float32)
    return registry


def _b(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


class TestCancelMidChunk:
    @pytest.mark.parametrize("block", [False, True])
    def test_cancel_running_lands_at_next_boundary(self, reg, lap, block):
        *_, n = lap
        h = ServiceHarness(reg, block_width=2, chunk_iters=4)
        t = h.submit("lap", _b(n, 1), tol=1e-10, maxiter=500, block=block)
        peer = h.submit("lap", _b(n, 2), tol=1e-10, maxiter=500,
                        block=block)
        h.step()                               # both running, mid-solve
        assert t.status == "running"
        assert h.cancel(t) is True
        assert t.status == "running"           # not yet — chunk boundary
        h.step()
        assert t.status == "cancelled" and t.result is None
        assert h.cancel(t) is False            # second cancel is a no-op
        assert h.service.stats["cancelled"] == 1
        assert t in h.service.completed
        h.drain()
        assert peer.status == "done" and peer.result.converged
        assert h.service.stats["retired"] == 1
        assert_consistent(h.service, [t, peer])

    def test_cancel_wins_over_convergence_at_same_boundary(self, reg, lap):
        """A cancel issued mid-chunk sticks even if the column converges
        inside that very chunk: cancel() == True must always mean the
        ticket ends cancelled (never 'done-anyway')."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=64)
        t = h.submit("lap", _b(n), tol=1e-3, maxiter=500)  # converges in 1
        # step() is atomic from the outside, so emulate the mid-chunk
        # moment: open the batch (admits the ticket), cancel, THEN run
        # the chunk that would converge it
        for key, q in list(h.service._queues.items()):
            if q:
                h.service._open_batch(key)
        assert t.status == "running"
        assert h.cancel(t) is True
        h.step()                               # chunk runs and converges
        assert t.status == "cancelled"         # but cancel won
        assert t.result is None
        assert h.service.stats["retired"] == 0
        assert h.service.stats["converged"] == 0
        assert_consistent(h.service, [t])

    def test_cancel_queued_never_admitted(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=4)
        hog = h.submit("lap", _b(n, 1), tol=1e-10, maxiter=500)
        waiting = h.submit("lap", _b(n, 2), tol=1e-10, maxiter=500)
        h.step()
        assert waiting.status == "queued"
        assert h.cancel(waiting) is True
        assert waiting.status == "cancelled"   # queued cancels are instant
        assert waiting.started_at is None
        h.drain()
        assert hog.status == "done"
        # the lazily-removed heap entry never resurfaced
        assert h.service.stats["cancelled"] == 1
        assert h.service.stats["retired"] == 1
        assert_consistent(h.service, [hog, waiting])


class TestDeadlineDuringRefill:
    def test_column_refill_expires_stale_request(self, reg, lap):
        """Deadline passes while queued behind a full column batch: the
        refill gate expires it — no slot, no result, counters exact."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=4)
        hog = h.submit("lap", _b(n, 1), tol=1e-4, maxiter=500)
        h.step()                               # hog takes the only slot
        stale = h.submit("lap", _b(n, 2), tol=1e-4, deadline=1.0)
        fresh = h.submit("lap", _b(n, 3), tol=1e-4, maxiter=500)
        h.run_until(lambda: stale.resolved)
        assert stale.status == "expired"
        assert stale.started_at is None and stale.result is None
        assert stale in h.service.completed
        h.drain()
        # the non-deadline sibling behind it was admitted and completed
        assert fresh.status == "done" and fresh.result.converged
        s = h.service.stats
        assert (s["expired"], s["retired"]) == (1, 2)
        assert_consistent(h.service, [hog, stale, fresh])

    def test_block_warm_restart_expires_stale_request(self, reg, lap):
        """Same hazard on the block path: the expiry fires inside
        _refill_block, before the warm restart admits newcomers, and the
        restart must stay consistent for the survivors."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=2, chunk_iters=4)
        quick = h.submit("lap", _b(n, 1), tol=1e-3, maxiter=500,
                         block=True)
        slow = h.submit("lap", _b(n, 2), tol=1e-10, maxiter=500,
                        block=True)
        h.step()                               # block batch of two, full
        stale = h.submit("lap", _b(n, 3), tol=1e-4, deadline=1.0,
                         block=True)
        late = h.submit("lap", _b(n, 4), tol=1e-4, maxiter=500,
                        block=True)
        h.run_until(lambda: stale.resolved)
        assert stale.status == "expired"
        assert stale.started_at is None and stale.result is None
        h.drain()
        assert quick.result.converged and slow.result.converged
        assert late.result.converged           # admitted by the restart
        # per-ticket iteration accounting survived the warm restart(s)
        assert slow.result.iters > 0 and late.result.iters > 0
        s = h.service.stats
        assert (s["expired"], s["retired"]) == (1, 3)
        assert_consistent(h.service, [quick, slow, stale, late])

    @pytest.mark.parametrize("block", [False, True])
    def test_whole_queue_expires_before_batch_init(self, reg, lap, block):
        """Every queued request is already past its deadline when the
        batch opens: the batch must come up empty (state None), expire
        them all without running a chunk, and get torn down cleanly."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=2, chunk_iters=4)
        a = h.submit("lap", _b(n, 1), tol=1e-4, deadline=1.0, block=block)
        b = h.submit("lap", _b(n, 2), tol=1e-4, deadline=1.5, block=block)
        h.clock.advance(5.0)                   # both deadlines long gone
        h.step()
        assert a.status == b.status == "expired"
        assert a.result is None and b.result is None
        assert h.service.stats["chunks"] == 0  # no chunk ever ran
        assert not h.service._batches          # batch torn down
        assert h.service.pending == 0
        assert_consistent(h.service, [a, b])
        # the service is still healthy afterwards
        ok = h.submit("lap", _b(n, 3), tol=1e-4, maxiter=500, block=block)
        h.drain()
        assert ok.status == "done" and ok.result.converged


class TestAdmissionRejection:
    def test_full_queue_rejects_and_recovers(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=4, max_queue=2)
        admitted = [h.submit("lap", _b(n, i), tol=1e-4, maxiter=500)
                    for i in range(2)]
        overflow = [h.submit("lap", _b(n, 9), tol=1e-4, maxiter=500)
                    for _ in range(3)]
        for t in overflow:
            assert t.rejected and t.result is None
            assert t.finished_at is not None and t.latency == 0.0
            assert t not in h.service.completed   # never admitted
        s = h.service.stats
        assert s["rejected"] == 3 and s["submitted"] == 5
        assert_consistent(h.service, admitted + overflow)
        # draining frees queue capacity: the next submit is admitted
        h.drain()
        again = h.submit("lap", _b(n, 10), tol=1e-4, maxiter=500)
        assert not again.rejected
        h.drain()
        assert again.status == "done"
        assert (h.service.stats["retired"], h.service.stats["rejected"]) \
            == (3, 3)
        assert_consistent(h.service, admitted + overflow + [again])

    def test_rejection_is_per_key(self, reg, lap):
        """The bound is per batch key: a full cg queue must not reject
        minres traffic."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=4, max_queue=1)
        h.submit("lap", _b(n, 1), tol=1e-4)            # fills the cg queue
        rej = h.submit("lap", _b(n, 2), tol=1e-4)
        ok = h.submit("lap", _b(n, 3), tol=1e-4, solver="minres")
        assert rej.rejected and not ok.rejected
        h.drain()
        assert ok.status == "done"
        assert_consistent(h.service)

    def test_cancelled_queue_entry_frees_capacity(self, reg, lap):
        """cancel() on a queued ticket must release its admission slot
        even though the heap removes entries lazily."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=4, max_queue=1)
        queued = h.submit("lap", _b(n, 1), tol=1e-4)
        assert h.submit("lap", _b(n, 2), tol=1e-4).rejected
        h.cancel(queued)
        ok = h.submit("lap", _b(n, 3), tol=1e-4)       # capacity is back
        assert not ok.rejected
        h.drain()
        assert ok.status == "done" and queued.status == "cancelled"
        assert_consistent(h.service, [queued, ok])
