"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode)
against its pure-jnp oracle in ref.py."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import from_dense, SpmvOpts
from repro.core.spmv import spmv_ref
from repro.kernels import ops
from repro.kernels import ref as kref


def random_sparse(rng, n, m, density=0.1, dtype=np.float32):
    return ((rng.random((n, m)) < density)
            * rng.standard_normal((n, m))).astype(dtype)


# ---------------------------------------------------------------- spmv
class TestSellcsSpmvKernel:
    @pytest.mark.parametrize("n,C,wt,b", [
        (64, 8, 2, 1), (96, 16, 4, 3), (200, 32, 8, 4), (33, 8, 1, 2),
    ])
    def test_shapes(self, rng, n, C, wt, b):
        a = random_sparse(rng, n, n)
        m = from_dense(a, C=C, sigma=4 * C, w_align=wt)
        x = rng.standard_normal((n, b)).astype(np.float32)
        xp = m.permute(x)
        yk, _, _ = ops.sellcs_spmv(m, xp)
        yr, _, _ = spmv_ref(m, xp)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                                   atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("dtype,tol", [
        (np.float32, 1e-4), (jnp.bfloat16, 5e-2),
    ])
    def test_dtypes(self, rng, dtype, tol):
        a = random_sparse(rng, 80, 80, dtype=np.float32)
        m = from_dense(a, C=8, sigma=16, w_align=4, dtype=dtype)
        x = rng.standard_normal((80, 2)).astype(np.float32)
        xp = m.permute(jnp.asarray(x, dtype))
        yk, _, _ = ops.sellcs_spmv(m, xp)
        yr, _, _ = spmv_ref(m, xp)
        np.testing.assert_allclose(np.asarray(yk, np.float32),
                                   np.asarray(yr, np.float32),
                                   atol=tol, rtol=tol)

    def test_complex_fallback(self, rng):
        """Specialization cascade: complex falls back to the jnp path."""
        a = (random_sparse(rng, 40, 40)
             + 1j * random_sparse(rng, 40, 40)).astype(np.complex64)
        m = from_dense(a, C=8, sigma=8)
        x = (rng.standard_normal(40) + 1j * rng.standard_normal(40)
             ).astype(np.complex64)
        y, _, _ = ops.sellcs_spmv(m, m.permute(x))
        np.testing.assert_allclose(m.unpermute(y), a @ x, atol=1e-3)

    @pytest.mark.parametrize("flags", [
        dict(dot_yy=True), dict(dot_xy=True), dict(dot_xx=True),
        dict(dot_yy=True, dot_xy=True, dot_xx=True),
    ])
    def test_fused_dots(self, rng, flags):
        a = random_sparse(rng, 72, 72)
        m = from_dense(a, C=8, sigma=16, w_align=4)
        x = rng.standard_normal((72, 3)).astype(np.float32)
        xp = m.permute(x)
        opts = SpmvOpts(**flags)
        yk, _, dk = ops.sellcs_spmv(m, xp, opts=opts)
        yr, _, dr = spmv_ref(m, xp, opts=opts)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                                   atol=1e-3, rtol=1e-3)

    def test_full_fusion(self, rng):
        """alpha (A - gamma I) x + beta y, chained z, all dots (paper C3)."""
        n = 96
        a = random_sparse(rng, n, n)
        m = from_dense(a, C=16, sigma=32, w_align=4)
        X = rng.standard_normal((n, 4)).astype(np.float32)
        Y = rng.standard_normal((n, 4)).astype(np.float32)
        Z = rng.standard_normal((n, 4)).astype(np.float32)
        g = rng.standard_normal(4).astype(np.float32)
        opts = SpmvOpts(alpha=0.7, beta=1.3, gamma=jnp.asarray(g),
                        delta=-0.5, eta=2.0,
                        dot_yy=True, dot_xy=True, dot_xx=True)
        Xp, Yp, Zp = m.permute(X), m.permute(Y), m.permute(Z)
        yk, zk, dk = ops.sellcs_spmv(m, Xp, Yp, Zp, opts)
        yr, zr, dr = spmv_ref(m, Xp, Yp, Zp, opts)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)
        np.testing.assert_allclose(np.asarray(zk), np.asarray(zr), atol=1e-4)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                                   atol=1e-3, rtol=1e-3)

    @pytest.mark.parametrize("store,tol", [
        (jnp.bfloat16, 2e-2), (jnp.float16, 2e-3), (jnp.float32, 1e-5),
    ])
    def test_store_dtype_matches_f64_reference(self, rng, store, tol):
        """Mixed-precision storage: the kernel streams narrow values and
        accumulates in the compute dtype — output within a storage-
        appropriate tolerance of the f64 dense reference."""
        n = 88
        a = random_sparse(rng, n, n, dtype=np.float64)
        m = from_dense(a, C=8, sigma=16, w_align=4, dtype=np.float32,
                       store_dtype=store)
        assert m.vals.dtype == jnp.dtype(store)
        x = rng.standard_normal((n, 3)).astype(np.float32)
        xp = m.permute(x)
        yk, _, _ = ops.sellcs_spmv(m, xp)
        assert yk.dtype == jnp.float32           # compute dtype out
        ref = m.permute(jnp.asarray(a @ x.astype(np.float64), np.float32))
        scale = max(1.0, float(np.abs(np.asarray(ref)).max()))
        err = np.abs(np.asarray(yk) - np.asarray(ref)).max() / scale
        assert err < tol, (str(jnp.dtype(store)), err)

    def test_store_dtype_kernel_matches_ref_path(self, rng):
        """Kernel and jnp oracle implement the same upcast contract: on
        the *same* bf16-stored matrix they agree to f32 roundoff."""
        n = 72
        a = random_sparse(rng, n, n)
        m = from_dense(a, C=8, sigma=16, w_align=4, dtype=np.float32,
                       store_dtype=jnp.bfloat16)
        x = rng.standard_normal((n, 2)).astype(np.float32)
        xp = m.permute(x)
        opts = SpmvOpts(dot_yy=True, dot_xy=True)
        yk, _, dk = ops.sellcs_spmv(m, xp, opts=opts)
        yr, _, dr = spmv_ref(m, xp, opts=opts)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                                   atol=1e-3, rtol=1e-3)

    def test_store_none_bit_identical_kernel_output(self, rng):
        """store_dtype=None reproduces the classic single-dtype kernel
        output bit-for-bit (the acceptance pin at the kernel layer)."""
        n = 64
        a = random_sparse(rng, n, n)
        m0 = from_dense(a, C=8, sigma=16, w_align=4)
        m1 = from_dense(a, C=8, sigma=16, w_align=4,
                        store_dtype=np.float32)
        x = rng.standard_normal((n, 2)).astype(np.float32)
        xp = m0.permute(x)
        y0, _, _ = ops.sellcs_spmv(m0, xp)
        y1, _, _ = ops.sellcs_spmv(m1, xp)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))

    def test_traced_coefficients(self, rng):
        """Coefficients must work as traced values inside jit (solvers)."""
        import jax
        a = random_sparse(rng, 32, 32)
        m = from_dense(a, C=8, sigma=8, w_align=4)
        x = m.permute(rng.standard_normal((32, 1)).astype(np.float32))

        @jax.jit
        def f(alpha):
            y, _, _ = ops.sellcs_spmv(m, x, opts=SpmvOpts(alpha=alpha))
            return y

        y1 = f(2.0)
        y2, _, _ = spmv_ref(m, x, opts=SpmvOpts(alpha=2.0))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ---------------------------------------------------------------- tsm
class TestTsm:
    @pytest.mark.parametrize("n,m,k", [
        (128, 1, 1), (512, 4, 8), (777, 8, 12), (1024, 16, 16), (100, 2, 32),
    ])
    def test_tsmttsm_shapes(self, rng, n, m, k):
        V = rng.standard_normal((n, m)).astype(np.float32)
        W = rng.standard_normal((n, k)).astype(np.float32)
        out = ops.tsmttsm(V, W)
        ref = kref.tsmttsm_ref(V, W)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3, rtol=1e-3)

    def test_tsmttsm_alpha_beta(self, rng):
        V = rng.standard_normal((300, 4)).astype(np.float32)
        W = rng.standard_normal((300, 6)).astype(np.float32)
        X = rng.standard_normal((4, 6)).astype(np.float32)
        out = ops.tsmttsm(V, W, X, alpha=1.5, beta=-0.5)
        np.testing.assert_allclose(np.asarray(out), 1.5 * V.T @ W - 0.5 * X,
                                   atol=1e-3)

    def test_tsmttsm_kahan_more_accurate(self):
        """Kahan variant beats naive f32 summation on adversarial data."""
        n = 20000
        rng = np.random.default_rng(7)
        base = rng.standard_normal((n, 1)).astype(np.float32)
        V = base * np.float32(1e4)
        V[::2] *= -1
        V = V + rng.standard_normal((n, 1)).astype(np.float32)
        W = np.ones((n, 1), np.float32)
        exact = np.sum(V.astype(np.float64))
        err_k = abs(float(ops.tsmttsm(V, W, kahan=True)[0, 0]) - exact)
        err_n = abs(float(np.float32(0) + np.sum(V.astype(np.float32))) - exact)
        assert err_k <= err_n + 1e-3

    @pytest.mark.parametrize("n,m,k", [(64, 2, 4), (500, 8, 8), (1000, 16, 4)])
    def test_tsmm_shapes(self, rng, n, m, k):
        V = rng.standard_normal((n, m)).astype(np.float32)
        X = rng.standard_normal((m, k)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(ops.tsmm(V, X)),
                                   np.asarray(kref.tsmm_ref(V, X)),
                                   atol=1e-4, rtol=1e-4)

    def test_tsmm_inplace(self, rng):
        V = rng.standard_normal((128, 4)).astype(np.float32)
        X = rng.standard_normal((4, 4)).astype(np.float32)
        out = ops.tsmm_inplace(V, X, alpha=1.0, beta=0.5)
        np.testing.assert_allclose(np.asarray(out), V @ X + 0.5 * V, atol=1e-4)

    def test_bf16(self, rng):
        V = jnp.asarray(rng.standard_normal((256, 8)), jnp.bfloat16)
        W = jnp.asarray(rng.standard_normal((256, 8)), jnp.bfloat16)
        out = ops.tsmttsm(V, W)
        ref = kref.tsmttsm_ref(V, W)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=0.5, rtol=0.05)

    def test_complex_fallback(self, rng):
        V = (rng.standard_normal((100, 3))
             + 1j * rng.standard_normal((100, 3))).astype(np.complex64)
        W = (rng.standard_normal((100, 2))
             + 1j * rng.standard_normal((100, 2))).astype(np.complex64)
        out = ops.tsmttsm(V, W)
        np.testing.assert_allclose(np.asarray(out), np.conj(V).T @ W,
                                   atol=1e-3)


# ------------------------------------------------------------- fused axpby
class TestFusedUpdate:
    @pytest.mark.parametrize("n,b", [(64, 1), (500, 4), (1024, 8)])
    def test_vs_ref(self, rng, n, b):
        x = rng.standard_normal((n, b)).astype(np.float32)
        y = rng.standard_normal((n, b)).astype(np.float32)
        a = rng.standard_normal(b).astype(np.float32)
        out, dots = ops.fused_axpby_dots(x, y, a, 0.5, dot_yy=True,
                                         dot_xy=True, dot_xx=True)
        ref_out, ref_dots = kref.fused_axpby_dots_ref(
            x, y, a, 0.5, dot_yy=True, dot_xy=True, dot_xx=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(dots), np.asarray(ref_dots),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 300), m=st.integers(1, 12), k=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_property_tsmttsm(n, m, k, seed):
    rng = np.random.default_rng(seed)
    V = rng.standard_normal((n, m)).astype(np.float32)
    W = rng.standard_normal((n, k)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.tsmttsm(V, W)), V.T @ W,
                               atol=1e-2, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 120), seed=st.integers(0, 2**31 - 1),
       C=st.sampled_from([4, 8, 16]), wt=st.sampled_from([1, 2, 4]))
def test_property_spmv_kernel(n, seed, C, wt):
    rng = np.random.default_rng(seed)
    a = ((rng.random((n, n)) < 0.25)
         * rng.standard_normal((n, n))).astype(np.float32)
    m = from_dense(a, C=C, sigma=C * 2, w_align=wt)
    x = rng.standard_normal((n, 2)).astype(np.float32)
    xp = m.permute(x)
    yk, _, _ = ops.sellcs_spmv(m, xp)
    yr, _, _ = spmv_ref(m, xp)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               atol=1e-3, rtol=1e-3)
