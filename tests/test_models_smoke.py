"""Per-architecture smoke tests (assigned-arch deliverable): reduced config
of the same family, one forward/train step on CPU, asserting shapes and
no NaNs; plus train/decode parity."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_smoke_config, list_archs
from repro.configs.base import shape_applicable, dryrun_cells, input_specs
from repro.models import transformer as T

ARCHS = list_archs()


def make_batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                          jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(key, (B, S * 2, cfg.d_model),
                                                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = T.forward(cfg, params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grad_finite(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    (loss, m), grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    assert sum(float(jnp.sum(jnp.abs(g))) for g in leaves) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, maxlen = 2, 24
    cache = T.init_cache(cfg, B, maxlen)
    enc_out = None
    if cfg.enc_dec:
        enc_out = jax.random.normal(jax.random.PRNGKey(1),
                                    (B, 8, cfg.d_model), cfg.dtype)
    tok = jnp.zeros((B, 1), jnp.int32)
    for step in range(3):
        logits, cache = T.decode_step(cfg, params, cache, tok, step,
                                      enc_out=enc_out)
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2_5_3b", "xlstm_1_3b",
                                  "jamba_1_5_large_398b", "grok_1_314b"])
def test_decode_matches_forward(arch):
    """Prefill/decode parity: token-by-token decode logits must match the
    full forward pass at every position (exact cache semantics)."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # parity needs (a) ample capacity — forward (T=8) and decode (T=1)
        # compute different capacities, so tight caps drop tokens only in
        # the forward pass — and (b) sharp router decisions so fp-level
        # attention differences can't flip near-tie expert choices
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if cfg.moe is not None:
        params = jax.tree_util.tree_map_with_path(
            lambda p, x: x * 20.0 if any(
                getattr(k, "key", None) == "router" for k in p) else x,
            params)
    B, S = 1, 8
    batch = make_batch(cfg, B=B, S=S, seed=2)
    ref_logits, _ = T.forward(cfg, params, batch, remat=False)

    cache = T.init_cache(cfg, B, S + 2)
    outs = []
    for t in range(S):
        logits, cache = T.decode_step(cfg, params, cache,
                                      batch["tokens"][:, t:t + 1], t)
        outs.append(np.asarray(logits[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(ref_logits),
                               atol=2e-2, rtol=2e-2)


def test_dryrun_cell_list():
    """8 full-attention archs x 3 shapes + 2 sub-quadratic archs x 4."""
    cells = dryrun_cells()
    assert len(cells) == 8 * 3 + 2 * 4
    assert ("xlstm_1_3b", "long_500k") in cells
    assert ("jamba_1_5_large_398b", "long_500k") in cells
    assert ("qwen2_5_3b", "long_500k") not in cells


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_well_defined(arch):
    """Every applicable (arch x shape) cell has concrete input specs."""
    from repro.configs import get_config
    cfg = get_config(arch)
    for sname, sp in SHAPES.items():
        ok, why = shape_applicable(cfg, sp)
        if not ok:
            assert "full-attention" in why
            continue
        spec = input_specs(cfg, sp)
        assert "tokens" in spec
        assert all(d > 0 for s in jax.tree.leaves(spec) for d in s.shape)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    from repro.configs import get_config
    want = {
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2_5_3b": (36, 2048, 16, 2, 11008, 151936),
        "mistral_nemo_12b": (40, 5120, 32, 8, 14336, 131072),
        "llama3_2_3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "llama4_maverick_400b": (48, 5120, 40, 8, 8192, 202048),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
    }
    for aid, (L, d, h, kv, ff, v) in want.items():
        c = get_config(aid)
        assert c.n_layers == L, aid
        assert c.d_model == d, aid
        assert c.n_heads == h, aid
        assert c.n_kv_heads == kv, aid
        assert c.d_ff == ff, aid
        assert c.vocab_size == v, aid
    assert get_config("grok_1_314b").moe.n_experts == 8
    assert get_config("grok_1_314b").moe.top_k == 2
    assert get_config("llama4_maverick_400b").moe.n_experts == 128
    assert get_config("llama4_maverick_400b").moe.top_k == 1
    assert get_config("jamba_1_5_large_398b").moe.n_experts == 16
