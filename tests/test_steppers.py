"""Stepper-form solvers: chunked composition is bit-identical to the
monolithic entry points, states merge column-wise, and the matrix-free
operator's fused dots match the SELL-C-sigma path exactly."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import from_coo
from repro.core.spmv import SpmvOpts
from repro.matrices import laplace3d
from repro.solvers import (cg, cg_finalize, cg_init, cg_step, make_operator,
                           merge_columns, minres, minres_finalize,
                           minres_init, minres_step, pipelined_cg,
                           pipelined_cg_finalize, pipelined_cg_init,
                           pipelined_cg_step)
from repro.solvers.operator import MatrixFreeOperator


@pytest.fixture(scope="module")
def lap():
    r, c, v, n = laplace3d(7)
    A = from_coo(r, c, v, (n, n), C=16, sigma=32, w_align=4, dtype=np.float32)
    Ad = np.zeros((n, n), np.float32)
    Ad[r, c] += v.astype(np.float32)
    return A, Ad, n


def _compose(init, step, fin, op, b, tol, maxiter, k):
    state = init(op, b, tol=tol, maxiter=maxiter)
    for _ in range(maxiter // k + 1):
        state = step(op, state, k)
    return state


class TestChunkedEqualsMonolithic:
    """cg/pipelined_cg/minres are compositions of their steppers; chunked
    composition with any chunk size must reproduce them bit for bit —
    including chunk=1 (every boundary) and chunk>maxiter (one chunk)."""

    @pytest.mark.parametrize("k", [1, 7, 100, 400])
    def test_cg(self, lap, rng, k):
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 3)).astype(np.float32))
        ref = cg(op, b, tol=1e-7, maxiter=200)
        st = _compose(cg_init, cg_step, cg_finalize, op, b, 1e-7, 200, k)
        res = cg_finalize(st)
        assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
        assert int(ref.iters) == int(res.iters)
        assert np.array_equal(np.asarray(ref.resnorm), np.asarray(res.resnorm))
        assert np.array_equal(np.asarray(ref.converged),
                              np.asarray(res.converged))

    @pytest.mark.parametrize("k", [3, 50])
    def test_pipelined_cg(self, lap, rng, k):
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        ref = pipelined_cg(op, b, tol=1e-6, maxiter=150)
        st = _compose(pipelined_cg_init, pipelined_cg_step,
                      pipelined_cg_finalize, op, b, 1e-6, 150, k)
        res = pipelined_cg_finalize(st)
        assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
        assert int(ref.iters) == int(res.iters)

    @pytest.mark.parametrize("k", [1, 5, 64, 500])
    def test_minres(self, lap, rng, k):
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        ref = minres(op, b, tol=1e-6, maxiter=300)
        st = _compose(minres_init, minres_step, minres_finalize,
                      op, b, 1e-6, 300, k)
        res = minres_finalize(st)
        assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
        assert int(ref.iters) == int(res.iters)
        assert np.array_equal(np.asarray(ref.resnorm), np.asarray(res.resnorm))

    @pytest.mark.parametrize("k", [1, 9, 300])
    def test_cg_complex64(self, rng, k):
        """complex64 solves go through the same steppers (conjugated
        norms engage only for complex dtypes); chunked composition stays
        bit-identical."""
        n = 48
        B = (rng.standard_normal((n, n))
             + 1j * rng.standard_normal((n, n)))
        H = (B @ B.conj().T + n * np.eye(n)).astype(np.complex64)
        r, c = np.nonzero(H)
        A = from_coo(r, c, H[r, c], (n, n), C=8, sigma=16,
                     dtype=np.complex64)
        op = make_operator(A)
        b = A.permute((rng.standard_normal((n, 2))
                       + 1j * rng.standard_normal((n, 2))
                       ).astype(np.complex64))
        ref = cg(op, b, tol=1e-6, maxiter=200)
        assert bool(np.all(np.asarray(ref.converged)))
        st = _compose(cg_init, cg_step, cg_finalize, op, b, 1e-6, 200, k)
        res = cg_finalize(st)
        assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
        assert int(ref.iters) == int(res.iters)
        # the solve is actually right (Hermitian PD, conjugated dots)
        x = np.asarray(A.unpermute(res.x))
        bb = np.asarray(A.unpermute(b))
        assert np.abs(H @ x - bb).max() / np.abs(bb).max() < 1e-3

    @pytest.mark.parametrize("k", [1, 11, 400])
    def test_minres_complex64(self, rng, k):
        n = 40
        B = (rng.standard_normal((n, n))
             + 1j * rng.standard_normal((n, n)))
        H = ((B + B.conj().T) / 2 + n * np.eye(n)).astype(np.complex64)
        r, c = np.nonzero(H)
        A = from_coo(r, c, H[r, c], (n, n), C=8, sigma=8,
                     dtype=np.complex64)
        op = make_operator(A)
        b = A.permute((rng.standard_normal(n)
                       + 1j * rng.standard_normal(n)).astype(np.complex64))
        ref = minres(op, b, tol=1e-5, maxiter=300)
        st = _compose(minres_init, minres_step, minres_finalize,
                      op, b, 1e-5, 300, k)
        res = minres_finalize(st)
        assert np.array_equal(np.asarray(ref.x), np.asarray(res.x[:, 0]))
        assert int(ref.iters) == int(res.iters)
        x = np.asarray(A.unpermute(res.x[:, 0]))
        bb = np.asarray(A.unpermute(b))
        assert np.abs(H @ x - bb).max() / np.abs(bb).max() < 1e-3

    def test_1d_entry_points_unchanged(self, lap, rng):
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        for solve in (cg, pipelined_cg, minres):
            res = solve(op, b, tol=1e-6, maxiter=300)
            assert res.x.ndim == 1 and res.resnorm.ndim == 0

    def test_step_early_exit_when_all_done(self, lap, rng):
        """Once every column converged, further chunks are no-ops (the
        iteration counter must not keep running)."""
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        st = cg_init(op, b, tol=1e-6, maxiter=500)
        st = cg_step(op, st, 500)
        it0 = int(st.it)
        st2 = cg_step(op, st, 50)
        assert int(st2.it) == it0
        assert np.array_equal(np.asarray(st.x), np.asarray(st2.x))


class TestPrecondNoneIsPR3Path:
    """Threading M through the steppers must not perturb the plain path:
    ``precond=None`` states keep the PR-3 layout and ``M=None`` solves
    are bit-identical to calls that never mention M."""

    # the PR-3 state layouts, pinned: adding/removing/reordering fields
    # changes the while_loop carry (and the service's merge semantics)
    CG_FIELDS = ("x", "r", "p", "rr", "tol2", "it", "maxiter", "done")
    PCG_FIELDS = ("x", "r", "w", "z", "s", "p", "gamma_prev", "alpha_prev",
                  "tol2", "fresh", "it", "maxiter", "done")
    MINRES_FIELDS = ("x", "v", "v_old", "w", "w_old", "beta", "eta", "c",
                     "c_old", "s", "s_old", "resn", "tolb", "it", "maxiter",
                     "done")

    def test_state_layouts_pinned(self):
        from repro.solvers import CGState, MinresState, PCGState
        assert CGState._fields == self.CG_FIELDS
        assert PCGState._fields == self.PCG_FIELDS
        assert MinresState._fields == self.MINRES_FIELDS

    def test_init_returns_plain_states(self, lap, rng):
        from repro.solvers import CGState, MinresState
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        assert type(cg_init(op, b)) is CGState
        assert type(cg_init(op, b, M=None)) is CGState
        assert type(minres_init(op, b)) is MinresState
        assert type(minres_init(op, b, M=None)) is MinresState

    def test_explicit_none_bit_identical(self, lap, rng):
        """cg/minres with M=None spelled out == the no-kwarg call, bit
        for bit (same states, same chunks, same cache entries)."""
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 3)).astype(np.float32))
        r1 = cg(op, b, tol=1e-7, maxiter=200)
        r2 = cg(op, b, tol=1e-7, maxiter=200, M=None)
        assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))
        assert int(r1.iters) == int(r2.iters)
        m1 = minres(op, b, tol=1e-6, maxiter=200)
        m2 = minres(op, b, tol=1e-6, maxiter=200, M=None)
        assert np.array_equal(np.asarray(m1.x), np.asarray(m2.x))
        assert np.array_equal(np.asarray(m1.resnorm), np.asarray(m2.resnorm))

    def test_none_and_precond_chunks_cached_separately(self, lap, rng):
        """A preconditioned chunk must never be served from (or evict)
        the plain chunk's cache slot for the same operator."""
        from repro.solvers import BlockJacobiPreconditioner
        from repro.solvers import stepper
        A, Ad, n = lap
        op = make_operator(A)
        M = BlockJacobiPreconditioner(A, block_size=8)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        st_plain = cg_init(op, b, tol=1e-6, maxiter=50)
        st_plain = cg_step(op, st_plain, 10)
        st_pre = cg_init(op, b, tol=1e-6, maxiter=50, M=M)
        st_pre = cg_step(op, st_pre, 10, M=M)
        names = {k[0] for k in stepper._chunk_cache[op]}
        assert "cg" in names and "cg_precond" in names


class TestMergeColumns:
    def test_merge_restarts_selected_columns_only(self, lap, rng):
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 3)).astype(np.float32))
        st = cg_init(op, b, tol=1e-7, maxiter=500)
        st = cg_step(op, st, 5)
        b2 = A.permute(rng.standard_normal((n, 3)).astype(np.float32))
        fresh = cg_init(op, b2, tol=1e-7, maxiter=500)
        merged = merge_columns(st, fresh, [1])
        # column 1 restarted, columns 0/2 untouched, counters preserved
        assert np.array_equal(np.asarray(merged.x[:, 1]),
                              np.asarray(fresh.x[:, 1]))
        for j in (0, 2):
            assert np.array_equal(np.asarray(merged.x[:, j]),
                                  np.asarray(st.x[:, j]))
            assert np.array_equal(np.asarray(merged.r[:, j]),
                                  np.asarray(st.r[:, j]))
        assert int(merged.it) == int(st.it)

    def test_merged_column_converges_like_standalone(self, lap, rng):
        """A column spliced into a running block solves its own system to
        the same tolerance as a standalone solve (column independence)."""
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        st = pipelined_cg_init(op, b, tol=1e-6, maxiter=400)
        st = pipelined_cg_step(op, st, 7)
        bnew = rng.standard_normal(n).astype(np.float32)
        b3 = np.asarray(b).copy()
        b3[:, 0] = np.asarray(A.permute(bnew))
        fresh = pipelined_cg_init(op, jnp.asarray(b3), tol=1e-6, maxiter=400)
        st = merge_columns(st, fresh, [0])
        st = pipelined_cg_step(op, st, 400)
        res = pipelined_cg_finalize(st)
        x0 = np.asarray(A.unpermute(res.x[:, 0]))
        assert bool(np.asarray(res.converged)[0])
        assert np.abs(Ad @ x0 - bnew).max() / np.abs(bnew).max() < 1e-3


class TestBlockKrylov:
    """block=True shares one Krylov space across the rhs block (ISSUE 9):
    width-1 delegates to the column stepper bit for bit, wider blocks
    converge to the same tolerance with coupled small-matrix recurrences,
    and chunked block composition stays bit-identical."""

    def test_width1_is_plain_stepper(self, lap, rng):
        """A 1-column block solve IS the column solve: same state type,
        bit-identical results."""
        from repro.solvers import CGState, MinresState
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 1)).astype(np.float32))
        assert type(cg_init(op, b, block=True)) is CGState
        assert type(minres_init(op, b, block=True)) is MinresState
        ref = cg(op, b, tol=1e-6, maxiter=200)
        blk = cg(op, b, tol=1e-6, maxiter=200, block=True)
        assert np.array_equal(np.asarray(ref.x), np.asarray(blk.x))
        assert int(ref.iters) == int(blk.iters)
        mref = minres(op, b, tol=1e-6, maxiter=300)
        mblk = minres(op, b, tol=1e-6, maxiter=300, block=True)
        assert np.array_equal(np.asarray(mref.x), np.asarray(mblk.x))
        assert int(mref.iters) == int(mblk.iters)

    @pytest.mark.parametrize("dtype,tol,check", [
        (np.float32, 1e-5, 1e-3),
        (np.float64, 1e-9, 1e-7),
    ])
    def test_block_cg_converges(self, lap, rng, dtype, tol, check):
        """Block CG solves every column to tolerance in no more (usually
        fewer) iterations than column CG — the shared space absorbs each
        column's Krylov information."""
        from contextlib import nullcontext
        from jax.experimental import enable_x64
        A, Ad, n = lap
        scope = nullcontext()
        if dtype == np.float64:
            scope = enable_x64()
            r, c = np.nonzero(Ad)
            A = from_coo(r, c, Ad[r, c].astype(np.float64), (n, n), C=16,
                         sigma=32, w_align=4, dtype=np.float64)
        with scope:
            op = make_operator(A)
            b = A.permute(rng.standard_normal((n, 4)).astype(dtype))
            ref = cg(op, b, tol=tol, maxiter=400)
            blk = cg(op, b, tol=tol, maxiter=400, block=True)
            assert bool(np.all(np.asarray(blk.converged)))
            assert int(blk.iters) <= int(ref.iters)
            X = np.asarray(A.unpermute(blk.x))
            B = np.asarray(A.unpermute(b))
        rel = np.abs(Ad.astype(dtype) @ X - B).max() / np.abs(B).max()
        assert rel < check, rel

    def test_block_cg_complex64(self, rng):
        n = 48
        B = (rng.standard_normal((n, n))
             + 1j * rng.standard_normal((n, n)))
        H = (B @ B.conj().T + n * np.eye(n)).astype(np.complex64)
        r, c = np.nonzero(H)
        A = from_coo(r, c, H[r, c], (n, n), C=8, sigma=16,
                     dtype=np.complex64)
        op = make_operator(A)
        b = A.permute((rng.standard_normal((n, 3))
                       + 1j * rng.standard_normal((n, 3))
                       ).astype(np.complex64))
        blk = cg(op, b, tol=1e-5, maxiter=200, block=True)
        assert bool(np.all(np.asarray(blk.converged)))
        X = np.asarray(A.unpermute(blk.x))
        bb = np.asarray(A.unpermute(b))
        assert np.abs(H @ X - bb).max() / np.abs(bb).max() < 1e-3

    def test_block_minres_indefinite(self, rng):
        """Block MINRES on an indefinite matrix: fewer sweeps than column
        MINRES, honest residuals (resnorm matches the true residual)."""
        n = 96
        Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        ev = np.linspace(-2.0, 3.0, n)
        ev[np.abs(ev) < 0.1] = 0.1                # keep it invertible
        H = (Q * ev) @ Q.T
        H = ((H + H.T) / 2).astype(np.float32)
        r, c = np.nonzero(H)
        A = from_coo(r, c, H[r, c], (n, n), C=8, sigma=8, dtype=np.float32)
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 4)).astype(np.float32))
        ref = minres(op, b, tol=1e-5, maxiter=400)
        blk = minres(op, b, tol=1e-5, maxiter=400, block=True)
        assert bool(np.all(np.asarray(blk.converged)))
        assert int(blk.iters) < int(ref.iters)
        X = np.asarray(A.unpermute(blk.x))
        B = np.asarray(A.unpermute(b))
        bn = np.linalg.norm(B, axis=0)
        true = np.linalg.norm(H @ X - B, axis=0)
        assert np.all(true / bn < 1e-4), true / bn
        # the carried recurrence residual tracks the true one
        np.testing.assert_allclose(np.asarray(blk.resnorm), true,
                                   rtol=0.5, atol=1e-6 * bn.max())

    @pytest.mark.parametrize("k", [1, 7, 100])
    def test_block_chunked_equals_monolithic(self, lap, rng, k):
        """Chunk boundaries never perturb the coupled recurrences: any
        chunk size reproduces the monolithic block solve bit for bit."""
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 3)).astype(np.float32))
        st = cg_init(op, b, tol=1e-6, maxiter=100, block=True)
        st = cg_step(op, st, 200)                 # one chunk covers all
        st2 = cg_init(op, b, tol=1e-6, maxiter=100, block=True)
        for _ in range(100 // k + 1):
            st2 = cg_step(op, st2, k)
        assert np.array_equal(np.asarray(st.x), np.asarray(st2.x))
        assert int(st.it) == int(st2.it)
        m1 = minres_init(op, b, tol=1e-6, maxiter=100, block=True)
        m1 = minres_step(op, m1, 200)
        m2 = minres_init(op, b, tol=1e-6, maxiter=100, block=True)
        for _ in range(100 // k + 1):
            m2 = minres_step(op, m2, k)
        assert np.array_equal(np.asarray(m1.x), np.asarray(m2.x))
        assert int(m1.it) == int(m2.it)

    def test_rank_deficient_rhs_deflates(self, lap, rng):
        """Duplicate rhs columns make the block rank-deficient from step
        one; deflation must absorb that instead of dividing by zero."""
        A, Ad, n = lap
        op = make_operator(A)
        col = rng.standard_normal(n).astype(np.float32)
        b = np.stack([col, col, rng.standard_normal(n).astype(np.float32)],
                     axis=1)
        bp = A.permute(jnp.asarray(b))
        for solve in (cg, minres):
            res = solve(op, bp, tol=1e-5, maxiter=400, block=True)
            assert bool(np.all(np.asarray(res.converged))), solve.__name__
            X = np.asarray(A.unpermute(res.x))
            rel = (np.abs(Ad @ X - b).max() / np.abs(b).max())
            assert rel < 1e-3, (solve.__name__, rel)
            # the duplicate columns get the same answer
            np.testing.assert_allclose(X[:, 0], X[:, 1], atol=1e-4)

    def test_zero_rhs_column_done_at_init(self, lap, rng):
        """A zero rhs column converges immediately with x = 0 in every
        stepper (tol^2 * ||b||^2 = 0 used to be unreachable)."""
        from repro.solvers import pipelined_cg_finalize
        A, Ad, n = lap
        op = make_operator(A)
        b = np.zeros((n, 2), np.float32)
        b[:, 1] = rng.standard_normal(n)
        bp = A.permute(jnp.asarray(b))
        x0 = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        for init, fin in ((cg_init, cg_finalize),
                          (minres_init, minres_finalize),
                          (pipelined_cg_init, pipelined_cg_finalize)):
            st = init(op, bp, x0, tol=1e-8, maxiter=100)
            assert bool(np.asarray(st.done)[0]), init.__name__
            res = fin(st)
            assert np.abs(np.asarray(res.x)[:, 0]).max() == 0.0
        # and in block mode, where the zero column deflates
        for init in (lambda *a, **k: cg_init(*a, block=True, **k),
                     lambda *a, **k: minres_init(*a, block=True, **k)):
            st = init(op, bp, tol=1e-8, maxiter=100)
            assert bool(np.asarray(st.done)[0])

    def test_block_states_refuse_column_merge(self, lap, rng):
        """The carried (b, b) Gram blocks couple every column; splicing
        must fail loudly (the service warm-restarts instead)."""
        A, Ad, n = lap
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 3)).astype(np.float32))
        st = cg_init(op, b, tol=1e-6, maxiter=100, block=True)
        fresh = cg_init(op, b, tol=1e-6, maxiter=100, block=True)
        with pytest.raises(ValueError, match="column-spliced"):
            merge_columns(st, fresh, [1])
        mst = minres_init(op, b, tol=1e-6, maxiter=100, block=True)
        with pytest.raises(ValueError, match="column-spliced"):
            merge_columns(mst, mst, [0])

    def test_block_with_precond_raises(self, lap, rng):
        from repro.solvers import BlockJacobiPreconditioner
        A, Ad, n = lap
        op = make_operator(A)
        M = BlockJacobiPreconditioner(A, block_size=8)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        with pytest.raises(NotImplementedError, match="block=True"):
            cg_init(op, b, M=M, block=True)
        with pytest.raises(NotImplementedError, match="block=True"):
            minres_init(op, b, M=M, block=True)
        with pytest.raises(NotImplementedError, match="block=True"):
            pipelined_cg_init(op, b, block=True)


class TestMatrixFreeFusedDots:
    def test_dots_match_ghost_operator(self, lap, rng):
        """Swapping in a matrix-free operator must not change solver
        numerics: the fused dots use the same widened/compensated
        accumulation as the SELL-C-sigma reference path."""
        A, Ad, n = lap
        ghost = make_operator(A)
        free = MatrixFreeOperator(lambda x: ghost.mv(x), ghost.n, np.float32)
        x = A.permute(rng.standard_normal((n, 3)).astype(np.float32))
        opts = SpmvOpts(dot_yy=True, dot_xy=True, dot_xx=True)
        _, _, d_ghost = ghost.mv_fused(x, opts=opts)
        _, _, d_free = free.mv_fused(x, opts=opts)
        assert d_free.dtype == d_ghost.dtype
        np.testing.assert_array_equal(np.asarray(d_ghost), np.asarray(d_free))

    def test_dots_conjugate_for_complex(self, rng):
        n = 64
        H = rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
        H = ((H + H.conj().T) / 2).astype(np.complex64)
        op = MatrixFreeOperator(lambda x: jnp.asarray(H) @ x, n, np.complex64)
        x = (rng.standard_normal((n, 1))
             + 1j * rng.standard_normal((n, 1))).astype(np.complex64)
        _, _, dots = op.mv_fused(jnp.asarray(x), opts=SpmvOpts(dot_xx=True))
        # <x, x> must be conjugated: real, positive, == ||x||^2
        expect = np.sum(np.abs(x[:, 0]) ** 2)
        got = np.asarray(dots)[2, 0]
        assert abs(got.imag) < 1e-4 * expect
        np.testing.assert_allclose(got.real, expect, rtol=1e-5)

    def test_chain_axpby_without_z_raises(self, lap, rng):
        A, Ad, n = lap
        ghost = make_operator(A)
        free = MatrixFreeOperator(lambda x: ghost.mv(x), ghost.n, np.float32)
        x = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        with pytest.raises(ValueError, match="chained AXPBY"):
            free.mv_fused(x, opts=SpmvOpts(delta=0.5, eta=1.0))
