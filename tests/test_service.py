"""SolverService + MatrixRegistry: continuous batching end-to-end, cache
behavior, and the registry-backed spectral-bounds path."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import from_coo
from repro.matrices import laplace3d, matpde
from repro.runtime import MatrixRegistry, SolverService
from repro.solvers import kpm_dos_moments, lanczos_extrema


@pytest.fixture(scope="module")
def lap():
    r, c, v, n = laplace3d(7)
    Ad = np.zeros((n, n), np.float32)
    Ad[r, c] += v.astype(np.float32)
    return (r, c, v, n), Ad


@pytest.fixture()
def reg(lap):
    (r, c, v, n), _ = lap
    registry = MatrixRegistry()
    registry.register("lap", rows=r, cols=c, vals=v, shape=(n, n), C=16,
                      sigma=32, w_align=4, dtype=np.float32)
    return registry


class TestMatrixRegistry:
    def test_build_then_hit(self, lap):
        (r, c, v, n), _ = lap
        registry = MatrixRegistry()
        registry.register("m", rows=r, cols=c, vals=v, shape=(n, n))
        registry.register("m", rows=r, cols=c, vals=v, shape=(n, n))
        assert registry.stats["builds"] == 1
        assert registry.stats["hits"] == 1
        assert "m" in registry and registry.names() == ["m"]

    def test_prebuilt_matrix_and_operator(self, lap):
        (r, c, v, n), _ = lap
        A = from_coo(r, c, v, (n, n), C=16, sigma=32, dtype=np.float32)
        registry = MatrixRegistry()
        registry.register("pre", A)
        op = registry.operator("pre")
        assert op.A is A
        # an operator-like object registers as-is
        registry.register("op", op)
        assert registry.operator("op") is op

    def test_unknown_matrix_raises(self):
        registry = MatrixRegistry()
        with pytest.raises(KeyError, match="not registered"):
            registry.operator("nope")
        with pytest.raises(ValueError, match="COO triplets"):
            registry.register("partial", rows=[0], cols=[0])

    def test_reregister_different_payload_raises(self, lap):
        """A name collision with different data must not silently serve
        the stale operator."""
        (r, c, v, n), _ = lap
        registry = MatrixRegistry()
        registry.register("m", rows=r, cols=c, vals=v, shape=(n, n))
        with pytest.raises(ValueError, match="different COO data"):
            registry.register("m", rows=r, cols=c, vals=2.0 * v,
                              shape=(n, n))
        # a value permutation with identical sums must still be rejected
        v2 = v.copy()
        v2[0], v2[1] = v[1], v[0]
        if not np.array_equal(v2, v):
            with pytest.raises(ValueError, match="different COO data"):
                registry.register("m", rows=r, cols=c, vals=v2, shape=(n, n))
        A = from_coo(r, c, v, (n, n), C=16, dtype=np.float32)
        with pytest.raises(ValueError, match="different object"):
            registry.register("m", A)
        # bare name lookup-style reuse stays a hit
        registry.register("m")
        assert registry.stats["hits"] == 1

    def test_incomplete_operator_rejected(self):
        class HalfOp:
            def mv(self, x):
                return x

            def mv_fused(self, x, y=None, z=None, opts=None):
                return x, None, None

        registry = MatrixRegistry()
        with pytest.raises(TypeError, match="solver protocol"):
            registry.register("half", HalfOp())

    def test_spectral_bounds_cached(self, reg, lap):
        _, Ad = lap
        lo, hi = reg.spectral_bounds("lap", k=30)
        assert reg.stats["bounds_computed"] == 1
        lo2, hi2 = reg.spectral_bounds("lap", k=30)
        assert (lo, hi) == (lo2, hi2)
        assert reg.stats["bounds_hits"] == 1
        ev = np.linalg.eigvalsh(Ad.astype(np.float64))
        assert lo <= ev[0] + 1e-3 and hi >= ev[-1] - 1e-3


class TestSolverService:
    def test_mixed_tolerance_retire_refill(self, reg, lap, make_harness):
        """More requests than slots with mixed tolerances: loose-tol
        columns retire early, freed slots are refilled from the queue,
        every request converges to ITS OWN tolerance.  Runs on the
        virtual clock, so the latency assertions are exact tick counts,
        not wall-clock inequalities."""
        (r, c, v, n), Ad = lap
        rng = np.random.default_rng(0)
        h = make_harness(reg, block_width=4, chunk_iters=8)
        svc = h.service
        tols = [1e-4, 1e-6, 1e-7]
        tickets = []
        for i in range(11):
            b = rng.standard_normal(n).astype(np.float32)
            solver = "minres" if i % 4 == 3 else "cg"
            tickets.append(svc.submit("lap", b, solver=solver,
                                      tol=tols[i % 3], maxiter=500))
        h.drain()
        steps = h.clock.now                      # 1 tick per step
        assert svc.stats["refills"] > 1          # the queue actually drained
        assert svc.stats["retired"] == 11
        for t in tickets:
            res = t.result
            assert res.converged, t
            rel = (np.abs(Ad @ res.x - np.asarray(t.b)).max()
                   / np.abs(np.asarray(t.b)).max())
            assert rel < 50 * t.tol + 1e-5, (t, rel)
            # every retire happens at a step boundary: latency is a whole
            # number of ticks, within the drain span, deterministically
            assert t.latency == t.finished_at - t.submitted_at
            assert t.latency == int(t.latency) and 0 < t.latency <= steps
        # not every ticket retired on the last step — early retirement
        # (the point of mixed tolerances) is visible in the tick counts
        assert min(t.latency for t in tickets) < steps
        # requests grouped per (matrix, solver, dtype): cg + minres batches
        assert svc.stats["batches_opened"] == 2

    def test_maxiter_retires_unconverged(self, reg, lap):
        (r, c, v, n), _ = lap
        rng = np.random.default_rng(1)
        svc = SolverService(reg, block_width=2, chunk_iters=4)
        b = rng.standard_normal(n).astype(np.float32)
        t = svc.submit("lap", b, solver="cg", tol=1e-12, maxiter=6)
        svc.drain()
        assert t.done and not t.result.converged
        assert t.result.iters >= 6
        assert svc.pending == 0

    def test_pipelined_cg_kind(self, reg, lap):
        (r, c, v, n), Ad = lap
        rng = np.random.default_rng(2)
        svc = SolverService(reg, block_width=3, chunk_iters=10)
        tickets = [svc.submit("lap",
                              rng.standard_normal(n).astype(np.float32),
                              solver="pipelined_cg", tol=1e-5, maxiter=400)
                   for _ in range(5)]
        svc.drain()
        for t in tickets:
            assert t.result.converged
            rel = (np.abs(Ad @ t.result.x - np.asarray(t.b)).max()
                   / np.abs(np.asarray(t.b)).max())
            assert rel < 1e-3
        # the refilled pipelined-cg columns restart their own recurrence
        assert svc.stats["refills"] > 1

    def test_service_matches_direct_solve(self, reg, lap):
        """A service solve matches a standalone solve of the same rhs to
        working precision (block width differs, so only the convergence
        guarantee — not bitwise identity — carries over)."""
        from repro.solvers import cg
        (r, c, v, n), Ad = lap
        rng = np.random.default_rng(3)
        b = rng.standard_normal(n).astype(np.float32)
        svc = SolverService(reg, block_width=2, chunk_iters=16)
        t = svc.submit("lap", b, solver="cg", tol=1e-7, maxiter=500)
        svc.drain()
        op = reg.operator("lap")
        ref = cg(op, op.to_op_space(jnp.asarray(b)), tol=1e-7, maxiter=500)
        x_ref = np.asarray(op.from_op_space(ref.x))
        np.testing.assert_allclose(t.result.x, x_ref, atol=1e-5)
        assert t.result.converged and bool(ref.converged)

    def test_bad_requests_raise(self, reg, lap):
        (r, c, v, n), _ = lap
        svc = SolverService(reg)
        with pytest.raises(ValueError, match="unknown solver"):
            svc.submit("lap", np.zeros(n, np.float32), solver="gmres")
        with pytest.raises(KeyError, match="not registered"):
            svc.submit("ghost", np.zeros(n, np.float32))
        with pytest.raises(ValueError, match="block_width"):
            SolverService(reg, block_width=0)
        # malformed rhs rejected at submit — a refill-time failure would
        # lose sibling requests dequeued in the same sweep
        with pytest.raises(ValueError, match="1-d of length"):
            svc.submit("lap", np.zeros(n + 1, np.float32))
        with pytest.raises(ValueError, match="1-d of length"):
            svc.submit("lap", np.zeros((n, 2), np.float32))
        assert svc.pending == 0

    def test_chunk_cache_releases_dead_operators(self, lap, rng):
        """The per-operator chunk cache must not pin the operator: its
        jitted chunks close over a weakref, so dropping the operator
        frees the cache entry (and the compiled programs)."""
        import gc
        import weakref
        from repro.core import from_coo as fc
        from repro.solvers import cg as cg_solve, make_operator
        from repro.solvers import stepper

        (r, c, v, n), _ = lap
        A = fc(r, c, v, (n, n), C=16, sigma=32, dtype=np.float32)
        op = make_operator(A)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        cg_solve(op, b, tol=1e-5, maxiter=50)
        ref = weakref.ref(op)
        assert op in stepper._chunk_cache
        del op, A
        gc.collect()
        assert ref() is None

    def test_engine_backed_matrix(self, rng):
        """Sharded matrices go through HeterogeneousEngine/DistOperator
        unchanged (single-device mesh here)."""
        from repro.runtime import HeterogeneousEngine
        r, c, v, n = matpde(16)
        Ad = np.zeros((n, n)); Ad[r, c] += v
        spd = (Ad @ Ad.T + n * np.eye(n)).astype(np.float32)
        rs, cs = np.nonzero(spd)
        eng = HeterogeneousEngine(rs, cs, spd[rs, cs], n, C=8, sigma=16,
                                  w_align=4, dtype=np.float32)
        registry = MatrixRegistry()
        registry.register("dist", eng)
        svc = SolverService(registry, block_width=2, chunk_iters=8)
        tickets = [svc.submit("dist", rng.standard_normal(n).astype(np.float32),
                              solver="cg", tol=1e-6, maxiter=300)
                   for _ in range(3)]
        svc.drain()
        for t in tickets:
            assert t.result.converged
            rel = (np.abs(spd @ t.result.x - np.asarray(t.b)).max()
                   / np.abs(np.asarray(t.b)).max())
            assert rel < 1e-3

    def test_precond_requests_batch_separately(self, lap):
        """Mixed preconditioned + plain requests on the same matrix must
        resolve to separate batch keys, retire/refill correctly, and the
        preconditioned solves must converge in fewer iterations (ticket
        iteration counts)."""
        from repro.matrices import anisotropic_laplace2d
        r, c, v, n = anisotropic_laplace2d(24, epsilon=1e-2)
        Ad = np.zeros((n, n), np.float32)
        Ad[r, c] += v.astype(np.float32)
        registry = MatrixRegistry()
        registry.register("ani", rows=r, cols=c, vals=v, shape=(n, n),
                          C=16, sigma=1, w_align=4, dtype=np.float32)
        svc = SolverService(registry, block_width=3, chunk_iters=8)
        rng = np.random.default_rng(4)
        specs = [None, "block_jacobi:24", "chebyshev:4"]
        tickets = {s: [] for s in specs}
        for i in range(12):                      # > block_width per key? no:
            b = rng.standard_normal(n).astype(np.float32)
            s = specs[i % 3]
            tickets[s].append(svc.submit("ani", b, solver="cg", tol=1e-6,
                                         maxiter=2000, precond=s))
        seen_keys = set()
        while svc.pending:
            svc.step()
            seen_keys.update(svc._batches.keys())
        # one batch key per precond spec — never shared
        assert {k[3] for k in seen_keys} == {"", "block_jacobi:24",
                                             "chebyshev:4"}
        assert svc.stats["batches_opened"] == 3
        assert svc.stats["refills"] >= 3         # 4 requests over 3 slots
        iters = {}
        for s, ts in tickets.items():
            for t in ts:
                assert t.result is not None and t.result.converged, t
                rel = (np.abs(Ad @ t.result.x - np.asarray(t.b)).max()
                       / np.abs(np.asarray(t.b)).max())
                assert rel < 1e-4, (t, rel)
            iters[s] = max(t.result.iters for t in ts)
        # preconditioned solves retire in fewer chunks/iterations
        assert iters["block_jacobi:24"] * 2 <= iters[None]
        assert iters["chebyshev:4"] * 2 <= iters[None]
        # the preconditioner itself was built once per spec, then reused
        assert registry.stats["precond_builds"] == 2

    def test_precond_registry_caching_and_validation(self, reg, lap):
        (r, c, v, n), _ = lap
        M1 = reg.preconditioner("lap", "block_jacobi:8")
        M2 = reg.preconditioner("lap", "block_jacobi:8")
        assert M1 is M2
        assert reg.stats["precond_builds"] == 1
        assert reg.stats["precond_hits"] == 1
        # chebyshev rides the cached spectral bounds
        Mc = reg.preconditioner("lap", "chebyshev")
        assert reg.stats["bounds_computed"] == 1
        assert Mc.degree == 4
        # the default-degree spec normalizes to the explicit one: same
        # cache entry, same service batch key
        assert reg.preconditioner("lap", "chebyshev:4") is Mc
        svc = SolverService(reg)
        with pytest.raises(ValueError, match="unknown preconditioner"):
            svc.submit("lap", np.zeros(n, np.float32), precond="ilu")
        with pytest.raises(NotImplementedError, match="pipelined_cg"):
            svc.submit("lap", np.zeros(n, np.float32),
                       solver="pipelined_cg", precond="block_jacobi")
        # engine-backed matrices reject block_jacobi with a clear error
        from repro.matrices import matpde
        from repro.runtime import HeterogeneousEngine
        r2, c2, v2, n2 = matpde(12)
        Ad2 = np.zeros((n2, n2)); Ad2[r2, c2] += v2
        spd = (Ad2 @ Ad2.T + n2 * np.eye(n2)).astype(np.float32)
        rs, cs = np.nonzero(spd)
        eng = HeterogeneousEngine(rs, cs, spd[rs, cs], n2, C=8, sigma=1,
                                  w_align=4, dtype=np.float32)
        reg.register("eng", eng)
        with pytest.raises(ValueError, match="block_jacobi"):
            reg.preconditioner("eng", "block_jacobi")

    def test_precond_service_engine_chebyshev(self, rng):
        """Chebyshev precond on an engine-backed (DistOperator) matrix:
        the polynomial apply rides the distributed matvec unchanged."""
        from repro.matrices import laplace3d
        from repro.runtime import HeterogeneousEngine
        r, c, v, n = laplace3d(6)
        eng = HeterogeneousEngine(r, c, v, n, C=8, sigma=16, w_align=4,
                                  dtype=np.float32)
        registry = MatrixRegistry()
        registry.register("dist", eng)
        svc = SolverService(registry, block_width=2, chunk_iters=8)
        tickets = [svc.submit("dist",
                              rng.standard_normal(n).astype(np.float32),
                              solver="cg", tol=1e-6, maxiter=400,
                              precond="chebyshev:3")
                   for _ in range(3)]
        svc.drain()
        Ad = np.zeros((n, n), np.float32)
        Ad[r, c] += v.astype(np.float32)
        for t in tickets:
            assert t.result.converged
            rel = (np.abs(Ad @ t.result.x - np.asarray(t.b)).max()
                   / np.abs(np.asarray(t.b)).max())
            assert rel < 1e-3

    def test_kpm_uses_cached_bounds(self, reg, lap):
        (r, c, v, n), _ = lap
        svc = SolverService(reg)
        mus = svc.kpm_moments("lap", 16, n_probes=2, seed=1)
        assert reg.stats["bounds_computed"] == 1
        op = reg.operator("lap")
        direct = kpm_dos_moments(op, 16, n_probes=2, seed=1,
                                 spectrum=reg.spectral_bounds("lap"))
        np.testing.assert_allclose(np.asarray(mus), np.asarray(direct),
                                   rtol=1e-5, atol=1e-7)
        assert reg.stats["bounds_hits"] >= 1


class TestBlockKrylovService:
    """block=True through submit: shared-Krylov batches, warm-restart
    refills, and the zero-rhs edge case (ISSUE 9)."""

    def test_block_retire_refill_converges(self, reg, lap):
        """More block requests than slots: the batch warm-restarts on
        every refill (block states cannot be column-spliced) and every
        request still converges to its own tolerance."""
        (r, c, v, n), Ad = lap
        rng = np.random.default_rng(7)
        svc = SolverService(reg, block_width=4, chunk_iters=8)
        tickets = []
        for i in range(11):
            b = rng.standard_normal(n).astype(np.float32)
            solver = "minres" if i % 4 == 3 else "cg"
            tickets.append(svc.submit("lap", b, solver=solver, tol=1e-5,
                                      maxiter=500, block=True))
        seen_keys = set()
        while svc.pending:
            svc.step()
            seen_keys.update(svc._batches.keys())
        assert {k[5] for k in seen_keys} == {"block"}
        assert svc.stats["refills"] > 1
        assert svc.stats["retired"] == 11
        for t in tickets:
            res = t.result
            assert res.converged, t
            assert res.iters <= 500
            rel = (np.abs(Ad @ res.x - np.asarray(t.b)).max()
                   / np.abs(np.asarray(t.b)).max())
            assert rel < 1e-3, (t, rel)

    def test_block_and_column_batch_separately(self, reg, lap):
        """block=True and block=False requests on the same matrix/solver
        must never share a batch (their stepper states differ)."""
        (r, c, v, n), Ad = lap
        rng = np.random.default_rng(9)
        svc = SolverService(reg, block_width=2, chunk_iters=8)
        tickets = [svc.submit("lap", rng.standard_normal(n).astype(np.float32),
                              solver="cg", tol=1e-5, block=bool(i % 2))
                   for i in range(4)]
        seen_keys = set()
        while svc.pending:
            svc.step()
            seen_keys.update(svc._batches.keys())
        assert {k[5] for k in seen_keys} == {"", "block"}
        assert svc.stats["batches_opened"] == 2
        for t in tickets:
            assert t.result.converged
            rel = (np.abs(Ad @ t.result.x - np.asarray(t.b)).max()
                   / np.abs(np.asarray(t.b)).max())
            assert rel < 1e-3

    def test_block_deflation_duplicate_rhs(self, reg, lap):
        """Identical rhs submitted twice into one block batch makes the
        shared space rank-deficient from step one; deflation absorbs it
        and both tickets converge to the same answer."""
        (r, c, v, n), Ad = lap
        rng = np.random.default_rng(11)
        b = rng.standard_normal(n).astype(np.float32)
        svc = SolverService(reg, block_width=3, chunk_iters=8)
        t1 = svc.submit("lap", b, solver="cg", tol=1e-5, block=True)
        t2 = svc.submit("lap", b.copy(), solver="cg", tol=1e-5, block=True)
        svc.drain()
        assert t1.result.converged and t2.result.converged
        np.testing.assert_allclose(t1.result.x, t2.result.x, atol=1e-4)
        rel = np.abs(Ad @ t1.result.x - b).max() / np.abs(b).max()
        assert rel < 1e-3

    @pytest.mark.parametrize("block", [False, True])
    def test_zero_rhs_converges_immediately(self, reg, lap, block):
        """A zero rhs used to make tol^2 * ||b||^2 = 0 unreachable and
        the column spun until maxiter; now x = 0 IS the converged answer
        in both batching modes."""
        (r, c, v, n), _ = lap
        rng = np.random.default_rng(13)
        svc = SolverService(reg, block_width=2, chunk_iters=4)
        tz = svc.submit("lap", np.zeros(n, np.float32), solver="cg",
                        tol=1e-10, maxiter=50, block=block)
        tb = svc.submit("lap", rng.standard_normal(n).astype(np.float32),
                        solver="cg", tol=1e-5, maxiter=500, block=block)
        svc.drain()
        assert tz.result.converged
        assert np.abs(tz.result.x).max() == 0.0
        assert tz.result.resnorm == 0.0
        assert tb.result.converged        # the sibling column is unharmed

    def test_zero_rhs_pipelined_cg(self, reg, lap):
        """pipelined_cg had the concrete failure (zero b + x0 != 0
        stalled to maxiter); the service path must now retire it
        converged with x = 0."""
        (r, c, v, n), _ = lap
        svc = SolverService(reg, block_width=2, chunk_iters=4)
        t = svc.submit("lap", np.zeros(n, np.float32),
                       solver="pipelined_cg", tol=1e-10, maxiter=50)
        svc.drain()
        assert t.result.converged
        assert np.abs(t.result.x).max() == 0.0

    def test_block_validation_at_submit(self, reg, lap):
        (r, c, v, n), _ = lap
        svc = SolverService(reg)
        with pytest.raises(NotImplementedError, match="block=True"):
            svc.submit("lap", np.zeros(n, np.float32),
                       solver="pipelined_cg", block=True)
        with pytest.raises(NotImplementedError, match="preconditioner"):
            svc.submit("lap", np.zeros(n, np.float32), solver="cg",
                       precond="block_jacobi", block=True)
        assert svc.pending == 0


class TestMixedPrecisionService:
    """store_dtype through the registry/service layer (ISSUE 5)."""

    def test_store_dtypes_batch_separately(self, lap):
        """f32-store and bf16-store requests land in separate batch keys
        (different compiled matvecs, different numerics) and every
        request converges against the dense reference."""
        (r, c, v, n), Ad = lap
        registry = MatrixRegistry()
        kw = dict(rows=r, cols=c, vals=v, shape=(n, n), C=16, sigma=32,
                  w_align=4, dtype=np.float32)
        registry.register("lap_f32", **kw)
        registry.register("lap_bf16", store_dtype=jnp.bfloat16, **kw)
        assert registry.entry("lap_f32").store_dtype == "float32"
        assert registry.entry("lap_bf16").store_dtype == "bfloat16"
        svc = SolverService(registry, block_width=3, chunk_iters=8)
        rng = np.random.default_rng(8)
        tickets = []
        for i in range(8):
            b = rng.standard_normal(n).astype(np.float32)
            name = "lap_bf16" if i % 2 else "lap_f32"
            tickets.append(svc.submit(name, b, solver="cg", tol=1e-5,
                                      maxiter=500))
        seen_keys = set()
        while svc.pending:
            svc.step()
            seen_keys.update(svc._batches.keys())
        # the storage dtype is the trailing batch-key component
        assert {k[4] for k in seen_keys} == {"float32", "bfloat16"}
        assert svc.stats["batches_opened"] == 2
        for t in tickets:
            assert t.result is not None and t.result.converged, t
            rel = (np.abs(Ad @ t.result.x - np.asarray(t.b)).max()
                   / np.abs(np.asarray(t.b)).max())
            tol = 5e-2 if t.matrix == "lap_bf16" else 1e-3
            assert rel < tol, (t, rel)

    def test_reregister_different_store_dtype_raises(self, lap):
        """Same COO payload at a different storage width is a different
        matrix: silently serving the narrow operator would hand back
        storage-rounded answers under the full-precision name."""
        (r, c, v, n), _ = lap
        registry = MatrixRegistry()
        kw = dict(rows=r, cols=c, vals=v, shape=(n, n), C=16, dtype=np.float32)
        registry.register("m", **kw)
        with pytest.raises(ValueError, match="storage dtype"):
            registry.register("m", store_dtype=jnp.bfloat16, **kw)
        # idempotent re-register with the matching store_dtype is a hit,
        # whether spelled as None or as the explicit compute dtype (the
        # fingerprint records the *resolved* storage dtype)
        registry.register("m", store_dtype=None, **kw)
        registry.register("m", store_dtype=np.float32, **kw)
        assert registry.stats["hits"] == 2

    def test_block_jacobi_on_bf16_storage(self, lap):
        """Block-Jacobi extraction upcasts before factorization: the
        preconditioner built from a bf16-stored matrix still cuts the
        iteration count and its inverse blocks live in the compute
        dtype."""
        from repro.matrices import anisotropic_laplace2d
        r, c, v, n = anisotropic_laplace2d(24, epsilon=1e-2)
        registry = MatrixRegistry()
        registry.register("ani16", rows=r, cols=c, vals=v, shape=(n, n),
                          C=16, sigma=1, w_align=4, dtype=np.float32,
                          store_dtype=jnp.bfloat16)
        M = registry.preconditioner("ani16", "block_jacobi:24")
        assert M.inv_blocks.dtype == jnp.float32     # compute, not storage
        svc = SolverService(registry, block_width=2, chunk_iters=16)
        rng = np.random.default_rng(3)
        b = rng.standard_normal(n).astype(np.float32)
        t_plain = svc.submit("ani16", b, solver="cg", tol=1e-5,
                             maxiter=4000)
        t_pc = svc.submit("ani16", b, solver="cg", tol=1e-5, maxiter=4000,
                          precond="block_jacobi:24")
        svc.drain()
        assert t_plain.result.converged and t_pc.result.converged
        assert t_pc.result.iters * 2 <= t_plain.result.iters
