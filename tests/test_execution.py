"""Execution-policy subsystem: backend auto-detection, env/context
overrides, the hardened specialization cascade, tile knobs + autotune,
and the correctness regressions that hid behind the always-interpret
defaults (tail-drop raise, f64 dot accumulation)."""
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import SpmvOpts, execution, from_dense
from repro.core.spmv import compensated_sum0, dot_acc_dtype, spmv_ref
from repro.kernels import ops
from repro.kernels.sellcs_spmv import sellcs_spmv_pallas


@pytest.fixture(autouse=True)
def _fresh_policy():
    """Each test sees (and leaves behind) pristine policy caches."""
    execution.reset()
    yield
    execution.reset()


def random_sparse(rng, n, m, density=0.15, dtype=np.float32):
    return ((rng.random((n, m)) < density)
            * rng.standard_normal((n, m))).astype(dtype)


# ------------------------------------------------------------------ policy
class TestPolicyResolution:
    def test_auto_detection(self):
        pol = execution.current_policy()
        assert pol.backend == jax.default_backend()
        assert pol.source == "auto"
        # CI/test machines run CPU: auto policy must pick interpret there,
        # and compiled iff the backend is in the trusted set
        assert pol.interpret == (pol.backend not in execution.COMPILED_BACKENDS)

    def test_explicit_argument_wins(self):
        assert execution.resolve_interpret(True) is True
        assert execution.resolve_interpret(False) is False
        assert execution.resolve_interpret(None) == \
            execution.current_policy().interpret

    def test_force_context_nests_and_restores(self):
        base = execution.current_policy()
        with execution.force(interpret=False) as outer:
            assert outer.source == "forced"
            assert execution.resolve_interpret(None) is False
            with execution.force(interpret=True):
                assert execution.resolve_interpret(None) is True
            assert execution.resolve_interpret(None) is False
        assert execution.current_policy() == base

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv(execution.ENV_INTERPRET, "0")
        execution.reset()
        pol = execution.current_policy()
        assert pol.interpret is False and pol.source == "env"
        monkeypatch.setenv(execution.ENV_INTERPRET, "true")
        execution.reset()
        assert execution.current_policy().interpret is True

    def test_env_tile_knobs(self, monkeypatch):
        monkeypatch.setenv(execution.ENV_ROW_TILE, "128")
        monkeypatch.setenv(execution.ENV_S_BLK, "16")
        monkeypatch.setenv(execution.ENV_W_TILE, "2")
        execution.reset()
        assert execution.resolve_row_tile() == 128
        assert execution.resolve_s_blk() == 16
        assert execution.resolve_w_tile(None, w_align=4) == 2
        # explicit call-site argument still wins
        assert execution.resolve_row_tile(256) == 256

    def test_w_tile_knob_degrades_when_incompatible(self):
        with execution.force(w_tile=4):
            assert execution.resolve_w_tile(None, w_align=8) == 4
            assert execution.resolve_w_tile(None, w_align=3) == 3  # hint dropped
        assert execution.resolve_w_tile(None, w_align=8) == 8

    def test_describe_names_the_mode(self):
        assert "mode=interpret" in execution.describe(
            execution.ExecutionPolicy(interpret=True, backend="cpu"))
        assert "mode=compiled" in execution.describe(
            execution.ExecutionPolicy(interpret=False, backend="tpu"))


# ----------------------------------------------------------------- cascade
class TestCascade:
    def test_compiled_failure_falls_back_to_ref(self, rng):
        """Forcing compiled mode on a Pallas-less backend must degrade to
        the jnp reference (with a warning), not crash."""
        a = random_sparse(rng, 64, 64)
        m = from_dense(a, C=8, sigma=16, w_align=4)
        x = m.permute(rng.standard_normal((64, 2)).astype(np.float32))
        y_ref, _, _ = spmv_ref(m, x)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            with execution.force(interpret=False):
                y, _, _ = ops.sellcs_spmv(m, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        if jax.default_backend() in execution.COMPILED_BACKENDS:
            assert not rec                       # genuinely compiled: no warning
        else:
            assert any(issubclass(w.category, RuntimeWarning) for w in rec)

    @pytest.mark.skipif(jax.default_backend() in execution.COMPILED_BACKENDS,
                        reason="backend compiles Pallas natively")
    def test_warns_once_per_kernel(self, rng):
        a = random_sparse(rng, 40, 40)
        m = from_dense(a, C=8, sigma=8)
        x = m.permute(rng.standard_normal(40).astype(np.float32))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            with execution.force(interpret=False):
                ops.sellcs_spmv(m, x)
                ops.sellcs_spmv(m, x)
        assert sum(issubclass(w.category, RuntimeWarning) for w in rec) == 1

    @pytest.mark.skipif(jax.default_backend() in execution.COMPILED_BACKENDS,
                        reason="backend compiles Pallas natively")
    def test_fallback_disabled_raises(self, rng):
        V = jnp.asarray(rng.standard_normal((64, 3)), jnp.float32)
        X = jnp.asarray(rng.standard_normal((3, 2)), jnp.float32)
        with execution.force(interpret=False, fallback=False):
            with pytest.raises(Exception):
                jax.block_until_ready(ops.tsmm(V, X))

    def test_interpret_failures_propagate(self):
        """Interpret-mode bugs are not swallowed by the cascade."""
        def boom():
            raise RuntimeError("logic bug")
        with pytest.raises(RuntimeError):
            execution.cascade("k", boom, lambda: 1, interpret=True)

    def test_every_wrapper_cascades(self, rng):
        """All five ops wrappers survive a forced-compiled run on any
        backend and match their references."""
        n = 96
        V = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
        W = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
        X = jnp.asarray(rng.standard_normal((3, 3)), jnp.float32)
        dt = jnp.full((1, 8, 4), 0.1, jnp.float32)
        A = -jnp.ones((4, 2), jnp.float32)
        B = jnp.ones((1, 8, 2), jnp.float32)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            with execution.force(interpret=False):
                np.testing.assert_allclose(
                    np.asarray(ops.tsmttsm(V, W)),
                    np.asarray(V).T @ np.asarray(W), atol=1e-4, rtol=1e-4)
                np.testing.assert_allclose(
                    np.asarray(ops.tsmm(V, X)),
                    np.asarray(V) @ np.asarray(X), atol=1e-4, rtol=1e-4)
                # kahan fallback must still honor alpha/beta/X
                X0 = jnp.asarray(np.eye(3, dtype=np.float32))
                np.testing.assert_allclose(
                    np.asarray(ops.tsmttsm(V, W, X0, alpha=2.0, beta=1.0,
                                           kahan=True)),
                    2.0 * (np.asarray(V).T @ np.asarray(W)) + np.eye(3),
                    atol=1e-3, rtol=1e-4)
                out, dots = ops.fused_axpby_dots(V[:, 0], W[:, 0], 2.0, 1.0,
                                                 dot_xy=True)
                np.testing.assert_allclose(
                    np.asarray(out),
                    2 * np.asarray(V[:, 0]) + np.asarray(W[:, 0]),
                    atol=1e-5, rtol=1e-5)
                y = ops.mamba_scan(dt, dt, B, B, A)
                assert y.shape == (1, 8, 4)


# ---------------------------------------------------------------- autotune
class TestAutotune:
    def test_caches_winner(self):
        calls = []

        def run(c):
            calls.append(c)
            return jnp.zeros(4)

        first = execution.autotune("k", ("shape",), (1, 2), run, iters=1)
        assert first in (1, 2) and set(calls) == {1, 2}
        # second lookup must not re-measure
        def explode(c):
            raise AssertionError("re-measured despite cache")
        assert execution.autotune("k", ("shape",), (1, 2), explode) == first
        execution.reset()
        with pytest.raises(AssertionError):
            execution.autotune("k", ("shape",), (1,), explode)


# -------------------------------------------------- tail-drop regression
class TestTailDropValidation:
    def test_incompatible_w_tile_raises(self, rng):
        """chunk_len % w_tile != 0 used to silently drop tail nonzeros;
        now the kernel refuses host-side."""
        a = random_sparse(rng, 64, 64, density=0.3)
        m = from_dense(a, C=8, sigma=1, w_align=1)    # ragged widths
        assert (np.asarray(m.chunk_len) % 4 != 0).any()
        x = m.permute(rng.standard_normal((64, 1)).astype(np.float32))
        with pytest.raises(ValueError, match="tail nonzeros"):
            sellcs_spmv_pallas(m.vals, m.cols, m.chunk_off, m.chunk_len,
                               x, C=m.C, w_tile=4)

    def test_aligned_build_passes(self, rng):
        a = random_sparse(rng, 64, 64, density=0.3)
        m = from_dense(a, C=8, sigma=1, w_align=4)
        x = m.permute(rng.standard_normal((64, 1)).astype(np.float32))
        y, _, _ = sellcs_spmv_pallas(m.vals, m.cols, m.chunk_off,
                                     m.chunk_len, x, C=m.C, w_tile=4,
                                     interpret=True)
        y_ref, _, _ = spmv_ref(m, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)


# ------------------------------------------------------- f64 dot accumulation
class TestDotAccumulation:
    def test_dots_exact_in_f64(self):
        """Fused dots accumulate in f64: one huge chunk partial must not
        swallow the small chunks' mass (exact powers of two throughout,
        so both paths reproduce the true sum bit-for-bit)."""
        from jax.experimental import enable_x64
        n, C = 256, 32
        with enable_x64():
            m = from_dense(np.eye(n, dtype=np.float32), C=C, sigma=1)
            x = np.full(n, 8.0, np.float32)
            x[:C] = 0.0
            x[0] = 2.0 ** 30
            x2 = jnp.asarray(x[:, None])
            expected = 2.0 ** 60 + (n - C) * 64.0        # exact in f64
            opts = SpmvOpts(dot_xx=True, dot_yy=True)

            _, _, dr = spmv_ref(m, x2, opts=opts)
            assert dr.dtype == jnp.float64
            assert float(dr[2, 0]) == expected
            assert float(dr[0, 0]) == expected           # y == x (identity A)

            _, _, dk = ops.sellcs_spmv(m, x2, opts=opts)
            assert dk.dtype == jnp.float64
            assert float(dk[2, 0]) == expected
            assert float(dk[0, 0]) == expected

    def test_solvers_stable_with_wide_dots(self):
        """f64 dot accumulation under x64 must not poison the solvers'
        f32 while_loop/scan carries (cg casts the recurrence scalar back,
        kpm casts at the moment boundary)."""
        from jax.experimental import enable_x64
        from repro.solvers import cg, make_operator
        from repro.solvers.kpm import kpm_dos_moments
        rng = np.random.default_rng(7)
        n = 64
        with enable_x64():
            a = random_sparse(rng, n, n, density=0.2)
            spd = (a @ a.T + n * np.eye(n)).astype(np.float32)
            m = from_dense(spd, C=8, sigma=16)
            op = make_operator(m)
            b = m.permute(rng.standard_normal(n).astype(np.float32))
            res = cg(op, b, tol=1e-5, maxiter=200)
            assert float(res.resnorm) < 1e-3
            mus = kpm_dos_moments(op, 16, n_probes=2, spectrum=(0.0, 2 * n))
            assert np.isfinite(np.asarray(mus)).all()

    def test_acc_dtype_without_x64(self):
        # x64 off (the tier-1 default): f32 stays f32, bf16 widens to f32,
        # integer inputs accumulate in float (norms are analytic, and
        # jnp.finfo on an int accumulator would crash)
        assert dot_acc_dtype(jnp.float32) == jnp.dtype(jnp.float32)
        assert dot_acc_dtype(jnp.bfloat16) == jnp.dtype(jnp.float32)
        assert dot_acc_dtype(jnp.int32) == jnp.dtype(jnp.float32)

    def test_integer_inputs_dont_crash_dots(self):
        from repro.core import from_coo
        m = from_coo([0, 1], [0, 1], np.array([2, 3], np.int32), (2, 2), C=2)
        x = jnp.asarray(np.array([[1], [1]], np.int32))
        _, _, dots = spmv_ref(m, x, opts=SpmvOpts(dot_xx=True, dot_yy=True))
        assert jnp.issubdtype(dots.dtype, jnp.floating)
        assert float(dots[2, 0]) == 2.0 and float(dots[0, 0]) == 13.0

    def test_pallas_chunk_reduce_compensated_without_x64(self):
        """x64 off: the cross-chunk dot reduction must Kahan-compensate —
        a spike chunk partial (2^30) must not swallow the other chunks'
        sub-ulp mass (63 chunks x 32, all below the f32 spacing of 128)."""
        n, C = 2048, 32
        diag = np.ones(n, np.float32)
        m = from_dense(np.diag(diag), C=C, sigma=1)
        x = np.ones(n, np.float32)
        x[:C] = 0.0
        x[0] = np.float32(2.0 ** 15)                  # square: 2^30
        x2 = jnp.asarray(x[:, None])
        _, _, dk = ops.sellcs_spmv(m, x2, opts=SpmvOpts(dot_xx=True))
        want = 2.0 ** 30 + (n - C)                    # exact in f64
        # Kahan bound: only the spike's 8-partial block can round (±128);
        # the old plain f32 running sum could lose all 2016
        assert abs(float(dk[2, 0]) - want) <= 128.0

    def test_compensated_sum_matches_f64(self, rng):
        p = jnp.asarray(rng.standard_normal((4097, 3)), jnp.float32)
        got = np.asarray(compensated_sum0(p))
        want = np.asarray(p, np.float64).sum(axis=0)
        np.testing.assert_allclose(got, want, rtol=2e-6)

    def test_compensated_sum_beats_naive_worst_case(self):
        # one spike block, then 64 blocks whose 64.0 partials each sit
        # *below* the f32 spacing at 2^30 (128): a plain running sum
        # rounds every one of them away, the Kahan carry recovers them
        # exactly (all quantities are exact f32, so equality is exact)
        p = np.zeros(256 + 64 * 256, np.float32)
        p[0] = 2.0 ** 30
        p[256:] = 0.25
        got = float(compensated_sum0(jnp.asarray(p[:, None]))[0])
        assert got == 2.0 ** 30 + 4096.0


# ----------------------------------------------- engine inherits the policy
class TestEnginePolicy:
    def test_make_matvec_cache_keys_on_resolved_mode(self, rng):
        from jax.sharding import Mesh
        from repro.runtime import DevicePool, HeterogeneousEngine

        r, c = np.arange(64), np.arange(64)
        v = np.ones(64, np.float32)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        eng = HeterogeneousEngine(r, c, v, 64, mesh=mesh,
                                  pool=DevicePool.from_bandwidths([1.0]),
                                  C=8, dtype=np.float32)
        fn_default = eng.make_matvec(nvecs=1)
        with execution.force(interpret=False):
            fn_compiled = eng.make_matvec(nvecs=1)
        with execution.force(interpret=True):
            fn_interp = eng.make_matvec(nvecs=1)
        base_interpret = execution.current_policy().interpret
        assert (fn_default is fn_interp) == (base_interpret is True)
        assert fn_compiled is not fn_interp
        # same policy twice -> cache hit
        assert eng.make_matvec(nvecs=1) is fn_default

    def test_forced_compiled_engine_degrades_inside_shard_map(self, rng):
        """The pipeline calls the Pallas kernel inside shard_map, where a
        lowering failure cannot be caught — the trace-time degrade leg of
        the cascade must kick in instead of crashing."""
        from jax.sharding import Mesh
        from repro.runtime import DevicePool, HeterogeneousEngine

        n = 64
        a = random_sparse(rng, n, n, density=0.3)
        r, c = np.nonzero(a)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        eng = HeterogeneousEngine(r, c, a[r, c], n, mesh=mesh,
                                  pool=DevicePool.from_bandwidths([1.0]),
                                  C=8, dtype=np.float32)
        x = rng.standard_normal((n, 1)).astype(np.float32)
        y_ref, _ = eng.spmv(x, impl="ref")
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            with execution.force(interpret=False):
                y, _ = eng.spmv(x, impl="pallas")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        if jax.default_backend() not in execution.COMPILED_BACKENDS:
            assert any(issubclass(w.category, RuntimeWarning) for w in rec)
