"""Solver correctness: CG / pipelined CG / MinRes / Lanczos / KPM / ChebFD
on the paper's application matrices."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import from_coo
from repro.matrices import anderson3d, laplace3d, matpde, spin_chain_xx
from repro.solvers import (cg, chebfd, kpm_dos_moments, lanczos_extrema,
                           make_operator, minres, pipelined_cg)
from repro.solvers.kpm import jackson_kernel
from repro.solvers.operator import MatrixFreeOperator


@pytest.fixture(scope="module")
def lap():
    r, c, v, n = laplace3d(7)
    A = from_coo(r, c, v, (n, n), C=16, sigma=32, w_align=4, dtype=np.float32)
    Ad = np.zeros((n, n), np.float32)
    Ad[r, c] += v.astype(np.float32)
    return A, Ad, n


class TestCG:
    def test_solves_block(self, lap, rng):
        A, Ad, n = lap
        op = make_operator(A)
        b = rng.standard_normal((n, 3)).astype(np.float32)
        res = cg(op, A.permute(b), tol=1e-7, maxiter=500)
        x = np.asarray(A.unpermute(res.x))
        assert bool(np.asarray(res.converged).all())
        assert np.abs(Ad @ x - b).max() < 1e-4

    def test_single_vector(self, lap, rng):
        A, Ad, n = lap
        op = make_operator(A)
        b = rng.standard_normal(n).astype(np.float32)
        res = cg(op, A.permute(b), tol=1e-7)
        assert np.abs(Ad @ np.asarray(A.unpermute(res.x)) - b).max() < 1e-4

    def test_pipelined_matches_cg(self, lap, rng):
        A, Ad, n = lap
        op = make_operator(A)
        b = rng.standard_normal((n, 2)).astype(np.float32)
        r1 = cg(op, A.permute(b), tol=1e-7, maxiter=400)
        r2 = pipelined_cg(op, A.permute(b), tol=1e-7, maxiter=400)
        x1 = np.asarray(A.unpermute(r1.x))
        x2 = np.asarray(A.unpermute(r2.x))
        np.testing.assert_allclose(x1, x2, atol=1e-3)

    def test_matrix_free(self, lap, rng):
        """Paper 5.1: custom SpMV function pointer (matrix-free hook)."""
        A, Ad, n = lap
        ghost_op = make_operator(A)
        op = MatrixFreeOperator(lambda x: ghost_op.mv(x), ghost_op.n,
                                np.float32)
        b = rng.standard_normal((n, 1)).astype(np.float32)
        res = cg(op, A.permute(b), tol=1e-6)
        assert bool(np.asarray(res.converged).all())


class TestMinres:
    def test_indefinite(self, lap, rng):
        A, Ad, n = lap
        # shift to make indefinite but safely nonsingular
        r, c = np.nonzero(Ad)
        v = Ad[r, c].astype(np.float64)
        shift = 2.7183           # irrational: far from lattice eigenvalues
        r2 = np.concatenate([r, np.arange(n)])
        c2 = np.concatenate([c, np.arange(n)])
        v2 = np.concatenate([v, -shift * np.ones(n)])
        As = from_coo(r2, c2, v2, (n, n), C=8, sigma=16, dtype=np.float32)
        op = make_operator(As)
        b = rng.standard_normal(n).astype(np.float32)
        res = minres(op, As.permute(b), tol=1e-7, maxiter=1500)
        x = np.asarray(As.unpermute(res.x))
        rel = np.abs((Ad - shift * np.eye(n)) @ x - b).max() / np.abs(b).max()
        assert rel < 1e-2, rel


class TestLanczos:
    def test_extrema_bracket_spectrum(self, lap):
        A, Ad, n = lap
        lo, hi = lanczos_extrema(make_operator(A), k=40)
        ev = np.linalg.eigvalsh(Ad.astype(np.float64))
        assert lo <= ev[0] + 1e-3
        assert hi >= ev[-1] - 1e-3


class TestKPM:
    def test_fused_equals_naive(self, lap):
        """The augmented-SpMV KPM (paper's 2.5x fusion showcase) must give
        identical moments to the unfused 3-kernel variant."""
        A, Ad, n = lap
        op = make_operator(A)
        lo, hi = lanczos_extrema(op, k=30)
        mf = kpm_dos_moments(op, 32, n_probes=2, spectrum=(lo, hi), fused=True)
        mn = kpm_dos_moments(op, 32, n_probes=2, spectrum=(lo, hi), fused=False)
        np.testing.assert_allclose(np.asarray(mf), np.asarray(mn),
                                   rtol=1e-3, atol=1e-5)

    def test_moments_match_exact_trace(self, lap):
        """mu_m ~ tr(T_m(As))/n: check against dense eigendecomposition.

        The operator acts on the SELL-padded space (nrows_pad), whose
        padding rows contribute exact zero eigenvalues — they must be in
        both the spectrum window (else Chebyshev diverges outside [-1,1])
        and the exact trace."""
        A, Ad, n = lap
        op = make_operator(A)
        ev = np.linalg.eigvalsh(Ad.astype(np.float64))
        ev_pad = np.concatenate([ev, np.zeros(A.nrows_pad - n)])
        lo, hi = min(ev[0], 0.0) - 0.1, ev[-1] + 0.1
        a, g = (hi - lo) / 2, (hi + lo) / 2
        evs = (ev_pad - g) / a
        M = 16
        mus = np.asarray(kpm_dos_moments(op, M, n_probes=24,
                                         spectrum=(lo, hi), seed=1))
        exact = np.array([np.mean(np.cos(m * np.arccos(np.clip(evs, -1, 1))))
                          for m in range(M)])
        # stochastic trace estimator: loose tolerance
        np.testing.assert_allclose(mus, exact, atol=0.3)

    def test_jackson_kernel_properties(self):
        g = jackson_kernel(64)
        assert abs(g[0] - 1.0) < 1e-12
        assert (np.diff(g) <= 1e-12).all()          # monotone decreasing
        assert g[-1] > 0


class TestChebFD:
    def test_interior_eigenvalues_anderson(self):
        """Chebyshev filter diagonalization on a disordered Hamiltonian
        (the ESSEX application domain)."""
        r, c, v, n = anderson3d(6, disorder=2.0, seed=3)
        A = from_coo(r, c, v, (n, n), C=16, sigma=32, dtype=np.float32)
        Ad = np.zeros((n, n)); Ad[r, c] += v
        ev = np.linalg.eigvalsh(Ad)
        op = make_operator(A)
        lo, hi = lanczos_extrema(op, k=40)
        target = (float(ev[0] - 0.1), float(ev[3] + 0.01))
        res = chebfd(op, target, block_size=6, degree=100, sweeps=6,
                     spectrum=(lo, hi))
        found = res.eigenvalues[res.residuals < 1e-2]
        assert len(found) >= 3
        for f in found[:3]:
            assert np.abs(ev - f).min() < 5e-3

    def test_pallas_tsm_path(self, lap):
        A, Ad, n = lap
        op = make_operator(A)
        ev = np.linalg.eigvalsh(Ad.astype(np.float64))
        lo_t, hi_t = float(ev[0] - 0.1), float(ev[3] + 0.02)
        # spectrum bound must include the SELL padding rows' exact zero
        # eigenvalues (Chebyshev diverges outside the scaled [-1, 1])
        res = chebfd(op, (lo_t, hi_t), block_size=6, degree=80, sweeps=5,
                     spectrum=(-0.2, float(ev[-1]) + 0.2),
                     use_pallas_tsm=True)
        # converged Ritz values inside the window (the SELL padding rows
        # contribute exact zero eigenvalues outside the target window)
        good = res.eigenvalues[(res.residuals < 5e-2)
                               & (res.eigenvalues > lo_t - 0.05)
                               & (res.eigenvalues < hi_t + 0.05)]
        assert len(good) >= 1
        for g in good:
            assert np.abs(ev - g).min() < 5e-2


class TestDtypeFidelity:
    """Internally generated start vectors follow op.dtype (f64 operators
    must not be silently downcast) and complex-Hermitian reorth uses the
    conjugate transpose."""

    def test_lanczos_f64(self):
        from jax.experimental import enable_x64
        from repro.solvers import lanczos
        with enable_x64():
            r, c, v, n = laplace3d(6)
            A = from_coo(r, c, v, (n, n), C=16, sigma=32, dtype=np.float64)
            op = make_operator(A)
            assert op.dtype == np.float64
            res = lanczos(op, None, 30, reorth=True, keep_basis=True)
            assert res.alphas.dtype == np.float64
            assert res.V.dtype == np.float64
            lo, hi = lanczos_extrema(op, k=40)
            Ad = np.zeros((n, n)); Ad[r, c] += v
            ev = np.linalg.eigvalsh(Ad)
            assert lo <= ev[0] + 1e-8 and hi >= ev[-1] - 1e-8

    def test_chebfd_f64(self):
        from jax.experimental import enable_x64
        with enable_x64():
            r, c, v, n = laplace3d(5)
            A = from_coo(r, c, v, (n, n), C=8, sigma=16, dtype=np.float64)
            Ad = np.zeros((n, n)); Ad[r, c] += v
            ev = np.linalg.eigvalsh(Ad)
            op = make_operator(A)
            target = (float(ev[0] - 0.1), float(ev[2] + 0.01))
            res = chebfd(op, target, block_size=4, degree=80, sweeps=5,
                         spectrum=(min(ev[0], 0.0) - 0.2, ev[-1] + 0.2))
            assert res.eigenvectors.dtype == np.float64
            found = res.eigenvalues[res.residuals < 1e-2]
            assert len(found) >= 1
            for f in found[:2]:
                assert np.abs(ev - f).min() < 5e-3

    def test_cg_f64_tiny_floor(self, rng):
        from jax.experimental import enable_x64
        with enable_x64():
            r, c, v, n = laplace3d(5)
            A = from_coo(r, c, v, (n, n), C=8, sigma=16, dtype=np.float64)
            op = make_operator(A)
            b = A.permute(rng.standard_normal(n))
            res = cg(op, b, tol=1e-12, maxiter=500)
            assert res.x.dtype == np.float64
            assert bool(np.asarray(res.converged))
            # an f64 solve can genuinely reach below f32 resolution
            assert float(res.resnorm) < 1e-10 * np.linalg.norm(np.asarray(b))

    def test_lanczos_happy_breakdown(self, rng):
        """On A = I every start vector is an eigenvector: beta_1 = 0 and
        the recurrence used to keep iterating on the zero vector, padding
        garbage alphas that dragged a spurious 0 into the tridiagonal
        spectrum.  Now nvalid reports the usable prefix and the extrema
        bracket stays tight around 1."""
        from repro.solvers import lanczos
        n = 64
        op = MatrixFreeOperator(lambda x: x, n, np.float32)
        v0 = np.zeros(n, np.float32)
        v0[0] = 1.0                     # exact eigenvector: w = v - 1*v = 0
        res = lanczos(op, jnp.asarray(v0), 12, keep_basis=True)
        assert int(res.nvalid) == 1
        # frozen steps write nothing: zero padding past the valid prefix
        assert np.allclose(np.asarray(res.alphas[1:]), 0.0)
        assert np.allclose(np.asarray(res.betas), 0.0)
        assert np.allclose(np.asarray(res.V[:, 1:]), 0.0)
        np.testing.assert_allclose(float(res.alphas[0]), 1.0, rtol=1e-6)
        # extrema on a 1-d operator: the random start is +-1 exactly, so
        # the recurrence breaks down after one step; the padded zero
        # alphas used to drag a spurious 0 into the bracket (lo ~ -0.05)
        op1 = MatrixFreeOperator(lambda x: 2.0 * x, 1, np.float32)
        lo, hi = lanczos_extrema(op1, k=12)
        assert lo > 1.8 and hi < 2.2 and lo <= 2.0 <= hi

    def test_lanczos_no_breakdown_unchanged(self, rng):
        """The breakdown masks are inert on a healthy run: full nvalid
        and the same recurrence values as before the guard."""
        from repro.solvers import lanczos
        r, c, v, n = laplace3d(6)
        A = from_coo(r, c, v, (n, n), C=16, sigma=32, dtype=np.float32)
        op = make_operator(A)
        res = lanczos(op, None, 20, seed=3)
        assert int(res.nvalid) == 20
        assert np.all(np.asarray(res.betas) > 0)

    def test_lanczos_complex_hermitian_reorth(self, rng):
        """Regression: reorthogonalization must project with V^H, not V^T.

        On a complex Hermitian operator the V^T variant destroys the
        basis; with V^H the Ritz extrema match the dense spectrum."""
        import jax.numpy as jnp
        from repro.solvers import lanczos
        from repro.solvers.lanczos import tridiag_eigh

        n = 48
        H = rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n))
        H = ((H + H.conj().T) / 2).astype(np.complex64)
        Hj = jnp.asarray(H)
        op = MatrixFreeOperator(lambda x: Hj @ x, n, np.complex64)
        res = lanczos(op, None, n, reorth=True, keep_basis=True, seed=2)
        # real tridiagonal coefficients, complex basis
        assert res.alphas.dtype == np.float32
        assert res.V.dtype == np.complex64
        # the reorthogonalized basis stays unitary to working precision
        G = np.asarray(res.V.conj().T @ res.V)
        np.testing.assert_allclose(G, np.eye(n), atol=5e-3)
        ev_dense = np.linalg.eigvalsh(H.astype(np.complex128))
        ev_lan, _ = tridiag_eigh(res.alphas, res.betas)
        np.testing.assert_allclose(ev_lan[0], ev_dense[0], atol=1e-2)
        np.testing.assert_allclose(ev_lan[-1], ev_dense[-1], atol=1e-2)


class TestQuantumMatrices:
    def test_spin_chain_indefinite_minres(self, rng):
        """'Completely indefinite, no mesh interpretation' matrices
        (paper 1.3) — XXZ chain."""
        r, c, v, n = spin_chain_xx(8)
        A = from_coo(r, c, v, (n, n), C=16, sigma=32, dtype=np.float32)
        Ad = np.zeros((n, n)); Ad[r, c] += v
        op = make_operator(A)
        b = rng.standard_normal(n).astype(np.float32)
        res = minres(op, A.permute(b), tol=1e-6, maxiter=2000)
        x = np.asarray(A.unpermute(res.x))
        rel = np.abs(Ad @ x - b).max() / np.abs(b).max()
        assert rel < 1e-2
