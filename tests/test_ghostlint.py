"""ghostlint: per-rule fixture matrix, engine machinery, and the
self-check that the linter passes over the repo's own src/ tree.

Each rule gets (at least) one *positive* fixture — a minimal snippet the
rule must flag — and a *suppressed negative* proving the inline
``# ghostlint: disable=`` escape hatch works for that rule.  Paths
passed to ``lint_source`` are fake repo-relative paths: they drive the
kernel-/test-file classification without touching disk.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from tools.ghostlint import lint_source, lint_paths, load_baseline
from tools.ghostlint.cli import main as cli_main
from tools.ghostlint.engine import Finding, write_baseline
from tools.ghostlint.rules import ALL_RULES, RULES_BY_ID

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

KERNEL_PATH = "src/repro/kernels/fake.py"
LIB_PATH = "src/repro/runtime/fake.py"


def rules_of(findings):
    return {f.rule for f in findings}


def lint(src, path=LIB_PATH, **kw):
    return lint_source(textwrap.dedent(src), path, **kw)


# ---------------------------------------------------------------- GL001
class TestGL001Cascade:
    def test_pallas_call_outside_kernels_flagged(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            def run(x):
                return pl.pallas_call(lambda r, o: None)(x)
        """)
        assert "GL001" in rules_of(fs)

    def test_kernel_wrapper_without_resolver_flagged(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            def foo_pallas(x):
                return pl.pallas_call(lambda r, o: None)(x)
        """, KERNEL_PATH)
        assert "GL001" in rules_of(fs)

    def test_kernel_wrapper_with_resolver_clean(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            from repro.core import execution
            def foo_pallas(x, *, interpret=None):
                interpret = execution.resolve_interpret(interpret)
                return pl.pallas_call(lambda r, o: None)(x)
        """, KERNEL_PATH)
        assert "GL001" not in rules_of(fs)

    def test_suppressed(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            def run(x):
                # ghostlint: disable=GL001
                return pl.pallas_call(lambda r, o: None)(x)
        """)
        assert "GL001" not in rules_of(fs)


# ---------------------------------------------------------------- GL002
class TestGL002Interpret:
    @pytest.mark.parametrize("default", ["True", "False"])
    def test_literal_bool_default_flagged(self, default):
        fs = lint(f"def f(x, interpret: bool = {default}):\n    return x\n")
        assert "GL002" in rules_of(fs)

    def test_kwonly_literal_flagged(self):
        fs = lint("def f(x, *, interpret=True):\n    return x\n")
        assert "GL002" in rules_of(fs)

    def test_none_default_clean(self):
        fs = lint("def f(x, *, interpret=None):\n    return x\n")
        assert "GL002" not in rules_of(fs)

    def test_pallas_call_literal_kwarg_flagged(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            from repro.core import execution
            def foo_pallas(x):
                execution.resolve_interpret(None)
                return pl.pallas_call(k, interpret=True)(x)
        """, KERNEL_PATH)
        assert "GL002" in rules_of(fs)

    def test_suppressed(self):
        fs = lint("def f(x, *, interpret=True):  "
                  "# ghostlint: disable=GL002\n    return x\n")
        assert "GL002" not in rules_of(fs)


# ---------------------------------------------------------------- GL003
class TestGL003AccDtype:
    def test_private_helper_flagged(self):
        fs = lint("""
            import jax.numpy as jnp
            def _acc_dtype(dt):
                return jnp.float32
        """, KERNEL_PATH)
        assert "GL003" in rules_of(fs)

    def test_literal_preferred_element_type_flagged(self):
        fs = lint("""
            import jax, jax.numpy as jnp
            def k(a, b):
                return jax.lax.dot_general(
                    a, b, ((((1,), (0,)), ((), ()))),
                    preferred_element_type=jnp.float32)
        """, KERNEL_PATH)
        assert "GL003" in rules_of(fs)

    def test_literal_astype_flagged(self):
        fs = lint("import jax.numpy as jnp\n"
                  "def k(v):\n    return v.astype(jnp.float32)\n",
                  KERNEL_PATH)
        assert "GL003" in rules_of(fs)

    def test_contract_dtype_clean(self):
        fs = lint("""
            from repro.core.spmv import storage_acc_dtype
            def k(v, out_dtype):
                acc = storage_acc_dtype(out_dtype)
                return v.astype(acc)
        """, KERNEL_PATH)
        assert "GL003" not in rules_of(fs)

    def test_outside_kernels_not_scoped(self):
        fs = lint("import jax.numpy as jnp\n"
                  "def f(v):\n    return v.astype(jnp.float32)\n")
        assert "GL003" not in rules_of(fs)

    def test_suppressed(self):
        fs = lint("import jax.numpy as jnp\n"
                  "def k(v):\n"
                  "    return v.astype(jnp.float32)  "
                  "# ghostlint: disable=GL003\n", KERNEL_PATH)
        assert "GL003" not in rules_of(fs)


# ---------------------------------------------------------------- GL004
class TestGL004Capture:
    def test_lru_cache_on_method_flagged(self):
        fs = lint("""
            import functools
            class A:
                @functools.lru_cache(maxsize=8)
                def solve(self, n):
                    return n
        """)
        assert "GL004" in rules_of(fs)

    def test_run_chunk_capture_without_extra_key_flagged(self):
        fs = lint("""
            def solve(op, M, state):
                return run_chunk(op, "cg", 8, state,
                                 lambda o, s: body(o, M, s))
        """)
        assert "GL004" in rules_of(fs)

    def test_run_chunk_with_extra_key_clean(self):
        fs = lint("""
            def solve(op, M, state):
                return run_chunk(op, "cg", 8, state,
                                 lambda o, s: body(o, M, s), extra_key=M)
        """)
        assert "GL004" not in rules_of(fs)

    def test_cache_store_strong_capture_flagged(self):
        fs = lint("""
            import jax
            class Service:
                def open(self, key, op):
                    fn = jax.jit(lambda B: init(op, B))
                    self._jit_cache[key] = fn
        """)
        assert "GL004" in rules_of(fs)

    def test_cache_store_weakref_clean(self):
        fs = lint("""
            import jax, weakref
            class Service:
                def open(self, key, op, M):
                    op_ref = weakref.ref(op)
                    M_ref = weakref.ref(M) if M is not None else None
                    def _init(B):
                        return init(op_ref(), B, M_ref)
                    self._jit_cache[key] = jax.jit(_init)
        """)
        assert "GL004" not in rules_of(fs)

    def test_cache_store_scalar_cast_capture_clean(self):
        fs = lint("""
            import jax, weakref
            class Service:
                def open(self, key, op):
                    op_ref = weakref.ref(op)
                    blk = bool(key[5])
                    def _init(B):
                        return init(op_ref(), B, block=blk)
                    self._jit_cache[key] = jax.jit(_init)
        """)
        assert "GL004" not in rules_of(fs)

    def test_suppressed(self):
        fs = lint("""
            import functools
            class A:
                # ghostlint: disable=GL004
                @functools.lru_cache(maxsize=8)
                def solve(self, n):
                    return n
        """)
        assert "GL004" not in rules_of(fs)


# ---------------------------------------------------------------- GL005
class TestGL005TraceSafety:
    def test_if_on_traced_param_flagged(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
        """)
        assert "GL005" in rules_of(fs)

    def test_float_conversion_flagged(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x):
                return float(x)
        """)
        assert "GL005" in rules_of(fs)

    def test_while_loop_body_flagged(self):
        fs = lint("""
            from jax import lax
            def outer(state):
                def body(carry):
                    if carry:
                        return carry
                    return carry
                return lax.while_loop(cond, body, state)
        """)
        assert "GL005" in rules_of(fs)

    def test_shape_branch_clean(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x):
                if x.shape[0] > 2:
                    return x
                return -x
        """)
        assert "GL005" not in rules_of(fs)

    def test_is_none_clean(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x, y):
                if y is not None:
                    return x + y
                return x
        """)
        assert "GL005" not in rules_of(fs)

    def test_kwonly_static_flag_clean(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x, *, fused):
                if fused:
                    return x * 2
                return x
        """)
        assert "GL005" not in rules_of(fs)

    def test_taint_propagates_through_assignment(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x):
                r = x * 2
                if r > 0:
                    return r
                return -r
        """)
        assert "GL005" in rules_of(fs)

    def test_dict_key_membership_clean(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(params, x):
                if "bias" in params:
                    return x + params["bias"]
                return x
        """)
        assert "GL005" not in rules_of(fs)

    def test_suppressed(self):
        fs = lint("""
            import jax
            @jax.jit
            def f(x):
                # ghostlint: disable=GL005
                if x > 0:
                    return x
                return -x
        """)
        assert "GL005" not in rules_of(fs)


# ---------------------------------------------------------------- GL006
class TestGL006Validation:
    def test_bare_assert_flagged(self):
        fs = lint("def f(n):\n    assert n > 0, 'n must be positive'\n")
        assert "GL006" in rules_of(fs)

    def test_raise_clean(self):
        fs = lint("def f(n):\n"
                  "    if n <= 0:\n"
                  "        raise ValueError('n must be positive')\n")
        assert "GL006" not in rules_of(fs)

    def test_test_files_exempt(self):
        fs = lint("def test_f():\n    assert 1 + 1 == 2\n",
                  "tests/test_fake.py")
        assert "GL006" not in rules_of(fs)

    def test_pallas_wrapper_without_validation_flagged(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            from repro.core import execution
            def foo_pallas(x, *, interpret=None):
                interpret = execution.resolve_interpret(interpret)
                return pl.pallas_call(lambda r, o: None)(x)
        """, KERNEL_PATH)
        assert "GL006" in rules_of(fs)

    def test_pallas_wrapper_with_raise_clean(self):
        fs = lint("""
            from jax.experimental import pallas as pl
            from repro.core import execution
            def foo_pallas(x, *, interpret=None):
                interpret = execution.resolve_interpret(interpret)
                if x.ndim != 2:
                    raise ValueError("x must be 2D")
                return pl.pallas_call(lambda r, o: None)(x)
        """, KERNEL_PATH)
        assert "GL006" not in rules_of(fs)

    def test_suppressed(self):
        fs = lint("def f(n):\n    assert n > 0  "
                  "# ghostlint: disable=GL006\n")
        assert "GL006" not in rules_of(fs)


# ---------------------------------------------------------------- GL007
class TestGL007Parity:
    def _write(self, tmp_path, kernel_src, ref_src):
        kdir = tmp_path / "kernels"
        kdir.mkdir()
        (kdir / "ref.py").write_text(textwrap.dedent(ref_src))
        kfile = kdir / "foo.py"
        kfile.write_text(textwrap.dedent(kernel_src))
        return lint_source(kfile.read_text(),
                           "src/repro/kernels/foo.py",
                           abspath=str(kfile))

    def test_missing_ref_flagged(self, tmp_path):
        fs = self._write(tmp_path,
                         "def foo_pallas(x):\n    return x\n",
                         "def bar_ref(x):\n    return x\n")
        assert "GL007" in rules_of(fs)

    def test_matching_ref_clean(self, tmp_path):
        fs = self._write(tmp_path,
                         "def foo_pallas(x):\n    return x\n",
                         "def foo_ref(x):\n    return x\n")
        assert "GL007" not in rules_of(fs)

    def test_suppressed(self, tmp_path):
        fs = self._write(tmp_path,
                         "# ghostlint: disable=GL007\n"
                         "def foo_pallas(x):\n    return x\n",
                         "def bar_ref(x):\n    return x\n")
        assert "GL007" not in rules_of(fs)

    def test_repo_kernels_all_have_refs(self):
        kdir = os.path.join(SRC, "repro", "kernels")
        findings, n = lint_paths([kdir],
                                 rules=[RULES_BY_ID["GL007"]])
        assert n > 0
        assert findings == []


# ---------------------------------------------------------------- GL008
class TestGL008BlanketExcept:
    def test_except_exception_flagged(self):
        fs = lint("try:\n    f()\nexcept Exception:\n    pass\n")
        assert "GL008" in rules_of(fs)

    def test_bare_except_flagged(self):
        fs = lint("try:\n    f()\nexcept:\n    pass\n")
        assert "GL008" in rules_of(fs)

    def test_concrete_types_clean(self):
        fs = lint("try:\n    f()\nexcept (ValueError, OSError):\n    pass\n")
        assert "GL008" not in rules_of(fs)

    def test_suppressed(self):
        fs = lint("try:\n    f()\n"
                  "# ghostlint: disable=GL008\n"
                  "except Exception:\n    pass\n")
        assert "GL008" not in rules_of(fs)


# ------------------------------------------------------------- engine bits
class TestEngine:
    def test_syntax_error_reported_as_gl000(self):
        fs = lint("def f(:\n")
        assert rules_of(fs) == {"GL000"}

    def test_disable_file_suppresses_everywhere(self):
        fs = lint("# ghostlint: disable-file=GL006\n"
                  "def f(n):\n    assert n\n"
                  "def g(n):\n    assert n\n")
        assert "GL006" not in rules_of(fs)

    def test_disable_all_on_line(self):
        fs = lint("def f(n):\n    assert n  # ghostlint: disable=all\n")
        assert fs == []

    def test_disable_in_string_literal_inert(self):
        fs = lint('S = "# ghostlint: disable=GL006"\n'
                  "def f(n):\n    assert n\n")
        assert "GL006" in rules_of(fs)

    def test_fingerprint_survives_line_shift(self):
        a = Finding("GL006", "x.py", 3, "m", "assert n")
        b = Finding("GL006", "x.py", 30, "m", "assert n")
        assert a.fingerprint == b.fingerprint

    def test_baseline_roundtrip(self, tmp_path):
        p = str(tmp_path / "bl.json")
        fs = [Finding("GL006", "x.py", 3, "m", "assert n")]
        write_baseline(fs, p)
        assert load_baseline(p) == {("GL006", "x.py", "assert n")}

    def test_load_missing_baseline_empty(self, tmp_path):
        assert load_baseline(str(tmp_path / "nope.json")) == set()

    def test_every_rule_has_id_and_title(self):
        ids = [r.RULE_ID for r in ALL_RULES]
        assert len(ids) == len(set(ids)) and len(ids) >= 7
        for r in ALL_RULES:
            assert r.RULE_ID.startswith("GL")
            assert r.RULE_TITLE


# ------------------------------------------------------------------- CLI
class TestCLI:
    def test_list_rules_exit_zero(self, capsys):
        assert cli_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for r in ALL_RULES:
            assert r.RULE_ID in out

    def test_no_paths_usage_error(self, capsys):
        assert cli_main([]) == 2

    def test_unknown_rule_usage_error(self, capsys):
        assert cli_main(["--select", "GL999", "src"]) == 2

    def test_findings_exit_one_and_json(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(n):\n    assert n\n")
        rc = cli_main([str(bad), "--format=json", "--no-baseline"])
        assert rc == 1
        data = json.loads(capsys.readouterr().out)
        assert data["files_checked"] == 1
        assert any(f["rule"] == "GL006" for f in data["findings"])

    def test_clean_file_exit_zero(self, tmp_path, capsys):
        ok = tmp_path / "ok.py"
        ok.write_text("def f(n):\n    return n\n")
        assert cli_main([str(ok), "--no-baseline"]) == 0

    def test_write_baseline_then_clean(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(n):\n    assert n\n")
        bl = str(tmp_path / "bl.json")
        assert cli_main([str(bad), "--write-baseline",
                         "--baseline", bl]) == 0
        capsys.readouterr()
        assert cli_main([str(bad), "--baseline", bl]) == 0
        assert cli_main([str(bad), "--baseline", bl,
                         "--no-baseline"]) == 1


# ------------------------------------------------------------- self-check
class TestSelfCheck:
    def test_src_tree_clean_beyond_baseline(self):
        """The linter's reason to exist: the repo's own library code has
        zero findings beyond the committed baseline."""
        findings, n = lint_paths([SRC])
        assert n > 50
        baseline = load_baseline()
        fresh = [f for f in findings if f.fingerprint not in baseline]
        assert fresh == [], "\n".join(f.format() for f in fresh)

    def test_parity_sweep_agrees(self):
        from tools.ghostlint.parity import run_parity_sweep
        assert run_parity_sweep() == []

    def test_parity_sweep_covers_every_discovered_kernel(self):
        """GL007's dynamic half is auto-discovered: every *_pallas def
        under src/repro/kernels/ must have a registered sweep driver, so
        a new kernel file cannot silently skip the sweep."""
        from tools.ghostlint.parity import (SWEEPS, check_sweep_coverage,
                                            discover_kernel_bases)
        assert check_sweep_coverage() == []
        assert set(discover_kernel_bases()) == set(SWEEPS)


# ------------------------------------------------- python -O regression
class TestOptimizedMode:
    def test_validation_survives_dash_O(self):
        """Converted assert->raise validation still fires under -O (a
        bare assert would silently vanish)."""
        code = (
            "from repro.models import sharding\n"
            "try:\n"
            "    sharding.set_layout('bogus')\n"
            "except ValueError:\n"
            "    print('VALIDATED')\n"
            "else:\n"
            "    raise SystemExit('validation vanished under -O')\n"
        )
        env = dict(os.environ, PYTHONPATH=SRC)
        res = subprocess.run([sys.executable, "-O", "-c", code],
                             capture_output=True, text=True, env=env)
        assert res.returncode == 0, res.stderr
        assert "VALIDATED" in res.stdout

    def test_kernel_validation_survives_dash_O(self):
        code = (
            "import jax.numpy as jnp\n"
            "from repro.kernels.tsmm import tsmm_pallas\n"
            "V = jnp.ones((16, 3)); X = jnp.ones((4, 4))\n"
            "try:\n"
            "    tsmm_pallas(V, X, row_tile=16, interpret=True)\n"
            "except ValueError:\n"
            "    print('VALIDATED')\n"
            "else:\n"
            "    raise SystemExit('kernel validation vanished under -O')\n"
        )
        env = dict(os.environ, PYTHONPATH=SRC)
        res = subprocess.run([sys.executable, "-O", "-c", code],
                             capture_output=True, text=True, env=env)
        assert res.returncode == 0, res.stderr
        assert "VALIDATED" in res.stdout


# ------------------------------------------------- execution.describe()
class TestDescribe:
    def test_describe_current_policy(self):
        from repro.core import execution
        s = execution.describe()
        for field in ("mode=", "backend=", "source=", "fallback=",
                      "row_tile=", "s_blk="):
            assert field in s

    def test_describe_explicit_policy_with_w_tile(self):
        from repro.core import execution
        pol = execution.ExecutionPolicy(
            interpret=True, backend="cpu", source="forced", w_tile=4)
        s = execution.describe(pol)
        assert "w_tile=4" in s
        assert "source=forced" in s
