"""Distributed SpMV (paper C4+C5): correctness on a multi-device mesh,
weighted distribution, halo compression, overlap modes.  Runs in a
subprocess with 8 forced host devices (the main test process keeps 1)."""
import numpy as np
import pytest

from conftest import run_with_devices

CODE_TEMPLATE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import dist_from_coo, dist_spmv
from repro.core.spmv import SpmvOpts
from repro.matrices import banded_random, matpde

rng = np.random.default_rng(0)
{body}
print("SUBPROCESS_OK")
"""


def run(body: str, n_devices: int = 8):
    out = run_with_devices(CODE_TEMPLATE.format(body=body), n_devices)
    assert "SUBPROCESS_OK" in out
    return out


class TestDistSpmv:
    def test_matches_dense_equal_weights(self):
        run("""
r, c, v, n = matpde(20)
A = np.zeros((n, n)); A[r, c] += v
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
D = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=16, w_align=4,
                  dtype=np.float32)
x = rng.standard_normal((n, 3)).astype(np.float32)
y, _ = dist_spmv(D, mesh, x)
assert np.allclose(np.asarray(y), A @ x, atol=1e-3), np.abs(np.asarray(y)-A@x).max()
""")

    def test_weighted_heterogeneous_split(self):
        """Paper section 4.1: bandwidth-proportional weights (e.g. the
        CPU:GPU:PHI = 50:150:150 example)."""
        run("""
r, c, v, n = banded_random(640, bw=10, density=0.7, seed=2)
A = np.zeros((n, n)); A[r, c] += v
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
w = [50, 150, 150, 50, 150, 150, 50, 150]      # paper's device bandwidths
D = dist_from_coo(r, c, v, n, nshards=8, weights=w, C=8, sigma=32,
                  w_align=4, dtype=np.float32)
x = rng.standard_normal(n).astype(np.float32)
y, _ = dist_spmv(D, mesh, x)
assert np.allclose(np.asarray(y), A @ x, atol=1e-3)
""")

    def test_nnz_balanced_partition(self):
        run("""
r, c, v, n = banded_random(512, bw=12, density=0.5, seed=3)
A = np.zeros((n, n)); A[r, c] += v
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
D = dist_from_coo(r, c, v, n, nshards=8, by_nnz=True, C=8, sigma=16,
                  w_align=4, dtype=np.float32)
x = rng.standard_normal(n).astype(np.float32)
y, _ = dist_spmv(D, mesh, x)
assert np.allclose(np.asarray(y), A @ x, atol=1e-3)
""")

    def test_overlap_and_no_overlap_agree(self):
        """Fig. 5: the overlap modes differ only in schedule, not result."""
        run("""
r, c, v, n = banded_random(400, bw=8, density=0.6, seed=4)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
D = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=16, w_align=4,
                  dtype=np.float32)
x = rng.standard_normal((n, 2)).astype(np.float32)
y1, _ = dist_spmv(D, mesh, x, overlap=True)
y2, _ = dist_spmv(D, mesh, x, overlap=False)
assert np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
""")

    def test_pallas_impl_in_shard_map(self):
        run("""
r, c, v, n = banded_random(320, bw=6, density=0.6, seed=5)
A = np.zeros((n, n)); A[r, c] += v
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
D = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=16, w_align=4,
                  dtype=np.float32)
x = rng.standard_normal((n, 2)).astype(np.float32)
y, _ = dist_spmv(D, mesh, x, impl="pallas")
assert np.allclose(np.asarray(y), A @ x, atol=1e-3)
""")

    def test_fused_dots_psum(self):
        run("""
r, c, v, n = banded_random(256, bw=6, density=0.7, seed=6)
A = np.zeros((n, n)); A[r, c] += v
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
D = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=16, w_align=4,
                  dtype=np.float32)
x = rng.standard_normal((n, 2)).astype(np.float32)
y, dots = dist_spmv(D, mesh, x,
                    opts=SpmvOpts(dot_yy=True, dot_xy=True, dot_xx=True))
ref = A @ x
assert np.allclose(np.asarray(dots[0]), (ref * ref).sum(0), rtol=1e-3)
assert np.allclose(np.asarray(dots[2]), (x * x).sum(0), rtol=1e-3)
""")

    def test_store_dtype_shards_stay_narrow(self):
        """Mixed-precision storage end-to-end: local AND remote value
        shards stay in the storage dtype, the halo/vector path stays in
        the compute dtype, and the distributed SpMV matches dense within
        bf16 tolerance."""
        run("""
r, c, v, n = banded_random(400, bw=8, density=0.6, seed=9)
A = np.zeros((n, n)); A[r, c] += v
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
D = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=16, w_align=4,
                  dtype=np.float32, store_dtype=jnp.bfloat16)
assert D.l_vals.dtype == jnp.bfloat16, D.l_vals.dtype
assert D.r_vals.dtype == jnp.bfloat16, D.r_vals.dtype
assert D.dtype == jnp.float32 and str(D.compute_dtype) == "float32"
x = rng.standard_normal((n, 2)).astype(np.float32)
y, _ = dist_spmv(D, mesh, x)
assert np.asarray(y).dtype == np.float32
ref = A @ x
scale = max(1.0, np.abs(ref).max())
assert np.abs(np.asarray(y) - ref).max() / scale < 2e-2
# storage axis off -> bit-identical to the classic build
D0 = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=16, w_align=4,
                   dtype=np.float32)
D1 = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=16, w_align=4,
                   dtype=np.float32, store_dtype=None)
y0, _ = dist_spmv(D0, mesh, x)
y1, _ = dist_spmv(D1, mesh, x)
assert np.array_equal(np.asarray(y0), np.asarray(y1))
""")

    def test_halo_compression_bounds_comm(self):
        """Remote-column compression (Fig. 3): halo volume must track the
        band width, not the matrix size."""
        run("""
r, c, v, n = banded_random(1024, bw=4, density=1.0, seed=7)
D = dist_from_coo(r, c, v, n, nshards=8, C=8, sigma=1, w_align=4,
                  dtype=np.float32)
# each shard needs at most bw rows from each neighbor
assert D.max_msg <= 8, D.max_msg
assert D.h_max <= 16, D.h_max
""", 8)
