"""§Perf optimization variants must be numerically faithful:
  * chunkwise mLSTM == recurrent mLSTM
  * causal-skip attention == masked-full attention
  * fsdp/zero1 layouts produce valid specs for every arch
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import layers as L
from repro.models.xlstm import XLSTMConfig, mlstm_apply, mlstm_init


class TestChunkwiseMLSTM:
    @pytest.mark.parametrize("S,chunk", [(50, 16), (64, 64), (37, 8)])
    def test_matches_recurrent(self, S, chunk):
        cfg_r = XLSTMConfig(n_heads=4, expand=2, chunk=chunk, chunkwise=False)
        cfg_c = dataclasses.replace(cfg_r, chunkwise=True)
        params = mlstm_init(jax.random.PRNGKey(0), 32, cfg_r,
                            dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, S, 32), jnp.float32)
        hr = mlstm_apply(params, x, cfg_r)
        hc = mlstm_apply(params, x, cfg_c)
        np.testing.assert_allclose(np.asarray(hr), np.asarray(hc),
                                   atol=5e-4, rtol=5e-4)

    def test_extreme_gates(self):
        """Stabilizers must survive large gate pre-activations."""
        cfg_r = XLSTMConfig(n_heads=2, expand=2, chunk=8, chunkwise=False)
        cfg_c = dataclasses.replace(cfg_r, chunkwise=True)
        params = mlstm_init(jax.random.PRNGKey(3), 16, cfg_r,
                            dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 33, 16)) * 5.0
        hr = mlstm_apply(params, x, cfg_r)
        hc = mlstm_apply(params, x, cfg_c)
        assert np.isfinite(np.asarray(hc)).all()
        np.testing.assert_allclose(np.asarray(hr), np.asarray(hc),
                                   atol=5e-3, rtol=5e-3)


class TestCausalSkip:
    def test_matches_masked_full(self):
        B, S, H, Hkv, D = 2, 64, 4, 2, 16
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, S, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
        try:
            L.set_causal_skip(False)
            base = L._online_attn(q, k, v, causal=True, q_offset=0,
                                  q_block=16, kv_block=16)
            L.set_causal_skip(True)
            skip = L._online_attn(q, k, v, causal=True, q_offset=0,
                                  q_block=16, kv_block=16)
        finally:
            L.set_causal_skip(False)
        np.testing.assert_allclose(np.asarray(base), np.asarray(skip),
                                   atol=2e-3, rtol=2e-3)

    def test_tile_count_halves(self):
        """Structural check: the skip path touches nqb(nqb+1)/2 tiles."""
        nqb = 8
        pairs = [(i, j) for i in range(nqb) for j in range(nqb) if j <= i]
        assert len(pairs) == nqb * (nqb + 1) // 2


class TestLayouts:
    @pytest.mark.parametrize("layout", ["tp", "fsdp", "zero1"])
    def test_specs_valid_all_archs(self, layout):
        from repro.configs import get_smoke_config, list_archs
        from repro.models import sharding as SH
        from repro.models import transformer as T
        mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
        try:
            SH.set_layout(layout)
            for arch in list_archs():
                cfg = get_smoke_config(arch)
                pshape = jax.eval_shape(
                    lambda cfg=cfg: T.init_params(cfg, jax.random.PRNGKey(0)))
                specs = SH.param_specs(cfg, pshape, mesh)
                oshape = jax.eval_shape(
                    lambda p=pshape: {"m": p, "count": jnp.zeros((), jnp.int32)})
                ospecs = SH.opt_specs(specs, oshape, mesh)
                assert len(jax.tree.leaves(
                    ospecs, is_leaf=lambda x: isinstance(x, P))) > 0
        finally:
            SH.set_layout("tp")

    def test_zero1_params_replicated(self):
        from repro.configs import get_smoke_config
        from repro.models import sharding as SH
        from repro.models import transformer as T
        mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
        try:
            SH.set_layout("zero1")
            cfg = get_smoke_config("qwen2_5_3b")
            pshape = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
            specs = SH.param_specs(cfg, pshape, mesh)
            for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
                assert all(a is None for a in s), s
        finally:
            SH.set_layout("tp")
