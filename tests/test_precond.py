"""Preconditioning subsystem: block extraction under the sigma-sort
permutation, the batched block-diagonal Pallas kernel, Chebyshev
polynomial composition with any operator (incl. DistOperator), and the
preconditioned CG/MINRES steppers."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import execution, from_coo, from_dense
from repro.kernels import ops
from repro.kernels.ref import block_diag_matmul_ref
from repro.matrices import anisotropic_laplace2d, laplace3d
from repro.solvers import (BlockJacobiPreconditioner, ChebyshevPreconditioner,
                           cg, cg_finalize, cg_init, cg_step, lanczos_extrema,
                           make_operator, minres, minres_finalize,
                           minres_init, minres_step, pipelined_cg,
                           pipelined_cg_init, pipelined_cg_step)
from repro.solvers.cg import PrecondCGState
from repro.solvers.minres import PrecondMinresState
from repro.solvers.precond import (extract_block_diag, factorize_blocks,
                                   make_preconditioner, parse_precond_spec)


@pytest.fixture(scope="module")
def ani():
    r, c, v, n = anisotropic_laplace2d(24, epsilon=1e-2)
    A = from_coo(r, c, v, (n, n), C=16, sigma=1, w_align=4, dtype=np.float32)
    Ad = np.zeros((n, n), np.float32)
    Ad[r, c] += v.astype(np.float32)
    return A, Ad, n


def _dense_permuted(A, Ad):
    """P A P^T on the padded permuted index space (padding rows zero)."""
    n = Ad.shape[0]
    perm = np.asarray(A.perm)
    out = np.zeros((A.nrows_pad, A.nrows_pad), np.float64)
    iv = np.nonzero(perm < n)[0]
    out[np.ix_(iv, iv)] = Ad.astype(np.float64)[np.ix_(perm[iv], perm[iv])]
    return out


class TestBlockExtraction:
    @pytest.mark.parametrize("sigma,bs", [(1, 4), (1, 16), (16, 8),
                                          (32, 16), (32, 4)])
    def test_blocks_match_dense_permuted(self, rng, sigma, bs):
        """Extraction must respect the sigma-sort row permutation: the
        blocks are the aligned diagonal blocks of P A P^T, the matrix the
        solvers actually iterate on."""
        n = 55
        a = ((rng.random((n, n)) < 0.15)
             * rng.standard_normal((n, n))).astype(np.float64)
        A = from_dense(a, C=16, sigma=sigma, w_align=2, dtype=np.float64)
        blocks = extract_block_diag(A, bs)
        want = _dense_permuted(A, a)
        nb = A.nrows_pad // bs
        for k in range(nb):
            np.testing.assert_allclose(
                blocks[k], want[k * bs:(k + 1) * bs, k * bs:(k + 1) * bs],
                atol=1e-5)

    def test_explicit_zeros_and_empty_rows(self):
        """Stored zeros keep their structural slot; empty rows do not
        break extraction."""
        # row 2 empty; explicit zero on the diagonal of row 1
        r = np.array([0, 0, 1, 3])
        c = np.array([0, 1, 1, 3])
        v = np.array([2.0, 1.0, 0.0, 5.0])
        A = from_coo(r, c, v, (4, 4), C=2, sigma=1)
        blocks = extract_block_diag(A, 2)
        want = np.array([[[2.0, 1.0], [0.0, 0.0]],
                         [[0.0, 0.0], [0.0, 5.0]]])
        np.testing.assert_allclose(blocks, want)

    def test_unpermuted_columns_path(self, rng):
        """External row_perm (permuted_cols=False): cols map through
        iperm during extraction."""
        n = 16
        a = np.diag(rng.random(n) + 1.0).astype(np.float64)
        a[0, 1] = a[1, 0] = 0.5
        ext = np.arange(n, dtype=np.int64)[::-1].copy()
        A = from_coo(*map(np.asarray, np.nonzero(a)), a[np.nonzero(a)],
                     (n, n), C=4, row_perm=ext)
        assert not A.permuted_cols
        blocks = extract_block_diag(A, 4)
        want = _dense_permuted(A, a)
        for k in range(n // 4):
            np.testing.assert_allclose(
                blocks[k], want[k * 4:(k + 1) * 4, k * 4:(k + 1) * 4],
                atol=1e-12)

    def test_bad_block_size(self, ani):
        A, _, _ = ani
        with pytest.raises(ValueError, match="must divide"):
            extract_block_diag(A, 7)
        with pytest.raises(ValueError, match="square"):
            rect = from_coo([0], [0], [1.0], (4, 6), C=2)
            extract_block_diag(rect, 2)

    def test_factorize_handles_empty_and_indefinite(self):
        blocks = np.zeros((3, 2, 2))
        blocks[0] = [[4.0, 1.0], [1.0, 4.0]]       # SPD -> Cholesky
        blocks[1] = [[0.0, 1.0], [1.0, 0.0]]       # indefinite -> LU
        # blocks[2] all-zero (padding rows)        # -> identity
        inv = factorize_blocks(blocks)
        np.testing.assert_allclose(inv[0] @ blocks[0], np.eye(2), atol=1e-12)
        np.testing.assert_allclose(inv[1] @ blocks[1], np.eye(2), atol=1e-12)
        np.testing.assert_allclose(inv[2], np.eye(2))


class TestBlockDiagKernel:
    @pytest.mark.parametrize("nb,bs,b", [(8, 16, 3), (5, 8, 1), (17, 4, 5)])
    def test_matches_ref(self, rng, nb, bs, b):
        blocks = rng.standard_normal((nb, bs, bs)).astype(np.float32)
        x = rng.standard_normal((nb * bs, b)).astype(np.float32)
        y = ops.block_jacobi_apply(jnp.asarray(blocks), jnp.asarray(x))
        want = block_diag_matmul_ref(jnp.asarray(blocks), jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

    def test_1d_and_forced_interpret(self, rng):
        blocks = rng.standard_normal((4, 8, 8)).astype(np.float32)
        x = rng.standard_normal(32).astype(np.float32)
        with execution.force(interpret=True):
            y = ops.block_jacobi_apply(jnp.asarray(blocks), jnp.asarray(x))
        assert y.shape == (32,)
        want = block_diag_matmul_ref(jnp.asarray(blocks),
                                     jnp.asarray(x)[:, None])[:, 0]
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

    def test_row_tile_snaps_to_block_multiple(self, rng):
        """Policy row_tile that is not a bs multiple must degrade, not
        corrupt."""
        blocks = rng.standard_normal((6, 24, 24)).astype(np.float32)
        x = rng.standard_normal((144, 2)).astype(np.float32)
        with execution.force(row_tile=64):        # 64 % 24 != 0
            y = ops.block_jacobi_apply(jnp.asarray(blocks), jnp.asarray(x))
        want = block_diag_matmul_ref(jnp.asarray(blocks), jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


class TestBlockJacobiCG:
    def test_iteration_reduction_and_solution(self, ani, rng):
        A, Ad, n = ani
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        plain = cg(op, b, tol=1e-6, maxiter=2000)
        M = BlockJacobiPreconditioner(A, block_size=24)   # line blocks
        pre = cg(op, b, tol=1e-6, maxiter=2000, M=M)
        assert bool(np.all(np.asarray(pre.converged)))
        assert int(pre.iters) * 2 <= int(plain.iters)
        x = np.asarray(A.unpermute(pre.x))
        bb = np.asarray(A.unpermute(b))
        assert np.abs(Ad @ x - bb).max() / np.abs(bb).max() < 1e-4

    def test_identity_blocks_match_plain_cg(self, ani, rng):
        """bs=1 block-Jacobi == diagonal (Jacobi); on a constant-diagonal
        matrix that is a scaled identity, so the iterates match plain CG
        to float tolerance (same Krylov space)."""
        A, Ad, n = ani
        op = make_operator(A)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        M = BlockJacobiPreconditioner(A, block_size=1)
        res = cg(op, b, tol=1e-6, maxiter=2000, M=M)
        ref = cg(op, b, tol=1e-6, maxiter=2000)
        # constant diagonal -> identical iteration counts
        assert abs(int(res.iters) - int(ref.iters)) <= 1
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                                   atol=1e-3)

    def test_chunked_equals_monolithic_precond(self, ani, rng):
        """The preconditioned stepper composes bit-identically too."""
        A, Ad, n = ani
        op = make_operator(A)
        M = BlockJacobiPreconditioner(A, block_size=8)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        ref = cg(op, b, tol=1e-7, maxiter=300, M=M)
        st = cg_init(op, b, tol=1e-7, maxiter=300, M=M)
        assert isinstance(st, PrecondCGState)
        for _ in range(300 // 7 + 1):
            st = cg_step(op, st, 7, M=M)
        res = cg_finalize(st)
        assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
        assert int(ref.iters) == int(res.iters)

    def test_step_rejects_mismatched_state(self, ani, rng):
        A, Ad, n = ani
        op = make_operator(A)
        M = BlockJacobiPreconditioner(A, block_size=8)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        plain_st = cg_init(op, b, tol=1e-6, maxiter=10)
        pre_st = cg_init(op, b, tol=1e-6, maxiter=10, M=M)
        with pytest.raises(ValueError, match="initialized without"):
            cg_step(op, plain_st, 5, M=M)
        with pytest.raises(ValueError, match="initialized with"):
            cg_step(op, pre_st, 5)

    def test_requires_sellcs(self):
        with pytest.raises(TypeError, match="SELL-C-sigma"):
            BlockJacobiPreconditioner(np.eye(4), block_size=2)

    def test_complex_hermitian_blocks(self, rng):
        """Complex matrices keep complex blocks (Hermitian Cholesky, L^H
        transposes) — a real cast would silently build the wrong M."""
        n = 32
        B = (rng.standard_normal((n, n))
             + 1j * rng.standard_normal((n, n)))
        H = (B @ B.conj().T + n * np.eye(n)).astype(np.complex64)
        r, c = np.nonzero(H)
        A = from_coo(r, c, H[r, c], (n, n), C=8, sigma=1,
                     dtype=np.complex64)
        M = BlockJacobiPreconditioner(A, block_size=8)
        assert np.iscomplexobj(np.asarray(M.inv_blocks))
        # block inverse really inverts the complex block
        blocks = extract_block_diag(A, 8)
        inv0 = np.asarray(M.inv_blocks, np.complex128)[0]
        np.testing.assert_allclose(inv0 @ blocks[0], np.eye(8), atol=1e-3)
        op = make_operator(A)
        b = A.permute((rng.standard_normal(n)
                       + 1j * rng.standard_normal(n)).astype(np.complex64))
        plain = cg(op, b, tol=1e-6, maxiter=500)
        pre = cg(op, b, tol=1e-6, maxiter=500, M=M)
        assert bool(pre.converged)
        assert int(pre.iters) <= int(plain.iters)
        x = np.asarray(A.unpermute(pre.x))
        bb = np.asarray(A.unpermute(b))
        assert np.abs(H @ x - bb).max() / np.abs(bb).max() < 1e-3


class TestPrecondMinres:
    def test_block_jacobi_minres(self, ani, rng):
        A, Ad, n = ani
        op = make_operator(A)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        plain = minres(op, b, tol=1e-6, maxiter=2000)
        M = BlockJacobiPreconditioner(A, block_size=24)
        pre = minres(op, b, tol=1e-6, maxiter=2000, M=M)
        assert bool(np.all(np.asarray(pre.converged)))
        assert int(pre.iters) * 2 <= int(plain.iters)
        x = np.asarray(A.unpermute(pre.x))
        bb = np.asarray(A.unpermute(b))
        assert np.abs(Ad @ x - bb).max() / np.abs(bb).max() < 1e-4

    def test_chunked_equals_monolithic(self, ani, rng):
        A, Ad, n = ani
        op = make_operator(A)
        M = BlockJacobiPreconditioner(A, block_size=8)
        b = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        ref = minres(op, b, tol=1e-6, maxiter=400, M=M)
        st = minres_init(op, b, tol=1e-6, maxiter=400, M=M)
        assert isinstance(st, PrecondMinresState)
        for _ in range(400 // 11 + 1):
            st = minres_step(op, st, 11, M=M)
        res = minres_finalize(st)
        assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
        assert int(ref.iters) == int(res.iters)

    def test_indefinite_matrix_absolute_value_preconditioner(self, rng):
        """MINRES requires an SPD M even over an indefinite matrix; the
        ``absolute=True`` factorization inverts |B_k| (flipped negative
        eigenvalues), the canonical SPD block-Jacobi for saddle-ish
        systems.  A plain (indefinite) block inverse must break down to
        x=0 rather than silently return garbage."""
        n = 64
        d = np.where(np.arange(n) % 2 == 0, 4.0, -4.0)
        a = np.diag(d).astype(np.float64)
        for i in range(n - 1):
            a[i, i + 1] = a[i + 1, i] = 0.7
        A = from_dense(a, C=8, sigma=1, dtype=np.float32)
        op = make_operator(A)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        M = BlockJacobiPreconditioner(A, block_size=2, absolute=True)
        res = minres(op, b, tol=1e-6, maxiter=500, M=M)
        assert bool(res.converged)
        x = np.asarray(A.unpermute(res.x))
        bb = np.asarray(A.unpermute(b))
        assert np.abs(a @ x - bb).max() / np.abs(bb).max() < 1e-4
        # |B|^{-1} really is SPD: quadratic form positive
        inv = np.asarray(M.inv_blocks, np.float64)
        z = rng.standard_normal((inv.shape[0], inv.shape[1]))
        quad = np.einsum("ki,kij,kj->k", z, inv, z)
        assert (quad > 0).all()


class TestChebyshev:
    def test_reduces_iterations(self, ani, rng):
        A, Ad, n = ani
        op = make_operator(A)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        lo, hi = lanczos_extrema(op, k=30, seed=0)
        M = ChebyshevPreconditioner(op, (lo, hi), degree=4)
        plain = cg(op, b, tol=1e-6, maxiter=2000)
        pre = cg(op, b, tol=1e-6, maxiter=2000, M=M)
        assert bool(pre.converged)
        assert int(pre.iters) * 2 <= int(plain.iters)

    def test_negative_lower_bound_clamped(self, ani):
        A, _, _ = ani
        op = make_operator(A)
        M = ChebyshevPreconditioner(op, (-5.0, 100.0), degree=3)
        assert M.lo > 0
        with pytest.raises(ValueError, match="SPD"):
            ChebyshevPreconditioner(op, (-5.0, -1.0))

    def test_apply_is_fixed_linear_operator(self, ani, rng):
        """p(A) must be linear and deterministic (PCG validity)."""
        A, _, n = ani
        op = make_operator(A)
        lo, hi = lanczos_extrema(op, k=30, seed=0)
        M = ChebyshevPreconditioner(op, (lo, hi), degree=5)
        u = A.permute(rng.standard_normal((n, 1)).astype(np.float32))
        v = A.permute(rng.standard_normal((n, 1)).astype(np.float32))
        lhs = M.apply(2.0 * u + 3.0 * v)
        rhs = 2.0 * M.apply(u) + 3.0 * M.apply(v)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(M.apply(u)),
                                      np.asarray(M.apply(u)))

    def test_does_not_pin_operator_in_chunk_cache(self, ani, rng):
        """The stepper chunk cache is weakly keyed on the operator but
        its jitted chunks close over M; an M holding the operator
        strongly would create an immortal value->key cycle.  Chebyshev
        therefore holds its operator weakly — dropping the operator must
        free the cache entry even after preconditioned chunks ran."""
        import gc
        import weakref
        A, _, n = ani
        op = make_operator(A)
        M = ChebyshevPreconditioner(op, (1.0, 50.0), degree=3)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        cg(op, b, tol=1e-4, maxiter=20, M=M)
        ref = weakref.ref(op)
        del op
        gc.collect()
        assert ref() is None, "chebyshev-preconditioned chunks pinned op"
        with pytest.raises(ReferenceError, match="garbage-collected"):
            M.apply(b)

    def test_composes_with_dist_operator(self, rng):
        """Chebyshev only calls mv_fused, so it runs on the heterogeneous
        engine's DistOperator (and its halo pipeline) unchanged."""
        from repro.runtime import HeterogeneousEngine
        r, c, v, n = laplace3d(6)
        eng = HeterogeneousEngine(r, c, v, n, C=8, sigma=16, w_align=4,
                                  dtype=np.float32)
        op = eng.operator()
        lo, hi = lanczos_extrema(op, k=20, seed=0)
        M = ChebyshevPreconditioner(op, (lo, hi), degree=3)
        b = rng.standard_normal(n).astype(np.float32)
        bop = op.to_op_space(jnp.asarray(b))
        res = cg(op, bop, tol=1e-6, maxiter=500, M=M)
        assert bool(res.converged)
        Ad = np.zeros((n, n), np.float32)
        Ad[r, c] += v.astype(np.float32)
        x = np.asarray(op.from_op_space(res.x))
        assert np.abs(Ad @ x - b).max() / np.abs(b).max() < 1e-4


class TestPipelinedCGPrecondRegression:
    def test_raises_instead_of_silently_ignoring(self, ani, rng):
        """pipelined_cg used to claim 'identity precond.' with no way to
        even ask for one; now M= raises loudly at every entry point."""
        A, _, n = ani
        op = make_operator(A)
        M = BlockJacobiPreconditioner(A, block_size=8)
        b = A.permute(rng.standard_normal(n).astype(np.float32))
        with pytest.raises(NotImplementedError, match="pipelined_cg"):
            pipelined_cg(op, b, M=M)
        with pytest.raises(NotImplementedError, match="pipelined_cg"):
            pipelined_cg_init(op, b, M=M)
        st = pipelined_cg_init(op, b)
        with pytest.raises(NotImplementedError, match="pipelined_cg"):
            pipelined_cg_step(op, st, 5, M=M)
        # M=None keeps working (loose tol: pipelined CG's single-sweep
        # recurrence drifts in f32 on this ill-conditioned matrix)
        res = pipelined_cg(op, b, tol=1e-3, maxiter=1000)
        assert bool(res.converged)


class TestSpecParsing:
    def test_specs(self):
        assert parse_precond_spec("block_jacobi") == ("block_jacobi", None)
        assert parse_precond_spec("block_jacobi:8") == ("block_jacobi", 8)
        assert parse_precond_spec("block_jacobi_abs:4") == \
            ("block_jacobi_abs", 4)
        assert parse_precond_spec("chebyshev:6") == ("chebyshev", 6)
        # resolvable defaults normalize: one cache entry / batch key for
        # "chebyshev" and "chebyshev:4"
        assert parse_precond_spec("chebyshev") == \
            parse_precond_spec("chebyshev:4")
        for bad in ("", "ilu", "chebyshev:x", "block_jacobi:-2", None):
            with pytest.raises(ValueError):
                parse_precond_spec(bad)

    def test_make_preconditioner(self, ani):
        A, _, _ = ani
        M = make_preconditioner("block_jacobi:8", matrix=A)
        assert M.block_size == 8
        with pytest.raises(ValueError, match="needs op="):
            make_preconditioner("chebyshev")
