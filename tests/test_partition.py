"""Weighted partitioning (paper C4), RCM bandwidth reduction, coloring."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import partition as pt
from repro.matrices import laplace2d, banded_random


class TestWeightedPartition:
    def test_equal_weights(self):
        ranges = pt.weighted_row_partition(100, [1, 1, 1, 1])
        assert ranges == [(0, 25), (25, 50), (50, 75), (75, 100)]

    def test_proportional(self):
        """Paper section 4.1: CPU:GPU 1:2.75 bandwidth split."""
        ranges = pt.weighted_row_partition(1000, [1.0, 2.75])
        s0 = ranges[0][1] - ranges[0][0]
        s1 = ranges[1][1] - ranges[1][0]
        assert abs(s1 / s0 - 2.75) < 0.1

    def test_alignment(self):
        ranges = pt.weighted_row_partition(1000, [1, 1.7, 0.4], align=32)
        for s, e in ranges[:-1]:
            assert s % 32 == 0

    def test_nnz_partition_balances_nonzeros(self, rng):
        rowlen = np.concatenate([np.full(100, 50), np.full(900, 5)])
        ranges = pt.weighted_nnz_partition(rowlen, [1, 1])
        nnz = [rowlen[s:e].sum() for s, e in ranges]
        assert abs(nnz[0] - nnz[1]) / sum(nnz) < 0.05

    def test_bad_weights(self):
        with pytest.raises(ValueError):
            pt.weighted_row_partition(10, [1, -1])


@settings(max_examples=30, deadline=None)
@given(n=st.integers(10, 5000),
       ws=st.lists(st.floats(0.1, 10), min_size=1, max_size=8))
def test_property_partition_covers(n, ws):
    """Property: ranges tile [0, n) exactly, in order."""
    ranges = pt.weighted_row_partition(n, ws)
    assert ranges[0][0] == 0 and ranges[-1][1] == n
    for (s0, e0), (s1, e1) in zip(ranges, ranges[1:]):
        assert e0 == s1
        assert s0 <= e0


class TestRCM:
    def test_reduces_bandwidth(self):
        rng = np.random.default_rng(0)
        n = 300
        # random permutation of a banded matrix -> RCM should recover a band
        r, c, v, _ = banded_random(n, bw=4, density=1.0, seed=1, sym=True)
        p = rng.permutation(n)
        rp, cp = p[r], p[c]
        bw0 = pt.bandwidth(rp, cp)
        perm = pt.rcm_permutation(rp, cp, n)
        inv = np.empty(n, np.int64)
        inv[perm] = np.arange(n)
        bw1 = pt.bandwidth(inv[rp], inv[cp])
        assert bw1 < bw0

    def test_is_permutation(self):
        r, c, v, n = laplace2d(8)
        perm = pt.rcm_permutation(r, c, n)
        assert sorted(perm.tolist()) == list(range(n))


class TestColoring:
    def test_valid_coloring(self):
        r, c, v, n = laplace2d(6)
        color = pt.greedy_coloring(r, c, n)
        off = r != c
        assert (color[r[off]] != color[c[off]]).all()
        # 2D laplacian is bipartite: greedy should need exactly 2 colors
        assert color.max() == 1
