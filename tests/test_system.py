"""End-to-end system behaviour: public-API scenarios from the paper, plus
dry-run tooling units (collective parsing, sharding rules)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.core import SpmvOpts, from_coo, ghost_spmv
from repro.matrices import matpde
from repro.solvers import cg, make_operator


class TestPaperScenarios:
    def test_matpde_krylov_case_study(self, rng):
        """Paper section 6.1 in miniature: MATPDE + Krylov solve through
        the GHOST public API."""
        r, c, v, n = matpde(16, beta_c=0.0)       # symmetric variant -> CG
        A = from_coo(r, c, v, (n, n), C=16, sigma=32, w_align=4,
                     dtype=np.float32)
        assert A.beta > 0.5                       # sigma-sorting keeps padding sane
        op = make_operator(A, impl="pallas")
        b = rng.standard_normal((n, 2)).astype(np.float32)
        res = cg(op, A.permute(b), tol=1e-6, maxiter=600)
        assert bool(np.asarray(res.converged).all())

    def test_single_interface_spmv(self, rng):
        """Paper listing: one ghost_spmv interface, augmentations by opts."""
        n = 64
        a = ((rng.random((n, n)) < 0.2)
             * rng.standard_normal((n, n))).astype(np.float32)
        r, c = np.nonzero(a)
        A = from_coo(r, c, a[r, c], (n, n), C=8, sigma=16, w_align=4)
        x = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        y0 = A.permute(rng.standard_normal((n, 2)).astype(np.float32))
        # plain
        y, _, _ = ghost_spmv(A, x)
        # vshift + axpby + dot, both impls agree
        opts = SpmvOpts(alpha=1.0, beta=-2.0,
                        gamma=jnp.asarray([0.3, -0.6]), dot_xy=True)
        yr, _, dr = ghost_spmv(A, x, y0, opts=opts, impl="ref")
        yk, _, dk = ghost_spmv(A, x, y0, opts=opts, impl="pallas")
        np.testing.assert_allclose(np.asarray(yr), np.asarray(yk), atol=1e-4)
        np.testing.assert_allclose(np.asarray(dr), np.asarray(dk), rtol=1e-3)


class TestDryrunTooling:
    def test_parse_collectives(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
  %ag = bf16[32,1024] all-gather(%x), replica_groups={}
  %ar.1 = f32[128] all-reduce(%y), to_apply=%sum
  %t = (f32[64], f32[256]) all-gather-start(%z)
  %d = f32[256] all-gather-done(%t)
  %rs = bf16[16,16] reduce-scatter(%w)
  %cp = f32[8] collective-permute(%v)
  %aa = f32[4,4] all-to-all(%u)
"""
        out = parse_collectives(hlo)
        assert out["all-gather"]["count"] == 2
        assert out["all-gather"]["bytes"] == 32 * 1024 * 2 + 256 * 4
        assert out["all-reduce"]["bytes"] == 128 * 4
        assert out["reduce-scatter"]["bytes"] == 16 * 16 * 2
        assert out["collective-permute"]["bytes"] == 8 * 4
        assert out["all-to-all"]["bytes"] == 16 * 4

    def test_sharding_rules_divisibility_guard(self):
        from repro.models import sharding as SH
        mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
        spec = SH.guard_spec(P("data", "model"), (7, 13), mesh)
        assert spec == P("data", "model")         # size-1 axes always divide

    def test_param_specs_cover_all_leaves(self):
        from repro.models import sharding as SH
        from repro.models import transformer as T
        mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
        for arch in ("qwen2_5_3b", "jamba_1_5_large_398b", "xlstm_1_3b",
                     "whisper_medium"):
            cfg = get_smoke_config(arch)
            pshape = jax.eval_shape(
                lambda cfg=cfg: T.init_params(cfg, jax.random.PRNGKey(0)))
            specs = SH.param_specs(cfg, pshape, mesh)
            flat_shape = jax.tree.leaves(pshape)
            flat_spec = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_shape) == len(flat_spec)
            for sh_, sp in zip(flat_shape, flat_spec):
                assert len(sp) <= sh_.ndim

    def test_mesh_factories(self):
        from repro.launch.mesh import make_host_mesh
        m = make_host_mesh()
        assert m.axis_names == ("data", "model")
        assert m.size == 1


class TestEndToEndTraining:
    def test_train_lm_smoke(self, tmp_path):
        """examples/train_lm.py path: a tiny LM trains and the loss drops."""
        from repro.train.trainer import TrainConfig, Trainer
        mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
        cfg = get_smoke_config("llama3_2_3b")
        tc = TrainConfig(lr=2e-3, warmup=3, total_steps=30,
                         ckpt_dir=str(tmp_path), ckpt_every=1000,
                         log_every=1000)
        tr = Trainer(cfg, tc, mesh, seq_len=32, global_batch=8)
        out = tr.fit(20)
        assert np.mean(out["losses"][-3:]) < np.mean(out["losses"][:3])
