"""MoE layer: GHOST sparse dispatch vs dense one-hot equivalence, capacity
semantics, load-balance loss."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.moe import MoEConfig, moe_apply, moe_init


@pytest.fixture
def setup():
    key = jax.random.PRNGKey(0)
    d, f = 32, 64
    cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0)  # ample cap
    params = moe_init(key, d, f, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d), jnp.float32)
    return cfg, params, x, d, f


class TestDispatchEquivalence:
    def test_ghost_equals_dense(self, setup):
        """With ample capacity the sparse (sort+gather) dispatch and the
        dense one-hot dispatch are the same linear operator."""
        cfg, params, x, d, f = setup
        yg, _ = moe_apply(params, x, dataclasses.replace(cfg, ghost_dispatch=True))
        yd, _ = moe_apply(params, x, dataclasses.replace(cfg, ghost_dispatch=False))
        np.testing.assert_allclose(np.asarray(yg), np.asarray(yd),
                                   atol=1e-4, rtol=1e-4)

    def test_top1(self, setup):
        cfg, params, x, d, f = setup
        c1 = dataclasses.replace(cfg, top_k=1)
        yg, _ = moe_apply(params, x, dataclasses.replace(c1, ghost_dispatch=True))
        yd, _ = moe_apply(params, x, dataclasses.replace(c1, ghost_dispatch=False))
        np.testing.assert_allclose(np.asarray(yg), np.asarray(yd),
                                   atol=1e-4, rtol=1e-4)

    def test_manual_reference(self):
        """Tiny case checked against an explicit per-token loop."""
        key = jax.random.PRNGKey(3)
        d, f = 8, 16
        cfg = MoEConfig(n_experts=2, top_k=1, capacity_factor=8.0)
        params = moe_init(key, d, f, cfg, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 6, d), jnp.float32)
        y, _ = moe_apply(params, x, cfg)

        xt = np.asarray(x).reshape(6, d)
        logits = xt @ np.asarray(params["router"])
        eid = logits.argmax(-1)
        wi = np.asarray(params["wi"]); wg = np.asarray(params["wg"])
        wo = np.asarray(params["wo"])
        ref = np.zeros_like(xt)
        for t in range(6):
            e = eid[t]
            h = xt[t] @ wi[e]
            g = xt[t] @ wg[e]
            silu = g / (1 + np.exp(-g))
            ref[t] = (silu * h) @ wo[e]
        np.testing.assert_allclose(np.asarray(y).reshape(6, d), ref,
                                   atol=1e-4, rtol=1e-4)


class TestCapacity:
    def test_drop_zeroes_contribution(self):
        """Tokens over capacity contribute nothing (not garbage)."""
        key = jax.random.PRNGKey(5)
        d, f = 16, 32
        cfg = MoEConfig(n_experts=2, top_k=1, capacity_factor=0.25)  # tight
        params = moe_init(key, d, f, cfg, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, d), jnp.float32)
        y, _ = moe_apply(params, x, cfg)
        assert np.isfinite(np.asarray(y)).all()
        # some tokens must have been dropped -> some rows ~ 0
        norms = np.linalg.norm(np.asarray(y).reshape(16, d), axis=-1)
        assert (norms < 1e-6).any()


class TestAux:
    def test_load_balance_positive(self, setup):
        cfg, params, x, d, f = setup
        _, aux = moe_apply(params, x, cfg)
        assert float(aux["load_balance"]) >= 1.0 - 1e-3  # >= 1 at optimum

    def test_grads_flow_through_router(self, setup):
        cfg, params, x, d, f = setup

        def loss(p):
            y, aux = moe_apply(p, x, cfg)
            return jnp.sum(y * y) + aux["load_balance"]

        g = jax.grad(loss)(params)
        assert float(jnp.sum(jnp.abs(g["router"]))) > 0
        assert float(jnp.sum(jnp.abs(g["wi"]))) > 0
