"""Training substrate: optimizers, loss descent, checkpoint/restart,
elastic mesh restore, gradient compression."""
import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from conftest import run_with_devices
from repro.configs import get_smoke_config
from repro.train import optimizer as OPT
from repro.train.checkpoint import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.trainer import TrainConfig, Trainer


class TestOptimizers:
    @pytest.mark.parametrize("kind", ["adamw", "adafactor"])
    def test_descends_quadratic(self, kind):
        opt = OPT.make_optimizer(kind)
        params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params, 0.05)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_adamw_matrix_decay_only(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        state = OPT.adamw_init(params)
        g = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        p2, _ = OPT.adamw_update(g, state, params, 0.1, weight_decay=0.5)
        assert float(p2["w"][0, 0]) < 1.0      # decayed
        assert float(p2["b"][0]) == 1.0        # not decayed

    def test_clip_global_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = OPT.clip_by_global_norm(g, 1.0)
        total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
        assert abs(total - 1.0) < 1e-5

    def test_warmup_cosine(self):
        lr = OPT.warmup_cosine(1.0, 10, 100)
        assert float(lr(0)) == 0.0
        assert abs(float(lr(10)) - 1.0) < 0.11
        assert float(lr(100)) < float(lr(50))

    def test_int8_roundtrip_error(self, rng):
        x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, s = OPT.quantize_int8(x)
        xr = OPT.dequantize_int8(q, s)
        rel = float(jnp.abs(xr - x).max() / jnp.abs(x).max())
        assert rel < 1.0 / 127 + 1e-3

    def test_compressed_psum_multidevice(self):
        run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.train.optimizer import compressed_psum
from repro.core.distributed import shard_map   # version-compat shim
mesh = Mesh(np.array(jax.devices()).reshape(4), ("pod",))
x = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8) / 7.0

def f(xs):
    return compressed_psum(xs[0], "pod", bits=8)[None]

y = shard_map(f, mesh=mesh, in_specs=(P("pod", None),),
              out_specs=P("pod", None))(x)
ref = x.sum(0)
err = float(jnp.abs(np.asarray(y)[0] - ref).max())
assert err < 0.2, err
print("SUBPROCESS_OK")
""", 4)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path, rng):
        tree = {"a": jnp.asarray(rng.standard_normal((4, 5)), jnp.float32),
                "b": {"c": jnp.arange(7)}}
        save_checkpoint(str(tmp_path), 3, tree)
        like = jax.eval_shape(lambda: tree)
        restored, man = restore_checkpoint(str(tmp_path), 3, like)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))
        assert man["step"] == 3

    def test_latest_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), every=1, keep=2)
        tree = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.maybe_save(s, tree)
        assert latest_step(str(tmp_path)) == 4
        steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert steps == [3, 4]

    def test_corrupt_tmp_ignored(self, tmp_path):
        os.makedirs(tmp_path / "step_9.tmp")
        save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
        assert latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
        like = jax.eval_shape(lambda: {"x": jnp.zeros(3)})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1, like)


class TestTrainerFT:
    def _mk(self, tmp, steps=40):
        mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
        cfg = get_smoke_config("qwen2_5_3b")
        tc = TrainConfig(lr=1e-3, warmup=5, total_steps=steps,
                         ckpt_dir=str(tmp), ckpt_every=5, log_every=100)
        return Trainer(cfg, tc, mesh, seq_len=24, global_batch=4)

    def test_loss_descends(self, tmp_path):
        tr = self._mk(tmp_path)
        out = tr.fit(25)
        first = np.mean(out["losses"][:3])
        last = np.mean(out["losses"][-3:])
        assert last < first, (first, last)

    def test_kill_and_restart_resumes_exactly(self, tmp_path):
        """Fault tolerance: a fresh Trainer (simulated restart after crash)
        resumes from the checkpoint and continues the same trajectory."""
        tr1 = self._mk(tmp_path)
        out1 = tr1.fit(10)                    # ckpt at step 10
        # crash: throw away the trainer; build a brand-new one
        tr2 = self._mk(tmp_path)
        out2 = tr2.fit(12)                    # resumes at 10, runs 10..11
        assert len(out2["losses"]) == 2
        # determinism: a run straight to 12 gives the same final loss
        shutil.rmtree(tmp_path)
        tr3 = self._mk(tmp_path)
        out3 = tr3.fit(12)
        np.testing.assert_allclose(out2["losses"][-1], out3["losses"][-1],
                                   rtol=1e-4, atol=1e-5)

    def test_elastic_mesh_restore(self, tmp_path):
        """Save on a (2,2) mesh, restore on (4,1): checkpoints are logical
        arrays, re-laid-out onto whatever mesh the restarted job has."""
        run_with_devices(f"""
import numpy as np, jax, shutil
from jax.sharding import Mesh
from repro.configs import get_smoke_config
from repro.train.trainer import Trainer, TrainConfig

cfg = get_smoke_config("qwen2_5_3b")
tmp = "{tmp_path}/elastic"
mesh1 = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
tc = TrainConfig(lr=1e-3, warmup=2, total_steps=10, ckpt_dir=tmp,
                 ckpt_every=4, log_every=100)
t1 = Trainer(cfg, tc, mesh1, seq_len=16, global_batch=4)
o1 = t1.fit(6)

mesh2 = Mesh(np.array(jax.devices()).reshape(4, 1), ("data", "model"))
t2 = Trainer(cfg, tc, mesh2, seq_len=16, global_batch=4)
o2 = t2.fit(8)          # resumes the step-6 final ckpt on the NEW mesh
assert len(o2["losses"]) == 2
assert all(np.isfinite(o2["losses"]))
print("SUBPROCESS_OK")
""", 4)


class TestData:
    def test_deterministic_across_restart(self):
        from repro.data.pipeline import SyntheticLM
        d1 = SyntheticLM(100, 16, 4, seed=7)
        d2 = SyntheticLM(100, 16, 4, seed=7)
        b1 = d1.batch(13)
        b2 = d2.batch(13)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])

    def test_labels_are_shifted_tokens(self):
        from repro.data.pipeline import SyntheticLM
        b = SyntheticLM(50, 8, 2, seed=1).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_has_learnable_structure(self):
        from repro.data.pipeline import SyntheticLM
        b = SyntheticLM(1000, 512, 8, seed=0, structure=0.5).batch(0)
        t = b["tokens"]
        copies = (t[:, 2:] == t[:, :-2]).mean()
        assert copies > 0.3
