"""Property-based differential tests for SELL-C-sigma construction/SpMV.

Hypothesis sweeps (C, sigma, w_align, dtype, explicit stored zeros, empty
rows, empty matrices) asserting that ``from_coo`` / ``from_csr`` /
``from_callback`` agree with each other, round-trip through ``to_dense``,
and match a dense SpMV reference.  The shared check helpers double as
deterministic edge-case tests, so the differential coverage survives even
when ``hypothesis`` is missing (the ``tests/conftest.py`` shim then skips
only the ``@given`` sweeps)."""
import jax
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import from_callback, from_coo, from_csr, spmv_ref, to_dense


# --------------------------------------------------------------- helpers
def _dense_of(rows, cols, vals, shape, dtype):
    d = np.zeros(shape, dtype)
    np.add.at(d, (rows, cols), vals.astype(dtype))
    return d


def _csr_of(rows, cols, vals, nrows):
    order = np.lexsort((cols, rows))
    r, c, v = rows[order], cols[order], vals[order]
    indptr = np.zeros(nrows + 1, np.int64)
    np.add.at(indptr, r + 1, 1)
    return np.cumsum(indptr), c, v


def _rowfunc_of(rows, cols, vals):
    by_row = {}
    for r, c, v in zip(rows, cols, vals):
        by_row.setdefault(int(r), ([], []))
        by_row[int(r)][0].append(int(c))
        by_row[int(r)][1].append(v)

    def rowfunc(i):
        c, v = by_row.get(i, ([], []))
        return np.asarray(c, np.int64), np.asarray(v)

    return rowfunc


def check_differential(rows, cols, vals, shape, *, C, sigma, w_align,
                       dtype):
    """The property: all three constructions agree, round-trip through
    to_dense, and SpMV matches the dense reference."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals, dtype)
    nrows, ncols = shape
    kw = dict(C=C, sigma=sigma, w_align=w_align, dtype=dtype)

    m_coo = from_coo(rows, cols, vals, shape, **kw)
    indptr, ci, vi = _csr_of(rows, cols, vals, nrows)
    m_csr = from_csr(indptr, ci, vi, shape, **kw)
    maxnz = int(max([1] + np.bincount(rows,
                                      minlength=1).tolist())) if rows.size \
        else 1
    m_cb = from_callback(_rowfunc_of(rows, cols, vals), nrows, ncols,
                         maxnz_per_row=maxnz, **kw)

    dense = _dense_of(rows, cols, vals, shape, dtype)
    for m in (m_coo, m_csr, m_cb):
        # identical metadata and storage geometry
        assert m.nnz == m_coo.nnz
        assert m.cap == m_coo.cap
        assert m.shape == tuple(shape)
        np.testing.assert_array_equal(np.asarray(m.chunk_len),
                                      np.asarray(m_coo.chunk_len))
        np.testing.assert_array_equal(np.asarray(m.perm),
                                      np.asarray(m_coo.perm))
        np.testing.assert_array_equal(m.nnz_per_row(), m_coo.nnz_per_row())
        # round-trip (exact: unique coordinates, low-entropy values)
        np.testing.assert_array_equal(to_dense(m), dense)
        # chunk widths honor the alignment pad
        cl = np.asarray(m.chunk_len)
        assert cl.size == 0 or (cl % w_align == 0).all()
        assert m.nnz_per_row().sum() == m.nnz
    # stored entries (incl. explicit zeros) all counted
    assert m_coo.nnz == rows.size
    assert int(m_coo.valid_slots().sum()) == rows.size

    # SpMV differential vs dense (block vector exercises the b axis).
    # spmv_ref's vectors live in permuted space padded to nrows_pad, so
    # the matvec leg applies to square matrices; rectangular structure is
    # still fully checked by the to_dense round-trip above.
    if nrows == ncols and nrows:
        rng = np.random.default_rng(abs(hash((nrows, ncols, rows.size))) %
                                    (2 ** 31))
        x = rng.standard_normal((ncols, 2)).astype(dtype)
        for m in (m_coo, m_csr, m_cb):
            y = m.unpermute(spmv_ref(m, m.permute(x))[0])
            np.testing.assert_allclose(np.asarray(y), dense @ x,
                                       atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------ strategies
@st.composite
def coo_problems(draw):
    """Random COO with unique coordinates, a slice of explicit zeros,
    guaranteed-empty rows, and occasionally an entirely empty matrix."""
    nrows = draw(st.integers(1, 70))
    square = draw(st.booleans())
    ncols = nrows if square else draw(st.integers(1, 70))
    C = draw(st.sampled_from([1, 2, 4, 8, 16]))
    sigma = C * draw(st.sampled_from([0, 1, 2, 4]))  # 0 -> unsorted
    sigma = max(sigma, 1)
    w_align = draw(st.sampled_from([1, 2, 4]))
    dtype = draw(st.sampled_from([np.float32, np.float64]))
    nnz_cap = nrows * ncols
    nnz = draw(st.integers(0, min(200, nnz_cap)))    # 0 == empty matrix
    lin = draw(st.lists(st.integers(0, nnz_cap - 1), min_size=nnz,
                        max_size=nnz, unique=True))
    lin = np.asarray(lin, np.int64)
    rows, cols = lin // ncols, lin % ncols
    # low-entropy values: exact in f32, includes explicit stored zeros
    vals = np.asarray(draw(st.lists(st.integers(-4, 4), min_size=nnz,
                                    max_size=nnz)), np.float64) / 2.0
    return (rows, cols, vals, (nrows, ncols), C, sigma, w_align, dtype)


@settings(max_examples=30, deadline=None)
@given(problem=coo_problems())
def test_property_constructions_agree(problem):
    rows, cols, vals, shape, C, sigma, w_align, dtype = problem
    check_differential(rows, cols, vals, shape, C=C, sigma=sigma,
                       w_align=w_align, dtype=dtype)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 50), C=st.sampled_from([2, 4, 8]),
       sigf=st.sampled_from([1, 2, 8]), seed=st.integers(0, 2 ** 31 - 1))
def test_property_explicit_zero_rows_and_diag(n, C, sigf, seed):
    """Ragged structure with a fully-zero stored diagonal: stored zeros
    must survive construction on every path."""
    rng = np.random.default_rng(seed)
    i = np.arange(n, dtype=np.int64)
    keep = rng.random(n) < 0.6                  # ~40% structurally empty rows
    rows = np.concatenate([i, i[keep]])
    cols = np.concatenate([i, ((i + 1) % n)[keep]])
    vals = np.concatenate([np.zeros(n), rng.integers(1, 5, keep.sum())
                           .astype(np.float64)])
    uniq = rows * n + cols
    _, first = np.unique(uniq, return_index=True)
    rows, cols, vals = rows[first], cols[first], vals[first]
    check_differential(rows, cols, vals, (n, n), C=C, sigma=C * sigf,
                       w_align=2, dtype=np.float32)


# ----------------------------------------------- deterministic edge cases
class TestDifferentialEdgeCases:
    """The same checks, pinned on the corners hypothesis may not hit —
    these run even without hypothesis installed."""

    def test_empty_matrix(self):
        check_differential([], [], [], (12, 12), C=4, sigma=8, w_align=2,
                           dtype=np.float32)

    def test_empty_matrix_single_chunk(self):
        check_differential([], [], [], (3, 5), C=8, sigma=1, w_align=4,
                           dtype=np.float64)

    def test_all_explicit_zeros(self):
        check_differential([0, 1, 2], [2, 0, 1], [0.0, 0.0, 0.0], (4, 4),
                           C=2, sigma=4, w_align=2, dtype=np.float32)

    def test_empty_rows_interleaved(self):
        # rows 1 and 3 empty; sigma sorting must keep them addressable
        check_differential([0, 0, 2, 4], [0, 3, 2, 1],
                           [1.0, -2.0, 3.0, 0.5], (5, 5),
                           C=2, sigma=4, w_align=1, dtype=np.float32)

    def test_single_row_wide(self):
        check_differential([0] * 6, [0, 2, 4, 6, 8, 9], [1, 2, 0, 4, 5, 6],
                           (1, 10), C=4, sigma=1, w_align=4,
                           dtype=np.float64)

    def test_rows_exceed_C_with_alignment(self):
        n = 21                                   # nrows_pad = 32 at C=16
        i = np.arange(n)
        check_differential(i, i[::-1].copy(), np.ones(n), (n, n),
                           C=16, sigma=16, w_align=4, dtype=np.float32)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtype_preserved(self, dtype):
        m = from_coo([0, 1], [1, 0], [1.5, -0.5], (2, 2), C=2, sigma=1,
                     dtype=dtype)
        want = jnp.asarray(np.zeros(0, dtype)).dtype  # canonicalized
        assert m.vals.dtype == want


# --------------------------------------------- mixed-precision storage axis
STORE_TOL = {
    "float32": 1e-5,            # storage == compute: construction-exact
    "float16": 2e-3,
    "bfloat16": 2e-2,
}


def _mixed_problem(seed=0, n=57):
    """Random square COO with stored zeros and empty rows (f32 compute)."""
    rng = np.random.default_rng(seed)
    d = (rng.random((n, n)) < 0.15) * rng.standard_normal((n, n))
    d[:, 3] = 0.0                                   # structural col untouched
    rows, cols = np.nonzero(d)
    vals = d[rows, cols]
    # a few explicit stored zeros on the diagonal
    zr = np.arange(0, n, 11)
    rows = np.concatenate([rows, zr])
    cols = np.concatenate([cols, zr])
    vals = np.concatenate([vals, np.zeros(len(zr))])
    uniq = rows * n + cols
    _, first = np.unique(uniq, return_index=True)
    return rows[first], cols[first], vals[first], n


class TestStoreDtype:
    """The storage-dtype axis: vals narrower than the compute dtype."""

    @pytest.mark.parametrize("store", [jnp.bfloat16, jnp.float16,
                                       jnp.float32])
    @pytest.mark.parametrize("C,sigma,w_align", [
        (4, 8, 2), (8, 8, 1), (16, 32, 4), (2, 1, 2),
    ])
    def test_spmv_matches_f64_reference(self, store, C, sigma, w_align):
        """For each store_dtype, SpMV matches the f64 dense reference
        within a dtype-appropriate tolerance across C/sigma/w_align and
        stored zeros (the ISSUE's differential contract)."""
        rows, cols, vals, n = _mixed_problem(seed=C * 100 + sigma)
        m = from_coo(rows, cols, vals, (n, n), C=C, sigma=sigma,
                     w_align=w_align, dtype=np.float32, store_dtype=store)
        sname = str(jnp.dtype(store))
        assert m.vals.dtype == jnp.dtype(store)
        assert m.dtype == jnp.float32                # compute dtype
        # geometry is storage-independent
        m_full = from_coo(rows, cols, vals, (n, n), C=C, sigma=sigma,
                          w_align=w_align, dtype=np.float32)
        assert m.cap == m_full.cap and m.nnz == m_full.nnz
        np.testing.assert_array_equal(np.asarray(m.perm),
                                      np.asarray(m_full.perm))
        # f64 dense reference (exact coordinates, rounded values)
        dense64 = np.zeros((n, n), np.float64)
        np.add.at(dense64, (rows, cols), vals)
        rng = np.random.default_rng(5)
        x = rng.standard_normal((n, 3)).astype(np.float32)
        y = m.unpermute(spmv_ref(m, m.permute(x))[0])
        assert np.asarray(y).dtype == np.float32     # accumulated in compute
        ref = dense64 @ x.astype(np.float64)
        scale = max(1.0, np.abs(ref).max())
        err = np.abs(np.asarray(y, np.float64) - ref).max() / scale
        assert err < STORE_TOL[sname], (sname, err)
        # to_dense upcasts to the compute dtype and keeps stored zeros
        d = to_dense(m)
        assert d.dtype == np.float32
        assert int(m.valid_slots().sum()) == rows.size

    @pytest.mark.parametrize("store", [jnp.bfloat16, jnp.float16])
    def test_constructions_agree_on_storage(self, store):
        """from_coo / from_csr / from_callback produce bit-identical
        narrow storage (rounding happens once, after dedup)."""
        rows, cols, vals, n = _mixed_problem(seed=3)
        kw = dict(C=8, sigma=16, w_align=2, dtype=np.float32,
                  store_dtype=store)
        m_coo = from_coo(rows, cols, vals, (n, n), **kw)
        indptr, ci, vi = _csr_of(rows, cols, vals, n)
        m_csr = from_csr(indptr, ci, vi, (n, n), **kw)
        maxnz = int(np.bincount(rows, minlength=1).max())
        m_cb = from_callback(_rowfunc_of(rows, cols, vals), n, n,
                             maxnz_per_row=maxnz, **kw)
        for m in (m_csr, m_cb):
            assert m.vals.dtype == jnp.dtype(store)
            assert m.compute_dtype == m_coo.compute_dtype == "float32"
            np.testing.assert_array_equal(
                np.asarray(m.vals, np.float32),
                np.asarray(m_coo.vals, np.float32))

    def test_store_none_bit_identical_to_classic_layout(self):
        """store_dtype=None pins bit-identity with today's arrays: the
        construction output and spmv_ref both reproduce the classic
        single-dtype formulas exactly."""
        rows, cols, vals, n = _mixed_problem(seed=9)
        m_def = from_coo(rows, cols, vals, (n, n), C=8, sigma=16,
                         w_align=2, dtype=np.float32)
        m_none = from_coo(rows, cols, vals, (n, n), C=8, sigma=16,
                          w_align=2, dtype=np.float32, store_dtype=None)
        assert m_def.compute_dtype is None and m_none.compute_dtype is None
        assert m_def.dtype == m_def.store_dtype == jnp.float32
        for a, b in zip(jax.tree_util.tree_leaves(m_def),
                        jax.tree_util.tree_leaves(m_none)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # spmv_ref == the pre-storage-axis segment-sum formula, bit-exact
        rng = np.random.default_rng(2)
        x = m_def.permute(rng.standard_normal((n, 2)).astype(np.float32))
        y_new = np.asarray(spmv_ref(m_def, x)[0])
        contrib = m_def.vals[:, None] * jnp.asarray(x)[m_def.cols]
        y_old = np.asarray(jax.ops.segment_sum(
            contrib, m_def.rowids, num_segments=m_def.nrows_pad))
        np.testing.assert_array_equal(y_new, y_old)

    def test_explicit_f32_storage_bit_identical_values(self):
        """store_dtype == compute dtype records the axis but must not
        change a single stored bit or SpMV bit."""
        rows, cols, vals, n = _mixed_problem(seed=4)
        kw = dict(C=8, sigma=16, w_align=2, dtype=np.float32)
        m0 = from_coo(rows, cols, vals, (n, n), **kw)
        m1 = from_coo(rows, cols, vals, (n, n), store_dtype=np.float32,
                      **kw)
        assert m1.compute_dtype == "float32"
        np.testing.assert_array_equal(np.asarray(m0.vals),
                                      np.asarray(m1.vals))
        rng = np.random.default_rng(1)
        x = m0.permute(rng.standard_normal(n).astype(np.float32))
        np.testing.assert_array_equal(np.asarray(spmv_ref(m0, x)[0]),
                                      np.asarray(spmv_ref(m1, x)[0]))

    def test_widening_store_dtype_raises(self):
        with pytest.raises(ValueError, match="wider than the compute"):
            from_coo([0], [0], [1.0], (2, 2), C=2, dtype=np.float16,
                     store_dtype=np.float32)

    def test_complex_store_dtype_raises(self):
        with pytest.raises(ValueError, match="complex"):
            from_coo([0], [0], [1.0 + 1j], (2, 2), C=2,
                     dtype=np.complex64, store_dtype=jnp.bfloat16)

    def test_non_float_store_dtype_raises(self):
        with pytest.raises(ValueError, match="floating"):
            from_coo([0], [0], [1.0], (2, 2), C=2, dtype=np.float32,
                     store_dtype=np.int8)

    def test_integer_compute_dtype_raises(self):
        """Integer COO values without dtype= must not silently pair an
        int compute dtype with float storage (solver states would be
        allocated as integers)."""
        with pytest.raises(ValueError, match="floating compute"):
            from_coo([0, 1], [0, 1], np.array([2, 3]), (2, 2), C=2,
                     store_dtype=jnp.bfloat16)
