"""Pallas mamba_scan kernel + the scan_impl variants of the Mamba block."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import mamba_scan
from repro.kernels.ref import mamba_scan_ref
from repro.models.ssm import SSMConfig, mamba_apply, mamba_init
import repro.models.xlstm as XL


def _inputs(rng, B, S, di, N):
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, di))) * 0.1,
                     jnp.float32)
    xc = jnp.asarray(rng.standard_normal((B, S, di)), jnp.float32)
    Bc = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cc = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((di, N))), jnp.float32)
    return dt, xc, Bc, Cc, A


class TestMambaScanKernel:
    @pytest.mark.parametrize("B,S,di,N", [
        (1, 16, 8, 2), (2, 64, 32, 4), (1, 128, 64, 8), (3, 32, 16, 16),
    ])
    def test_vs_ref(self, rng, B, S, di, N):
        args = _inputs(rng, B, S, di, N)
        np.testing.assert_allclose(np.asarray(mamba_scan(*args)),
                                   np.asarray(mamba_scan_ref(*args)),
                                   atol=1e-4, rtol=1e-4)

    def test_state_carries_across_blocks(self, rng):
        """Sequence blocking must not reset the state (s_blk < S)."""
        args = _inputs(rng, 1, 128, 8, 4)
        y = mamba_scan(*args)
        yr = mamba_scan_ref(*args)
        # late positions depend on early state: compare the tail closely
        np.testing.assert_allclose(np.asarray(y[:, -8:]),
                                   np.asarray(yr[:, -8:]), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(4, 64), di=st.sampled_from([4, 8, 16]),
       N=st.sampled_from([2, 4]), seed=st.integers(0, 2**31 - 1))
def test_property_mamba_kernel(S, di, N, seed):
    rng = np.random.default_rng(seed)
    S = (S // 4) * 4 or 4
    args = _inputs(rng, 1, S, di, N)
    np.testing.assert_allclose(np.asarray(mamba_scan(*args)),
                               np.asarray(mamba_scan_ref(*args)),
                               atol=1e-3, rtol=1e-3)


class TestScanImpls:
    def test_all_impls_agree(self, rng):
        cfg = SSMConfig(d_state=4, d_conv=4, expand=2,
                        scan_impl="materialized")
        params = mamba_init(jax.random.PRNGKey(0), 16, cfg, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 16), jnp.float32)
        y0 = mamba_apply(params, x, cfg, chunk=16)
        for impl in ("chunked", "pallas"):
            yi = mamba_apply(params, x,
                             dataclasses.replace(cfg, scan_impl=impl),
                             chunk=16)
            np.testing.assert_allclose(np.asarray(y0), np.asarray(yi),
                                       atol=1e-4, rtol=1e-4, err_msg=impl)

    def test_chunked_grads(self):
        cfg = SSMConfig(d_state=4, scan_impl="chunked")
        params = mamba_init(jax.random.PRNGKey(0), 16, cfg, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16), jnp.float32)
        g = jax.grad(lambda p: float(0) + jnp.sum(
            mamba_apply(p, x, cfg, chunk=8) ** 2))(params)
        assert all(np.isfinite(np.asarray(v)).all()
                   for v in jax.tree.leaves(g))


class TestSlstmCustomVjp:
    def test_grads_match_autodiff(self):
        cfg = XL.XLSTMConfig(n_heads=4, expand=2)
        params = XL.slstm_init(jax.random.PRNGKey(0), 16, cfg,
                               dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16), jnp.float32)

        def loss(p, custom):
            XL.SLSTM_CUSTOM_VJP = custom
            return jnp.sum(jnp.sin(XL.slstm_apply(p, x, cfg, chunk=8)))

        try:
            assert abs(float(loss(params, True))
                       - float(loss(params, False))) < 1e-6
            g1 = jax.grad(lambda p: loss(p, True))(params)
            g0 = jax.grad(lambda p: loss(p, False))(params)
            for k in g0:
                d = float(jnp.abs(g1[k].astype(jnp.float32)
                                  - g0[k].astype(jnp.float32)).max())
                scale = float(jnp.abs(g0[k]).max())
                assert d < 1e-4 * max(scale, 1.0), (k, d, scale)
        finally:
            XL.SLSTM_CUSTOM_VJP = True
