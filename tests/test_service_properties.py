"""Property tests over random arrival mixes on the virtual-clock harness.

One scenario runner drives both entry points: a Hypothesis ``@given``
over generated request mixes (solver × precond × block × store_dtype ×
deadline × priority × cancel points) and a plain-pytest deterministic
sweep over seeded random mixes, so the invariants stay exercised even
where hypothesis is not installed (the conftest shim skips the
``@given`` tests gracefully).

Invariants checked after — and during — every scenario:

* every ticket completes, cancels, expires, or is rejected **exactly
  once** (the ``_terminal_transitions`` counter and the stats partition);
* incompatible requests never share a batch (batch key == compatibility
  class, checked slot-by-slot at every step);
* no admitted request starves: ``drain`` resolves everything.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.matrices import laplace3d
from repro.runtime import MatrixRegistry
from service_harness import ServiceHarness, assert_consistent

N_SIDE = 5          # laplace3d(5): n = 125, small enough for many mixes


@pytest.fixture(scope="module")
def registry():
    import jax.numpy as jnp
    r, c, v, n = laplace3d(N_SIDE)
    reg = MatrixRegistry()
    kw = dict(rows=r, cols=c, vals=v, shape=(n, n), C=8, sigma=16,
              w_align=4, dtype=np.float32)
    reg.register("m_f32", **kw)
    reg.register("m_bf16", store_dtype=jnp.bfloat16, **kw)
    return reg


N = N_SIDE ** 3


def _spec_is_valid(spec) -> bool:
    solver, precond, block = spec["solver"], spec["precond"], spec["block"]
    if block and (solver == "pipelined_cg" or precond is not None):
        return False
    if precond is not None and solver == "pipelined_cg":
        return False
    return True


def run_mix(registry, specs, *, admission, max_queue=None, block_width=3,
            chunk_iters=4, check_every=2):
    """Submit a request mix, apply its cancel points, drain, verify."""
    h = ServiceHarness(registry, admission=admission, max_queue=max_queue,
                       block_width=block_width, chunk_iters=chunk_iters)
    rng = np.random.default_rng(7)
    tickets = []
    for spec in specs:
        t = h.submit(spec["matrix"],
                     rng.standard_normal(N).astype(np.float32),
                     solver=spec["solver"], tol=spec["tol"],
                     maxiter=spec["maxiter"], precond=spec["precond"],
                     block=spec["block"], deadline=spec["deadline"],
                     priority=spec["priority"])
        tickets.append((t, spec))
    step = 0
    while h.service.pending:
        for t, spec in tickets:
            if spec["cancel_at"] == step:
                h.cancel(t)
        h.step()
        if step % check_every == 0:
            assert_consistent(h.service, [t for t, _ in tickets])
        step += 1
        if step > 5_000:
            raise AssertionError(
                f"mix did not drain (starvation?): {h.service.describe()}")
    assert_consistent(h.service, [t for t, _ in tickets])
    # exactly-once resolution for every ticket, admitted or not
    for t, spec in tickets:
        assert t.resolved, f"admitted request starved: {t!r}"
        assert t._terminal_transitions == 1
        if t.status == "done":
            assert t.result is not None
        if t.rejected:
            assert max_queue is not None
    # incompatible requests never shared a batch: every pair of tickets
    # with different config got different keys (the per-step check above
    # enforced key == batch membership)
    for t, spec in tickets:
        if t.rejected:
            continue
        k = t.key
        assert k[0] == spec["matrix"]
        assert k[1] == spec["solver"]
        assert k[3] == (spec["precond"] or "")
        assert k[4] == ("bfloat16" if spec["matrix"] == "m_bf16"
                        else "float32")
        assert k[5] == ("block" if spec["block"] else "")
    return h, tickets


# ------------------------------------------------------------- hypothesis
spec_strategy = st.fixed_dictionaries({
    "matrix": st.sampled_from(["m_f32", "m_bf16"]),
    "solver": st.sampled_from(["cg", "minres", "pipelined_cg"]),
    "precond": st.sampled_from([None, "chebyshev:3"]),
    "block": st.booleans(),
    "tol": st.sampled_from([1e-3, 1e-5, 1e-8]),
    "maxiter": st.sampled_from([50, 300]),
    "deadline": st.sampled_from([None, None, 2.0, 6.0]),
    "priority": st.integers(min_value=0, max_value=3),
    "cancel_at": st.sampled_from([None, None, None, 0, 1, 3]),
}).filter(_spec_is_valid)


@given(specs=st.lists(spec_strategy, min_size=1, max_size=12),
       admission=st.sampled_from(["fifo", "bucketed"]),
       max_queue=st.sampled_from([None, 2]))
@settings(max_examples=15, deadline=None)
def test_random_mix_property(registry, specs, admission, max_queue):
    run_mix(registry, specs, admission=admission, max_queue=max_queue)


# ------------------------------------------------- deterministic fallback
def _random_spec(rng) -> dict:
    while True:
        spec = {
            "matrix": rng.choice(["m_f32", "m_bf16"]),
            "solver": rng.choice(["cg", "minres", "pipelined_cg"]),
            "precond": rng.choice([None, "chebyshev:3"]),
            "block": bool(rng.integers(2)),
            "tol": float(rng.choice([1e-3, 1e-5, 1e-8])),
            "maxiter": int(rng.choice([50, 300])),
            "deadline": (None if rng.random() < 0.5
                         else float(rng.choice([2.0, 6.0]))),
            "priority": int(rng.integers(4)),
            "cancel_at": (None if rng.random() < 0.6
                          else int(rng.integers(4))),
        }
        if _spec_is_valid(spec):
            return spec


@pytest.mark.parametrize("seed,admission,max_queue", [
    (0, "fifo", None),
    (1, "bucketed", None),
    (2, "bucketed", 2),
    (3, "fifo", 2),
])
def test_seeded_mix_deterministic(registry, seed, admission, max_queue):
    """The same invariants as the hypothesis sweep on fixed seeds — runs
    everywhere, keeps the property coverage when hypothesis is absent."""
    rng = np.random.default_rng(seed)
    specs = [_random_spec(rng) for _ in range(int(rng.integers(6, 12)))]
    h, tickets = run_mix(registry, specs, admission=admission,
                         max_queue=max_queue)
    # the scenario actually exercised interesting paths
    stats = h.service.stats
    assert stats["submitted"] == len(specs)
    assert stats["batches_opened"] >= 2          # mixed keys really split
