"""ghostsan: seeded-bug fixtures per analyzer, engine machinery, CLI,
and the self-check that the sanitizer passes over the repo's own tree.

Mirrors tests/test_ghostlint.py: each GS rule gets *positive* fixtures —
minimal seeded bugs the analyzer must flag (an overlapping output index
map, an uncovered tail chunk, an out-of-bounds tile, an accumulator
downcast, a storage round-trip, a cache-key churn loop) — plus clean
negatives proving the legal patterns (reduction outputs, boundary casts,
cached jits) never fire, and a src/-clean-beyond-baseline self-check.
"""
import json

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from tools.ghostsan import load_baseline
from tools.ghostsan.cli import main as cli_main
from tools.ghostsan.engine import (DEFAULT_BASELINE, Finding,
                                   apply_suppressions, suppressed_lines)
from tools.ghostsan.gs101_grid import (analyze_capture, audit_callable,
                                       capture_pallas_calls, run_grid_audit)
from tools.ghostsan.gs102_dtype import audit_function, run_dtype_audit
from tools.ghostsan.gs103_recompile import audit_workload, run_recompile_audit


def rules_of(findings):
    return {f.rule for f in findings}


def _fake_pallas(out_specs, out_shape, grid):
    """A minimal wrapper issuing one pallas_call with the given specs."""
    def thunk():
        pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=grid,
            in_specs=[pl.BlockSpec((2, 8), lambda i: (i, 0))],
            out_specs=out_specs,
            out_shape=out_shape,
        )(jnp.zeros((8, 8), jnp.float32))
    return thunk


# ---------------------------------------------------------------- GS101
class TestGS101Grid:
    def test_overlapping_output_map_is_race(self):
        # i -> (i//2, 0): grid points 0 and 1 both write tile (0, 0)
        fs = audit_callable(_fake_pallas(
            pl.BlockSpec((2, 8), lambda i: (i // 2, 0)),
            jax.ShapeDtypeStruct((8, 8), jnp.float32), (4,)))
        assert "GS101" in rules_of(fs)
        assert any("write race" in f.message for f in fs)

    def test_uncovered_tail_chunk(self):
        # grid 3 over a 4-block output: tile (3, 0) never written
        fs = audit_callable(_fake_pallas(
            pl.BlockSpec((2, 8), lambda i: (i, 0)),
            jax.ShapeDtypeStruct((8, 8), jnp.float32), (3,)))
        assert any("uncovered" in f.message for f in fs)
        assert rules_of(fs) == {"GS101"}

    def test_out_of_bounds_tile(self):
        fs = audit_callable(_fake_pallas(
            pl.BlockSpec((2, 8), lambda i: (i + 1, 0)),
            jax.ShapeDtypeStruct((8, 8), jnp.float32), (4,)))
        assert any("out of bounds" in f.message for f in fs)

    def test_identity_map_clean(self):
        fs = audit_callable(_fake_pallas(
            pl.BlockSpec((2, 8), lambda i: (i, 0)),
            jax.ShapeDtypeStruct((8, 8), jnp.float32), (4,)))
        assert fs == []

    def test_reduction_output_is_not_a_race(self):
        # constant map over the whole grid = accumulator tile (the
        # tsmttsm pattern); the map depends on no axis, so revisiting
        # the tile is deliberate
        fs = audit_callable(_fake_pallas(
            pl.BlockSpec((4, 4), lambda i: (0, 0)),
            jax.ShapeDtypeStruct((4, 4), jnp.float32), (4,)))
        assert fs == []

    def test_multi_output_only_bad_one_flagged(self):
        def thunk():
            pl.pallas_call(
                lambda x_ref, a_ref, b_ref: None,
                grid=(4,),
                in_specs=[pl.BlockSpec((2, 8), lambda i: (i, 0))],
                out_specs=[pl.BlockSpec((2, 8), lambda i: (i, 0)),
                           pl.BlockSpec((2, 8), lambda i: (i // 2, 0))],
                out_shape=[jax.ShapeDtypeStruct((8, 8), jnp.float32),
                           jax.ShapeDtypeStruct((8, 8), jnp.float32)],
            )(jnp.zeros((8, 8), jnp.float32))
        fs = audit_callable(thunk)
        assert all("out[1]" in f.message for f in fs) and fs

    def test_capture_shim_records_and_restores(self):
        caps = []
        real = pl.pallas_call
        with capture_pallas_calls(caps):
            _fake_pallas(pl.BlockSpec((2, 8), lambda i: (i, 0)),
                         jax.ShapeDtypeStruct((8, 8), jnp.float32),
                         (4,))()
        assert pl.pallas_call is real
        assert len(caps) == 1
        assert caps[0].grid == (4,) and len(caps[0].out_specs) == 1
        assert analyze_capture(caps[0]) == []

    def test_findings_anchor_in_this_repo(self):
        fs = audit_callable(_fake_pallas(
            pl.BlockSpec((2, 8), lambda i: (i // 2, 0)),
            jax.ShapeDtypeStruct((8, 8), jnp.float32), (4,)))
        assert fs and all(f.path.endswith(".py") for f in fs)
        assert all(f.line > 0 for f in fs)


# ---------------------------------------------------------------- GS102
class TestGS102Dtype:
    def test_accumulator_downcast_narrow_dot(self):
        def bf16_dot(a, b):
            return jnp.dot(a, b)        # bf16 x bf16 -> bf16 reduction
        a = jnp.ones((8, 8), jnp.bfloat16)
        fs = audit_function(bf16_dot, a, a, compute_bits=32)
        assert rules_of(fs) == {"GS102"}
        assert any("narrow accumulation" in f.message for f in fs)

    def test_widened_dot_clean(self):
        def widened(a, b):
            return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
        a = jnp.ones((8, 8), jnp.bfloat16)
        assert audit_function(widened, a, a, compute_bits=32) == []

    def test_downcast_below_compute(self):
        def drop(x):
            return (x * 2.0).astype(jnp.bfloat16)
        fs = audit_function(drop, jnp.ones((4,), jnp.float32),
                            compute_bits=32)
        assert any("downcast below compute" in f.message for f in fs)

    def test_storage_roundtrip(self):
        def rt(x):
            return x.astype(jnp.bfloat16).astype(jnp.float32) + 1.0
        fs = audit_function(rt, jnp.ones((4,), jnp.float32),
                            compute_bits=32)
        assert any("storage round-trip" in f.message for f in fs)

    def test_boundary_cast_to_compute_dtype_clean(self):
        # an f64 Kahan/dot result folding back into f32 solver state is
        # the contract's sanctioned boundary, not a violation
        def legal(x):
            return (x * 2.0).astype(jnp.float32)
        with jax.experimental.enable_x64():
            fs = audit_function(legal, jnp.ones((4,), jnp.float64),
                                compute_bits=32)
        assert fs == []

    def test_x64_roundtrip_through_f32_flagged(self):
        def rt64(x):
            return x.astype(jnp.float32).astype(jnp.float64) * 2.0
        with jax.experimental.enable_x64():
            fs = audit_function(rt64, jnp.ones((4,), jnp.float64),
                                compute_bits=64)
        assert any("storage round-trip" in f.message for f in fs)
        assert any("downcast below compute" in f.message for f in fs)

    def test_audit_recurses_into_while_loop(self):
        def looped(x):
            def body(c):
                return (c.astype(jnp.bfloat16).astype(jnp.float32)
                        * 1.5)
            return jax.lax.while_loop(lambda c: c[0] < 5.0, body, x)
        fs = audit_function(looped, jnp.ones((4,), jnp.float32),
                            compute_bits=32)
        assert any("storage round-trip" in f.message for f in fs)


# ---------------------------------------------------------------- GS103
class TestGS103Recompile:
    def test_cache_key_churn_loop_flagged(self):
        def churn():
            # a fresh function object per round = a fresh jit cache key:
            # the armed identical replay must re-trace
            fn = jax.jit(lambda x: x * 2 + 1)
            fn(jnp.ones((4,), jnp.float32)).block_until_ready()
        fs = audit_workload(churn, name="churn-fixture")
        assert rules_of(fs) == {"GS103"}
        assert any("churn-fixture" in f.message for f in fs)

    def test_cached_jit_clean(self):
        cached = jax.jit(lambda x: x * 3 - 1)

        def steady():
            cached(jnp.ones((4,), jnp.float32)).block_until_ready()
        assert audit_workload(steady, name="steady") == []

    def test_varying_shape_churn_flagged(self):
        cached = jax.jit(lambda x: x.sum())
        state = {"n": 3}

        def grow():
            # shape changes every round — a retrace per call even with
            # one function object (the varying-gather refill bug class)
            state["n"] += 1
            cached(jnp.ones((state["n"],), jnp.float32)).block_until_ready()
        fs = audit_workload(grow, name="grow")
        assert rules_of(fs) == {"GS103"}


# ------------------------------------------------------------ machinery
class TestEngine:
    def test_ghostsan_prefix_own_suppressions(self):
        per_line, file_level = suppressed_lines(
            "x = 1  # ghostsan: disable=GS101\n"
            "# ghostlint: disable=GS102\n"
            "y = 2\n")
        assert per_line == {1: {"GS101"}}       # ghostlint prefix inert
        assert file_level is None

    def test_apply_suppressions_filters_at_anchor(self, tmp_path,
                                                  monkeypatch):
        mod = tmp_path / "anchored.py"
        mod.write_text("# ghostsan: disable=GS101\n"
                       "def wrapper():\n"
                       "    pass\n")
        import tools.ghostsan.engine as eng
        monkeypatch.setattr(eng, "REPO", str(tmp_path))
        kept = Finding("GS102", "anchored.py", 2, "m", "def wrapper():")
        dropped = Finding("GS101", "anchored.py", 2, "m",
                          "def wrapper():")
        out = apply_suppressions([kept, dropped])
        assert out == [kept]

    def test_shared_fingerprint_semantics(self):
        a = Finding("GS101", "x.py", 3, "msg", "def f():")
        b = Finding("GS101", "x.py", 33, "other msg", "def f():")
        assert a.fingerprint == b.fingerprint

    def test_default_baseline_is_committed_empty(self):
        assert load_baseline(DEFAULT_BASELINE) == set()
        with open(DEFAULT_BASELINE, encoding="utf-8") as f:
            assert json.load(f)["findings"] == []


# ------------------------------------------------------------------- CLI
class TestCLI:
    def test_list_rules_exit_zero(self, capsys):
        assert cli_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rid in ("GS101", "GS102", "GS103"):
            assert rid in out

    def test_unknown_rule_usage_error(self, capsys):
        assert cli_main(["--select", "GS999"]) == 2

    def test_select_gs101_json_clean_tree(self, capsys):
        rc = cli_main(["--select", "GS101", "--format=json"])
        data = json.loads(capsys.readouterr().out)
        assert rc == 0
        assert data["findings"] == [] and data["analyzers"] == ["GS101"]


# ------------------------------------------------------------- self-check
class TestSelfCheck:
    def test_grid_audit_clean_beyond_baseline(self):
        """The sanitizer's reason to exist: every in-tree kernel's grid
        is race-free and covering, with the committed baseline empty."""
        fresh = [f for f in apply_suppressions(run_grid_audit())
                 if f.fingerprint not in load_baseline()]
        assert fresh == [], "\n".join(f.format() for f in fresh)

    def test_dtype_audit_clean_beyond_baseline(self):
        fresh = [f for f in apply_suppressions(run_dtype_audit())
                 if f.fingerprint not in load_baseline()]
        assert fresh == [], "\n".join(f.format() for f in fresh)

    def test_recompile_audit_clean_beyond_baseline(self):
        fresh = [f for f in apply_suppressions(run_recompile_audit())
                 if f.fingerprint not in load_baseline()]
        assert fresh == [], "\n".join(f.format() for f in fresh)


# ----------------------------------------------- parity auto-discovery
class TestParityDiscovery:
    def test_discovers_every_kernel_file(self):
        from tools.ghostlint.parity import SWEEPS, discover_kernel_bases
        bases = discover_kernel_bases()
        # the six shipped kernels, by construction of the scan
        for base in ("sellcs_spmv", "tsmm", "tsmttsm", "fused_axpby_dots",
                     "block_diag_matmul", "mamba_scan"):
            assert base in bases, base
        assert set(bases) <= set(SWEEPS)

    def test_unregistered_kernel_fails_coverage(self, tmp_path,
                                                monkeypatch):
        import tools.ghostlint.parity as parity
        (tmp_path / "newkern.py").write_text(
            "def shiny_new_pallas(x):\n    return x\n")
        monkeypatch.setattr(parity, "KERNELS_DIR", str(tmp_path))
        problems = parity.check_sweep_coverage()
        assert any("shiny_new" in p and "no sweep driver" in p
                   for p in problems)
        # and the stale direction: drivers for kernels that vanished
        assert any("stale entry" in p for p in problems)

    def test_sweep_cases_feed_gs101(self):
        from tools.ghostlint.parity import iter_sweep_cases
        cases = list(iter_sweep_cases())
        assert len(cases) >= 21          # 16 sellcs configs + 5 dense
        names = {c.name for c in cases}
        assert "sellcs_spmv" in names and "tsmttsm" in names
