"""Shared fixtures.  NOTE: tests run on the single real CPU device — the
512-device XLA flag is set ONLY inside launch/dryrun.py (and subprocess
tests that need a multi-device mesh spawn a fresh interpreter)."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a subprocess with n forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(12345)
