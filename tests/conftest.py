"""Shared fixtures.  NOTE: tests run on the single real CPU device — the
512-device XLA flag is set ONLY inside launch/dryrun.py (and subprocess
tests that need a multi-device mesh spawn a fresh interpreter)."""
import os
import subprocess
import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Optional-dependency shim: several test modules import `hypothesis` at the
# top level for property-based tests.  Without this shim a missing install
# kills *collection* of those modules (taking all their plain pytest tests
# down too).  Install the real package via requirements-dev.txt to run the
# property tests; with the shim, property tests skip and everything else runs.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _skip_given(*_a, **_k):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _identity_settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _FakeStrategy:
        def __getattr__(self, name):
            return self

        def __call__(self, *a, **k):
            return self

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _skip_given
    _hyp.settings = _identity_settings
    _hyp.assume = lambda *a, **k: True
    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "sampled_from", "booleans",
                  "tuples", "just", "one_of", "text", "composite",
                  "fixed_dictionaries", "dictionaries", "none"):
        setattr(_st, _name, _FakeStrategy())
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a subprocess with n forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(12345)


@pytest.fixture
def virtual_clock():
    """A monotonic clock that only moves when the test advances it."""
    from service_harness import VirtualClock
    return VirtualClock()


@pytest.fixture
def make_harness():
    """Factory for virtual-clock-driven SolverService harnesses:
    ``make_harness(registry, block_width=..., ...)`` — each step advances
    the injected clock one tick, so scheduling tests are deterministic
    (no sleeps, no wall-clock assertions)."""
    from service_harness import ServiceHarness

    def factory(registry, **kwargs):
        return ServiceHarness(registry, **kwargs)

    return factory
