"""Deterministic virtual-clock harness for SolverService scheduling tests.

Every timestamp, deadline comparison, and chunk-size decision inside the
service flows through its injected ``clock``; this module supplies a
:class:`VirtualClock` whose time only moves when a test says so, and a
:class:`ServiceHarness` that advances it by a fixed tick per service
step.  Scheduling behavior then depends only on submit order, tick size,
and solver arithmetic — no ``time.sleep``, no wall-clock flake: a
latency of ``3.0`` means "retired on the third step", always.

:func:`assert_consistent` is the shared invariant checker the property
and failure-injection tests run after every scenario: each ticket takes
exactly one terminal transition, the stats partition adds up, batch
state matches ticket state, and incompatible requests never share a
batch.
"""
from collections import Counter

from repro.runtime.service import TERMINAL_STATES, SolverService


class VirtualClock:
    """Monotonic clock that advances only when told to."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"a monotonic clock cannot rewind (dt={dt})")
        self._now += float(dt)
        return self._now


class ServiceHarness:
    """A SolverService driven on a virtual clock, one tick per step.

    With ``tick=1.0`` (the default) virtual time counts service steps:
    a request submitted at step a and retired at step b has latency
    ``b - a`` exactly.  Deadlines passed to ``submit(deadline=...)`` are
    therefore "number of steps from now" — deterministic deadline tests
    pick the step at which expiry must happen.
    """

    def __init__(self, registry, *, tick: float = 1.0, start: float = 0.0,
                 **service_kwargs):
        self.clock = VirtualClock(start)
        self.tick = float(tick)
        self.service = SolverService(registry, clock=self.clock,
                                     **service_kwargs)

    def submit(self, *args, **kwargs):
        return self.service.submit(*args, **kwargs)

    def cancel(self, ticket) -> bool:
        return self.service.cancel(ticket)

    def step(self) -> int:
        """One service step, then one clock tick."""
        chunks = self.service.step()
        self.clock.advance(self.tick)
        return chunks

    def drain(self, max_steps: int = 10_000):
        """Step (advancing the clock) until nothing is pending."""
        steps = 0
        while self.service.pending:
            if steps >= max_steps:
                raise AssertionError(
                    f"harness did not drain in {max_steps} steps "
                    f"({self.service.pending} pending): "
                    f"{self.service.describe()}")
            self.step()
            steps += 1
        return self.service.completed

    def run_until(self, predicate, max_steps: int = 10_000) -> int:
        """Step until ``predicate()`` holds; returns steps taken."""
        steps = 0
        while not predicate():
            if steps >= max_steps:
                raise AssertionError(
                    f"predicate still false after {max_steps} steps: "
                    f"{self.service.describe()}")
            self.step()
            steps += 1
        return steps


def assert_consistent(service: SolverService, tickets=()):
    """Service-wide invariants that must hold at ANY step boundary.

    * every known ticket is in a legal state, and resolved tickets took
      exactly one terminal transition (the ``_terminal_transitions``
      counter is the service's own tamper-evidence);
    * ``submitted == done + cancelled + expired + rejected + pending``
      — the stats partition, no request lost or double-counted;
    * batch bookkeeping is shape-consistent and every slotted ticket is
      ``running`` with the batch's own key — incompatible requests
      (different matrix/solver/precond/store_dtype/block/bucket) can
      never share a batch because the key IS the compatibility class;
    * the ``completed`` log holds admitted terminal tickets only, at
      most once each, and never a rejected one.
    """
    stats = service.stats
    tickets = list(tickets)
    for t in tickets:
        if t.status not in TERMINAL_STATES and t.status not in (
                "queued", "running"):
            raise AssertionError(f"illegal status on {t!r}")
        expected = 1 if t.status in TERMINAL_STATES else 0
        if t._terminal_transitions != expected:
            raise AssertionError(
                f"{t!r} took {t._terminal_transitions} terminal "
                f"transitions (expected {expected})")
        if t.status == "rejected" and t.result is not None:
            raise AssertionError(f"rejected ticket with a result: {t!r}")
        if t.status == "cancelled" and t.result is not None:
            raise AssertionError(f"cancelled ticket with a result: {t!r}")
        if t.status == "done" and t.result is None:
            raise AssertionError(f"done ticket without a result: {t!r}")

    resolved = stats["retired"] + stats["cancelled"] + stats["expired"] \
        + stats["rejected"]
    if resolved + service.pending != stats["submitted"]:
        raise AssertionError(
            f"stats partition broken: retired={stats['retired']} + "
            f"cancelled={stats['cancelled']} + expired={stats['expired']} + "
            f"rejected={stats['rejected']} + pending={service.pending} != "
            f"submitted={stats['submitted']}")

    slotted = []
    for key, batch in service._batches.items():
        if not (len(batch.slots) == len(batch.insert_it) == batch.width):
            raise AssertionError(
                f"batch {key} shape drift: {len(batch.slots)} slots, "
                f"{len(batch.insert_it)} insert_its, width {batch.width}")
        if batch.width > service.block_width:
            raise AssertionError(
                f"batch {key} width {batch.width} exceeds the "
                f"block_width cap {service.block_width}")
        for t in batch.slots:
            if t is None:
                continue
            slotted.append(t)
            if t.status != "running":
                raise AssertionError(
                    f"{t!r} sits in batch {key} but is not running")
            if t.key != key:
                raise AssertionError(
                    f"{t!r} (key {t.key}) sits in batch {key}: "
                    f"incompatible requests share a batch")
    if len(set(id(t) for t in slotted)) != len(slotted):
        raise AssertionError("one ticket occupies two batch slots")

    log_counts = Counter(id(t) for t in service.completed)
    if log_counts and max(log_counts.values()) > 1:
        raise AssertionError("a ticket appears twice in the completed log")
    for t in service.completed:
        if t.status not in TERMINAL_STATES:
            raise AssertionError(f"non-terminal ticket in completed: {t!r}")
        if t.status == "rejected":
            raise AssertionError(
                f"rejected (never admitted) ticket in completed: {t!r}")
    # queued live-counts agree with the heaps they summarize
    for key, q in service._queues.items():
        alive = sum(1 for (_, _, _, t) in q._heap if t.status == "queued")
        if alive != len(q):
            raise AssertionError(
                f"queue {key} live count {len(q)} != {alive} actually "
                f"queued entries")
