"""Heterogeneous execution engine (GHOST 4.1 + 4.2).

Host-side pieces (DevicePool, SplitPlan, rebalance convergence) run in the
main process; everything needing a multi-shard mesh runs in a 2-device
subprocess via conftest.run_with_devices.
"""
import numpy as np
import pytest

from conftest import run_with_devices

from repro.launch.costmodel import spmv_code_balance, spmv_cost
from repro.launch.hillclimb import proportional_step
from repro.runtime import DevicePool, plan_split


# ---------------------------------------------------------------- devicepool
class TestDevicePool:
    def test_detect_host(self):
        pool = DevicePool.detect()
        assert pool.ndevices >= 1
        assert len(pool.device_classes()) == pool.ndevices

    def test_synthetic_paper_node(self):
        """Paper Table 1: CPU 50 + GPU 150 + PHI 150 GB/s."""
        pool = DevicePool.from_bandwidths([50, 150, 150])
        w = pool.device_weights()
        assert np.allclose(w, [50 / 350, 150 / 350, 150 / 350])
        # min code balance 6 B/flop (f64 vals + i32 idx) -> 350/6 Gflop/s
        pred = pool.aggregate_spmv_gflops(nnzr=1e9)   # huge row amortizes y
        assert abs(pred - 350.0 / 6.0) < 1.0

    def test_code_balance_reference_point(self):
        assert spmv_code_balance(val_bytes=8, idx_bytes=4,
                                 nnzr=1e12) == pytest.approx(6.0)
        # block vectors amortize the matrix stream (paper's SpMMV argument)
        cb4 = spmv_code_balance(val_bytes=8, idx_bytes=4, nvecs=4, nnzr=1e12)
        assert cb4 < 6.0 / 2

    def test_roofline_time(self):
        pool = DevicePool.from_bandwidths([100])
        cost = spmv_cost(10_000, 100, val_bytes=4)
        t = pool.classes[0].time_for(cost)
        assert t == pytest.approx(cost.hbm_bytes / 100e9)


# ---------------------------------------------------------------- splitting
class TestSplitPlan:
    def test_split_sums_and_alignment(self):
        for n, align in [(1000, 32), (997, 8), (64, 32), (12345, 16)]:
            p = plan_split(n, [1, 2.75, 0.5], align=align)
            assert p.sizes.sum() == n
            starts = [s for s, _ in p.ranges]
            assert all(s % align == 0 for s in starts)
            # contiguous cover
            assert p.ranges[0][0] == 0 and p.ranges[-1][1] == n
            assert all(p.ranges[i][1] == p.ranges[i + 1][0]
                       for i in range(p.nshards - 1))

    def test_no_empty_shards_under_skew(self):
        p = plan_split(256, [1000.0, 1.0, 1.0, 1.0], align=32)
        assert (p.sizes > 0).all()
        assert p.sizes.sum() == 256

    def test_proportionality(self):
        p = plan_split(100_000, [1.0, 3.0], align=32)
        assert abs(p.sizes[1] / p.sizes[0] - 3.0) < 0.01

    def test_nnz_criterion(self):
        rowlen = np.concatenate([np.full(100, 50), np.full(900, 5)])
        p = plan_split(1000, [1, 1], align=4, rowlen=rowlen)
        nnz = p.shard_nnz()
        assert abs(nnz[0] - nnz[1]) / nnz.sum() < 0.1
        assert p.sizes.sum() == 1000

    def test_rebalance_one_step_moves_toward_measured(self):
        p = plan_split(10_000, [1.0, 1.0], align=8)
        # shard 0's device is 3x slower -> its time is 3x at equal rows
        p2 = p.rebalance([3.0, 1.0], step=1.0)
        assert p2.generation == 1
        assert p2.weights[0] < p2.weights[1]

    def test_rebalance_converges_on_skewed_pool(self):
        """Satellite criterion: weights converge toward the measured
        throughput ratio of a synthetic 1:3 pool."""
        speed = np.array([1.0, 3.0])
        p = plan_split(30_000, [1.0, 1.0], align=8)
        for _ in range(8):
            times = (p.sizes / p.sizes.sum()) / speed
            p = p.rebalance(times, step=0.7)
        w = np.asarray(p.weights)
        assert abs(w[1] / w[0] - 3.0) < 0.15, w
        # fixed point: per-shard times equalized
        times = (p.sizes / p.sizes.sum()) / speed
        assert p.imbalance(times) < 1.02

    def test_proportional_step_validates(self):
        with pytest.raises(ValueError):
            proportional_step([1.0, -1.0], [1.0, 1.0])


# ------------------------------------------------------------------- engine
class TestEngineSingleDevice:
    def test_spmv_matches_dense(self, rng):
        from repro.matrices import matpde
        from repro.runtime import HeterogeneousEngine
        r, c, v, n = matpde(16)
        A = np.zeros((n, n)); A[r, c] += v
        eng = HeterogeneousEngine(r, c, v, n, C=8, sigma=16, w_align=4,
                                  dtype=np.float32)
        x = rng.standard_normal((n, 2)).astype(np.float32)
        y, _ = eng.spmv(x)
        assert np.allclose(np.asarray(y), A @ x, atol=1e-3)

    def test_rebalance_keeps_correctness(self, rng):
        from repro.matrices import matpde
        from repro.runtime import HeterogeneousEngine
        r, c, v, n = matpde(12)
        A = np.zeros((n, n)); A[r, c] += v
        eng = HeterogeneousEngine(r, c, v, n, C=8, sigma=8, w_align=4,
                                  dtype=np.float32)
        eng.rebalance()          # modeled-times fallback path
        x = rng.standard_normal(n).astype(np.float32)
        y, _ = eng.spmv(x)
        assert np.allclose(np.asarray(y), A @ x, atol=1e-3)


CODE_TEMPLATE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.runtime import DevicePool, HeterogeneousEngine
from repro.core.spmv import SpmvOpts
from repro.matrices import banded_random, matpde

rng = np.random.default_rng(0)
mesh = Mesh(np.array(jax.devices()).reshape(2), ("data",))
pool = DevicePool.from_bandwidths([50, 150])
{body}
print("SUBPROCESS_OK")
"""


def run2(body: str) -> str:
    out = run_with_devices(CODE_TEMPLATE.format(body=body), 2)
    assert "SUBPROCESS_OK" in out
    return out


class TestEngineMultiShard:
    def test_engine_end_to_end_two_shards(self):
        """One subprocess (jax init is the dominant cost), five checks:

        1. acceptance: overlap=True == overlap=False bit-for-bit through
           the runtime, both matching spmv_ref on the 2-shard host mesh;
        2. the double-buffered halo chain is a pure re-schedule
           (bit-identical to the unbuffered chain);
        3. fused gamma-shift + dots through the engine;
        4. CG through DistOperator converges to the dense solution;
        5. the split follows the pool's 150/50 bandwidth ratio.
        """
        run2("""
from repro.core import from_coo
from repro.core.spmv import spmv_ref
from repro.solvers import cg, make_operator

# -- 1. overlap bit-identity + correctness ----------------------------------
r, c, v, n = banded_random(400, bw=8, density=0.6, seed=4)
A = np.zeros((n, n)); A[r, c] += v
eng = HeterogeneousEngine(r, c, v, n, mesh=mesh, pool=pool, C=8, sigma=16,
                          w_align=4, dtype=np.float32)
x = rng.standard_normal((n, 2)).astype(np.float32)
y1, _ = eng.spmv(x, overlap=True)
y2, _ = eng.spmv(x, overlap=False)
assert np.array_equal(np.asarray(y1), np.asarray(y2)), "overlap changed bits"
As = from_coo(r, c, v, (n, n), C=8, sigma=16, w_align=4, dtype=np.float32)
yr = As.unpermute(spmv_ref(As, As.permute(jnp.asarray(x)))[0])
assert np.allclose(np.asarray(y1), np.asarray(yr), atol=1e-4)
assert np.allclose(np.asarray(y1), A @ x, atol=1e-3)
print("CHECK overlap_bit_identical OK")

# -- 2. double-buffered chain == unbuffered chain ---------------------------
xs = eng.A.distribute_vec(x[:, :1])
run_db = eng.make_matvec(nvecs=1, double_buffer=True)
run_nb = eng.make_matvec(nvecs=1)
w, stg = xs, None
for _ in range(3):
    w, _, stg = run_db(w, staging=stg)
w2 = xs
for _ in range(3):
    w2, _, _ = run_nb(w2)
assert np.array_equal(np.asarray(w), np.asarray(w2))
print("CHECK double_buffer OK")

# -- 3. fused gamma + dots --------------------------------------------------
y, dots = eng.spmv(x, opts=SpmvOpts(alpha=2.0, gamma=0.5,
                                    dot_yy=True, dot_xx=True))
ref = 2.0 * (A @ x - 0.5 * x)
assert np.allclose(np.asarray(y), ref, atol=1e-3)
assert np.allclose(np.asarray(dots[0]), (ref * ref).sum(0), rtol=1e-3)
assert np.allclose(np.asarray(dots[2]), (x * x).sum(0), rtol=1e-3)
print("CHECK fused_dots OK")

# -- 4. CG runs unchanged on the engine -------------------------------------
r, c, v, n = matpde(16, beta_c=0.0)
A = np.zeros((n, n)); A[r, c] += v
engs = HeterogeneousEngine(r, c, v, n, mesh=mesh, pool=pool, C=8, sigma=16,
                           w_align=4, dtype=np.float32)
op = make_operator(engs)
b = rng.standard_normal((n, 2)).astype(np.float32)
res = cg(op, op.to_op_space(b), tol=1e-6, maxiter=600)
assert bool(np.asarray(res.converged).all())
xsol = np.asarray(op.from_op_space(res.x))
assert np.abs(A @ xsol - b).max() < 1e-3
print("CHECK cg_solver OK")

# -- 5. split follows the pool ----------------------------------------------
sizes = eng.plan.sizes
assert abs(sizes[1] / sizes[0] - 3.0) < 0.3, sizes   # 150/50 bandwidth ratio
print("CHECK weighted_split OK")
""")
