"""Block vector operations (paper C2): tall-skinny kernels, BLAS-1 with
per-column scalars, Kahan summation, views and layout."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import blockvec as bv


class TestTallSkinny:
    def test_tsmttsm(self, rng):
        V = rng.standard_normal((500, 6)).astype(np.float32)
        W = rng.standard_normal((500, 4)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(bv.tsmttsm(V, W)), V.T @ W,
                                   rtol=1e-4, atol=1e-4)

    def test_tsmttsm_conj(self, rng):
        V = (rng.standard_normal((100, 3))
             + 1j * rng.standard_normal((100, 3))).astype(np.complex64)
        W = (rng.standard_normal((100, 3))
             + 1j * rng.standard_normal((100, 3))).astype(np.complex64)
        np.testing.assert_allclose(np.asarray(bv.tsmttsm(V, W)),
                                   np.conj(V).T @ W, atol=1e-3)

    def test_tsmm(self, rng):
        V = rng.standard_normal((200, 8)).astype(np.float32)
        X = rng.standard_normal((8, 5)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(bv.tsmm(V, X)), V @ X,
                                   rtol=1e-4, atol=1e-4)

    def test_tsmm_inplace(self, rng):
        V = rng.standard_normal((64, 4)).astype(np.float32)
        X = np.eye(4, dtype=np.float32) * 2
        np.testing.assert_allclose(np.asarray(bv.tsmm_inplace(V, X, beta=1.0)),
                                   3 * V, rtol=1e-5)

    def test_nonzero_beta_without_out_raises(self, rng):
        """beta != 0 with no output operand used to silently drop the
        beta term — reference AND Pallas paths must refuse instead."""
        import jax
        from repro.kernels.tsmm import tsmm_pallas
        from repro.kernels.tsmttsm import tsmttsm_pallas
        V = rng.standard_normal((512, 4)).astype(np.float32)
        X = rng.standard_normal((4, 3)).astype(np.float32)
        W = rng.standard_normal((512, 3)).astype(np.float32)
        for fn, args in ((bv.tsmm, (V, X)), (bv.tsmttsm, (V, W)),
                         (lambda *a, **k: tsmm_pallas(*a, interpret=True, **k),
                          (V, X)),
                         (lambda *a, **k: tsmttsm_pallas(*a, interpret=True,
                                                         **k), (V, W))):
            with pytest.raises(ValueError, match="beta"):
                fn(*args, None, 1.0, 0.5)
            # a *traced* beta cannot be proven zero: rejected too
            with pytest.raises(ValueError, match="beta"):
                jax.jit(lambda b: fn(*args, None, 1.0, b))(0.0)
        # concrete beta=0 without the operand stays fine
        np.testing.assert_allclose(np.asarray(bv.tsmm(V, X, None, 1.0, 0.0)),
                                   V @ X, rtol=1e-4, atol=1e-4)
        # and beta with the operand still works in the kernels
        got = np.asarray(tsmm_pallas(V, X, W, 1.0, 0.5, interpret=True))
        np.testing.assert_allclose(got, V @ X + 0.5 * W, rtol=1e-4, atol=1e-4)


class TestBlas1:
    def test_vaxpby(self, rng):
        x = rng.standard_normal((50, 3)).astype(np.float32)
        y = rng.standard_normal((50, 3)).astype(np.float32)
        a = np.array([1.0, 2.0, 3.0], np.float32)
        b = np.array([-1.0, 0.5, 0.0], np.float32)
        np.testing.assert_allclose(np.asarray(bv.vaxpby(y, x, a, b)),
                                   b[None] * y + a[None] * x, rtol=1e-5)

    def test_dot_columnwise(self, rng):
        x = rng.standard_normal((100, 4)).astype(np.float32)
        y = rng.standard_normal((100, 4)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(bv.dot(x, y)), (x * y).sum(0),
                                   rtol=1e-4)

    def test_vscal(self, rng):
        x = rng.standard_normal((30, 2)).astype(np.float32)
        a = np.array([2.0, -3.0], np.float32)
        np.testing.assert_allclose(np.asarray(bv.vscal(x, a)), a[None] * x)


class TestKahan:
    def test_dot_kahan_accuracy(self):
        """Compensated dot beats naive f32 on a cancellation-heavy input."""
        n = 40000
        rng = np.random.default_rng(3)
        x = np.empty((n, 1), np.float32)
        x[0::2, 0] = 1e4
        x[1::2, 0] = -1e4
        x[:, 0] += rng.standard_normal(n).astype(np.float32) * 0.001
        y = np.ones((n, 1), np.float32)
        exact = float(np.sum(x.astype(np.float64)))
        naive = float(jnp.sum(jnp.asarray(x) * jnp.asarray(y)))
        kahan = float(bv.dot_kahan(jnp.asarray(x), jnp.asarray(y))[0])
        assert abs(kahan - exact) <= abs(naive - exact) + 1e-6

    def test_tsmttsm_kahan_matches(self, rng):
        V = rng.standard_normal((333, 5)).astype(np.float32)
        W = rng.standard_normal((333, 3)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(bv.tsmttsm_kahan(V, W)),
                                   V.T @ W, rtol=1e-4, atol=1e-4)


class TestViews:
    def test_scattered_view_and_clone(self, rng):
        """Paper Fig. 2: scattered column views; compact clone for compute."""
        v = rng.standard_normal((20, 8)).astype(np.float32)
        view = bv.view_cols(v, [1, 4, 6])
        np.testing.assert_allclose(np.asarray(view), v[:, [1, 4, 6]])
        clone = bv.compact_clone(view)
        np.testing.assert_allclose(np.asarray(clone), v[:, [1, 4, 6]])

    def test_layout_transpose_roundtrip(self, rng):
        v = rng.standard_normal((10, 4)).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(bv.to_row_major(bv.to_col_major(v))), v)
