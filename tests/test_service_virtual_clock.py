"""Deterministic scheduling tests on the virtual-clock harness.

Everything here is exact: deadlines are tick counts, latencies are whole
numbers of steps, and no assertion depends on how fast the machine runs
the chunks.  Covers the injectable clock itself, deadline expiry on the
queued and running paths, priority/EDF ordering, the deadline-driven
chunk shrinking, bucketed admission keys, the dispatcher's
anti-starvation aging, and adaptive batch width.
"""
import time

import numpy as np
import pytest

from repro.matrices import laplace3d
from repro.runtime import MatrixRegistry, SolverService
from repro.solvers.stepper import snap_chunk
from service_harness import ServiceHarness, assert_consistent


@pytest.fixture(scope="module")
def lap():
    r, c, v, n = laplace3d(6)
    return r, c, v, n


@pytest.fixture()
def reg(lap):
    r, c, v, n = lap
    registry = MatrixRegistry()
    registry.register("lap", rows=r, cols=c, vals=v, shape=(n, n), C=16,
                      sigma=32, w_align=4, dtype=np.float32)
    return registry


def _b(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


class TestInjectableClock:
    def test_default_clock_is_perf_counter(self, reg):
        assert SolverService(reg).clock is time.perf_counter

    def test_all_timestamps_come_from_injected_clock(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, start=100.0, block_width=2, chunk_iters=8)
        t = h.submit("lap", _b(n), tol=1e-4, maxiter=500)
        assert t.submitted_at == 100.0
        h.drain()
        assert t.started_at == 100.0            # admitted on the first step
        assert t.finished_at == 100.0 + t.latency
        assert t.latency == int(t.latency) >= 1  # whole ticks, ≥ one step

    def test_latency_counts_steps_exactly(self, reg, lap):
        """Two identical services on the virtual clock retire the same
        workload with identical tick latencies — the determinism claim."""
        *_, n = lap
        lat = []
        for _ in range(2):
            h = ServiceHarness(reg, block_width=2, chunk_iters=8)
            ts = [h.submit("lap", _b(n, seed=i), tol=1e-5, maxiter=500)
                  for i in range(5)]
            h.drain()
            lat.append([t.latency for t in ts])
        assert lat[0] == lat[1]

    def test_queue_wait_is_visible(self, reg, lap):
        """A request admitted only after a refill shows its queued ticks."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=4)
        first = h.submit("lap", _b(n, 1), tol=1e-6, maxiter=500)
        second = h.submit("lap", _b(n, 2), tol=1e-6, maxiter=500)
        h.drain()
        assert first.queue_wait == 0.0
        assert second.queue_wait == first.latency   # admitted when #1 left
        assert_consistent(h.service, [first, second])


class TestDeadlines:
    def test_running_request_expires_at_chunk_boundary(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=2, chunk_iters=4)
        t = h.submit("lap", _b(n), tol=1e-30, maxiter=10**6, deadline=3.0)
        ok = h.submit("lap", _b(n, 5), tol=1e-4, maxiter=500)
        h.drain()
        assert t.status == "expired"
        assert t.latency == 3.0                  # the boundary right at it
        assert t.result is not None              # best-effort iterate
        assert not t.result.converged and t.result.iters > 0
        assert ok.status == "done" and ok.result.converged
        assert h.service.stats["expired"] == 1
        assert_consistent(h.service, [t, ok])

    def test_queued_request_expires_at_refill(self, reg, lap):
        """Deadline passes while waiting in the queue: the request is
        expired at the refill gate, never occupies a slot, gets no
        result."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=4)
        hog = h.submit("lap", _b(n, 1), tol=1e-30, maxiter=10**6)
        h.step()                                 # hog takes the only slot
        starved = h.submit("lap", _b(n, 2), tol=1e-4, deadline=2.0)
        for _ in range(4):
            h.step()
        assert starved.status == "queued"        # hog still holds the slot
        h.cancel(hog)
        h.drain()
        assert starved.status == "expired"
        assert starved.result is None and starved.started_at is None
        assert_consistent(h.service, [hog, starved])

    def test_deadline_validation(self, reg, lap):
        *_, n = lap
        svc = SolverService(reg)
        with pytest.raises(ValueError, match="deadline"):
            svc.submit("lap", _b(n), deadline=0.0)
        with pytest.raises(ValueError, match="deadline"):
            svc.submit("lap", _b(n), deadline=-1.0)


class TestPriorityAndEDF:
    def _drain_order(self, h, tickets):
        h.drain()
        done = [t for t in h.service.completed if t in tickets]
        return [tickets.index(t) for t in done]

    def test_higher_priority_dequeues_first(self, reg, lap):
        """Width-1 batch, three queued: admission order follows priority,
        visible in started_at ticks."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=8)
        lo = h.submit("lap", _b(n, 1), tol=1e-4, priority=0)
        hi = h.submit("lap", _b(n, 2), tol=1e-4, priority=5)
        mid = h.submit("lap", _b(n, 3), tol=1e-4, priority=2)
        h.step()                                  # admits exactly one
        assert (hi.status, mid.status, lo.status) == (
            "running", "queued", "queued")
        h.drain()
        assert hi.started_at < mid.started_at < lo.started_at

    def test_edf_within_priority_fifo_on_ties(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=8)
        no_dl = h.submit("lap", _b(n, 1), tol=1e-4)
        late = h.submit("lap", _b(n, 2), tol=1e-4, deadline=1000.0)
        soon = h.submit("lap", _b(n, 3), tol=1e-4, deadline=500.0)
        h.step()
        # earliest deadline admitted first; the no-deadline request last
        assert soon.status == "running"
        h.drain()
        assert soon.started_at < late.started_at < no_dl.started_at
        # pure FIFO on full ties: same priority, no deadlines
        h2 = ServiceHarness(reg, block_width=1, chunk_iters=8)
        a = h2.submit("lap", _b(n, 4), tol=1e-4)
        b = h2.submit("lap", _b(n, 5), tol=1e-4)
        h2.drain()
        assert a.started_at <= b.started_at


class TestDeadlineChunkShrinking:
    def test_snap_chunk(self):
        assert snap_chunk(100, 16) == 16
        assert snap_chunk(16, 16) == 16
        assert snap_chunk(15, 16) == 8
        assert snap_chunk(5, 16) == 4
        assert snap_chunk(1, 16) == 1
        assert snap_chunk(0, 16) == 1
        assert snap_chunk(-3, 16) == 1
        with pytest.raises(ValueError, match="k_max"):
            snap_chunk(4, 0)

    def test_tight_deadline_shrinks_chunks(self, reg, lap):
        """With a seconds-per-iteration hint and a deadline shorter than
        a full chunk, the service cuts the chunk so the boundary lands
        near the deadline (power-of-two sizes only)."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=16,
                           iter_time_hint=lambda key: 1.0)  # 1 iter = 1 tick
        t = h.submit("lap", _b(n), tol=1e-30, maxiter=10**6, deadline=6.0)
        h.step()
        # 6 ticks of slack at 1 tick/iter → snap_chunk(6,16)=4, not 16
        assert int(h.service._batches[t.key].state.it) == 4
        assert h.service.stats["deadline_chunks"] == 1
        h.drain()
        assert t.status == "expired"
        assert_consistent(h.service, [t])

    def test_no_deadline_runs_full_chunks(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, chunk_iters=16,
                           iter_time_hint=lambda key: 1.0)
        t = h.submit("lap", _b(n), tol=1e-30, maxiter=64)
        h.step()
        assert int(h.service._batches[t.key].state.it) == 16
        h.drain()
        assert h.service.stats["deadline_chunks"] == 0
        assert t.status == "done"


class TestBucketedAdmission:
    def test_difficulty_buckets_split_batch_keys(self, reg, lap):
        """Same matrix/solver, very different tol: bucketed admission
        separates the keys; fifo keeps them together."""
        *_, n = lap
        fifo = ServiceHarness(reg, block_width=4)
        easy_f = fifo.submit("lap", _b(n, 1), tol=1e-2, maxiter=10**6)
        hard_f = fifo.submit("lap", _b(n, 2), tol=1e-12, maxiter=10**6)
        assert easy_f.key == hard_f.key and easy_f.key[6] == ""
        assert easy_f.pred_iters is None         # fifo never predicts

        buck = ServiceHarness(reg, block_width=4, admission="bucketed",
                              bucket_base=2.0)
        easy = buck.submit("lap", _b(n, 1), tol=1e-2, maxiter=10**6)
        hard = buck.submit("lap", _b(n, 2), tol=1e-12, maxiter=10**6)
        assert easy.key[:6] == hard.key[:6]      # same config...
        assert easy.key[6] != hard.key[6]        # ...different bucket
        assert 0 < easy.pred_iters < hard.pred_iters
        buck.drain()
        assert buck.service.stats["batches_opened"] == 2
        assert easy.result.converged and hard.status == "done"
        assert_consistent(buck.service, [easy, hard])

    def test_predicted_iters_scales_with_tol_and_clamps(self, reg):
        p_loose = reg.predicted_iters("lap", tol=1e-2)
        p_tight = reg.predicted_iters("lap", tol=1e-12)
        assert 1 <= p_loose < p_tight
        assert reg.predicted_iters("lap", tol=1e-12, maxiter=7) == 7
        with pytest.raises(ValueError, match="unknown solver"):
            reg.predicted_iters("lap", solver="gmres")
        with pytest.raises(ValueError, match="tol"):
            reg.predicted_iters("lap", tol=0.0)
        # the prediction rides the cached bounds: no second Lanczos run
        assert reg.stats["bounds_computed"] == 1

    def test_dispatcher_advances_one_batch_per_step(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=2, admission="bucketed",
                           bucket_base=2.0)
        h.submit("lap", _b(n, 1), tol=1e-2, maxiter=10**6)
        h.submit("lap", _b(n, 2), tol=1e-12, maxiter=500)
        assert h.step() == 1                     # one chunk, not two
        h.drain()
        assert_consistent(h.service)

    def test_no_starvation_under_aging(self, reg, lap):
        """A straggler batch must still be scheduled within
        starvation_limit rounds even while short work keeps arriving."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=1, admission="bucketed",
                           bucket_base=2.0, chunk_iters=4,
                           starvation_limit=3)
        hard = h.submit("lap", _b(n, 0), tol=1e-12, maxiter=10**6)
        h.step()                                 # open + advance hard batch
        hard_key = hard.key
        progress = [int(h.service._batches[hard_key].state.it)]
        for i in range(12):
            h.submit("lap", _b(n, i + 1), tol=1e-2, maxiter=10**6,
                     priority=10)                # a stream of urgent work
            h.step()
            bt = h.service._batches.get(hard_key)
            progress.append(int(bt.state.it) if bt is not None else
                            progress[-1])
        # the straggler advanced despite never winning the urgency score
        assert progress[-1] > progress[0], progress
        h.drain()
        assert hard.status == "done"
        assert_consistent(h.service, [hard])


class TestAdaptiveWidth:
    def test_column_batch_width_tracks_queue_depth(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=8, admission="bucketed",
                           chunk_iters=8)
        t = h.submit("lap", _b(n), tol=1e-10, maxiter=500)
        h.step()
        assert h.service._batches[t.key].width == 1   # one request: width 1
        h.drain()

        h2 = ServiceHarness(reg, block_width=8, admission="bucketed",
                            chunk_iters=8)
        ts = [h2.submit("lap", _b(n, i), tol=1e-10, maxiter=500)
              for i in range(3)]
        h2.step()
        assert h2.service._batches[ts[0].key].width == 4  # pow2ceil(3)
        h2.drain()
        assert all(t.result.converged for t in ts)

    def test_fifo_keeps_fixed_width(self, reg, lap):
        *_, n = lap
        h = ServiceHarness(reg, block_width=8, chunk_iters=8)
        t = h.submit("lap", _b(n), tol=1e-10, maxiter=500)
        h.step()
        assert h.service._batches[t.key].width == 8
        h.drain()

    def test_block_batch_width_adapts_at_warm_restart(self, reg, lap):
        """Block batches re-init on refill; the restart repacks the
        survivors and resizes to demand."""
        *_, n = lap
        h = ServiceHarness(reg, block_width=4, admission="bucketed",
                           chunk_iters=8)
        first = [h.submit("lap", _b(n, i), tol=1e-5, maxiter=500,
                          block=True) for i in range(4)]
        h.step()
        key = first[0].key
        assert h.service._batches[key].width == 4
        # after the first wave retires, a single follow-up shrinks it
        h.run_until(lambda: all(t.resolved for t in first))
        late = h.submit("lap", _b(n, 9), tol=1e-5, maxiter=500, block=True)
        h.run_until(lambda: late.started_at is not None)
        assert h.service._batches[key].width < 4
        h.drain()
        assert late.result.converged
        assert_consistent(h.service, first + [late])
