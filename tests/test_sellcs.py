"""SELL-C-sigma format: construction, round-trip, special cases,
permutation handling, storage efficiency.  Includes hypothesis property
tests over random sparsity patterns."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (SellCS, from_callback, from_coo, from_csr,
                        from_dense, to_dense, spmv_ref)


def random_sparse(rng, n, m, density=0.1):
    a = (rng.random((n, m)) < density) * rng.standard_normal((n, m))
    return a.astype(np.float32)


class TestConstruction:
    @pytest.mark.parametrize("C,sigma,w_align", [
        (1, 1, 1), (2, 4, 1), (4, 8, 2), (8, 16, 4), (16, 1, 8), (32, 64, 8),
    ])
    def test_roundtrip(self, rng, C, sigma, w_align):
        a = random_sparse(rng, 57, 57)
        m = from_dense(a, C=C, sigma=sigma, w_align=w_align)
        assert np.allclose(to_dense(m), a)
        assert m.nnz == (a != 0).sum()

    def test_rectangular(self, rng):
        a = random_sparse(rng, 40, 23)
        m = from_dense(a, C=8, sigma=1)
        assert not m.permuted_cols
        assert np.allclose(to_dense(m), a)

    def test_crs_is_sell_1_1(self, rng):
        """Paper section 3.1: CRS == SELL-1-1 (no padding at all)."""
        a = random_sparse(rng, 30, 30, 0.2)
        m = from_dense(a, C=1, sigma=1)
        # beta = nnz / cap can only be < 1 because empty rows take 1 slot
        nempty = int((np.count_nonzero(a, axis=1) == 0).sum())
        assert m.cap == m.nnz + nempty

    def test_sigma_sorting_improves_beta(self, rng):
        # strongly varying row lengths: sigma-sorting must reduce padding
        n = 256
        a = np.zeros((n, n), np.float32)
        for i in range(n):
            k = 1 + (i * 7) % 32
            cols = rng.choice(n, size=k, replace=False)
            a[i, cols] = 1.0
        m1 = from_dense(a, C=16, sigma=1)
        m2 = from_dense(a, C=16, sigma=256)
        assert m2.beta > m1.beta

    def test_from_csr(self, rng):
        a = random_sparse(rng, 25, 25)
        indptr = np.concatenate([[0], np.cumsum((a != 0).sum(1))])
        indices = np.concatenate([np.nonzero(a[i])[0] for i in range(25)])
        data = np.concatenate([a[i][a[i] != 0] for i in range(25)])
        m = from_csr(indptr, indices, data, (25, 25), C=4, sigma=8)
        assert np.allclose(to_dense(m), a)

    def test_from_callback(self):
        """Paper's preferred construction: per-row callback."""
        def row(i):
            cols = [i, (i + 1) % 10]
            vals = [2.0, -1.0]
            return np.array(cols), np.array(vals)

        m = from_callback(row, 10, C=2, sigma=4)
        d = to_dense(m)
        assert np.allclose(np.diag(d), 2.0)
        assert m.nnz == 20

    def test_duplicate_entries_summed(self):
        m = from_coo([0, 0], [1, 1], [2.0, 3.0], (2, 2), C=1)
        assert to_dense(m)[0, 1] == 5.0

    def test_permute_unpermute_identity(self, rng):
        a = random_sparse(rng, 37, 37)
        m = from_dense(a, C=8, sigma=16)
        v = rng.standard_normal((37, 3)).astype(np.float32)
        assert np.allclose(m.unpermute(m.permute(v)), v)

    def test_bad_inputs(self):
        with pytest.raises(ValueError):
            from_coo([5], [0], [1.0], (3, 3), C=2)
        with pytest.raises(ValueError):
            from_coo([0], [0], [1.0], (3, 3), C=4, sigma=6)  # sigma % C != 0


class TestStoredZeros:
    """Slot validity comes from construction-recorded row lengths, so
    explicitly stored zeros are structure, not padding."""

    def test_explicit_zero_counted(self):
        m = from_coo([0, 0, 1], [0, 1, 1], [0.0, 2.0, 3.0], (2, 2), C=2)
        assert m.nnz == 3
        rl = m.nnz_per_row()
        assert rl[0] == 2 and rl[1] == 1     # old vals!=0 logic said 1, 1
        assert int(m.valid_slots().sum()) == 3

    def test_duplicates_summing_to_zero_counted(self):
        m = from_coo([0, 0, 1], [1, 1, 0], [2.0, -2.0, 4.0], (2, 2), C=1)
        assert m.nnz == 2                    # deduplicated, zero-sum kept
        rl = m.nnz_per_row()
        assert rl[0] == 1 and rl[1] == 1

    def test_zero_slot_column_remapped(self, rng):
        """The permuted-column remap must include stored-zero slots; with
        sigma sorting active an unremapped column would alias another row
        after to_dense's perm mapping."""
        n = 8
        a = np.zeros((n, n), np.float32)
        # ragged row lengths to force a non-trivial sigma permutation
        for i in range(n):
            a[i, : (i % 4) + 1] = i + 1.0
        r, c = np.nonzero(a)
        v = a[r, c]
        # explicit zero stored at (0, 5)
        r = np.concatenate([r, [0]])
        c = np.concatenate([c, [5]])
        v = np.concatenate([v, [0.0]]).astype(np.float32)
        m = from_coo(r, c, v, (n, n), C=4, sigma=8)
        assert m.permuted_cols
        np.testing.assert_allclose(to_dense(m), a)
        # the zero keeps its row slot in the counts
        iperm = np.asarray(m.iperm)
        assert m.nnz_per_row()[iperm[0]] == 2

    def test_nnz_per_row_matches_dense_structure(self, rng):
        a = random_sparse(rng, 40, 40, 0.2)
        m = from_dense(a, C=8, sigma=16, w_align=2)
        perm = np.asarray(m.perm)
        want = np.zeros(m.nrows_pad, np.int64)
        counts = (a != 0).sum(axis=1)
        want[: len(perm)] = np.where(perm < m.nrows, counts[np.minimum(perm, m.nrows - 1)], 0)
        np.testing.assert_array_equal(m.nnz_per_row(), want)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(5, 80), seed=st.integers(0, 2**31 - 1),
       C=st.sampled_from([1, 2, 4, 8]), sigma_f=st.sampled_from([1, 2, 4]))
def test_property_spmv_matches_dense(n, seed, C, sigma_f):
    """Property: for any random pattern, SELL-C-sigma SpMV == dense @."""
    rng = np.random.default_rng(seed)
    a = ((rng.random((n, n)) < 0.2) * rng.standard_normal((n, n))
         ).astype(np.float32)
    sigma = 1 if sigma_f == 1 else C * sigma_f
    m = from_dense(a, C=C, sigma=sigma)
    x = rng.standard_normal(n).astype(np.float32)
    y, _, _ = spmv_ref(m, m.permute(x))
    np.testing.assert_allclose(m.unpermute(y), a @ x, atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 60), seed=st.integers(0, 2**31 - 1))
def test_property_beta_bounds(n, seed):
    """Property: 0 < beta <= 1 and cap >= nnz."""
    rng = np.random.default_rng(seed)
    a = ((rng.random((n, n)) < 0.3) * 1.0).astype(np.float32)
    m = from_dense(a, C=4, sigma=8)
    assert 0 < m.beta <= 1.0
    assert m.cap >= m.nnz
