"""ghostsan: trace-level sanitizer for the repro stack.

ghostlint (``tools/ghostlint``) checks what the *source* says; ghostsan
checks what JAX actually *builds* from it — the Pallas grid, the jaxpr,
and the jit cache.  Three analyzers, one CLI::

    PYTHONPATH=src python -m tools.ghostsan                # all rules
    PYTHONPATH=src python -m tools.ghostsan --select GS101,GS102
    PYTHONPATH=src python -m tools.ghostsan --format=json

- **GS101** grid/race analysis: concretely evaluates every output
  ``BlockSpec`` index map over the full grid of every ``*_pallas``
  wrapper (across the parity sweep's C/sigma/w_tile/store_dtype
  configuration grid) and reports out-of-bounds tiles, overlapping
  output-tile writes, and uncovered output regions.
- **GS102** dtype-flow audit: traces wrappers, ``core/spmv.py`` entry
  points, and stepper bodies with ``jax.make_jaxpr`` and walks the
  jaxpr for promotions/downcasts that violate the ``storage_acc_dtype``
  contract.
- **GS103** recompile sentry: replays an identical steady-state
  ``SolverService`` workload and ``HeterogeneousEngine`` matvec loop
  under a ``jax.monitoring`` compile listener; any compilation in the
  armed second round is retrace churn.

Findings share ghostlint's fingerprint/baseline machinery
(``tools/ghostsan/baseline.json``, committed empty) and support
``# ghostsan: disable=GS00x`` suppression comments at the anchored
source line.  See docs/static_analysis.md.
"""
from tools.ghostsan.engine import (DEFAULT_BASELINE, Finding,  # noqa: F401
                                   apply_suppressions, load_baseline,
                                   write_baseline)

ANALYZER_IDS = ("GS101", "GS102", "GS103")
