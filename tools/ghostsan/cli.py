"""ghostsan command line.

Usage::

    PYTHONPATH=src python -m tools.ghostsan               # all analyzers
    PYTHONPATH=src python -m tools.ghostsan --select GS101,GS103
    PYTHONPATH=src python -m tools.ghostsan --format=json
    PYTHONPATH=src python -m tools.ghostsan --write-baseline
    python -m tools.ghostsan --list-rules                 # no jax needed

Exit codes: 0 clean, 1 findings, 2 usage error — mirroring ghostlint.
Unlike ghostlint this tool *runs* the code under analysis, so it needs
jax importable and ``PYTHONPATH=src``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Callable, Dict, List, Optional, Tuple

from tools.ghostsan.engine import (DEFAULT_BASELINE, Finding,
                                   apply_suppressions, load_baseline,
                                   write_baseline)


def _analyzers() -> Dict[str, Tuple[str, Callable[..., List[Finding]]]]:
    # the analyzer modules defer jax/repro imports to run time, so this
    # is cheap and --list-rules works without PYTHONPATH=src
    from tools.ghostsan import gs101_grid, gs102_dtype, gs103_recompile
    return {
        gs101_grid.RULE_ID: (gs101_grid.RULE_TITLE,
                             gs101_grid.run_grid_audit),
        gs102_dtype.RULE_ID: (gs102_dtype.RULE_TITLE,
                              gs102_dtype.run_dtype_audit),
        gs103_recompile.RULE_ID: (gs103_recompile.RULE_TITLE,
                                  gs103_recompile.run_recompile_audit),
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.ghostsan",
        description=("Trace-level sanitizer: Pallas grid/race analysis, "
                     "jaxpr dtype-flow audit, and a jit recompile sentry "
                     "over the repro stack."))
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", metavar="GS10x[,GS10y]",
                    help="run only these analyzers (default: all)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file (default: tools/ghostsan/"
                         "baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings too")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    analyzers = _analyzers()
    catalog = {rid: title for rid, (title, _) in analyzers.items()}
    if args.list_rules:
        for rid in sorted(catalog):
            print(f"{rid}  {catalog[rid]}")
        return 0

    if args.select:
        wanted = {s.strip().upper() for s in args.select.split(",")
                  if s.strip()}
        unknown = wanted - set(catalog)
        if unknown:
            print(f"ghostsan: unknown analyzer id(s): "
                  f"{', '.join(sorted(unknown))} "
                  f"(known: {', '.join(sorted(catalog))})",
                  file=sys.stderr)
            return 2
    else:
        wanted = set(catalog)

    verbose = args.format == "text"
    findings: List[Finding] = []
    for rid in sorted(wanted):
        _, run = analyzers[rid]
        findings.extend(run(verbose=verbose,
                            progress=lambda m: print(f"  {m}",
                                                     file=sys.stderr)))
    findings = apply_suppressions(findings)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))

    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"ghostsan: wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    fresh = [f for f in findings if f.fingerprint not in baseline]
    baselined = len(findings) - len(fresh)

    if args.format == "json":
        print(json.dumps({
            "analyzers": sorted(wanted),
            "findings": [f.to_json() for f in fresh],
            "baselined": baselined,
        }, indent=1))
    else:
        for f in fresh:
            print(f.format())
        tail = (f"ghostsan: {len(fresh)} finding(s) from "
                f"{len(wanted)} analyzer(s)")
        if baselined:
            tail += f" ({baselined} baselined)"
        print(tail)
    return 1 if fresh else 0


if __name__ == "__main__":
    raise SystemExit(main())
