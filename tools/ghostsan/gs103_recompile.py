"""GS103 — recompile sentry over steady-state service/engine workloads.

A ``SolverService`` batch key ``(matrix, solver, dtype, precond,
store_dtype)`` and an engine matvec cache key must each compile at most
once: retire/refill churn that re-traces (varying-shape gathers, fresh
closures per refill, cache keys that include object identity) silently
turns a throughput win into a compile loop.

The sentry hooks ``jax.monitoring``'s backend-compile duration event —
XLA fires it once per actual compilation and never on a cache hit — and
splits a workload into a **warmup** round (compiles are expected and
uncounted) and an **armed** round replaying the *identical* workload:
every code path the armed round takes was already taken during warmup,
so any compile observed while armed is a retrace, and the Python stack
at that moment names the in-repo line that caused it.

``jax.monitoring`` has no per-listener unregister, so one module-level
listener is registered on first use and toggled with an armed flag.

Findings anchor at the innermost in-repo frame of the captured stack,
so ``# ghostsan: disable=GS103`` works at the churn site.
"""
from __future__ import annotations

import contextlib
import os
import traceback
from typing import Callable, List, NamedTuple, Optional

from tools.ghostsan.engine import REPO, Finding, relpath, source_line

RULE_ID = "GS103"
RULE_TITLE = ("steady-state SolverService / engine workloads compile "
              "each logical key at most once — an armed identical "
              "replay must be compile-free")

_COMPILE_EVENT_SUBSTR = "compile"


class CompileEvent(NamedTuple):
    event: str
    frames: List[traceback.FrameSummary]   # in-repo frames, outer->inner


class RecompileSentry:
    """Armable compile-event recorder (context manager arms it).

    >>> sentry = RecompileSentry()
    >>> workload()                 # warmup: compiles expected
    >>> with sentry:
    ...     workload()             # identical replay: must be quiet
    >>> sentry.events              # every compile seen while armed
    """

    _registered: Optional["RecompileSentry"] = None
    _listener_installed = False

    def __init__(self):
        self.events: List[CompileEvent] = []
        self._armed = False
        self._install()

    @classmethod
    def _install(cls) -> None:
        # single process-wide listener; instances swap themselves in
        # because jax.monitoring cannot unregister one listener
        if cls._listener_installed:
            return
        import jax.monitoring as jmon

        def listener(event: str, duration: float, **kw) -> None:
            s = cls._registered
            if s is None or not s._armed:
                return
            if _COMPILE_EVENT_SUBSTR not in event:
                return
            stack = traceback.extract_stack()
            frames = [f for f in stack
                      if f.filename.startswith(REPO)
                      and f"{os.sep}tools{os.sep}" not in f.filename]
            s.events.append(CompileEvent(event, frames))

        jmon.register_event_duration_secs_listener(listener)
        cls._listener_installed = True

    def __enter__(self) -> "RecompileSentry":
        type(self)._registered = self
        self._armed = True
        return self

    def __exit__(self, *exc) -> None:
        self._armed = False
        type(self)._registered = None

    def findings(self, workload: str) -> List[Finding]:
        out = []
        for ev in self.events:
            if ev.frames:
                inner = ev.frames[-1]
                path = relpath(inner.filename)
                line = int(inner.lineno or 0)
                text = source_line(inner.filename, line)
                site = " <- ".join(
                    f"{os.path.basename(f.filename)}:{f.lineno}:{f.name}"
                    for f in ev.frames[-4:])
            else:
                path, line, text, site = "<unknown>", 0, "", "(no in-repo frames)"
            out.append(Finding(
                rule=RULE_ID, path=path, line=line,
                message=(f"[{workload}] steady-state recompile "
                         f"({ev.event}) — identical replay re-traced at "
                         f"{site}"),
                text=text))
        return out


def audit_workload(workload: Callable[[], None], *, warmup_rounds: int = 1,
                   name: str = "workload") -> List[Finding]:
    """Run ``workload`` ``warmup_rounds`` times, then once armed.

    ``workload`` must be *replayable*: same requests, same shapes, same
    seeds each call — that is the invariant that makes any armed-round
    compile a genuine retrace.  Public seam for the churn fixtures.
    """
    sentry = RecompileSentry()
    for _ in range(max(1, warmup_rounds)):
        workload()
    with sentry:
        workload()
    return sentry.findings(name)


# -------------------------------------------------------- in-tree drives
def _service_workload() -> Callable[[], None]:
    """A mixed cg/minres workload with enough requests to force the
    retire/refill path, plain and preconditioned batch keys, and varied
    tolerances so retirement order differs across the drain."""
    import numpy as np

    from repro.core import sellcs
    from repro.runtime.service import MatrixRegistry, SolverService

    n = 48
    rng = np.random.default_rng(3)
    dense = np.where(rng.random((n, n)) < 0.2,
                     rng.standard_normal((n, n)), 0.0)
    dense = dense + dense.T + np.eye(n) * 10.0

    reg = MatrixRegistry()
    reg.register("gs103", sellcs.from_dense(dense, C=4, sigma=16,
                                            dtype=np.float32))
    svc = SolverService(reg, block_width=4, chunk_iters=4)
    tols = [1e-3, 1e-5, 1e-7, 1e-8, 1e-4, 1e-6]

    def round_() -> None:
        r = np.random.default_rng(11)        # re-seeded: identical rhs
        for i in range(10):
            solver = "minres" if i % 3 == 2 else "cg"
            precond = "block_jacobi" if i % 4 == 3 else None
            b = np.asarray(r.standard_normal(n), np.float32)
            svc.submit("gs103", b, solver=solver, tol=tols[i % len(tols)],
                       precond=precond)
        svc.drain()

    return round_


def _engine_workload() -> Callable[[], None]:
    """A HeterogeneousEngine overlapped-matvec loop on one shard."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.spmv import SpmvOpts
    from repro.runtime.engine import HeterogeneousEngine

    n = 64
    rng = np.random.default_rng(5)
    mask = rng.random((n, n)) < 0.2
    np.fill_diagonal(mask, True)
    rows, cols = np.nonzero(mask)
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    eng = HeterogeneousEngine(rows, cols, vals, n, nshards=1, C=8, sigma=8)
    opts = SpmvOpts(dot_yy=True)

    def round_() -> None:
        x = jnp.ones((n, 2), jnp.float32)
        for _ in range(3):
            x, _ = eng.spmv(x, opts=opts)
        jax.block_until_ready(x)

    return round_


def run_recompile_audit(verbose: bool = False,
                        progress=None) -> List[Finding]:
    """GS103 over the in-tree service + engine steady-state workloads."""
    from repro.core import execution

    findings: List[Finding] = []
    with execution.force(interpret=True):
        for name, build in (("SolverService", _service_workload),
                            ("HeterogeneousEngine", _engine_workload)):
            if verbose and progress:
                progress(f"GS103 {name} (warmup + armed replay)")
            findings.extend(
                audit_workload(build(), warmup_rounds=1, name=name))
    return findings
