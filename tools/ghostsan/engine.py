"""ghostsan engine: findings, suppressions, baseline.

Reuses ghostlint's machinery wholesale — :class:`Finding` (and its
line-number-free fingerprint), the tokenize-based suppression scanner,
and the JSON baseline format — under the ``ghostsan:`` comment prefix
and a separate committed baseline.  A dynamic finding is anchored at a
*source* location (the wrapper def, the audited entry point, or the
innermost in-repo frame that triggered a recompile), so the same
``# ghostsan: disable=GS00x`` inline escape hatch works even though the
analysis itself never parses that file.
"""
from __future__ import annotations

import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# Finding + baseline format are shared with ghostlint: one fingerprint
# definition, one JSON schema, two tools.
from tools.ghostlint.engine import (REPO, Finding, is_suppressed,  # noqa: F401
                                    load_baseline, relpath, write_baseline)
from tools.ghostlint.engine import suppressed_lines as _gl_suppressed_lines

#: ``# ghostsan: disable=GS101`` / ``disable=GS101,GS102`` / ``disable=all``
_SUPPRESS_RE = re.compile(
    r"#\s*ghostsan:\s*disable=([A-Za-z0-9_,\s]+|all)")
_FILE_SUPPRESS_RE = re.compile(
    r"#\s*ghostsan:\s*disable-file=([A-Za-z0-9_,\s]+|all)")

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def suppressed_lines(source: str) -> Tuple[Dict[int, Optional[Set[str]]],
                                           Optional[Set[str]]]:
    """ghostlint's scanner with the ``ghostsan:`` comment prefix.

    Same semantics: an own-line comment suppresses the next line, an
    inline comment its own line; ``disable-file=`` is file-wide;
    comments inside string literals are inert.
    """
    return _gl_suppressed_lines(source, suppress_re=_SUPPRESS_RE,
                                file_suppress_re=_FILE_SUPPRESS_RE)


def anchor(obj) -> Tuple[str, int, str]:
    """(repo-relative path, line, stripped line text) of a Python object.

    Dynamic findings need a stable source anchor for fingerprinting and
    suppression; the def line of the audited function is that anchor.
    Falls back to ``("<unknown>", 0, "")`` for builtins/partials without
    source.
    """
    import inspect
    try:
        fn = inspect.unwrap(obj)
        path = inspect.getsourcefile(fn) or ""
        _, line = inspect.getsourcelines(fn)
    except (TypeError, OSError):
        return "<unknown>", 0, ""
    return relpath(os.path.abspath(path)), line, source_line(path, line)


def source_line(path: str, line: int) -> str:
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.read().splitlines()
    except OSError:
        return ""
    if 1 <= line <= len(lines):
        return lines[line - 1].strip()
    return ""


def apply_suppressions(findings: Sequence[Finding]) -> List[Finding]:
    """Filter findings through ``# ghostsan: disable=`` comments.

    Each finding's ``path`` is resolved against the repo root and the
    file's suppression map is consulted at the finding's line — the
    engine-side filtering mirror of ghostlint's ``lint_source``, for
    findings that were produced by tracing rather than parsing.
    """
    maps: Dict[str, Tuple[Dict[int, Optional[Set[str]]],
                          Optional[Set[str]]]] = {}
    out: List[Finding] = []
    for f in findings:
        ap = os.path.join(REPO, f.path)
        if f.path not in maps:
            try:
                with open(ap, encoding="utf-8") as fh:
                    maps[f.path] = suppressed_lines(fh.read())
            except OSError:
                maps[f.path] = ({}, None)
        per_line, file_level = maps[f.path]
        if not is_suppressed(f, per_line, file_level):
            out.append(f)
    return out


def fresh_findings(findings: Iterable[Finding],
                   baseline_path: str = DEFAULT_BASELINE,
                   use_baseline: bool = True) -> List[Finding]:
    baseline = load_baseline(baseline_path) if use_baseline else set()
    return [f for f in findings if f.fingerprint not in baseline]
