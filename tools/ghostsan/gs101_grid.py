"""GS101 — concrete grid/race analysis of Pallas output BlockSpecs.

At wrapper level every grid is concrete, so the index maps can simply be
*evaluated* over the full grid instead of reasoned about.  For each
``pl.pallas_call`` a wrapper issues, this analyzer records the grid,
specs, and scalar-prefetch operands (via a capture shim that replaces
``pallas_call`` — the kernel body never runs), then checks every output
``BlockSpec``:

- **out-of-bounds**: an index map may never produce a block index
  outside ``ceil(dim / block)`` on any axis;
- **write race**: two grid points that differ on an axis the map
  *depends on* may never produce the same output tile.  Axes the map
  ignores are reduction axes (the program revisits the tile on purpose
  — e.g. ``tsmttsm``'s single accumulator tile) and are legal;
- **uncovered region**: the set of produced tiles must cover the whole
  output — a missing tile is exactly the tail-drop bug class PR 2
  fixed by hand (Pallas leaves unwritten tiles as uninitialized or
  zero memory, silently).

The in-tree drive (:func:`run_grid_audit`) replays the parity sweep's
configuration grid (``tools/ghostlint/parity.py::iter_sweep_cases``), so
the race analysis sees the same C/sigma/w_tile/store_dtype space the
shape-parity sweep proves.

Findings anchor at the kernel body's def line in ``src/repro/kernels/``
(the construct that owns the specs), so ``# ghostsan: disable=GS101``
works at the site.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import itertools
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from tools.ghostsan.engine import Finding, anchor

RULE_ID = "GS101"
RULE_TITLE = ("Pallas output BlockSpecs: no out-of-bounds tiles, no "
              "overlapping writes, no uncovered output regions over the "
              "concrete grid")

#: full-product evaluation cap; in-tree grids are tiny (tens of points),
#: anything past this would be a config-grid bug, not a kernel to audit
MAX_GRID_POINTS = 65536


@dataclasses.dataclass
class GridCapture:
    """One recorded ``pallas_call``: everything GS101 needs, nothing run."""
    kernel_fn: Any                      # the kernel body (anchor source)
    grid: Tuple[int, ...]
    out_specs: List[Any]                # BlockSpec per output
    out_shapes: List[Any]               # ShapeDtypeStruct per output
    prefetch: List[Any]                 # concrete scalar-prefetch operands
    tag: str = ""                       # config tag from the sweep case


def _unwrap_kernel(kernel) -> Any:
    while isinstance(kernel, functools.partial):
        kernel = kernel.func
    return kernel


@contextlib.contextmanager
def capture_pallas_calls(captures: List[GridCapture]):
    """Swap ``pl.pallas_call`` for a recording shim.

    The shim returns zero-filled stand-ins of ``out_shape`` so wrapper
    post-processing (slicing off padding, unpacking dot tiles) still
    runs; the kernel body itself is never traced or executed, which
    keeps a full configuration sweep at Python speed.
    """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    real = pl.pallas_call

    def shim(kernel, *, grid_spec=None, grid=None, in_specs=None,
             out_specs=None, out_shape=None, **kw):
        if grid_spec is not None:
            g = grid_spec.grid
            outs = grid_spec.out_specs
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
        else:
            g = grid if isinstance(grid, tuple) else \
                (() if grid is None else (grid,))
            outs = out_specs
            nsp = 0
        outs = list(outs) if isinstance(outs, (list, tuple)) else [outs]
        multi = isinstance(out_shape, (list, tuple))
        shapes = list(out_shape) if multi else [out_shape]

        def runner(*operands):
            import numpy as np
            captures.append(GridCapture(
                kernel_fn=_unwrap_kernel(kernel),
                grid=tuple(int(d) for d in g),
                out_specs=outs, out_shapes=shapes,
                prefetch=[np.asarray(o) for o in operands[:nsp]]))
            res = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            return res if multi else res[0]

        return runner

    pl.pallas_call = shim
    try:
        yield captures
    finally:
        pl.pallas_call = real


def _nblocks(dims, block) -> Tuple[int, ...]:
    return tuple(-(-d // max(1, b)) for d, b in zip(dims, block))


def _finding(cap: GridCapture, message: str) -> Finding:
    path, line, text = anchor(cap.kernel_fn)
    tag = f"[{cap.tag}] " if cap.tag else ""
    return Finding(rule=RULE_ID, path=path, line=line,
                   message=f"{tag}{message}", text=text)


def analyze_capture(cap: GridCapture) -> List[Finding]:
    """Evaluate every output index map over the full grid."""
    findings: List[Finding] = []
    if not cap.grid:
        return findings
    npoints = 1
    for d in cap.grid:
        npoints *= int(d)
    if npoints > MAX_GRID_POINTS:
        return [_finding(cap, f"grid {cap.grid} has {npoints} points — "
                              f"past the {MAX_GRID_POINTS}-point audit "
                              f"cap, shrink the audited config")]

    for oi, (spec, shp) in enumerate(zip(cap.out_specs, cap.out_shapes)):
        block = getattr(spec, "block_shape", None)
        imap = getattr(spec, "index_map", None)
        if block is None or imap is None:     # pl.ANY / whole-array spec
            continue
        block = tuple(1 if b is None else int(b) for b in block)
        nblocks = _nblocks(shp.shape, block)

        def at(pt):
            idx = imap(*pt, *cap.prefetch)
            idx = idx if isinstance(idx, tuple) else (idx,)
            return tuple(int(i) for i in idx)

        # an axis the map *depends on* changes the produced tile when
        # varied alone; ignored axes are reduction axes and may legally
        # revisit a tile
        dep = []
        origin = [0] * len(cap.grid)
        for ax in range(len(cap.grid)):
            seen = set()
            pt = list(origin)
            for v in range(cap.grid[ax]):
                pt[ax] = v
                seen.add(at(tuple(pt)))
            dep.append(len(seen) > 1)

        tiles: Dict[Tuple[int, ...], List[Tuple[int, ...]]] = {}
        for pt in itertools.product(*(range(d) for d in cap.grid)):
            tiles.setdefault(at(pt), []).append(pt)

        name = getattr(cap.kernel_fn, "__name__", "<kernel>")
        for idx in sorted(tiles):
            if len(idx) != len(nblocks):
                findings.append(_finding(
                    cap, f"{name} out[{oi}]: index map returns rank "
                         f"{len(idx)} for a rank-{len(nblocks)} output"))
                break
            if any(i < 0 or i >= nb for i, nb in zip(idx, nblocks)):
                findings.append(_finding(
                    cap, f"{name} out[{oi}]: tile {idx} out of bounds "
                         f"(valid block grid {nblocks}, block {block}, "
                         f"output {tuple(shp.shape)})"))

        for idx, pts in tiles.items():
            if len(pts) < 2:
                continue
            race = next(
                ((a, b) for a, b in itertools.combinations(pts, 2)
                 if any(x != y and dep[ax]
                        for ax, (x, y) in enumerate(zip(a, b)))), None)
            if race is not None:
                findings.append(_finding(
                    cap, f"{name} out[{oi}]: write race — grid points "
                         f"{race[0]} and {race[1]} differ on a depended-"
                         f"on axis yet both write tile {idx}"))

        missing = [i for i in itertools.product(
            *(range(nb) for nb in nblocks)) if i not in tiles]
        if missing:
            shown = ", ".join(map(str, missing[:4]))
            more = f" (+{len(missing) - 4} more)" if len(missing) > 4 else ""
            findings.append(_finding(
                cap, f"{name} out[{oi}]: uncovered output tiles "
                     f"{shown}{more} — block grid {nblocks} from block "
                     f"{block} over {tuple(shp.shape)}, the tail-drop "
                     f"bug class"))
    return findings


def audit_callable(fn: Callable[[], Any], tag: str = "") -> List[Finding]:
    """Capture + analyze every ``pallas_call`` a zero-arg thunk issues.

    The public seam the tests' seeded-bug fixtures drive; the in-tree
    audit is this applied to every parity sweep case.
    """
    from repro.core import execution

    captures: List[GridCapture] = []
    with execution.force(interpret=True), capture_pallas_calls(captures):
        fn()
    findings: List[Finding] = []
    for cap in captures:
        cap.tag = tag
        findings.extend(analyze_capture(cap))
    return findings


def run_grid_audit(verbose: bool = False,
                   progress: Optional[Callable[[str], None]] = None,
                   ) -> List[Finding]:
    """GS101 over the in-tree kernels across the parity config grid."""
    from tools.ghostlint.parity import iter_sweep_cases

    findings: List[Finding] = []
    seen_tags = 0
    for case in iter_sweep_cases():
        seen_tags += 1
        if verbose and progress:
            progress(f"GS101 {case.tag}")
        findings.extend(audit_callable(case.kernel, tag=case.tag))
    if seen_tags == 0:
        raise RuntimeError("GS101: parity sweep yielded no cases — the "
                           "sweep registry is broken, not the kernels")
    return findings
