"""GS102 — jaxpr dtype-flow audit of the storage/accumulate contract.

The contract (``core/spmv.py::storage_acc_dtype`` / ``dot_acc_dtype``):
bf16/f16 are *storage* formats — narrow values widen exactly once on
the way into an accumulator, accumulators never silently drop to the
storage dtype, and under x64 the f64 results never round-trip through
f32.  ghostlint's GL003 checks that source code *names* the contract;
this analyzer traces the real program with ``jax.make_jaxpr`` and walks
every equation (recursing into ``pallas_call`` kernel jaxprs and
control-flow sub-jaxprs) for three violation classes:

- **narrow accumulation** — a ``dot_general``/``reduce_sum``/``cumsum``
  whose float output is below 32 bits: the reduction itself runs in the
  storage dtype;
- **downcast below compute** — a float→float ``convert_element_type``
  to a dtype narrower than both its input and the target's declared
  compute dtype: a value silently lost precision mid-flow (a *boundary*
  cast down to the compute dtype itself, e.g. an f64 Kahan dot folding
  back into f32 solver state, is legal);
- **storage round-trip** — an upcast whose operand was itself produced
  by a downcast: the tell-tale of a result bounced through a narrower
  dtype (x64 results through f32, f32 accumulators through bf16).

Findings anchor at the audited entry point's def line, so
``# ghostsan: disable=GS102`` works there.
"""
from __future__ import annotations

from typing import Any, Callable, Iterator, List, NamedTuple, Tuple

from tools.ghostsan.engine import Finding, anchor

RULE_ID = "GS102"
RULE_TITLE = ("traced dtype flow honors the storage/accumulate "
              "contract: no narrow accumulation, no downcast below the "
              "compute dtype, no storage round-trips")

_ACC_PRIMS = ("dot_general", "reduce_sum", "cumsum")


def _iter_eqns(jaxpr) -> Iterator[Any]:
    """Every equation, recursing through sub-jaxprs (scan/while/cond
    bodies, custom_jvp calls, and ``pallas_call`` kernel jaxprs)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                subj = getattr(sub, "jaxpr", None)
                if subj is not None and hasattr(subj, "eqns"):
                    yield from _iter_eqns(subj)


def _bits(dtype) -> int:
    import numpy as np
    return int(np.dtype(dtype).itemsize) * 8


def _is_float(dtype) -> bool:
    import jax.numpy as jnp
    return jnp.issubdtype(dtype, jnp.floating)


def audit_jaxpr(jaxpr, *, compute_bits: int, target: str,
                anchor_obj: Any) -> List[Finding]:
    """Walk one jaxpr for the three violation classes."""
    path, line, text = anchor(anchor_obj)

    def finding(msg: str) -> Finding:
        return Finding(rule=RULE_ID, path=path, line=line,
                       message=f"[{target}] {msg}", text=text)

    findings: List[Finding] = []
    downcasts = {}                       # outvar -> (src_bits, dst_bits)
    for eqn in _iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim in _ACC_PRIMS:
            out = eqn.outvars[0].aval
            if _is_float(out.dtype) and _bits(out.dtype) < 32:
                findings.append(finding(
                    f"narrow accumulation: {prim} reduces in "
                    f"{out.dtype} — widen the operands first "
                    f"(storage_acc_dtype) so the sum runs >= f32"))
        elif prim == "convert_element_type":
            src = eqn.invars[0].aval.dtype
            dst = eqn.outvars[0].aval.dtype
            if not (_is_float(src) and _is_float(dst)):
                continue
            sb, db = _bits(src), _bits(dst)
            if db < sb:
                downcasts[eqn.outvars[0]] = (sb, db)
                if db < compute_bits:
                    findings.append(finding(
                        f"downcast below compute dtype: {src} -> {dst} "
                        f"with declared compute width {compute_bits} "
                        f"bits — a mid-flow value lost precision"))
            elif db > sb and eqn.invars[0] in downcasts:
                osb, odb = downcasts[eqn.invars[0]]
                findings.append(finding(
                    f"storage round-trip: a {osb}-bit value was cast "
                    f"down to {odb} bits and back up to {db} — the "
                    f"intermediate narrowing silently quantized it"))
    return findings


def audit_function(fn: Callable, *example_args, compute_bits: int = 32,
                   target: str = "", anchor_obj: Any = None,
                   ) -> List[Finding]:
    """Trace ``fn(*example_args)`` and audit the resulting jaxpr.

    The public seam for seeded-bug fixtures; the in-tree audit builds
    concrete targets and funnels them through here.
    """
    import jax
    closed = jax.make_jaxpr(fn)(*example_args)
    return audit_jaxpr(closed.jaxpr, compute_bits=compute_bits,
                       target=target or getattr(fn, "__name__", "<fn>"),
                       anchor_obj=anchor_obj if anchor_obj is not None
                       else fn)


class _Target(NamedTuple):
    name: str
    fn: Callable                        # traced callable
    args: Tuple[Any, ...]
    compute_bits: int
    anchor_obj: Any                     # where the finding points


def _solver_targets(dense, *, store_dtype, tag) -> Iterator[_Target]:
    import importlib
    import numpy as np
    import jax.numpy as jnp

    from repro.core import sellcs
    from repro.solvers.operator import GhostOperator

    cg = importlib.import_module("repro.solvers.cg")
    minres = importlib.import_module("repro.solvers.minres")
    stepper = importlib.import_module("repro.solvers.stepper")

    n = dense.shape[0]
    A = sellcs.from_dense(dense, C=4, sigma=16, dtype=np.float32,
                          store_dtype=store_dtype)
    op = GhostOperator(A)
    B = jnp.ones((n, 2), jnp.float32)

    st = cg.cg_init(op, B)
    yield _Target(f"cg_step[{tag}]",
                  lambda s: cg.cg_step(op, s, 0), (st,), 32, cg.cg_step)
    mst = minres.minres_init(op, B)
    yield _Target(f"minres_step[{tag}]",
                  lambda s: minres.minres_step(op, s, 0), (mst,), 32,
                  minres.minres_step)
    # the chunked driver: the while_loop body run_chunk actually jits —
    # trace the loop itself so merge/termination plumbing is audited too
    yield _Target(
        f"run_chunk.cg[{tag}]",
        lambda s: stepper.run_chunk(op, "cg", 2, s,
                                    lambda o, x: cg.cg_step(o, x, 0)),
        (st,), 32, stepper.run_chunk)
    # shared-Krylov block steppers: the SVQB/Gram/band-QR small-matrix
    # algebra must hold the same contract as the column recurrences
    blockm = importlib.import_module("repro.solvers.block")
    bst = cg.cg_init(op, B, block=True)
    yield _Target(f"block_cg_step[{tag}]",
                  lambda s: cg.cg_step(op, s, 0), (bst,), 32,
                  blockm.block_cg_body)
    bmst = minres.minres_init(op, B, block=True)
    yield _Target(f"block_minres_step[{tag}]",
                  lambda s: minres.minres_step(op, s, 0), (bmst,), 32,
                  blockm.block_minres_body)


def iter_targets() -> Iterator[_Target]:
    """Concrete in-tree audit targets: kernel wrappers, core entry
    points, and stepper bodies, in f32 and bf16-storage flavors, plus an
    x64 flavor guarding the f64-through-f32 round-trip."""
    import numpy as np
    import jax.numpy as jnp

    from repro.core import sellcs
    from repro.core.spmv import (SpmvOpts, fused_dots, spmv_ref,
                                 storage_acc_dtype)
    from repro.kernels import ops

    n = 48
    rng = np.random.default_rng(7)
    dense = np.where(rng.random((n, n)) < 0.25,
                     rng.standard_normal((n, n)), 0.0)
    dense = dense + dense.T + np.eye(n) * 8.0      # SPD for the solvers

    opts = SpmvOpts(dot_yy=True, dot_xy=True)
    for store in (None, "bfloat16", "float16"):
        A = sellcs.from_dense(dense, C=4, sigma=16, dtype=np.float32,
                              store_dtype=store)
        x = jnp.ones((n, 2), jnp.float32)
        y = jnp.ones((n, 2), jnp.float32)
        cb = _bits(storage_acc_dtype(A.dtype))
        tag = store or "f32"
        yield _Target(f"spmv_ref[{tag}]",
                      lambda xv, yv, A=A: spmv_ref(A, xv, yv, None, opts),
                      (x, y), cb, spmv_ref)
        yield _Target(f"ops.sellcs_spmv[{tag}]",
                      lambda xv, yv, A=A: ops.sellcs_spmv(
                          A, xv, yv, opts=opts),
                      (x, y), cb, ops.sellcs_spmv)

    V = jnp.ones((40, 4), jnp.float32)
    W = jnp.ones((40, 4), jnp.float32)
    X = jnp.ones((4, 4), jnp.float32)
    yield _Target("ops.tsmttsm", lambda a, b: ops.tsmttsm(a, b), (V, W),
                  32, ops.tsmttsm)
    yield _Target("ops.tsmm", lambda a, b: ops.tsmm(a, b), (V, X),
                  32, ops.tsmm)
    yield _Target("ops.fused_axpby_dots",
                  lambda a, b: ops.fused_axpby_dots(a, b, dot_yy=True),
                  (V, W), 32, ops.fused_axpby_dots)
    yield _Target("fused_dots",
                  lambda a, b: fused_dots(a, b, opts), (V, W),
                  32, fused_dots)

    yield from _solver_targets(dense, store_dtype=None, tag="f32")
    yield from _solver_targets(dense, store_dtype="bfloat16", tag="bf16")


def _iter_x64_targets() -> Iterator[_Target]:
    import numpy as np
    import jax.numpy as jnp

    from repro.core import sellcs
    from repro.core.spmv import SpmvOpts, spmv_ref

    n = 32
    rng = np.random.default_rng(7)
    dense = np.where(rng.random((n, n)) < 0.3,
                     rng.standard_normal((n, n)), 0.0)
    dense = dense + dense.T + np.eye(n) * 8.0
    A = sellcs.from_dense(dense, C=4, sigma=8, dtype=np.float64)
    x = jnp.ones((n, 2), jnp.float64)
    opts = SpmvOpts(dot_yy=True)
    yield _Target("spmv_ref[x64]",
                  lambda xv: spmv_ref(A, xv, None, None, opts), (x,),
                  64, spmv_ref)


def run_dtype_audit(verbose: bool = False,
                    progress=None) -> List[Finding]:
    """GS102 over the in-tree targets (default-precision and x64)."""
    import jax

    from repro.core import execution

    findings: List[Finding] = []
    with execution.force(interpret=True):
        for t in iter_targets():
            if verbose and progress:
                progress(f"GS102 {t.name}")
            findings.extend(audit_function(
                t.fn, *t.args, compute_bits=t.compute_bits,
                target=t.name, anchor_obj=t.anchor_obj))
        # x64 scope: f64 results must not round-trip through f32
        with jax.experimental.enable_x64():
            for t in _iter_x64_targets():
                if verbose and progress:
                    progress(f"GS102 {t.name}")
                findings.extend(audit_function(
                    t.fn, *t.args, compute_bits=t.compute_bits,
                    target=t.name, anchor_obj=t.anchor_obj))
    return findings
