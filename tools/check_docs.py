#!/usr/bin/env python
"""Docs link checker (CI: the ``docs-check`` job).

Validates, across ``README.md`` and ``docs/*.md``:

1. **Relative markdown links** ``[text](path[#anchor])`` resolve to an
   existing file, and an ``#anchor`` into a markdown target matches one
   of its headings (GitHub slug rules).
2. **Reachability**: every ``docs/*.md`` is linked from the README (the
   "Docs index" acceptance criterion — no orphaned doc pages).
3. **Code anchors**: backticked references like ``core/sellcs.py`` or
   ``core/sellcs.py:from_coo`` name a real file (searched at the repo
   root and under ``src/repro``) and, when a ``:symbol`` is given, the
   symbol actually occurs in that file — so a refactor that renames a
   function fails the docs job instead of silently rotting the docs.

Exit code 0 = clean; 1 = problems (each printed as ``file: message``).
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
# `path/to/file.py` or `path/file.py:symbol` / `path/file.py::symbol`
CODE_REF_RE = re.compile(
    r"`([\w][\w/.\-]*\.(?:py|md|yml))(?:::?([A-Za-z_][\w.]*))?`")


def github_slug(heading: str) -> str:
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def heading_slugs(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def resolve_code_ref(path: str) -> str | None:
    """Find a backticked code path at the repo root or under src/repro."""
    for base in (REPO, os.path.join(REPO, "src", "repro"),
                 os.path.join(REPO, "src")):
        cand = os.path.join(base, path)
        if os.path.isfile(cand):
            return cand
    return None


def check_file(md_path: str, errors: list) -> None:
    rel = os.path.relpath(md_path, REPO)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()

    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        path, _, anchor = target.partition("#")
        if path:
            dest = os.path.normpath(
                os.path.join(os.path.dirname(md_path), path))
            if not os.path.exists(dest):
                errors.append(f"{rel}: broken link target {target!r}")
                continue
        else:
            dest = md_path                       # same-file anchor
        if anchor and dest.endswith(".md"):
            if anchor not in heading_slugs(dest):
                errors.append(
                    f"{rel}: anchor #{anchor} not found in "
                    f"{os.path.relpath(dest, REPO)}")

    for m in CODE_REF_RE.finditer(text):
        path, symbol = m.group(1), m.group(2)
        if "/" not in path:       # bare filenames are prose, not anchors
            continue
        dest = resolve_code_ref(path)
        if dest is None:
            errors.append(f"{rel}: code reference `{path}` does not exist")
            continue
        if symbol:
            with open(dest, encoding="utf-8") as f:
                if symbol.split(".")[0] not in f.read():
                    errors.append(
                        f"{rel}: symbol {symbol!r} not found in `{path}`")


def main() -> int:
    readme = os.path.join(REPO, "README.md")
    docs = sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))
    errors: list = []

    for md in [readme] + docs:
        check_file(md, errors)

    # every doc page must be reachable from the README (docs index)
    with open(readme, encoding="utf-8") as f:
        readme_targets = {
            os.path.normpath(os.path.join(REPO, t.partition("#")[0]))
            for t in LINK_RE.findall(f.read()) if "://" not in t}
    for md in docs:
        if os.path.normpath(md) not in readme_targets:
            errors.append(
                f"README.md: docs/{os.path.basename(md)} is not linked "
                f"from the README docs index")

    if errors:
        print(f"docs check FAILED ({len(errors)} problem(s)):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs check OK ({1 + len(docs)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
