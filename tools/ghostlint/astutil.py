"""Small AST helpers shared by the ghostlint rules."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Sequence, Set, Tuple

FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)
SCOPE_NODES = FUNC_NODES + (ast.Lambda,)


def name_chain(node: ast.AST) -> str:
    """Dotted name of a Name/Attribute chain (``pl.pallas_call``), else ''."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def root_name(node: ast.AST) -> str:
    """Leftmost Name of an expression chain (attribute/subscript/call)."""
    while True:
        if isinstance(node, ast.Attribute):
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            break
    return node.id if isinstance(node, ast.Name) else ""


def walk_with_parents(tree: ast.AST) -> Iterator[Tuple[ast.AST,
                                                       List[ast.AST]]]:
    """Yield (node, ancestor_stack) pairs, outermost ancestor first."""
    stack: List[ast.AST] = []

    def rec(node: ast.AST):
        yield node, list(stack)
        stack.append(node)
        for child in ast.iter_child_nodes(node):
            yield from rec(child)
        stack.pop()

    yield from rec(tree)


def enclosing_function(parents: Sequence[ast.AST]
                       ) -> Optional[ast.AST]:
    for p in reversed(parents):
        if isinstance(p, FUNC_NODES):
            return p
    return None


def local_defs(func: ast.AST) -> dict:
    """Name -> FunctionDef for defs nested directly anywhere in ``func``."""
    out = {}
    for node in ast.walk(func):
        if isinstance(node, FUNC_NODES) and node is not func:
            out[node.name] = node
    return out


def bound_names(func: ast.AST) -> Set[str]:
    """Names bound inside a function scope (params, assignments, defs,
    imports, comprehension targets), *excluding* nested function bodies'
    own locals but *including* the nested function names themselves."""
    names: Set[str] = set()
    if isinstance(func, ast.Lambda):
        args = func.args
    else:
        args = func.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        names.add(a.arg)
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)

    body = func.body if isinstance(func.body, list) else [func.body]

    def visit(node: ast.AST):
        if isinstance(node, SCOPE_NODES):
            if isinstance(node, FUNC_NODES):
                names.add(node.name)
            return                                   # do not descend
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
        if isinstance(node, ast.ClassDef):
            names.add(node.name)
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in body:
        visit(stmt)
    return names


def free_names(func: ast.AST, enclosing: Sequence[ast.AST]) -> Set[str]:
    """Names loaded in ``func`` that are bound in an enclosing *function*
    scope — i.e. genuine closure captures (module globals excluded)."""
    own = bound_names(func)
    outer: Set[str] = set()
    for scope in enclosing:
        if isinstance(scope, SCOPE_NODES):
            outer |= bound_names(scope)
    loads: Set[str] = set()

    body = func.body if isinstance(func.body, list) else [func.body]

    def visit(node: ast.AST):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            loads.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in body:
        visit(stmt)
    return (loads - own) & outer


def scope_assignments(scope: ast.AST) -> dict:
    """Last assignment expression for each name assigned directly in the
    scope (nested function bodies excluded)."""
    out = {}

    def visit(node: ast.AST):
        if isinstance(node, SCOPE_NODES) and node is not scope:
            return
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = node.value
                elif isinstance(tgt, (ast.Tuple, ast.List)):
                    for el in tgt.elts:
                        if isinstance(el, ast.Name):
                            out[el.id] = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if isinstance(node.target, ast.Name):
                out[node.target.id] = node.value
        for child in ast.iter_child_nodes(node):
            visit(child)

    body = scope.body if isinstance(scope.body, list) else [scope.body]
    for stmt in body:
        visit(stmt)
    return out


def param_annotations(func: ast.AST) -> dict:
    """Param name -> annotation source string ('' when unannotated)."""
    out = {}
    if isinstance(func, ast.Lambda):
        args = func.args
    else:
        args = func.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        out[a.arg] = ast.unparse(a.annotation) if a.annotation else ""
    if args.vararg:
        out[args.vararg.arg] = ""
    if args.kwarg:
        out[args.kwarg.arg] = ""
    return out


def is_dtype_literal(node: ast.AST) -> bool:
    """``jnp.float32`` / ``np.float64`` / ``"float32"``-style literals."""
    _DTYPES = {"float64", "float32", "float16", "bfloat16",
               "complex64", "complex128"}
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value in _DTYPES
    if isinstance(node, ast.Attribute) and node.attr in _DTYPES:
        root = root_name(node)
        return root in ("jnp", "np", "jax", "numpy")
    # jnp.dtype(jnp.float32) — unwrap one dtype() call
    if isinstance(node, ast.Call) and name_chain(node.func).endswith("dtype"):
        return any(is_dtype_literal(a) for a in node.args)
    return False
