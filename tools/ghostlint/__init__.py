"""ghostlint — repo-specific static analysis for the GHOST/Pallas stack.

The repo's performance and correctness story rests on a handful of
implementation invariants (execution-policy routing, the storage-vs-
compute accumulation contract, weakref cache discipline, trace safety,
``python -O``-proof host validation, kernel/reference parity).  Each is
trivial to break silently in review; ghostlint machine-checks them.

Usage::

    python -m tools.ghostlint src/                # lint, text output
    python -m tools.ghostlint src/ --format=json  # CI
    python -m tools.ghostlint --list-rules
    PYTHONPATH=src python -m tools.ghostlint --parity-sweep

Suppression: append ``# ghostlint: disable=GL004`` to the offending line
(or put the comment alone on the line above).  Intentional findings that
cannot carry a comment live in ``tools/ghostlint/baseline.json``
(``--write-baseline`` regenerates it).  See ``docs/static_analysis.md``.
"""
from tools.ghostlint.engine import (Finding, FileContext, lint_paths,
                                    lint_source, load_baseline)

__all__ = ["Finding", "FileContext", "lint_paths", "lint_source",
           "load_baseline"]
