from tools.ghostlint.cli import main

raise SystemExit(main())
