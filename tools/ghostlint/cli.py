"""ghostlint command line.

Usage::

    python -m tools.ghostlint src/                 # lint, text output
    python -m tools.ghostlint src/ --format=json   # machine-readable
    python -m tools.ghostlint src/ --write-baseline
    python -m tools.ghostlint --select GL004,GL005 src/
    python -m tools.ghostlint --list-rules
    python -m tools.ghostlint --parity-sweep       # eval_shape grid (needs jax)

Exit codes: 0 clean, 1 findings (or parity mismatches), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from tools.ghostlint.engine import (DEFAULT_BASELINE, Finding, lint_paths,
                                    load_baseline, write_baseline)
from tools.ghostlint.rules import ALL_RULES, RULES_BY_ID


def _select_rules(spec: Optional[str]):
    if not spec:
        return ALL_RULES
    wanted = {s.strip().upper() for s in spec.split(",") if s.strip()}
    unknown = wanted - set(RULES_BY_ID)
    if unknown:
        raise SystemExit(
            f"ghostlint: unknown rule id(s): {', '.join(sorted(unknown))} "
            f"(known: {', '.join(sorted(RULES_BY_ID))})")
    return [RULES_BY_ID[r] for r in sorted(wanted)]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.ghostlint",
        description=("JAX/Pallas-aware static analysis for the repro "
                     "stack's kernel, dtype, and cache invariants."))
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", metavar="GL00x[,GL00y]",
                    help="run only these rules")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file (default: tools/ghostlint/"
                         "baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings too")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--include-tests", action="store_true",
                    help="also lint test_*.py / tests/ files")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--parity-sweep", action="store_true",
                    help="run the GL007 jax.eval_shape kernel/reference "
                         "sweep instead of static linting (needs jax and "
                         "PYTHONPATH=src)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.RULE_ID}  {rule.RULE_TITLE}")
        return 0

    if args.parity_sweep:
        from tools.ghostlint.parity import run_parity_sweep
        mismatches = run_parity_sweep(verbose=args.format == "text")
        if args.format == "json":
            print(json.dumps({"parity_mismatches": mismatches}, indent=1))
        elif mismatches:
            for m in mismatches:
                print(f"parity: {m}")
        else:
            print("parity sweep: all kernel/reference pairs agree")
        return 1 if mismatches else 0

    if not args.paths:
        ap.print_usage(sys.stderr)
        print("ghostlint: no paths given (try: python -m tools.ghostlint "
              "src/)", file=sys.stderr)
        return 2

    try:
        rules = _select_rules(args.select)
    except SystemExit as e:
        print(e, file=sys.stderr)
        return 2

    findings, files_checked = lint_paths(
        args.paths, rules=rules, include_tests=args.include_tests)

    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"ghostlint: wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    fresh = [f for f in findings if f.fingerprint not in baseline]
    suppressed_by_baseline = len(findings) - len(fresh)

    if args.format == "json":
        print(json.dumps({
            "files_checked": files_checked,
            "findings": [f.to_json() for f in fresh],
            "baselined": suppressed_by_baseline,
        }, indent=1))
    else:
        for f in fresh:
            print(f.format())
        tail = (f"ghostlint: {len(fresh)} finding(s) in "
                f"{files_checked} file(s)")
        if suppressed_by_baseline:
            tail += f" ({suppressed_by_baseline} baselined)"
        print(tail)
    return 1 if fresh else 0


if __name__ == "__main__":
    raise SystemExit(main())
