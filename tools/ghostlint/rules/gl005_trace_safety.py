"""GL005 — trace safety: no Python control flow on traced values.

Inside a jitted function or a ``lax.while_loop``/``fori_loop``/``scan``/
``cond`` body, the array arguments are tracers.  Python ``if``/``while``
on them, or ``bool()``/``int()``/``float()``/``.item()`` conversions,
either raise ``TracerBoolConversionError`` at trace time or — worse —
silently bake one branch into the compiled program (the
``DistOperator._mask`` tracer leak PR 3 fixed was this class).

Conservative intra-procedural dataflow, matching the repo's conventions:

* *traced* seeds: positional parameters of a traced context (jit-
  decorated functions, functions passed to ``jax.jit``/``lax.*`` loop
  combinators/``shard_map``, and ``_kernel``-style Pallas bodies in
  ``kernels/`` files);
* keyword-only parameters are **static** (the repo binds compile-time
  flags via ``functools.partial(..., flag=...)`` — keyword-only by
  convention);
* taint propagates through assignments, but *not* through static
  extractors: ``.shape``/``.ndim``/``.dtype``/``.size``, ``len()``,
  ``isinstance()``, ``jnp.result_type``/``iscomplexobj``/``issubdtype``/
  ``finfo``/``iinfo``/``dtype``/``ndim``/``shape``, string formatting;
* ``x is None`` / ``x is not None`` comparisons are always trace-safe
  (tracers are never ``None`` — that branch is structural).
"""
from __future__ import annotations

import ast
from typing import List, Set

from tools.ghostlint.astutil import name_chain, walk_with_parents

RULE_ID = "GL005"
RULE_TITLE = ("no Python if/bool()/float()/.item() on traced values "
              "inside jitted code or lax loop bodies")

_LOOP_COMBINATORS = {"while_loop", "fori_loop", "scan", "cond", "switch",
                     "map", "associated_scan", "associative_scan",
                     "shard_map", "checkpoint", "remat", "custom_vjp",
                     "vmap", "pmap", "grad", "value_and_grad"}

#: attribute accesses on a traced value that yield *static* information
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "weak_type",
                 "sharding", "aval"}

#: calls whose result is static regardless of traced arguments
_STATIC_CALLS = {"len", "isinstance", "type", "str", "repr", "format",
                 "getattr", "hasattr", "id", "callable"}
_STATIC_CALL_SUFFIXES = ("result_type", "iscomplexobj", "issubdtype",
                         "finfo", "iinfo", "dtype", "ndim", "shape",
                         "eval_shape", "canonicalize_dtype", "zeros_like",
                         "broadcast_shapes")

#: conversions that force a concrete value out of a tracer
_CONCRETIZERS = {"bool", "int", "float", "complex"}
_CONCRETIZER_METHODS = {"item", "tolist", "__bool__", "__float__"}


def _is_jit_decorator(dec: ast.AST) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    chain = name_chain(target)
    if chain in ("jax.jit", "jit"):
        return True
    # functools.partial(jax.jit, ...)
    if (isinstance(dec, ast.Call) and chain.endswith("partial")
            and dec.args and name_chain(dec.args[0]) in ("jax.jit", "jit")):
        return True
    return False


def _traced_contexts(tree: ast.Module, ctx) -> List[ast.AST]:
    """Function/Lambda nodes whose positional params are traced."""
    out: List[ast.AST] = []
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                out.append(node)
            elif ctx.is_kernel_file and "kernel" in node.name.lower():
                out.append(node)                 # Pallas kernel body
        elif isinstance(node, ast.Call):
            chain = name_chain(node.func)
            is_jit = chain in ("jax.jit", "jit")
            last = chain.rsplit(".", 1)[-1]
            is_combinator = last in _LOOP_COMBINATORS
            if not (is_jit or is_combinator):
                continue
            for arg in node.args:
                if isinstance(arg, ast.Lambda):
                    out.append(arg)
                elif isinstance(arg, ast.Name) and arg.id in defs:
                    out.append(defs[arg.id])
    return out


def _is_static_expr(node: ast.AST, tainted: Set[str]) -> bool:
    """True when ``node`` cannot be (or expose) a traced value."""
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return True
        return _is_static_expr(node.value, tainted)
    if isinstance(node, ast.Call):
        chain = name_chain(node.func)
        last = chain.rsplit(".", 1)[-1]
        if chain in _STATIC_CALLS or last in _STATIC_CALLS:
            return True
        if any(last == s for s in _STATIC_CALL_SUFFIXES):
            return True
        return False
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id not in tainted
    if isinstance(node, (ast.Compare,)):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return True
        # '"key" in params' — structural pytree-dict membership, static
        if (all(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops)
                and isinstance(node.left, ast.Constant)
                and isinstance(node.left.value, str)):
            return True
        return all(_is_static_expr(c, tainted)
                   for c in [node.left] + node.comparators)
    if isinstance(node, ast.BoolOp):
        return all(_is_static_expr(v, tainted) for v in node.values)
    if isinstance(node, ast.UnaryOp):
        return _is_static_expr(node.operand, tainted)
    if isinstance(node, ast.BinOp):
        return (_is_static_expr(node.left, tainted)
                and _is_static_expr(node.right, tainted))
    if isinstance(node, ast.Subscript):
        return _is_static_expr(node.value, tainted)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_static_expr(e, tainted) for e in node.elts)
    if isinstance(node, ast.IfExp):
        return (_is_static_expr(node.body, tainted)
                and _is_static_expr(node.orelse, tainted))
    return False


def _tainted_names(expr: ast.AST, tainted: Set[str]) -> Set[str]:
    """Tainted names reached in ``expr`` outside static extractors."""
    hits: Set[str] = set()

    def visit(node: ast.AST) -> None:
        if _is_static_expr(node, tainted):
            return
        if isinstance(node, ast.Name) and node.id in tainted:
            hits.add(node.id)
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return hits


def _check_context(fn: ast.AST, ctx, findings: list) -> None:
    args = fn.args
    tainted: Set[str] = {a.arg for a in args.posonlyargs + args.args}
    if args.vararg:
        tainted.add(args.vararg.arg)
    # keyword-only params are static flags by repo convention

    body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]

    def visit(node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn:
            return                      # nested scopes analyzed separately
        if isinstance(node, ast.Assign):
            visit(node.value)
            if _tainted_names(node.value, tainted):
                for tgt in node.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            tainted.add(n.id)
            return
        if isinstance(node, (ast.If, ast.While)):
            hits = _tainted_names(node.test, tainted)
            if hits:
                kind = "if" if isinstance(node, ast.If) else "while"
                findings.append(ctx.finding(
                    RULE_ID, node,
                    f"Python {kind!r} on traced value(s) "
                    f"{', '.join(sorted(hits))} inside a traced "
                    f"context — use lax.cond/jnp.where, or hoist the "
                    f"decision to trace time"))
        if isinstance(node, ast.Assert):
            hits = _tainted_names(node.test, tainted)
            if hits:
                findings.append(ctx.finding(
                    RULE_ID, node,
                    f"assert on traced value(s) "
                    f"{', '.join(sorted(hits))} inside a traced "
                    f"context — concretizes a tracer (and vanishes "
                    f"under -O); use checkify or validate host-side"))
        if isinstance(node, ast.Call):
            chain = name_chain(node.func)
            if chain in _CONCRETIZERS and node.args:
                hits = _tainted_names(node.args[0], tainted)
                if hits:
                    findings.append(ctx.finding(
                        RULE_ID, node,
                        f"{chain}() concretizes traced value(s) "
                        f"{', '.join(sorted(hits))} inside a traced "
                        f"context"))
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in _CONCRETIZER_METHODS
                  and _tainted_names(node.func.value, tainted)):
                findings.append(ctx.finding(
                    RULE_ID, node,
                    f".{node.func.attr}() concretizes a traced value "
                    f"inside a traced context"))
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in body:
        visit(stmt)


def check(tree: ast.Module, ctx) -> list:
    findings: list = []
    seen = set()
    for fn in _traced_contexts(tree, ctx):
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        _check_context(fn, ctx, findings)
    return findings
