"""GL004 — cache-capture / tracer-leak discipline.

PR 3 and PR 4 each shipped a bug of this class: a callable stored in a
long-lived cache closed over something it must not own — a traced value
(tracer leak), an operator (the weakly-keyed chunk cache became an
immortal value->key cycle), or ``self`` (``lru_cache`` on a method pins
the instance forever).  The repo's discipline: cached callables close
over **weakrefs** (``stepper.run_chunk``, ``ChebyshevPreconditioner``),
and distinct auxiliary objects ride in the cache key (``extra_key=``).

Flagged:

* ``functools.lru_cache`` decorating a method (first parameter
  ``self``) — the cache holds every ``self`` it ever saw;
* a closure passed as the ``body`` of ``stepper.run_chunk`` that
  captures enclosing-scope state without an ``extra_key=`` distinguishing
  it — two bodies closing over different objects would share one
  compiled chunk;
* a callable stored into a cache container (an assignment target whose
  name contains ``cache``) capturing enclosing-scope names that are not
  provably safe.  Safe captures: ``weakref.ref(...)``/``weakref.proxy``
  results, scalar-annotated parameters (int/float/str/bool), literal
  constants, and lookups rooted in module-level ALL_CAPS registries.
  Everything else — ``self``, operators, preconditioners, arrays — must
  be rekeyed or weakly held.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from tools.ghostlint.astutil import (SCOPE_NODES, enclosing_function,
                                     free_names, local_defs, name_chain,
                                     param_annotations, root_name,
                                     scope_assignments, walk_with_parents)

RULE_ID = "GL004"
RULE_TITLE = ("callables stored in caches must not strongly capture "
              "operators/arrays/self (weakref discipline)")

_SCALAR_ANNOTATIONS = {"int", "float", "str", "bool",
                       "Optional[int]", "Optional[float]", "Optional[str]",
                       "Optional[bool]", "int | None", "float | None",
                       "str | None", "bool | None"}


def _is_lru_cache(dec: ast.AST) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    chain = name_chain(target)
    return chain in ("lru_cache", "functools.lru_cache", "cache",
                     "functools.cache")


def _is_weakref_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = name_chain(node.func)
    return chain in ("weakref.ref", "weakref.proxy", "ref", "proxy",
                     "weakref.WeakMethod")


def _safe_value(value: ast.AST) -> bool:
    """Is this assigned expression provably safe to hold strongly?"""
    if _is_weakref_call(value):
        return True
    if isinstance(value, ast.Constant):
        return True
    if (isinstance(value, ast.Call) and isinstance(value.func, ast.Name)
            and value.func.id in ("bool", "int", "float", "str")
            and not value.keywords):
        return True                         # builtin scalar cast

    if isinstance(value, ast.IfExp):        # weakref.ref(x) if ... else None
        return _safe_value(value.body) and _safe_value(value.orelse)
    root = root_name(value)
    if root and root == root.upper() and not root.startswith("_"):
        return True                         # ALL_CAPS registry lookup
    if _is_weakref_call(getattr(value, "func", None)):
        return True
    return False


def _safe_capture(name: str, scopes: Sequence[ast.AST]) -> bool:
    """Is a captured name provably safe to hold strongly?"""
    if name == "self":
        return False
    for scope in reversed(list(scopes)):
        if not isinstance(scope, SCOPE_NODES):
            continue
        assigned = scope_assignments(scope)
        if name in assigned:
            return _safe_value(assigned[name])
        anns = param_annotations(scope)
        if name in anns:
            ann = anns[name].replace(" ", "")
            return ann in {a.replace(" ", "") for a in _SCALAR_ANNOTATIONS}
    return True          # bound at module level (or a builtin): no capture


def _callables_in(expr: ast.AST, parents: List[ast.AST],
                  defs: dict) -> List[ast.AST]:
    """Lambda nodes and referenced local defs inside an expression."""
    out = []
    for node in ast.walk(expr):
        if isinstance(node, ast.Lambda):
            out.append(node)
        elif isinstance(node, ast.Name) and node.id in defs:
            out.append(defs[node.id])
    return out


def _capture_findings(ctx, call_or_assign, fn: ast.AST,
                      parents: List[ast.AST], what: str) -> list:
    findings = []
    captured = sorted(free_names(fn, parents))
    risky = [n for n in captured if not _safe_capture(n, parents)]
    if risky:
        findings.append(ctx.finding(
            RULE_ID, call_or_assign,
            f"{what} strongly captures {', '.join(risky)} — hold "
            f"captured operators/arrays through weakref.ref (see "
            f"solvers/stepper.py) or move them into the cache key"))
    return findings


def check(tree: ast.Module, ctx) -> list:
    findings = []
    module_defs = {n.name: n for n in ast.walk(tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}

    for node, parents in walk_with_parents(tree):
        # (a) lru_cache on a method
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args.posonlyargs + node.args.args
            if args and args[0].arg == "self":
                for dec in node.decorator_list:
                    if _is_lru_cache(dec):
                        findings.append(ctx.finding(
                            RULE_ID, dec,
                            f"functools.lru_cache on method "
                            f"{node.name!r} pins every self it is "
                            f"called on for the process lifetime — "
                            f"cache on a module-level function keyed "
                            f"by value, or use a WeakKeyDictionary"))

        # (b) run_chunk body with captures but no extra_key
        if isinstance(node, ast.Call):
            chain = name_chain(node.func)
            if chain == "run_chunk" or chain.endswith(".run_chunk"):
                body_arg: Optional[ast.AST] = None
                if len(node.args) >= 5:
                    body_arg = node.args[4]
                for kw in node.keywords:
                    if kw.arg == "body":
                        body_arg = kw.value
                has_extra = any(kw.arg == "extra_key"
                                for kw in node.keywords)
                if body_arg is not None and not has_extra:
                    fn = None
                    if isinstance(body_arg, ast.Lambda):
                        fn = body_arg
                    elif isinstance(body_arg, ast.Name):
                        enc = enclosing_function(parents)
                        fn = (local_defs(enc).get(body_arg.id)
                              if enc is not None else None)
                    if fn is not None:
                        captured = sorted(free_names(fn, parents))
                        risky = [n for n in captured
                                 if not _safe_capture(n, parents)]
                        if risky:
                            findings.append(ctx.finding(
                                RULE_ID, node,
                                f"run_chunk body captures "
                                f"{', '.join(risky)} without an "
                                f"extra_key= — two bodies closing over "
                                f"different objects would share one "
                                f"compiled chunk (pass extra_key=<the "
                                f"captured object>)"))

        # (c) callable stored into a *cache* container
        if isinstance(node, ast.Assign):
            def _is_cache_store(t: ast.AST) -> bool:
                if not isinstance(t, ast.Subscript):
                    return False
                if "cache" in root_name(t).lower():
                    return True
                return (isinstance(t.value, ast.Attribute)
                        and "cache" in t.value.attr.lower())

            cache_targets = [t for t in node.targets if _is_cache_store(t)]
            if not cache_targets:
                continue
            enc = enclosing_function(parents)
            defs = local_defs(enc) if enc is not None else dict(module_defs)
            value = node.value
            # stored name -> resolve to its last assignment in this scope
            if isinstance(value, ast.Name) and enc is not None:
                value = scope_assignments(enc).get(value.id, value)
            for fn in _callables_in(value, parents, defs):
                findings.extend(_capture_findings(
                    ctx, node, fn, parents,
                    "callable stored in a cache"))
    return findings
