"""Rule registry.  A rule module exposes ``RULE_ID``, ``RULE_TITLE`` and
``check(tree, ctx) -> list[Finding]``; adding a rule = adding a module
here and listing it in ``ALL_RULES`` (see docs/static_analysis.md)."""
from tools.ghostlint.rules import (gl001_cascade, gl002_interpret,
                                   gl003_acc_dtype, gl004_capture,
                                   gl005_trace_safety, gl006_validation,
                                   gl007_parity, gl008_blanket_except)

ALL_RULES = [
    gl001_cascade,
    gl002_interpret,
    gl003_acc_dtype,
    gl004_capture,
    gl005_trace_safety,
    gl006_validation,
    gl007_parity,
    gl008_blanket_except,
]

RULES_BY_ID = {r.RULE_ID: r for r in ALL_RULES}

__all__ = ["ALL_RULES", "RULES_BY_ID"]
