"""GL008 — no blanket ``except Exception`` / bare ``except``.

A handler that swallows every exception hides the bugs the rest of this
suite exists to catch: a tracer leak, a dtype mismatch, or a typo inside
the guarded block all degrade into whatever the fallback path does.
Catch the concrete types the block can actually raise.

The sanctioned exceptions are the two handlers in
``core/execution.py`` — the AOT capability probe (any lowering failure
*means* "compiled unavailable", by design) and the cascade's
compiled->reference fallback (the hardening contract is "never crash the
solve") — each carrying an inline ``# ghostlint: disable=GL008`` with a
justification.
"""
from __future__ import annotations

import ast

RULE_ID = "GL008"
RULE_TITLE = ("catch concrete exception types, not Exception/bare "
              "except")


def check(tree: ast.Module, ctx) -> list:
    if ctx.is_test:
        return []
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            findings.append(ctx.finding(
                RULE_ID, node,
                "bare except: swallows KeyboardInterrupt/SystemExit too "
                "— name the exception types this block can raise"))
        elif (isinstance(node.type, ast.Name)
              and node.type.id in ("Exception", "BaseException")):
            findings.append(ctx.finding(
                RULE_ID, node,
                f"except {node.type.id} hides unrelated bugs behind the "
                f"fallback path — catch the concrete types (or add an "
                f"inline disable with a justification if the blanket "
                f"catch is the contract)"))
    return findings
