"""GL002 — ``interpret`` parameters must default to ``None``.

The execution mode is decided in one place (:mod:`repro.core.execution`);
a function signature defaulting ``interpret`` to a literal ``True`` or
``False`` pins the mode at the call site and silently overrides the
policy (the seed's ``interpret: bool = True`` bug class: the compiled
path could never run).  ``interpret: bool | None = None`` defers to
``execution.resolve_interpret``.

This rule replaces the old CI ``grep 'interpret: bool = True'`` step —
it also catches ``= False`` pins, keyword-only variants, and literal
``interpret=True/False`` arguments passed to ``pallas_call`` outside the
resolver itself.
"""
from __future__ import annotations

import ast

from tools.ghostlint.astutil import name_chain

RULE_ID = "GL002"
RULE_TITLE = ("interpret must default to None (defer to the "
              "core.execution policy), never a literal bool")


def _bool_default(arg_name: str, args: ast.arguments):
    """(arg, default) pairs where ``arg_name`` has a literal bool default."""
    pairs = []
    pos = args.posonlyargs + args.args
    defaults = args.defaults
    for a, d in zip(pos[len(pos) - len(defaults):], defaults):
        pairs.append((a, d))
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            pairs.append((a, d))
    return [(a, d) for a, d in pairs
            if a.arg == arg_name and isinstance(d, ast.Constant)
            and isinstance(d.value, bool)]


def check(tree: ast.Module, ctx) -> list:
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            for a, d in _bool_default("interpret", node.args):
                findings.append(ctx.finding(
                    RULE_ID, a,
                    f"interpret defaults to {d.value} — use "
                    f"'interpret: bool | None = None' so the call site "
                    f"defers to execution.resolve_interpret"))
        elif isinstance(node, ast.Call):
            chain = name_chain(node.func)
            if chain == "pallas_call" or chain.endswith(".pallas_call"):
                for kw in node.keywords:
                    if (kw.arg == "interpret"
                            and isinstance(kw.value, ast.Constant)
                            and isinstance(kw.value.value, bool)):
                        findings.append(ctx.finding(
                            RULE_ID, kw.value,
                            f"pallas_call(interpret={kw.value.value}) pins "
                            f"the execution mode — pass the resolved "
                            f"policy value instead"))
    return findings
