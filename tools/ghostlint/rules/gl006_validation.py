"""GL006 — host-side validation must survive ``python -O``.

``assert`` statements are stripped when Python runs with ``-O``; a
library that validates shapes/dtypes with bare asserts silently accepts
garbage in optimized deployments.  Anything under ``src/`` that guards a
public contract must ``raise ValueError``/``TypeError`` instead (tests
keep their asserts — pytest rewrites them).

Additionally, a ``kernels/`` wrapper that builds a ``pallas_call`` must
validate *before* launching it: at least one ``raise`` statement (or a
call to a ``_validate*`` helper) must appear in the function, because a
shape mismatch inside the kernel surfaces as an opaque Mosaic/XLA error
instead of a Python exception naming the offending argument.
"""
from __future__ import annotations

import ast

from tools.ghostlint.astutil import name_chain, walk_with_parents

RULE_ID = "GL006"
RULE_TITLE = ("library validation raises (assert is stripped under "
              "python -O); Pallas wrappers validate before pallas_call")


def _is_pallas_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = name_chain(node.func)
    return chain == "pallas_call" or chain.endswith(".pallas_call")


def _validates(func: ast.AST) -> bool:
    for node in ast.walk(func):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            chain = name_chain(node.func)
            last = chain.rsplit(".", 1)[-1]
            if last.startswith("_validate") or last.startswith("validate"):
                return True
    return False


def check(tree: ast.Module, ctx) -> list:
    if ctx.is_test:
        return []
    findings = []
    for node, parents in walk_with_parents(tree):
        if isinstance(node, ast.Assert):
            # asserts inside traced/kernel bodies are GL005's problem;
            # here we flag the host-side validation pattern.
            msg = ""
            if node.msg is not None and isinstance(node.msg, ast.Constant):
                msg = f" ({node.msg.value!r})"
            findings.append(ctx.finding(
                RULE_ID, node,
                f"bare assert{msg} is stripped under python -O — raise "
                f"ValueError/TypeError so the contract holds in "
                f"optimized runs"))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not ctx.is_kernel_file:
                continue
            has_pallas = any(_is_pallas_call(n) for n in ast.walk(node)
                             if n is not node)
            # only top-level wrappers (not nested kernel bodies)
            if has_pallas and not any(
                    isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef))
                    for p in parents):
                if not _validates(node):
                    findings.append(ctx.finding(
                        RULE_ID, node,
                        f"Pallas wrapper {node.name!r} builds a "
                        f"pallas_call without any host-side validation "
                        f"— raise on bad shapes/dtypes before launch so "
                        f"errors name the argument, not a Mosaic "
                        f"lowering failure"))
    return findings
