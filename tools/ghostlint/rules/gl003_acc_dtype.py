"""GL003 — accumulation-dtype hygiene in kernel code.

The storage-vs-compute contract lives in two functions —
``core/spmv.py::storage_acc_dtype`` (value streams: bf16/f16 storage
upcasts to an f32+ accumulator) and ``core/spmv.py::dot_acc_dtype``
(fused dots: f64 when x64 is on, Kahan otherwise).  Kernel code that
hardcodes a literal dtype on a value stream forks that contract: the
kernel and the jnp reference drift, and mixed-precision storage breaks
subtly (PR 5's whole axis).

Flagged, in ``kernels/`` files only:

* a private accumulator-dtype helper (``def _acc_dtype``) — three copies
  of this function were already deduplicated once in PR 5;
* ``preferred_element_type=<literal dtype>`` — accumulate in the shared
  contract's dtype, not a hardcoded one;
* ``.astype(<literal dtype>)`` — upcasts/downcasts on kernel streams go
  through the contract (``.astype(acc_dt)``), not literals.
"""
from __future__ import annotations

import ast

from tools.ghostlint.astutil import is_dtype_literal

RULE_ID = "GL003"
RULE_TITLE = ("kernel value streams accumulate via core.spmv."
              "storage_acc_dtype/dot_acc_dtype, not literal dtypes")

_HELPER_NAMES = {"_acc_dtype", "acc_dtype", "_dot_acc_dtype"}


def check(tree: ast.Module, ctx) -> list:
    if not ctx.is_kernel_file:
        return []
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name in _HELPER_NAMES:
                findings.append(ctx.finding(
                    RULE_ID, node,
                    f"private accumulation-dtype helper {node.name!r} — "
                    f"import storage_acc_dtype/dot_acc_dtype from "
                    f"repro.core.spmv (the shared storage-vs-compute "
                    f"contract) instead of forking it"))
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if (kw.arg == "preferred_element_type"
                        and is_dtype_literal(kw.value)):
                    findings.append(ctx.finding(
                        RULE_ID, kw.value,
                        "literal preferred_element_type on a kernel "
                        "dot — derive the accumulator from "
                        "storage_acc_dtype/dot_acc_dtype"))
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype"
                    and node.args and is_dtype_literal(node.args[0])):
                findings.append(ctx.finding(
                    RULE_ID, node,
                    "literal .astype(...) on a kernel stream — cast "
                    "to the contract dtype (storage_acc_dtype/"
                    "dot_acc_dtype/compute_dtype), not a hardcoded one"))
    return findings
