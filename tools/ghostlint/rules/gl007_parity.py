"""GL007 — kernel/reference parity (static half).

Every Pallas kernel entry point ``<name>_pallas`` in ``kernels/`` must
have a matching pure-jnp reference ``<name>_ref`` in
``kernels/ref.py`` — the reference is the fallback the execution cascade
degrades to *and* the oracle every parity test compares against.  A
kernel without a reference is untestable and unfallbackable.

This is the static half of the rule: name parity, checked per kernel
file against ``ref.py`` in the same directory.  The dynamic half — a
``jax.eval_shape`` sweep proving wrapper and reference agree on output
shape/dtype over the C/sigma/w_tile/store_dtype grid — runs via
``python -m tools.ghostlint --parity-sweep`` (and from the test suite),
because it needs jax importable.
"""
from __future__ import annotations

import ast
import os

from tools.ghostlint.astutil import name_chain

RULE_ID = "GL007"
RULE_TITLE = ("every *_pallas kernel has a *_ref reference in "
              "kernels/ref.py (cascade fallback + parity oracle)")


def _ref_names(ref_path: str):
    try:
        with open(ref_path, "r", encoding="utf-8") as fh:
            tree = ast.parse(fh.read())
    except (OSError, SyntaxError):
        return None
    return {n.name for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def check(tree: ast.Module, ctx) -> list:
    if not ctx.is_kernel_file or ctx.is_ref_file:
        return []
    findings = []
    kernels = [n for n in tree.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
               and n.name.endswith("_pallas")
               and not n.name.startswith("_")]
    if not kernels:
        return []
    ref_path = os.path.join(os.path.dirname(ctx.abspath or ctx.path),
                            "ref.py")
    refs = _ref_names(ref_path)
    if refs is None:
        findings.append(ctx.finding(
            RULE_ID, kernels[0],
            "kernels/ref.py missing or unparseable — every *_pallas "
            "kernel needs a jnp reference there"))
        return findings
    for k in kernels:
        want = k.name[: -len("_pallas")] + "_ref"
        if want not in refs:
            findings.append(ctx.finding(
                RULE_ID, k,
                f"kernel {k.name!r} has no reference {want!r} in "
                f"kernels/ref.py — the execution cascade cannot fall "
                f"back and parity tests have no oracle"))
    return findings
