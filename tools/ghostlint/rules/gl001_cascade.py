"""GL001 — execution-cascade discipline for ``pl.pallas_call``.

Every Pallas kernel must live under ``kernels/`` and resolve its
execution mode through :mod:`repro.core.execution` (the wrapper calls
``execution.resolve_interpret`` before building the ``pallas_call``, and
the public entry routes through ``execution.cascade``).  A stray
``pallas_call`` anywhere else bypasses backend detection, the
env/``force()`` overrides, and the hardened compiled->reference
fallback — exactly the silent-always-interpret class of bug PR 2 fixed.

The one sanctioned exception is the AOT capability probe in
``core/execution.py`` (it *implements* the policy), which carries an
inline ``# ghostlint: disable=GL001``.
"""
from __future__ import annotations

import ast

from tools.ghostlint.astutil import (enclosing_function, name_chain,
                                     walk_with_parents)

RULE_ID = "GL001"
RULE_TITLE = ("pl.pallas_call only inside kernels/ wrappers that resolve "
              "the execution policy")


def _is_pallas_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = name_chain(node.func)
    return chain == "pallas_call" or chain.endswith(".pallas_call")


def _resolves_policy(func: ast.AST) -> bool:
    """Does the function call ``execution.resolve_interpret`` (or receive
    the resolved mode via a ``resolve_*`` helper) anywhere in its body?"""
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            chain = name_chain(node.func)
            if chain.endswith("resolve_interpret"):
                return True
    return False


def check(tree: ast.Module, ctx) -> list:
    findings = []
    for node, parents in walk_with_parents(tree):
        if not _is_pallas_call(node):
            continue
        if not ctx.is_kernel_file:
            findings.append(ctx.finding(
                RULE_ID, node,
                "pl.pallas_call outside kernels/ — move the kernel into "
                "src/repro/kernels/ and route it through the "
                "execution.cascade wrapper in kernels/ops.py"))
            continue
        func = enclosing_function(parents)
        if func is None or not _resolves_policy(func):
            findings.append(ctx.finding(
                RULE_ID, node,
                "pallas_call whose wrapper never calls "
                "execution.resolve_interpret — the kernel bypasses the "
                "central execution policy (env overrides, force(), "
                "backend auto-detection)"))
    return findings
