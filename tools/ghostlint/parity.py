"""GL007 dynamic half: kernel/reference ``jax.eval_shape`` parity sweep.

The static rule (rules/gl007_parity.py) proves every ``*_pallas`` kernel
has a ``*_ref``; this module proves the *wrappers* and references agree
on output structure — shape and dtype of every leaf — across the
SELL-C-sigma configuration grid (C, sigma, w_tile, store_dtype) plus the
dense kernels.  ``eval_shape`` traces both sides abstractly, so the
sweep is seconds, not minutes, and runs on any backend.

Requires jax and ``PYTHONPATH=src``; invoked by
``python -m tools.ghostlint --parity-sweep`` and by
``tests/test_ghostlint.py``.
"""
from __future__ import annotations

from typing import List


def _describe(tree) -> str:
    import jax
    leaves = jax.tree_util.tree_leaves(tree)
    return ", ".join(f"{tuple(l.shape)}:{l.dtype}" for l in leaves)


def _compare(name: str, got, want, mismatches: List[str]) -> None:
    import jax
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    if gt != wt:
        mismatches.append(f"{name}: tree structure {gt} != {wt}")
        return
    for i, (g, w) in enumerate(zip(gl, wl)):
        if g is None and w is None:
            continue
        if tuple(g.shape) != tuple(w.shape) or g.dtype != w.dtype:
            mismatches.append(
                f"{name}: leaf {i}: kernel {tuple(g.shape)}:{g.dtype} "
                f"!= reference {tuple(w.shape)}:{w.dtype}")


def run_parity_sweep(verbose: bool = False) -> List[str]:
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import execution, sellcs
    from repro.core.spmv import SpmvOpts, spmv_ref
    from repro.kernels import ops
    from repro.kernels import ref as kref

    mismatches: List[str] = []
    n = 48
    rng = np.random.default_rng(7)
    dense = np.where(rng.random((n, n)) < 0.25,
                     rng.standard_normal((n, n)), 0.0)
    np.fill_diagonal(dense, 1.0)          # no empty rows

    with execution.force(interpret=True):
        # ---- sellcs_spmv over the C/sigma/w_tile/store_dtype grid
        opts = SpmvOpts(dot_yy=True, dot_xy=True)
        for C in (4, 16):
            for sigma in (1, 16):
                for w_tile in (1, 2):
                    for store in (None, "bfloat16"):
                        A = sellcs.from_dense(
                            dense, C=C, sigma=sigma, w_align=w_tile,
                            dtype=np.float32, store_dtype=store)
                        x = jnp.ones((n, 2), jnp.float32)
                        y = jnp.ones((n, 2), jnp.float32)
                        tag = (f"sellcs_spmv[C={C},sigma={sigma},"
                               f"w_tile={w_tile},store={store or 'f32'}]")
                        got = jax.eval_shape(
                            lambda xv, yv: ops.sellcs_spmv(
                                A, xv, yv, opts=opts, w_tile=w_tile),
                            x, y)
                        want = jax.eval_shape(
                            lambda xv, yv: spmv_ref(A, xv, yv, None, opts),
                            x, y)
                        _compare(tag, got, want, mismatches)
                        if verbose:
                            print(f"  {tag}: {_describe(got)}")

        # ---- dense kernels (one representative config each)
        V = jnp.ones((40, 4), jnp.float32)
        W = jnp.ones((40, 4), jnp.float32)
        X = jnp.ones((4, 4), jnp.float32)
        _compare("tsmttsm",
                 jax.eval_shape(lambda v, w: ops.tsmttsm(v, w), V, W),
                 jax.eval_shape(kref.tsmttsm_ref, V, W), mismatches)
        _compare("tsmm",
                 jax.eval_shape(lambda v, x: ops.tsmm(v, x), V, X),
                 jax.eval_shape(kref.tsmm_ref, V, X), mismatches)
        _compare("fused_axpby_dots",
                 jax.eval_shape(
                     lambda xv, yv: ops.fused_axpby_dots(xv, yv), V, W),
                 jax.eval_shape(kref.fused_axpby_dots_ref, V, W),
                 mismatches)
        blocks = jnp.ones((10, 4, 4), jnp.float32)
        bx = jnp.ones((40, 3), jnp.float32)
        _compare("block_jacobi_apply",
                 jax.eval_shape(
                     lambda b, x: ops.block_jacobi_apply(b, x), blocks, bx),
                 jax.eval_shape(kref.block_diag_matmul_ref, blocks, bx),
                 mismatches)
        dt = jnp.ones((2, 8, 16), jnp.float32)
        xc = jnp.ones((2, 8, 16), jnp.float32)
        Bc = jnp.ones((2, 8, 4), jnp.float32)
        Cc = jnp.ones((2, 8, 4), jnp.float32)
        Am = jnp.ones((16, 4), jnp.float32)
        _compare("mamba_scan",
                 jax.eval_shape(
                     lambda *a: ops.mamba_scan(*a), dt, xc, Bc, Cc, Am),
                 jax.eval_shape(kref.mamba_scan_ref, dt, xc, Bc, Cc, Am),
                 mismatches)
    return mismatches
