"""GL007 dynamic half: kernel/reference ``jax.eval_shape`` parity sweep.

The static rule (rules/gl007_parity.py) proves every ``*_pallas`` kernel
has a ``*_ref``; this module proves the *wrappers* and references agree
on output structure — shape and dtype of every leaf — across the
SELL-C-sigma configuration grid (C, sigma, w_tile, store_dtype) plus the
dense kernels.  ``eval_shape`` traces both sides abstractly, so the
sweep is seconds, not minutes, and runs on any backend.

Kernels are **auto-discovered**: :func:`discover_kernel_bases` AST-scans
``src/repro/kernels/`` for public ``*_pallas`` entry points, and every
discovered kernel must have a sweep driver registered in :data:`SWEEPS`
— a new kernel file cannot silently skip the parity sweep; the sweep
itself fails until a driver is added.  The same :class:`SweepCase`
stream feeds ``tools/ghostsan``'s GS101 grid audit, so the sanitizer
sees exactly the configuration grid the parity sweep proves.

Requires jax and ``PYTHONPATH=src``; invoked by
``python -m tools.ghostlint --parity-sweep`` and by
``tests/test_ghostlint.py``.
"""
from __future__ import annotations

import ast
import os
from typing import (Any, Callable, Dict, Iterator, List, NamedTuple,
                    Optional)

from tools.ghostlint.engine import REPO

KERNELS_DIR = os.path.join(REPO, "src", "repro", "kernels")


class SweepCase(NamedTuple):
    """One concrete kernel-vs-reference configuration.

    ``kernel`` and ``ref`` are zero-arg thunks closing over concrete
    inputs; callers trace them (``jax.eval_shape``) or invoke them under
    a capture shim (ghostsan GS101) — the thunk never decides how it is
    executed.
    """
    name: str                        # kernel base name ("sellcs_spmv")
    tag: str                         # unique config tag for messages
    kernel: Callable[[], Any]        # wrapper thunk
    ref: Callable[[], Any]           # jnp reference thunk


def discover_kernel_bases(kernels_dir: Optional[str] = None
                          ) -> Dict[str, str]:
    """AST-scan ``kernels/`` for public ``*_pallas`` defs.

    Returns ``{kernel_base_name: file_path}`` (base name = def name with
    the ``_pallas`` suffix stripped) so callers can anchor findings at
    the defining file.  ``ref.py`` is excluded by construction (it holds
    the references, not kernels).
    """
    kernels_dir = KERNELS_DIR if kernels_dir is None else kernels_dir
    bases: Dict[str, str] = {}
    for fn in sorted(os.listdir(kernels_dir)):
        if not fn.endswith(".py") or fn == "ref.py":
            continue
        path = os.path.join(kernels_dir, fn)
        try:
            with open(path, encoding="utf-8") as f:
                tree = ast.parse(f.read())
        except (OSError, SyntaxError):
            continue
        for node in tree.body:
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name.endswith("_pallas")
                    and not node.name.startswith("_")):
                bases[node.name[: -len("_pallas")]] = path
    return bases


# ----------------------------------------------------------- sweep drivers
def _test_matrix(n: int = 48):
    import numpy as np
    rng = np.random.default_rng(7)
    dense = np.where(rng.random((n, n)) < 0.25,
                     rng.standard_normal((n, n)), 0.0)
    np.fill_diagonal(dense, 1.0)          # no empty rows
    return dense


def _sellcs_spmv_cases() -> Iterator[SweepCase]:
    import numpy as np
    import jax.numpy as jnp

    from repro.core import sellcs
    from repro.core.spmv import SpmvOpts, spmv_ref
    from repro.kernels import ops

    n = 48
    dense = _test_matrix(n)
    opts = SpmvOpts(dot_yy=True, dot_xy=True)
    for C in (4, 16):
        for sigma in (1, 16):
            for w_tile in (1, 2):
                for store in (None, "bfloat16"):
                    A = sellcs.from_dense(
                        dense, C=C, sigma=sigma, w_align=w_tile,
                        dtype=np.float32, store_dtype=store)
                    x = jnp.ones((n, 2), jnp.float32)
                    y = jnp.ones((n, 2), jnp.float32)
                    tag = (f"sellcs_spmv[C={C},sigma={sigma},"
                           f"w_tile={w_tile},store={store or 'f32'}]")
                    yield SweepCase(
                        "sellcs_spmv", tag,
                        lambda A=A, x=x, y=y, w=w_tile: ops.sellcs_spmv(
                            A, x, y, opts=opts, w_tile=w),
                        lambda A=A, x=x, y=y: spmv_ref(A, x, y, None, opts))


def _tsmttsm_cases() -> Iterator[SweepCase]:
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels import ref as kref
    V = jnp.ones((40, 4), jnp.float32)
    W = jnp.ones((40, 4), jnp.float32)
    yield SweepCase("tsmttsm", "tsmttsm[40x4]",
                    lambda: ops.tsmttsm(V, W),
                    lambda: kref.tsmttsm_ref(V, W))


def _tsmm_cases() -> Iterator[SweepCase]:
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels import ref as kref
    V = jnp.ones((40, 4), jnp.float32)
    X = jnp.ones((4, 4), jnp.float32)
    yield SweepCase("tsmm", "tsmm[40x4]",
                    lambda: ops.tsmm(V, X),
                    lambda: kref.tsmm_ref(V, X))


def _fused_axpby_dots_cases() -> Iterator[SweepCase]:
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels import ref as kref
    V = jnp.ones((40, 4), jnp.float32)
    W = jnp.ones((40, 4), jnp.float32)
    yield SweepCase("fused_axpby_dots", "fused_axpby_dots[40x4]",
                    lambda: ops.fused_axpby_dots(V, W, dot_yy=True),
                    lambda: kref.fused_axpby_dots_ref(V, W, dot_yy=True))


def _block_diag_matmul_cases() -> Iterator[SweepCase]:
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels import ref as kref
    blocks = jnp.ones((10, 4, 4), jnp.float32)
    bx = jnp.ones((40, 3), jnp.float32)
    yield SweepCase("block_diag_matmul", "block_diag_matmul[10x4x4]",
                    lambda: ops.block_jacobi_apply(blocks, bx),
                    lambda: kref.block_diag_matmul_ref(blocks, bx))


def _mamba_scan_cases() -> Iterator[SweepCase]:
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels import ref as kref
    dt = jnp.ones((2, 8, 16), jnp.float32)
    xc = jnp.ones((2, 8, 16), jnp.float32)
    Bc = jnp.ones((2, 8, 4), jnp.float32)
    Cc = jnp.ones((2, 8, 4), jnp.float32)
    Am = jnp.ones((16, 4), jnp.float32)
    yield SweepCase("mamba_scan", "mamba_scan[2x8x16,state=4]",
                    lambda: ops.mamba_scan(dt, xc, Bc, Cc, Am),
                    lambda: kref.mamba_scan_ref(dt, xc, Bc, Cc, Am))


#: kernel base name -> sweep-case generator.  Keys must cover every
#: base returned by :func:`discover_kernel_bases`; run_parity_sweep
#: reports any gap as a mismatch, so a new kernel file fails the sweep
#: until its driver lands here.
SWEEPS: Dict[str, Callable[[], Iterator[SweepCase]]] = {
    "sellcs_spmv": _sellcs_spmv_cases,
    "tsmttsm": _tsmttsm_cases,
    "tsmm": _tsmm_cases,
    "fused_axpby_dots": _fused_axpby_dots_cases,
    "block_diag_matmul": _block_diag_matmul_cases,
    "mamba_scan": _mamba_scan_cases,
}


def iter_sweep_cases() -> Iterator[SweepCase]:
    """All registered sweep cases (build under the caller's policy)."""
    for base in sorted(SWEEPS):
        yield from SWEEPS[base]()


def check_sweep_coverage() -> List[str]:
    """Registry-vs-discovery gaps, as human-readable mismatch strings."""
    discovered = discover_kernel_bases()
    problems = []
    for base in sorted(set(discovered) - set(SWEEPS)):
        problems.append(
            f"{base}: kernel {base}_pallas in "
            f"{os.path.relpath(discovered[base], REPO)} has no sweep "
            f"driver in tools/ghostlint/parity.py::SWEEPS — register one "
            f"or the parity sweep (and ghostsan GS101) never sees it")
    for base in sorted(set(SWEEPS) - set(discovered)):
        problems.append(
            f"{base}: SWEEPS registers a driver but no {base}_pallas "
            f"kernel exists under src/repro/kernels/ — stale entry")
    return problems


# ------------------------------------------------------------------ sweep
def _describe(tree) -> str:
    import jax
    leaves = jax.tree_util.tree_leaves(tree)
    return ", ".join(f"{tuple(l.shape)}:{l.dtype}" for l in leaves)


def _compare(name: str, got, want, mismatches: List[str]) -> None:
    import jax
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    if gt != wt:
        mismatches.append(f"{name}: tree structure {gt} != {wt}")
        return
    for i, (g, w) in enumerate(zip(gl, wl)):
        if g is None and w is None:
            continue
        if tuple(g.shape) != tuple(w.shape) or g.dtype != w.dtype:
            mismatches.append(
                f"{name}: leaf {i}: kernel {tuple(g.shape)}:{g.dtype} "
                f"!= reference {tuple(w.shape)}:{w.dtype}")


def run_parity_sweep(verbose: bool = False) -> List[str]:
    import jax

    from repro.core import execution

    mismatches: List[str] = check_sweep_coverage()
    with execution.force(interpret=True):
        for case in iter_sweep_cases():
            got = jax.eval_shape(case.kernel)
            want = jax.eval_shape(case.ref)
            _compare(case.tag, got, want, mismatches)
            if verbose:
                print(f"  {case.tag}: {_describe(got)}")
    return mismatches
