"""ghostlint engine: findings, suppressions, baseline, file runner.

A *rule* is a module exposing ``RULE_ID`` (``"GL00x"``), ``RULE_TITLE``
(one line) and ``check(tree, ctx) -> list[Finding]``.  The engine parses
each file once, hands every rule the same AST + :class:`FileContext`,
then filters the findings through per-line suppression comments and the
committed baseline.  Rules never filter themselves — suppression is an
engine concern so ``--no-baseline`` / ``--select`` behave uniformly.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
import tokenize
from io import StringIO
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))

#: ``# ghostlint: disable=GL001`` / ``disable=GL001,GL004`` / ``disable=all``
_SUPPRESS_RE = re.compile(
    r"#\s*ghostlint:\s*disable=([A-Za-z0-9_,\s]+|all)")
_FILE_SUPPRESS_RE = re.compile(
    r"#\s*ghostlint:\s*disable-file=([A-Za-z0-9_,\s]+|all)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                      # "GL004"
    path: str                      # repo-relative posix path
    line: int                      # 1-based
    message: str
    text: str = ""                 # stripped source of the flagged line

    @property
    def fingerprint(self) -> Tuple[str, str, str]:
        """Line-number-free identity used by the baseline: a finding
        survives unrelated edits above it, but changing the flagged line
        (or the rule) retires the baseline entry."""
        return (self.rule, self.path, self.text)

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "text": self.text}


@dataclasses.dataclass
class FileContext:
    """Everything a rule may want to know about the file under analysis."""

    path: str                      # repo-relative posix path
    abspath: str
    source: str
    lines: List[str]               # 0-based raw source lines

    @property
    def is_test(self) -> bool:
        base = os.path.basename(self.path)
        return (base.startswith("test_") or base == "conftest.py"
                or "/tests/" in f"/{self.path}")

    @property
    def is_kernel_file(self) -> bool:
        return "/kernels/" in f"/{self.path}"

    @property
    def is_ref_file(self) -> bool:
        return self.is_kernel_file and os.path.basename(self.path) == "ref.py"

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(rule=rule, path=self.path, line=int(line),
                       message=message, text=self.line_text(int(line)))


# ------------------------------------------------------------- suppressions
def _parse_rule_list(raw: str) -> Optional[Set[str]]:
    """``"GL001, GL004"`` -> {'GL001', 'GL004'}; ``"all"`` -> None (=all)."""
    raw = raw.strip()
    if raw.lower() == "all":
        return None
    return {r.strip().upper() for r in raw.split(",") if r.strip()}


def suppressed_lines(source: str, *,
                     suppress_re: re.Pattern = _SUPPRESS_RE,
                     file_suppress_re: re.Pattern = _FILE_SUPPRESS_RE,
                     ) -> Tuple[Dict[int, Optional[Set[str]]],
                                Optional[Set[str]]]:
    """Map of line -> suppressed rule ids (None = all), plus file-level set.

    A ``# ghostlint: disable=...`` comment suppresses its own line; when
    the comment is the only thing on the line it suppresses the next
    line instead (so long statements can carry a suppression above).
    Comments are found with :mod:`tokenize`, so a disable string inside a
    string literal does not suppress anything.  The regexes are
    injectable so ``tools/ghostsan`` reuses the exact same semantics
    under its own ``# ghostsan:`` comment prefix.
    """
    per_line: Dict[int, Optional[Set[str]]] = {}
    file_level: Optional[Set[str]] = set()
    try:
        tokens = list(tokenize.generate_tokens(StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return per_line, file_level or None

    def add(store: Dict[int, Optional[Set[str]]], line: int,
            rules: Optional[Set[str]]) -> None:
        if store.get(line, set()) is None or rules is None:
            store[line] = None
        else:
            store.setdefault(line, set()).update(rules)

    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = file_suppress_re.search(tok.string)
        if m:
            rules = _parse_rule_list(m.group(1))
            if rules is None or file_level is None:
                file_level = None
            else:
                file_level.update(rules)
            continue
        m = suppress_re.search(tok.string)
        if not m:
            continue
        rules = _parse_rule_list(m.group(1))
        line = tok.start[0]
        own_line = tok.line[:tok.start[1]].strip() == ""
        add(per_line, line + 1 if own_line else line, rules)
    return per_line, (file_level if file_level else None)


def is_suppressed(finding: Finding,
                  per_line: Dict[int, Optional[Set[str]]],
                  file_level: Optional[Set[str]]) -> bool:
    if file_level is not None and finding.rule in file_level:
        return True
    if finding.line in per_line:
        rules = per_line[finding.line]
        return rules is None or finding.rule in rules
    return False


# ----------------------------------------------------------------- baseline
DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def load_baseline(path: str = DEFAULT_BASELINE) -> Set[Tuple[str, str, str]]:
    """Set of finding fingerprints accepted as intentional."""
    if not os.path.isfile(path):
        return set()
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    out = set()
    for e in data.get("findings", []):
        out.add((e["rule"], e["path"], e.get("text", "")))
    return out


def write_baseline(findings: Sequence[Finding],
                   path: str = DEFAULT_BASELINE) -> None:
    entries = sorted(
        {f.fingerprint for f in findings})
    data = {
        "comment": ("ghostlint baseline: intentional findings, keyed "
                    "(rule, path, flagged-line-text).  Regenerate with "
                    "python -m tools.ghostlint src/ --write-baseline; "
                    "prefer inline '# ghostlint: disable=' comments for "
                    "anything that deserves an explanation at the site."),
        "findings": [{"rule": r, "path": p, "text": t}
                     for r, p, t in entries],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


# ------------------------------------------------------------------- runner
def _all_rules():
    from tools.ghostlint.rules import ALL_RULES
    return ALL_RULES


def lint_source(source: str, path: str, *,
                rules=None, abspath: str = "") -> List[Finding]:
    """Lint one in-memory file; returns *unsuppressed* findings.

    ``path`` is the repo-relative posix path the rules see (it drives
    kernel-/test-file classification), so tests can exercise kernel-only
    rules by passing a fake ``src/repro/kernels/x.py`` path.
    """
    rules = _all_rules() if rules is None else rules
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(rule="GL000", path=path, line=e.lineno or 1,
                        message=f"syntax error: {e.msg}")]
    ctx = FileContext(path=path, abspath=abspath or path,
                      source=source, lines=source.splitlines())
    per_line, file_level = suppressed_lines(source)
    found: List[Finding] = []
    for rule in rules:
        for f in rule.check(tree, ctx):
            if not is_suppressed(f, per_line, file_level):
                found.append(f)
    return sorted(found, key=lambda f: (f.path, f.line, f.rule))


def discover(paths: Iterable[str], *, include_tests: bool = False
             ) -> List[str]:
    """Expand files/dirs into a sorted list of lintable ``.py`` files."""
    out: Set[str] = set()
    for p in paths:
        ap = os.path.abspath(p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.add(ap)
            continue
        for root, dirs, files in os.walk(ap):
            dirs[:] = [d for d in dirs
                       if d not in ("__pycache__", ".git", ".ghostlint")]
            for fn in files:
                if fn.endswith(".py"):
                    out.add(os.path.join(root, fn))
    files = []
    for ap in sorted(out):
        rel = relpath(ap)
        base = os.path.basename(rel)
        if not include_tests and (base.startswith("test_")
                                  or "/tests/" in f"/{rel}"):
            continue
        files.append(ap)
    return files


def relpath(abspath: str) -> str:
    try:
        rel = os.path.relpath(abspath, REPO)
    except ValueError:                    # different drive (windows)
        rel = abspath
    return rel.replace(os.sep, "/")


def lint_paths(paths: Iterable[str], *, rules=None,
               include_tests: bool = False) -> Tuple[List[Finding], int]:
    """Lint files/directories; returns (findings, files_checked)."""
    files = discover(paths, include_tests=include_tests)
    findings: List[Finding] = []
    for ap in files:
        with open(ap, encoding="utf-8") as f:
            source = f.read()
        findings.extend(lint_source(source, relpath(ap), rules=rules,
                                    abspath=ap))
    return findings, len(files)
