# Developer entry points.  CI runs the same commands (see
# .github/workflows/ci.yml); `make verify` is the full pre-push gate.

PY ?= python

.PHONY: test lint ghostlint parity sanitize docs verify baseline \
	baseline-san bench

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

ghostlint:
	$(PY) -m tools.ghostlint src/

parity:
	PYTHONPATH=src $(PY) -m tools.ghostlint --parity-sweep

# trace-level sanitizer: Pallas grid/race analysis, jaxpr dtype-flow
# audit, and the recompile sentry over a small service workload
sanitize:
	PYTHONPATH=src $(PY) -m tools.ghostsan

docs:
	$(PY) tools/check_docs.py

lint: ghostlint parity sanitize docs

verify: lint test

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# Accept all current findings as intentional (prefer inline
# '# ghostlint: disable=' / '# ghostsan: disable=' comments with a
# justification instead).
baseline:
	$(PY) -m tools.ghostlint src/ --write-baseline

baseline-san:
	PYTHONPATH=src $(PY) -m tools.ghostsan --write-baseline
