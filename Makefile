# Developer entry points.  CI runs the same commands (see
# .github/workflows/ci.yml); `make verify` is the full pre-push gate.

PY ?= python

.PHONY: test lint ghostlint parity docs verify baseline

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

ghostlint:
	$(PY) -m tools.ghostlint src/

parity:
	PYTHONPATH=src $(PY) -m tools.ghostlint --parity-sweep

docs:
	$(PY) tools/check_docs.py

lint: ghostlint parity docs

verify: lint test

# Accept all current findings as intentional (prefer inline
# '# ghostlint: disable=' comments with a justification instead).
baseline:
	$(PY) -m tools.ghostlint src/ --write-baseline
