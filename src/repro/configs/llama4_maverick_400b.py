"""llama4-maverick-400b-a17b [moe]: 48L, d=5120, 40H (kv=8), ff=8192,
vocab=202048, MoE 128 experts top-1, alternating dense/MoE layers (the
maverick interleave), early-fusion multimodal (frontend stubbed)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

128 experts % tp=16 == 0 -> full expert parallelism over 'model'."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="llama4_maverick_400b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    pattern=(("attn", "mlp"), ("attn", "moe")),     # dense/MoE interleave
    rope="rope", rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, ghost_dispatch=True),
    tie_embeddings=False, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="llama4_maverick_400b_smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    pattern=(("attn", "mlp"), ("attn", "moe")),
    moe=MoEConfig(n_experts=4, top_k=1, ghost_dispatch=True),
    tie_embeddings=False, dtype=jnp.float32,
)

register("llama4_maverick_400b", FULL, SMOKE,
         notes="128e top-1, EP over model axis; long_500k skipped")
