"""qwen2-vl-7b [vlm]: 28L, d=3584, 28H (kv=4), ff=18944, vocab=152064 —
M-RoPE, dynamic resolution [arXiv:2409.12191; hf].  Backbone only; the
vision patch-embedding frontend is a STUB per the assignment spec
(positions3 default to text positions)."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="qwen2_vl_7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    pattern=(("attn", "mlp"),),
    rope="mrope", rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    qkv_bias=True, tie_embeddings=False, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="qwen2_vl_7b_smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    pattern=(("attn", "mlp"),),
    rope="mrope", mrope_sections=(2, 3, 3), qkv_bias=True,
    tie_embeddings=False, dtype=jnp.float32,
)

register("qwen2_vl_7b", FULL, SMOKE,
         notes="M-RoPE; vision frontend stubbed; long_500k skipped")
