"""mistral-nemo-12b [dense]: 40L, d=5120, 32H (kv=8), ff=14336,
vocab=131072, head_dim=128, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="mistral_nemo_12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    pattern=(("attn", "mlp"),),
    rope="rope", rope_theta=1_000_000.0,
    tie_embeddings=False, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="mistral_nemo_12b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    pattern=(("attn", "mlp"),),
    tie_embeddings=False, dtype=jnp.float32,
)

register("mistral_nemo_12b", FULL, SMOKE,
         notes="head_dim=128 (< d_model/n_heads); long_500k skipped")
