"""llama3.2-3b [dense]: 28L, d=3072, 24H (kv=8), ff=8192, vocab=128256 —
small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="llama3_2_3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=128256,
    pattern=(("attn", "mlp"),),
    rope="rope", rope_theta=500_000.0,
    tie_embeddings=True, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="llama3_2_3b_smoke", family="dense",
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1,
    d_ff=96, vocab_size=512,
    pattern=(("attn", "mlp"),),
    dtype=jnp.float32,
)

register("llama3_2_3b", FULL, SMOKE,
         notes="24 heads (non-divisible by tp=16: head dim stays unsharded); "
               "long_500k skipped")
