"""The paper's own workload as a dry-run config: a distributed fused SpMV
(CG iteration kernel mix) on the production mesh.

Not a ModelConfig — this drives ``core.distributed`` directly.  Used by
``python -m repro.launch.dryrun_spmv`` and the overlap benchmark.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpmvWorkload:
    name: str
    n: int                 # global matrix dimension
    bw: int                # band half-width (banded_random generator)
    density: float
    nvecs: int             # block-vector width
    C: int = 128           # SELL chunk height (TPU lane count)
    sigma: int = 1024
    w_align: int = 8


# ML_Geer-class problem scaled to pod level (n ~ 1.5M, ~110M nnz in the
# paper; here parameterized so the dry-run partitioner sees realistic
# halo structure)
WORKLOADS = {
    "mlgeer_like": SpmvWorkload("mlgeer_like", n=1_504_002, bw=40,
                                density=0.9, nvecs=4),
    "cage15_like": SpmvWorkload("cage15_like", n=5_154_859, bw=20,
                                density=0.5, nvecs=1),
    "smoke": SpmvWorkload("smoke", n=4_096, bw=8, density=0.5, nvecs=2,
                          C=16, sigma=64, w_align=4),
}
