"""Config registry: assigned architectures x input shapes.

Every architecture registers a full :class:`ModelConfig` plus a *reduced*
smoke variant (same family/pattern, tiny dims) for CPU tests.  The FULL
configs are only ever touched through ``jax.eval_shape`` /
``ShapeDtypeStruct`` (dry-run) — never allocated.

Shape cells (LM shapes are seq_len x global_batch):
    train_4k     4,096 x 256   train_step
    prefill_32k  32,768 x 32   serve prefill (forward, no loss)
    decode_32k   32,768 x 128  serve_step: 1 new token, KV cache of seq_len
    long_500k    524,288 x 1   serve_step; sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "whisper_medium", "minitron_8b", "qwen2_5_3b", "mistral_nemo_12b",
    "llama3_2_3b", "qwen2_vl_7b", "grok_1_314b", "llama4_maverick_400b",
    "jamba_1_5_large_398b", "xlstm_1_3b",
]

ARCHS: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ModelConfig
    smoke: ModelConfig
    notes: str = ""


def register(arch_id: str, config: ModelConfig, smoke: ModelConfig,
             notes: str = ""):
    ARCHS[arch_id] = ArchEntry(config, smoke, notes)


def _load_all():
    for aid in ARCH_IDS + ["ghost_spmv"]:
        if aid not in ARCHS:
            try:
                importlib.import_module(f"repro.configs.{aid}")
            except ModuleNotFoundError:
                if aid != "ghost_spmv":
                    raise


def list_archs() -> List[str]:
    _load_all()
    return [a for a in ARCHS if a != "ghost_spmv"]


def get_config(arch_id: str) -> ModelConfig:
    _load_all()
    return ARCHS[arch_id].config


def get_smoke_config(arch_id: str) -> ModelConfig:
    _load_all()
    return ARCHS[arch_id].smoke


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """The long_500k sub-quadratic rule (see DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k skipped (quadratic)"
    return True, ""


def dryrun_cells() -> List[Tuple[str, str]]:
    """All applicable (arch, shape) dry-run cells."""
    _load_all()
    cells = []
    for aid in list_archs():
        cfg = ARCHS[aid].config
        for sname, sp in SHAPES.items():
            ok, _ = shape_applicable(cfg, sp)
            if ok:
                cells.append((aid, sname))
    return cells


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                *, batch_override: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct batch for one cell.

    train/prefill: tokens (B, S) [+ labels/mask for train; enc_embeds stub
    for enc-dec].  decode: tokens (B, 1) + cur_len scalar (the KV cache is a
    separate argument built by ``init_cache`` — see launch/dryrun.py).
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        S_dec = S // cfg.dec_len_ratio if cfg.enc_dec else S
        spec = {"tokens": jax.ShapeDtypeStruct((B, max(S_dec, 1)), i32)}
        if cfg.enc_dec:
            spec["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.float32)
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct(spec["tokens"].shape, i32)
        return spec

    # decode: one new token against a cache of S
    spec = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.enc_dec:
        spec["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.float32)
    return spec
