"""jamba-1.5-large-398b [hybrid]: 72L, d=8192, 64H (kv=8), ff=24576,
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every
other layer [arXiv:2403.19887; hf].

Period-8 block pattern (attention at index 4, Mamba elsewhere; MoE on odd
layers).  Sub-quadratic -> the long_500k cell RUNS for this arch."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig

_PATTERN = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

FULL = ModelConfig(
    name="jamba_1_5_large_398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    pattern=_PATTERN,
    rope="none",                      # jamba uses no positional encoding
    moe=MoEConfig(n_experts=16, top_k=2, ghost_dispatch=True),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="jamba_1_5_large_398b_smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    pattern=_PATTERN,
    rope="none",
    moe=MoEConfig(n_experts=4, top_k=2, ghost_dispatch=True),
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    tie_embeddings=False, dtype=jnp.float32,
)

register("jamba_1_5_large_398b", FULL, SMOKE,
         notes="hybrid mamba/attn 7:1 + MoE 16e; long_500k RUNS")
