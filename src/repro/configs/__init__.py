"""Architecture configs: 10 assigned archs + the paper's own SpMV workload."""
from repro.configs.base import (
    ARCHS, SHAPES, ShapeSpec, get_config, get_smoke_config, input_specs,
    list_archs, dryrun_cells,
)

__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_config", "get_smoke_config",
           "input_specs", "list_archs", "dryrun_cells"]
