"""minitron-8b [dense]: 32L, d=4096, 32H (kv=8), ff=16384, vocab=256000 —
pruned nemotron [arXiv:2407.14679; hf]."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="minitron_8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    pattern=(("attn", "mlp"),),
    rope="rope", rope_theta=10000.0,
    tie_embeddings=False, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="minitron_8b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    pattern=(("attn", "mlp"),),
    tie_embeddings=False, dtype=jnp.float32,
)

register("minitron_8b", FULL, SMOKE,
         notes="dense GQA; long_500k skipped (full attention)")
