"""xlstm-1.3b [ssm]: 48L, d=2048, 4H (kv=4), d_ff=0, vocab=50304 —
sLSTM + mLSTM blocks, 7:1 interleave [arXiv:2405.04517; unverified].

d_ff=0: the mLSTM/sLSTM blocks carry their own up/down projections, no
separate MLP.  Sub-quadratic -> long_500k RUNS."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.transformer import ModelConfig
from repro.models.xlstm import XLSTMConfig

_PATTERN = tuple(
    ("slstm" if i == 7 else "mlstm", "none") for i in range(8)
)

FULL = ModelConfig(
    name="xlstm_1_3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    pattern=_PATTERN,
    rope="none",
    xlstm=XLSTMConfig(n_heads=4, expand=2, slstm_every=8),
    tie_embeddings=True, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="xlstm_1_3b_smoke", family="ssm",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512,
    pattern=_PATTERN,
    rope="none",
    xlstm=XLSTMConfig(n_heads=4, expand=2, slstm_every=8),
    dtype=jnp.float32,
)

register("xlstm_1_3b", FULL, SMOKE,
         notes="mLSTM/sLSTM 7:1, recurrent decode state; long_500k RUNS")
