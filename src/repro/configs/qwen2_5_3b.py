"""qwen2.5-3b [dense]: 36L, d=2048, 16H (kv=2), ff=11008, vocab=151936 —
GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="qwen2_5_3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936,
    pattern=(("attn", "mlp"),),
    rope="rope", rope_theta=1_000_000.0, qkv_bias=True,
    tie_embeddings=True, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="qwen2_5_3b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    pattern=(("attn", "mlp"),), qkv_bias=True,
    dtype=jnp.float32,
)

register("qwen2_5_3b", FULL, SMOKE,
         notes="QKV bias; long_500k skipped (full attention)")
