"""whisper-medium [audio]: enc-dec, 24+24L, d=1024, 16H (kv=16), ff=4096,
vocab=51865 [arXiv:2212.04356; unverified].  Conv frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, S, d)."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="whisper_medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    pattern=(("attn", "mlp"),),
    rope="sinusoidal", norm="layernorm", act="gelu",
    tie_embeddings=True, enc_dec=True, n_enc_layers=24, dec_len_ratio=8,
    dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="whisper_medium_smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
    pattern=(("attn", "mlp"),),
    rope="sinusoidal", norm="layernorm", act="gelu",
    tie_embeddings=True, enc_dec=True, n_enc_layers=2, dec_len_ratio=4,
    dtype=jnp.float32,
)

register("whisper_medium", FULL, SMOKE,
         notes="enc-dec; frontend stubbed; full attention -> long_500k skipped")
