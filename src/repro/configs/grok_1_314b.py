"""grok-1-314b [moe]: 64L, d=6144, 48H (kv=8), ff=32768, vocab=131072,
MoE 8 experts top-2 every layer [hf:xai-org/grok-1; unverified].

MoE dispatch uses the GHOST sparse path (paper C1/C4 analogue); with 8
experts < tp=16 the experts are TP-sharded internally (d_ff over 'model')."""
import jax.numpy as jnp

from repro.configs.base import register
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="grok_1_314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    pattern=(("attn", "moe"),),
    rope="rope",
    moe=MoEConfig(n_experts=8, top_k=2, ghost_dispatch=True),
    tie_embeddings=False, dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="grok_1_314b_smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=4, top_k=2, ghost_dispatch=True),
    tie_embeddings=False, dtype=jnp.float32,
)

register("grok_1_314b", FULL, SMOKE,
         notes="GHOST sparse MoE dispatch; long_500k skipped")
