"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent), interleaved 7:1 in the
xlstm-1.3b configuration.

Both are sub-quadratic: O(S) train compute, O(1)/token decode state —
which is why the 500k long-context cell runs for this architecture.

mLSTM uses exponential input gating with the max-state stabilizer m_t
(log-space) and a per-head matrix memory C (d_head x d_head).  The train
path scans time in remat'ed chunks like the Mamba block.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init

__all__ = ["XLSTMConfig", "mlstm_init", "mlstm_apply", "mlstm_decode_init",
           "mlstm_decode_step", "slstm_init", "slstm_apply",
           "slstm_decode_init", "slstm_decode_step"]


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    n_heads: int = 4
    expand: int = 2          # mLSTM up-projection factor
    slstm_every: int = 8     # every k-th block is sLSTM (7:1 ratio)
    chunk: int = 256
    chunkwise: bool = False  # §Perf H2: chunkwise-parallel mLSTM (matmul
                             # form; touches the (dh x dh) state once per
                             # chunk instead of every step)


# ---------------------------------------------------------------- mLSTM
def mlstm_init(key, d_model, cfg: XLSTMConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 7)
    di = cfg.expand * d_model
    H = cfg.n_heads
    return {
        "up": dense_init(ks[0], d_model, (d_model, 2 * di), dtype),
        "wq": dense_init(ks[1], di, (di, di), dtype),
        "wk": dense_init(ks[2], di, (di, di), dtype),
        "wv": dense_init(ks[3], di, (di, di), dtype),
        "wi": dense_init(ks[4], di, (di, H), jnp.float32),
        "wf": dense_init(ks[5], di, (di, H), jnp.float32),
        "bi": jnp.zeros((H,), jnp.float32),
        "bf": jnp.full((H,), 3.0, jnp.float32),       # open forget gates
        "down": dense_init(ks[6], di, (di, d_model), dtype),
        "skip_scale": jnp.ones((di,), dtype),
    }


def _mlstm_heads(params, x, cfg, d_model):
    di = cfg.expand * d_model
    H = cfg.n_heads
    dh = di // H
    B, S = x.shape[:2]
    up = jnp.einsum("bsd,de->bse", x, params["up"])
    xm, z = jnp.split(up, 2, axis=-1)                      # (B, S, di)
    q = jnp.einsum("bsd,de->bse", xm, params["wq"]).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,de->bse", xm, params["wk"]).reshape(B, S, H, dh)
    v = jnp.einsum("bsd,de->bse", xm, params["wv"]).reshape(B, S, H, dh)
    k = k / jnp.sqrt(jnp.asarray(dh, k.dtype))
    logi = jnp.einsum("bsd,dh->bsh", xm.astype(jnp.float32), params["wi"]) + params["bi"]
    logf = jnp.einsum("bsd,dh->bsh", xm.astype(jnp.float32), params["wf"]) + params["bf"]
    logf = -jax.nn.softplus(-logf)                          # log sigmoid
    return xm, z, q, k, v, logi, logf


def _mlstm_chunkwise(q, k, v, logi, logf, chunk: int):
    """Chunkwise-parallel mLSTM (§Perf H2; flash-linear-attention style).

    The recurrent form streams the (dh x dh) matrix memory from HBM every
    timestep — S*H*dh^2*4 bytes/layer of pure state traffic.  The chunkwise
    form carries (C, n, m) across chunks of W steps and handles the
    intra-chunk part with three masked matmuls, touching the state once per
    chunk: state traffic drops by W, and the compute becomes MXU matmuls.

    q,k,v: (B, S, H, dh) (k pre-scaled); logi/logf: (B, S, H) log gates.
    Returns h: (B, S, H, dh), matching the recurrent reference to fp
    tolerance (tests/test_xlstm_chunkwise.py).
    """
    B, S, H, dh = q.shape
    W = min(chunk, S)
    nch = (S + W - 1) // W
    Sp = nch * W
    if Sp != S:
        pad4 = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, pad4) for a in (q, k, v))
        logi = jnp.pad(logi, ((0, 0), (0, Sp - S), (0, 0)),
                       constant_values=-30.0)
        logf = jnp.pad(logf, ((0, 0), (0, Sp - S), (0, 0)))

    def chunks(a):
        return jnp.moveaxis(a.reshape((B, nch, W) + a.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = map(chunks, (q, k, v, logi, logf))

    @jax.checkpoint
    def chunk_body(carry, inp):
        C, n, m = carry                    # (B,H,dh,dh), (B,H,dh), (B,H)
        qk, kk, vk, lik, lfk = inp         # (B,W,...)
        qk = qk.astype(jnp.float32)
        kk = kk.astype(jnp.float32)
        vk = vk.astype(jnp.float32)
        # cumulative log forget within the chunk: F[t] = sum_{s<=t} logf[s]
        F = jnp.cumsum(lfk, axis=1)                       # (B, W, H)
        Ftot = F[:, -1]                                   # (B, H)
        # log weights: inter (state) contribution decays by F[t];
        # intra source s -> target t weight: F[t]-F[s]+logi[s]
        log_inter = F + m[:, None]                        # (B, W, H)
        log_src = lik - F                                 # (B, W, H) + const
        # stabilizer per (b, t, h): max over inter and best intra source
        run_max_src = lax.cummax(log_src, axis=1)         # (B, W, H)
        m_t = jnp.maximum(log_inter, F + run_max_src)     # (B, W, H)
        # intra-chunk masked attention-like matrix
        #   D[t,s] = exp(F[t] - F[s] + logi[s] - m_t)   (s <= t)
        logD = (F[:, :, None, :] - F[:, None, :, :]
                + lik[:, None, :, :] - m_t[:, :, None, :])  # (B, t, s, H)
        tri = jnp.tril(jnp.ones((W, W), bool))
        Dm = jnp.where(tri[None, :, :, None], jnp.exp(logD), 0.0)
        s_qk = jnp.einsum("bthd,bshd->btsh", qk, kk)      # (B, t, s, H)
        w_ts = s_qk * Dm
        h_intra = jnp.einsum("btsh,bshd->bthd", w_ts, vk)
        n_intra = jnp.einsum("btsh,bshd->bthd", Dm, kk)   # for normalizer
        # inter-chunk (carried state) contribution
        scale_t = jnp.exp(log_inter - m_t)                # (B, W, H)
        h_inter = jnp.einsum("bthd,bhde->bthe", qk, C) * scale_t[..., None]
        n_inter = n[:, None] * scale_t[..., None]         # (B, W, H, dh)
        num = h_intra + h_inter
        den = jnp.abs(jnp.einsum("bthd,bthd->bth", qk, n_intra + n_inter))
        h = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # ---- state update to end of chunk -------------------------------
        m_new = jnp.maximum(Ftot + m,
                            jnp.max(log_src + Ftot[:, None], axis=1))
        # source weights for the state: exp(Ftot - F[s] + logi[s] - m_new)
        w_src = jnp.exp(Ftot[:, None] + log_src - m_new[:, None])  # (B,W,H)
        C_new = (jnp.exp(Ftot + m - m_new)[..., None, None] * C
                 + jnp.einsum("bsh,bshd,bshe->bhde", w_src, kk, vk))
        n_new = (jnp.exp(Ftot + m - m_new)[..., None] * n
                 + jnp.einsum("bsh,bshd->bhd", w_src, kk))
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    _, hs = lax.scan(chunk_body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, H, dh)[:, :S]
    return hs


def mlstm_apply(params, x, cfg: XLSTMConfig):
    """x: (B, S, d_model) -> (B, S, d_model).  Chunked recurrent scan."""
    B, S, d_model = x.shape
    di = cfg.expand * d_model
    H = cfg.n_heads
    dh = di // H
    xm, z, q, k, v, logi, logf = _mlstm_heads(params, x, cfg, d_model)

    if cfg.chunkwise:
        hs = _mlstm_chunkwise(q, k, v, logi, logf, cfg.chunk)
        h = hs.reshape(B, S, di).astype(x.dtype)
        h = h + params["skip_scale"] * xm
        h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
        return jnp.einsum("bse,ed->bsd", h, params["down"])

    chunk = min(cfg.chunk, S)
    nch = (S + chunk - 1) // chunk
    Sp = nch * chunk

    def padt(a, fill=0.0):
        if Sp == S:
            return a
        return jnp.pad(a, ((0, 0), (0, Sp - S)) + ((0, 0),) * (a.ndim - 2),
                       constant_values=fill)

    q, k, v = padt(q), padt(k), padt(v)
    logi = padt(logi, -30.0)           # padded steps contribute ~nothing
    logf = padt(logf, 0.0)             # and leave the state untouched

    def to_chunks(a):
        return jnp.moveaxis(
            a.reshape((B, nch, chunk) + a.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = map(to_chunks, (q, k, v, logi, logf))

    @jax.checkpoint
    def chunk_body(carry, inp):
        C, n, m = carry                # (B,H,dh,dh), (B,H,dh), (B,H)
        qk, kk, vk, lik, lfk = inp

        def step(st, t_inp):
            C, n, m = st
            qt, kt, vt, lit, lft = t_inp      # (B,H,dh)... (B,H)
            m_new = jnp.maximum(lft + m, lit)
            i_ = jnp.exp(lit - m_new)
            f_ = jnp.exp(lft + m - m_new)
            ktf = kt.astype(jnp.float32)
            vtf = vt.astype(jnp.float32)
            C = f_[..., None, None] * C + i_[..., None, None] * (
                ktf[..., :, None] * vtf[..., None, :])
            n = f_[..., None] * n + i_[..., None] * ktf
            qtf = qt.astype(jnp.float32)
            num = jnp.einsum("bhk,bhkv->bhv", qtf, C)
            den = jnp.abs(jnp.einsum("bhk,bhk->bh", qtf, n))
            h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
            return (C, n, m_new), h

        tseq = tuple(jnp.moveaxis(a, 1, 0) for a in (qk, kk, vk, lik, lfk))
        (C, n, m), hs = lax.scan(step, (C, n, m), tseq)
        return (C, n, m), jnp.moveaxis(hs, 0, 1)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    _, hs = lax.scan(chunk_body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, H, dh)[:, :S]
    h = hs.reshape(B, S, di).astype(x.dtype)
    h = h + params["skip_scale"] * xm
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", h, params["down"])


def mlstm_decode_init(B, d_model, cfg: XLSTMConfig):
    di = cfg.expand * d_model
    H = cfg.n_heads
    dh = di // H
    return {
        "C": jnp.zeros((B, H, dh, dh), jnp.float32),
        "n": jnp.zeros((B, H, dh), jnp.float32),
        "m": jnp.zeros((B, H), jnp.float32),
    }


def mlstm_decode_step(params, x, state, cfg: XLSTMConfig):
    B, _, d_model = x.shape
    di = cfg.expand * d_model
    H = cfg.n_heads
    dh = di // H
    xm, z, q, k, v, logi, logf = _mlstm_heads(params, x, cfg, d_model)
    qt, kt, vt = q[:, 0], k[:, 0], v[:, 0]
    lit, lft = logi[:, 0], logf[:, 0]
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lft + m, lit)
    i_ = jnp.exp(lit - m_new)
    f_ = jnp.exp(lft + m - m_new)
    ktf, vtf = kt.astype(jnp.float32), vt.astype(jnp.float32)
    C = f_[..., None, None] * C + i_[..., None, None] * (
        ktf[..., :, None] * vtf[..., None, :])
    n = f_[..., None] * n + i_[..., None] * ktf
    qtf = qt.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qtf, C)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", qtf, n))
    h = (num / jnp.maximum(den, jnp.exp(-m_new))[..., None]).reshape(B, di)
    h = h.astype(x.dtype) + params["skip_scale"] * xm[:, 0]
    h = h * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", h, params["down"])[:, None]
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------- sLSTM
def slstm_init(key, d_model, cfg: XLSTMConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    # Dense (d, 4d) recurrent matrix (classic LSTM form).  Sharded on its
    # CONTRACTION dim ('model'): the per-step forward psum is then a tiny
    # (B, 4d) activation reduction while the weight gradient accumulates
    # shard-locally — the block-diagonal (4, H, dh, dh) form forced XLA to
    # all-reduce the full weight-shaped gradient EVERY timestep (measured:
    # 4.2 MB x 24576 executions = 97% of the xlstm train collective term;
    # EXPERIMENTS.md §Perf H2).
    return {
        "wx": dense_init(ks[0], d_model, (d_model, 4 * d_model), dtype),
        "r": dense_init(ks[1], d_model, (d_model, 4 * d_model), jnp.float32),
        "b": jnp.concatenate([
            jnp.zeros((2 * d_model,), jnp.float32),
            jnp.full((d_model,), 3.0, jnp.float32),       # forget bias
            jnp.zeros((d_model,), jnp.float32)]),
        "out": dense_init(ks[2], d_model, (d_model, d_model), dtype),
    }


def _slstm_cell(pre, st):
    """One sLSTM cell given gate pre-activations.  pre: (B, 4, d)."""
    h, c, n, m = st
    zt = jnp.tanh(pre[:, 0])
    logi = pre[:, 1]
    logf = -jax.nn.softplus(-pre[:, 2])       # log sigmoid
    ot = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(logf + m, logi)
    i_ = jnp.exp(logi - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c = f_ * c + i_ * zt
    n = f_ * n + i_
    h_new = ot * c / jnp.maximum(n, 1e-6)
    return (h_new, c, n, m_new)


def _slstm_scan_raw(r, b, gx, st0):
    """Plain scan (reference path; weight grads accumulate in the carry,
    which XLA all-reduces EVERY timestep under data-parallel sharding)."""
    B, S = gx.shape[:2]
    d = gx.shape[2] // 4

    def step(st, g_t):
        rec = jnp.einsum("bd,de->be", st[0], r).reshape(B, 4, d)
        pre = g_t.astype(jnp.float32).reshape(B, 4, d) + rec + b.reshape(4, d)
        st = _slstm_cell(pre, st)
        return st, st[0]

    st, hs = lax.scan(step, st0, jnp.moveaxis(gx, 1, 0))
    return st, jnp.moveaxis(hs, 0, 1)


@jax.custom_vjp
def _slstm_scan_cv(r, b, gx, st0):
    return _slstm_scan_raw(r, b, gx, st0)


def _slstm_cv_fwd(r, b, gx, st0):
    out = _slstm_scan_raw(r, b, gx, st0)
    (st_end, hs) = out
    return out, (r, b, gx, st0, hs)


def _slstm_cv_bwd(res, cts):
    """Hand-rolled BPTT (§Perf H2): per-step pre-activation grads are
    *stacked* scan outputs, and dr/db are formed with ONE einsum after the
    reverse scan — so the weight-shaped gradient is reduced once per chunk
    instead of every timestep."""
    r, b, gx, st0, hs = res
    (d_st_end, d_hs) = cts
    B, S = gx.shape[:2]
    d = gx.shape[2] // 4
    h_prev_stack = jnp.concatenate([st0[0][:, None], hs[:, :-1]], axis=1)

    def pre_of(h_prev, g_t):
        rec = jnp.einsum("bd,de->be", h_prev, r).reshape(B, 4, d)
        return g_t.astype(jnp.float32).reshape(B, 4, d) + rec + b.reshape(4, d)

    def fwd_state(st, inp):
        h_prev, g_t = inp
        st_new = _slstm_cell(pre_of(h_prev, g_t), st)
        return st_new, st

    # recompute per-step input states (cheap relative to storing them)
    _, st_stack = lax.scan(fwd_state, st0,
                           (jnp.moveaxis(h_prev_stack, 1, 0),
                            jnp.moveaxis(gx, 1, 0)))

    def bwd_step(d_st, inp):
        st_prev, h_prev, g_t, d_h_out = inp
        d_h, d_c, d_n, d_m = d_st

        def f(pre, st):
            st_new = _slstm_cell(pre, st)
            return st_new

        pre = pre_of(h_prev, g_t)
        _, vjp = jax.vjp(f, pre, st_prev)
        (d_pre, d_st_prev) = vjp((d_h + d_h_out, d_c, d_n, d_m))
        # route the recurrent path to h_{t-1} locally (no weight grad here)
        d_hprev_rec = jnp.einsum("be,de->bd", d_pre.reshape(B, 4 * d), r)
        d_st_prev = (d_st_prev[0] + d_hprev_rec, d_st_prev[1],
                     d_st_prev[2], d_st_prev[3])
        return d_st_prev, d_pre

    xs = (st_stack,
          jnp.moveaxis(h_prev_stack, 1, 0),
          jnp.moveaxis(gx, 1, 0),
          jnp.moveaxis(d_hs, 1, 0))
    d_st0, d_pre_stack = lax.scan(bwd_step, d_st_end, xs, reverse=True)
    d_pre_flat = jnp.moveaxis(d_pre_stack, 0, 1).reshape(B, S, 4 * d)

    # single reductions for the weight grads (the whole point)
    dr = jnp.einsum("bsd,bse->de", h_prev_stack, d_pre_flat)
    db = jnp.sum(d_pre_flat, axis=(0, 1))
    dgx = d_pre_flat.astype(gx.dtype)
    return dr, db, dgx, d_st0


_slstm_scan_cv.defvjp(_slstm_cv_fwd, _slstm_cv_bwd)

# §Perf H2 toggle: custom-VJP (chunk-reduced weight grads) vs plain scan
SLSTM_CUSTOM_VJP = True


def _slstm_scan(params, gx, h0, c0, n0, m0, H, dh):
    """gx: (B, S, 4*d) precomputed input contributions."""
    r = params["r"].astype(jnp.float32)
    b = params["b"]
    st0 = (h0, c0, n0, m0)
    if SLSTM_CUSTOM_VJP:
        (st, hs) = _slstm_scan_cv(r, b, gx, st0)
    else:
        (st, hs) = _slstm_scan_raw(r, b, gx, st0)
    return st, hs


def slstm_apply(params, x, cfg: XLSTMConfig, *, chunk: int = 256):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    gx = jnp.einsum("bsd,de->bse", x, params["wx"])      # (B, S, 4d)

    nch = max(1, (S + chunk - 1) // chunk)
    Sp = nch * chunk
    if Sp != S:
        gx = jnp.pad(gx, ((0, 0), (0, Sp - S), (0, 0)))
    gc = jnp.moveaxis(gx.reshape(B, nch, chunk, 4 * d), 1, 0)

    @jax.checkpoint
    def chunk_body(st, g_k):
        st, hs = _slstm_scan(params, g_k, *st, H=H, dh=dh)
        return st, hs

    st0 = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
           jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32))
    _, hs = lax.scan(chunk_body, st0, gc)
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, d)[:, :S]
    return jnp.einsum("bsd,de->bse", hs.astype(x.dtype), params["out"])


def slstm_decode_init(B, d_model, cfg: XLSTMConfig):
    z = jnp.zeros((B, d_model), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def slstm_decode_step(params, x, state, cfg: XLSTMConfig):
    B, _, d = x.shape
    H = cfg.n_heads
    dh = d // H
    gx = jnp.einsum("bsd,de->bse", x, params["wx"])
    st = (state["h"], state["c"], state["n"], state["m"])
    (h, c, n, m), hs = _slstm_scan(params, gx, *st, H=H, dh=dh)
    out = jnp.einsum("bsd,de->bse", hs.astype(x.dtype), params["out"])
    return out, {"h": h, "c": c, "n": n, "m": m}
