"""Mixture-of-Experts layer with GHOST-style sparse dispatch (paper C1/C4).

The token->expert dispatch of an MoE layer is a sparse matrix: T*topk
nonzeros in a (E*capacity, T) selection operator.  The conventional dense
formulation materializes a one-hot (T, E, capacity) combine tensor — the
analogue of storing a sparse matrix densely.  ``ghost_dispatch`` instead
uses the compressed-index machinery of the distributed SELL-C-sigma SpMV
(paper Fig. 3): tokens are *sorted by expert* (the MoE analogue of GHOST's
sigma-sort — it turns the scattered gather into contiguous slab access),
compressed positions are computed with a cumulative count, and the
gather/scatter runs with int32 index vectors, never a one-hot.

Expert placement follows paper C4's weighted data-parallel philosophy:
experts are sharded over the 'model' mesh axis when E divides it (EP),
otherwise each expert's d_ff is sharded (TP-in-expert); see
``models/sharding.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    ghost_dispatch: bool = True      # sparse (sort+gather) vs dense one-hot
    router_jitter: float = 0.0


def moe_init(key, d_model, d_ff, cfg: MoEConfig, *, act="swiglu",
             dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    E = cfg.n_experts
    p = {
        "router": dense_init(ks[0], d_model, (d_model, E), jnp.float32),
        "wi": dense_init(ks[1], d_model, (E, d_model, d_ff), dtype),
        "wo": dense_init(ks[3], d_ff, (E, d_ff, d_model), dtype),
    }
    if act == "swiglu":
        p["wg"] = dense_init(ks[2], d_model, (E, d_model, d_ff), dtype)
    return p


def _expert_ffn(params, xe, act):
    """xe: (E, cap, d) -> (E, cap, d), batched over experts.

    NOTE (§Perf H3, refuted direction): constraining the workspaces to
    (E@model, cap@data, ·) to avoid the d-contraction activation psum was
    tried and made things 3-4x WORSE — the dispatch scatter then has to
    realize cross-shard token movement per layer.  Under GSPMD the sorted
    dispatch keeps tokens where the router put them; the structural fix is
    a shard_map-local dispatch with an explicit expert all-to-all (future
    lever, measured bound in EXPERIMENTS.md)."""
    h = jnp.einsum("ecd,edf->ecf", xe, params["wi"])
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, params["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, params["wo"])


def moe_apply(params, x, cfg: MoEConfig, *, act="swiglu",
              rng: Optional[jax.Array] = None):
    """x: (B, S, d) -> (B, S, d), plus aux losses dict."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    if cfg.router_jitter and rng is not None:
        logits = logits + cfg.router_jitter * jax.random.normal(
            rng, logits.shape, jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)     # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = {"load_balance": E * jnp.sum(me * ce)}

    cap = int(max(1, T * K * cfg.capacity_factor / E))

    if cfg.ghost_dispatch:
        out = _ghost_dispatch(params, xt, expert_ids, gate_vals, E, K, cap, act)
    else:
        out = _dense_dispatch(params, xt, expert_ids, gate_vals, E, K, cap, act)
    return out.reshape(B, S, d), aux


def _ghost_dispatch(params, xt, expert_ids, gate_vals, E, K, cap, act):
    """Sparse dispatch: sort by expert (sigma-sort analogue), compressed
    int32 gather/scatter (remote-column compression analogue)."""
    T, d = xt.shape
    flat_e = expert_ids.reshape(T * K)                  # (TK,)
    flat_t = jnp.repeat(jnp.arange(T), K)               # token of each slot
    flat_g = gate_vals.reshape(T * K)

    order = jnp.argsort(flat_e, stable=True)            # sigma-sort by expert
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    g_sorted = flat_g[order]

    # position of each slot within its expert (compressed halo index)
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos_in_e = jnp.arange(T * K) - seg_start[e_sorted]

    keep = pos_in_e < cap                               # capacity drop
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, E * cap)

    # gather tokens into the (E*cap, d) workspace (scatter with drop)
    buf = jnp.zeros((E * cap + 1, d), xt.dtype)
    buf = buf.at[slot].set(xt[t_sorted])
    xe = buf[: E * cap].reshape(E, cap, d)

    ye = _expert_ffn(params, xe, act).reshape(E * cap, d)

    # combine: weighted scatter-add back to tokens (int32 segment-sum —
    # the SpMMV y += A_remote @ halo step)
    contrib = ye[jnp.where(keep, slot, 0)] * jnp.where(
        keep, g_sorted, 0.0)[:, None].astype(ye.dtype)
    out = jax.ops.segment_sum(contrib, t_sorted, num_segments=T)
    return out.astype(xt.dtype)


def _dense_dispatch(params, xt, expert_ids, gate_vals, E, K, cap, act):
    """Conventional one-hot dispatch/combine (the paper's 'dense storage'
    baseline; kept for the benchmark comparison)."""
    T, d = xt.shape
    # position of each (t, k) within its expert via cumsum over one-hot
    oh = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)      # (T, K, E)
    pos = jnp.cumsum(oh.reshape(T * K, E), axis=0).reshape(T, K, E) - 1
    pos = jnp.sum(pos * oh, axis=-1)                         # (T, K)
    keep = pos < cap
    disp = (jax.nn.one_hot(expert_ids, E, dtype=xt.dtype)[..., :, None]
            * jax.nn.one_hot(pos, cap, dtype=xt.dtype)[..., None, :]
            * keep[..., None, None])                         # (T, K, E, cap)
    xe = jnp.einsum("td,tkec->ecd", xt, disp)
    ye = _expert_ffn(params, xe, act)
    comb = disp * gate_vals[..., None, None].astype(xt.dtype)
    return jnp.einsum("ecd,tkec->td", ye, comb)
