"""Composable LM-family model definition.

Supports every family in the assigned pool through a *block pattern*: a
periodic sequence of mixer kinds ("attn", "mamba", "mlstm", "slstm"), each
optionally followed by a dense MLP or an MoE FFN.  Layers are executed as a
``lax.scan`` over pattern periods (params stacked over periods) so the HLO
stays compact for 72-layer models, with per-period remat.

Encoder-decoder (whisper) runs an encoder stack (bidirectional) and a
decoder stack with interleaved cross-attention; the audio/vision frontends
are stubs per the assignment spec (``input_specs`` provides precomputed
frame/patch embeddings).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL

__all__ = ["ModelConfig", "init_params", "forward", "loss_fn",
           "init_cache", "decode_step", "param_count"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # block pattern: list of (mixer, ffn) kind tuples, length = period
    # mixer in {"attn","mamba","mlstm","slstm"}; ffn in {"mlp","moe","none"}
    pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    rope: str = "rope"               # rope|mrope|sinusoidal|none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    act: str = "swiglu"
    tie_embeddings: bool = True
    moe: Optional[MOE.MoEConfig] = None
    ssm: SSM.SSMConfig = SSM.SSMConfig()
    xlstm: XL.XLSTMConfig = XL.XLSTMConfig()
    enc_dec: bool = False
    n_enc_layers: int = 0            # encoder stack depth (enc_dec only)
    dec_len_ratio: int = 8           # S_dec = S / ratio for enc-dec cells
    dtype: Any = jnp.bfloat16
    vocab_pad: int = 256
    max_position: int = 1 << 20

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        v, p = self.vocab_size, self.vocab_pad
        return ((v + p - 1) // p) * p

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        if self.n_layers % self.period != 0:
            raise ValueError(f"n_layers={self.n_layers} not a multiple of "
                             f"the layer pattern period {self.period}")
        return self.n_layers // self.period

    def full_pattern(self) -> List[Tuple[str, str]]:
        return list(self.pattern) * self.n_periods

    @property
    def sub_quadratic(self) -> bool:
        mixers = {m for m, _ in self.pattern}
        return "attn" not in mixers or mixers & {"mamba", "mlstm", "slstm"}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _mixer_init(key, cfg: ModelConfig, kind: str, cross: bool = False):
    if kind == "attn":
        p = {
            "norm": L.norm_init(cfg.norm, cfg.d_model),
            "attn": L.attention_init(key, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.hd,
                                     qkv_bias=cfg.qkv_bias, dtype=cfg.dtype),
        }
        if cross:
            k2 = jax.random.fold_in(key, 1)
            p["xnorm"] = L.norm_init(cfg.norm, cfg.d_model)
            p["xattn"] = L.attention_init(k2, cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.hd,
                                          qkv_bias=cfg.qkv_bias,
                                          dtype=cfg.dtype)
        return p
    if kind == "mamba":
        return {"norm": L.norm_init(cfg.norm, cfg.d_model),
                "mamba": SSM.mamba_init(key, cfg.d_model, cfg.ssm, cfg.dtype)}
    if kind == "mlstm":
        return {"norm": L.norm_init(cfg.norm, cfg.d_model),
                "mlstm": XL.mlstm_init(key, cfg.d_model, cfg.xlstm, cfg.dtype)}
    if kind == "slstm":
        return {"norm": L.norm_init(cfg.norm, cfg.d_model),
                "slstm": XL.slstm_init(key, cfg.d_model, cfg.xlstm, cfg.dtype)}
    raise ValueError(kind)


def _ffn_init(key, cfg: ModelConfig, kind: str):
    if kind == "none":
        return {}
    if kind == "mlp":
        return {"norm": L.norm_init(cfg.norm, cfg.d_model),
                "mlp": L.mlp_init(key, cfg.d_model, cfg.d_ff, act=cfg.act,
                                  dtype=cfg.dtype)}
    if kind == "moe":
        if cfg.moe is None:
            raise ValueError("mixer kind 'moe' needs cfg.moe")
        return {"norm": L.norm_init(cfg.norm, cfg.d_model),
                "moe": MOE.moe_init(key, cfg.d_model, cfg.d_ff, cfg.moe,
                                    act=cfg.act, dtype=cfg.dtype)}
    raise ValueError(kind)


def _stack_init(key, cfg: ModelConfig, n_periods: int, *, cross: bool):
    """Init a layer stack: pytree with leading n_periods dim per leaf."""
    def one_period(k):
        sub = {}
        for i, (mix, ffn) in enumerate(cfg.pattern):
            km = jax.random.fold_in(k, 2 * i)
            kf = jax.random.fold_in(k, 2 * i + 1)
            sub[f"l{i}_mix"] = _mixer_init(km, cfg, mix, cross=cross)
            sub[f"l{i}_ffn"] = _ffn_init(kf, cfg, ffn)
        return sub

    keys = jax.random.split(key, n_periods)
    trees = [one_period(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 5)
    params = {"embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                    cfg.dtype),
              "final_norm": L.norm_init(cfg.norm, cfg.d_model)}
    if cfg.enc_dec:
        enc_cfg = cfg  # same dims, bidirectional handled at apply time
        if cfg.n_enc_layers % cfg.period != 0:
            raise ValueError(
                f"n_enc_layers={cfg.n_enc_layers} not a multiple of the "
                f"layer pattern period {cfg.period}")
        params["encoder"] = _stack_init(ks[1], cfg, cfg.n_enc_layers // cfg.period,
                                        cross=False)
        params["enc_norm"] = L.norm_init(cfg.norm, cfg.d_model)
        params["decoder"] = _stack_init(ks[2], cfg, cfg.n_periods, cross=True)
    else:
        params["decoder"] = _stack_init(ks[2], cfg, cfg.n_periods, cross=False)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L.dense_init(ks[3], cfg.d_model,
                                               (cfg.d_model, cfg.padded_vocab),
                                               cfg.dtype)}
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Parameters touched per token (MoE counts top_k of n_experts)."""
    total = param_count(params)
    if cfg.moe is None:
        return total
    moe_leaves = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        if any(getattr(p, "key", None) == "moe" for p in path):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("wi", "wg", "wo"):
                moe_leaves += leaf.size
    act = total - moe_leaves + moe_leaves * cfg.moe.top_k // cfg.moe.n_experts
    return act


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_mixer(cfg: ModelConfig, p, x, kind, *, causal, positions,
                 positions3, enc_out=None, kv_cache=None, cache_len=None):
    h = L.apply_norm(cfg.norm, p["norm"], x)
    new_cache = None
    if kind == "attn":
        out, new_kv = L.attention_apply(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hd, positions=positions, positions3=positions3,
            rope=cfg.rope, rope_theta=cfg.rope_theta,
            mrope_sections=cfg.mrope_sections, causal=causal,
            kv_cache=None if kv_cache is None else kv_cache.get("self"),
            cache_len=cache_len)
        x = x + out
        new_cache = {"self": new_kv}
        if "xattn" in p:
            hx = L.apply_norm(cfg.norm, p["xnorm"], x)
            xo, _ = L.attention_apply(
                p["xattn"], hx, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.hd, rope="none", causal=False,
                cross_kv=enc_out)
            x = x + xo
    elif kind == "mamba":
        if kv_cache is None:
            x = x + SSM.mamba_apply(p["mamba"], h, cfg.ssm)
        else:
            out, st = SSM.mamba_decode_step(p["mamba"], h, kv_cache["ssm"],
                                            cfg.ssm)
            x = x + out
            new_cache = {"ssm": st}
    elif kind == "mlstm":
        if kv_cache is None:
            x = x + XL.mlstm_apply(p["mlstm"], h, cfg.xlstm)
        else:
            out, st = XL.mlstm_decode_step(p["mlstm"], h, kv_cache["mlstm"],
                                           cfg.xlstm)
            x = x + out
            new_cache = {"mlstm": st}
    elif kind == "slstm":
        if kv_cache is None:
            x = x + XL.slstm_apply(p["slstm"], h, cfg.xlstm)
        else:
            out, st = XL.slstm_decode_step(p["slstm"], h, kv_cache["slstm"],
                                           cfg.xlstm)
            x = x + out
            new_cache = {"slstm": st}
    else:
        raise ValueError(kind)
    return x, new_cache


def _apply_ffn(cfg: ModelConfig, p, x, kind):
    aux = {}
    if kind == "none" or not p:
        return x, aux
    h = L.apply_norm(cfg.norm, p["norm"], x)
    if kind == "mlp":
        x = x + L.mlp_apply(p["mlp"], h, act=cfg.act)
    else:
        out, aux = MOE.moe_apply(p["moe"], h, cfg.moe, act=cfg.act)
        x = x + out
    return x, aux


def _run_stack(cfg: ModelConfig, stack_params, x, *, causal, positions,
               positions3, enc_out=None, remat=True):
    """Scan over pattern periods; remat each period."""
    from repro.models.sharding import constrain
    pattern = cfg.pattern
    aux_acc = jnp.zeros((), jnp.float32)

    def period_body(carry, p):
        x, aux = carry
        x = constrain(x, "dp", None, None)    # residual stream: DP only
        for i, (mix, ffn) in enumerate(pattern):
            x, _ = _apply_mixer(cfg, p[f"l{i}_mix"], x, mix, causal=causal,
                                positions=positions, positions3=positions3,
                                enc_out=enc_out)
            x, a = _apply_ffn(cfg, p[f"l{i}_ffn"], x, ffn)
            if "load_balance" in a:
                aux = aux + a["load_balance"]
        return (x, aux), None

    body = jax.checkpoint(period_body) if remat else period_body
    (x, aux_acc), _ = lax.scan(body, (x, aux_acc), stack_params)
    return x, aux_acc


def forward(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            *, remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits, aux_loss).

    batch keys: ``tokens`` (B, S) int32 — decoder tokens; for enc-dec also
    ``enc_embeds`` (B, S_enc, d) stub frontend output; for vlm optionally
    ``positions3`` (B, S, 3).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed_apply(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    positions3 = batch.get("positions3")
    if cfg.rope == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(positions[..., None], (B, S, 3))
    if cfg.rope == "sinusoidal":
        x = x + L.sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)

    aux = jnp.zeros((), jnp.float32)
    if cfg.enc_dec:
        e = batch["enc_embeds"].astype(cfg.dtype)
        Se = e.shape[1]
        e = e + L.sinusoidal_positions(Se, cfg.d_model)[None].astype(e.dtype)
        e, aux_e = _run_stack(cfg, params["encoder"], e, causal=False,
                              positions=None, positions3=None, remat=remat)
        e = L.apply_norm(cfg.norm, params["enc_norm"], e)
        aux = aux + aux_e
        x, aux_d = _run_stack_encdec(cfg, params["decoder"], x, e, positions,
                                     remat=remat)
    else:
        x, aux_d = _run_stack(cfg, params["decoder"], x, causal=True,
                              positions=positions, positions3=positions3,
                              remat=remat)
    aux = aux + aux_d

    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.lm_head_apply(params["embed"], x, params.get("lm_head"))
    from repro.models.sharding import constrain
    logits = constrain(logits, "dp", None, "model")   # vocab-parallel
    return logits, aux


def _run_stack_encdec(cfg: ModelConfig, stack_params, x, enc_states,
                      positions, *, remat=True):
    """Decoder stack with cross attention to ``enc_states``."""
    from repro.models.sharding import constrain
    pattern = cfg.pattern

    def period_body(carry, p):
        x, aux = carry
        x = constrain(x, "dp", None, None)
        for i, (mix, ffn) in enumerate(pattern):
            pm = p[f"l{i}_mix"]
            h = L.apply_norm(cfg.norm, pm["norm"], x)
            out, _ = L.attention_apply(
                pm["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.hd, positions=positions, rope=cfg.rope,
                causal=True)
            x = x + out
            # cross-attention: project enc states to K/V per layer
            hx = L.apply_norm(cfg.norm, pm["xnorm"], x)
            B, Se = enc_states.shape[:2]
            k = jnp.einsum("bsd,df->bsf", enc_states, pm["xattn"]["wk"])
            v = jnp.einsum("bsd,df->bsf", enc_states, pm["xattn"]["wv"])
            k = k.reshape(B, Se, cfg.n_kv_heads, cfg.hd)
            v = v.reshape(B, Se, cfg.n_kv_heads, cfg.hd)
            xo, _ = L.attention_apply(
                pm["xattn"], hx, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.hd, rope="none", causal=False, cross_kv=(k, v))
            x = x + xo
            x, a = _apply_ffn(cfg, p[f"l{i}_ffn"], x, ffn)
            if "load_balance" in a:
                aux = aux + a["load_balance"]
        return (x, aux), None

    body = jax.checkpoint(period_body) if remat else period_body
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack_params)
    return x, aux


def loss_fn(cfg: ModelConfig, params, batch, *, remat=True):
    """Next-token cross entropy (+ MoE aux)."""
    logits, aux = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    V = logits.shape[-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    loss = nll.sum() / denom
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, B: int, max_len: int,
               enc_len: int = 0) -> Dict:
    """Decode cache pytree, stacked over periods like the params."""
    def one_period():
        sub = {}
        for i, (mix, _) in enumerate(cfg.pattern):
            if mix == "attn":
                kv = {
                    "self": (
                        jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
                        jnp.zeros((B, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
                    )
                }
                sub[f"l{i}"] = kv
            elif mix == "mamba":
                sub[f"l{i}"] = {"ssm": SSM.mamba_decode_init(
                    B, cfg.d_model, cfg.ssm, cfg.dtype)}
            elif mix == "mlstm":
                sub[f"l{i}"] = {"mlstm": XL.mlstm_decode_init(
                    B, cfg.d_model, cfg.xlstm)}
            elif mix == "slstm":
                sub[f"l{i}"] = {"slstm": XL.slstm_decode_init(
                    B, cfg.d_model, cfg.xlstm)}
        return sub

    trees = [one_period() for _ in range(cfg.n_periods)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def decode_step(cfg: ModelConfig, params, cache, tokens, cur_len,
                enc_out=None):
    """One decode step.  tokens (B, 1) -> (logits (B, 1, V), new_cache).

    ``cur_len`` is the current valid cache length (traced scalar ok).
    For enc-dec models pass ``enc_out`` (B, S_enc, d) encoder states.
    """
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens)
    positions = jnp.full((B, 1), cur_len, jnp.int32)
    positions3 = None
    if cfg.rope == "mrope":
        positions3 = jnp.broadcast_to(positions[..., None], (B, 1, 3))
    if cfg.rope == "sinusoidal":
        pos_emb = L.sinusoidal_positions(cfg.max_position, cfg.d_model)
        x = x + lax.dynamic_slice_in_dim(pos_emb, cur_len, 1, 0)[None].astype(x.dtype)

    pattern = cfg.pattern

    def period_body(x, inp):
        p, kv = inp
        new_kv = {}
        for i, (mix, ffn) in enumerate(pattern):
            pm = p[f"l{i}_mix"]
            if mix == "attn":
                h = L.apply_norm(cfg.norm, pm["norm"], x)
                out, nkv = L.attention_apply(
                    pm["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.hd, positions=positions, positions3=positions3,
                    rope=cfg.rope, rope_theta=cfg.rope_theta,
                    mrope_sections=cfg.mrope_sections, causal=True,
                    kv_cache=kv[f"l{i}"]["self"], cache_len=cur_len)
                x = x + out
                if "xattn" in pm and enc_out is not None:
                    hx = L.apply_norm(cfg.norm, pm["xnorm"], x)
                    Se = enc_out.shape[1]
                    k = jnp.einsum("bsd,df->bsf", enc_out, pm["xattn"]["wk"]
                                   ).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
                    v = jnp.einsum("bsd,df->bsf", enc_out, pm["xattn"]["wv"]
                                   ).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
                    xo, _ = L.attention_apply(
                        pm["xattn"], hx, n_heads=cfg.n_heads,
                        n_kv=cfg.n_kv_heads, head_dim=cfg.hd, rope="none",
                        causal=False, cross_kv=(k, v))
                    x = x + xo
                new_kv[f"l{i}"] = {"self": nkv}
            else:
                x, nc = _apply_mixer(cfg, pm, x, mix, causal=True,
                                     positions=positions, positions3=positions3,
                                     kv_cache=kv[f"l{i}"], cache_len=cur_len)
                new_kv[f"l{i}"] = nc
            x, _ = _apply_ffn(cfg, p[f"l{i}_ffn"], x, ffn)
        return x, new_kv

    x, new_cache = lax.scan(period_body, x, (params["decoder"], cache))
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.lm_head_apply(params["embed"], x, params.get("lm_head"))
    return logits, new_cache
