"""Core NN layers for the LM substrate (pure functional JAX).

Design notes:
* Parameters are plain pytrees (nested dicts of jax.Array); init functions
  return (params, ...) and apply functions are pure.
* Attention is memory-streamed ("flash"-style online softmax over KV
  blocks) so no (S, S) score matrix is ever materialized — mandatory for
  the 32k prefill cells of the dry-run.
* GQA throughout; RoPE / M-RoPE (qwen2-vl) / sinusoidal (whisper) position
  encodings.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, fan_in, shape, dtype):
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def norm_init(kind, d, dtype=jnp.float32):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def apply_norm(kind, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, sections=(16, 24, 24),
                theta: float = 10000.0):
    """Multimodal RoPE (qwen2-vl): head_dim/2 frequencies split into
    (temporal, height, width) sections, each rotated by its own position
    component.  positions3: (B, S, 3) int32."""
    d = x.shape[-1]
    half = d // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to "
                         f"head_dim/2 = {half}")
    freqs = rope_freqs(d, theta)                       # (half,)
    # section id per frequency slot
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec[None, None, :], positions3.shape[:2] + (half,)),
        axis=-1)                                       # (B, S, half)
    ang = pos * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((S, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out


# ---------------------------------------------------------------------------
# attention (streamed online-softmax; GQA)
# ---------------------------------------------------------------------------

# §Perf toggle: process only unmasked causal tiles (halves attention flops)
CAUSAL_SKIP = False


def set_causal_skip(enabled: bool) -> None:
    global CAUSAL_SKIP
    CAUSAL_SKIP = bool(enabled)

def attention_init(key, d_model, n_heads, n_kv, head_dim, *,
                   qkv_bias=False, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(ks[1], d_model, (d_model, n_kv * head_dim), dtype),
        "wv": dense_init(ks[2], d_model, (d_model, n_kv * head_dim), dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _online_attn(q, k, v, *, causal: bool, q_offset, kv_len=None,
                 q_block: int = 256, kv_block: int = 512):
    """Streamed attention.  q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D).

    Flash-attention dataflow in pure JAX: an outer sequential map over query
    blocks and an inner scan over KV blocks with a running (max, denom,
    acc), so peak memory is O(q_block * kv_block) scores — never the
    (Sq, Skv) matrix (mandatory for the 32k prefill dry-run cells).

    Sharding: all head tensors run *flat-H* (GQA KV heads broadcast to H
    inside each block) and are explicitly constrained to (dp, None,
    'model', None).  The earlier grouped (B,S,Hkv,G,D) formulation left
    GSPMD no shardable head axis when Hkv < tp, and the measured dry-run
    HLO showed it replicating the batch with per-block all-gathers
    (~134 MB x 2304 executions per step on qwen2.5).  Flat-H removes every
    attention-internal collective; the KV broadcast is a fused
    broadcast-in-dim, not HBM traffic.

    ``q_offset``: absolute position of q[0] (causal masking for decode /
    chunked prefill).  ``kv_len``: valid prefix length of the KV buffers.
    Masked blocks are still computed (baseline; see EXPERIMENTS.md §Perf for
    the causal-skip optimization).
    """
    from repro.models.sharding import constrain

    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)

    qb = min(q_block, Sq)
    kvb = min(kv_block, Skv)
    if CAUSAL_SKIP and causal:
        kvb = qb                      # skip path pairs same-size tiles
    nqb = (Sq + qb - 1) // qb
    nkb = (Skv + kvb - 1) // kvb
    Sq_pad, Skv_pad = nqb * qb, nkb * kvb
    if Sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    if Skv_pad != Skv:
        pad = ((0, 0), (0, Skv_pad - Skv), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    q = constrain(q, "dp", None, "model", None)
    k = constrain(k, "dp", None, "model", None)
    v = constrain(v, "dp", None, "model", None)

    # keep q/k/v in their storage dtype (bf16): the score matmul uses
    # preferred_element_type=f32 (MXU-accumulate) so softmax stays stable
    # while the operands — and their gradients/collectives — ride bf16
    qg = q.reshape(B, nqb, qb, H, D) * jnp.asarray(scale, q.dtype)
    kb_t = jnp.moveaxis(k.reshape(B, nkb, kvb, Hkv, D), 1, 0)
    vb_t = jnp.moveaxis(v.reshape(B, nkb, kvb, Hkv, D), 1, 0)
    valid_kv = Skv if kv_len is None else kv_len

    def expand(blk):
        """(B, kvb, Hkv, D) -> flat-H (B, kvb, H, D) broadcast."""
        e = jnp.broadcast_to(blk[:, :, :, None, :],
                             (B, blk.shape[1], Hkv, G, D))
        return e.reshape(B, blk.shape[1], H, D)

    def one_block(qblk, q_pos, kblk, vblk, kv_pos0, carry):
        """Online-softmax update of (m, l, acc) with one (q, kv) tile."""
        m, l, acc = carry
        ke = constrain(expand(kblk), "dp", None, "model", None)
        ve = constrain(expand(vblk), "dp", None, "model", None)
        s = jnp.einsum("bqhd,bkhd->bhqk", qblk, ke,
                       preferred_element_type=jnp.float32)
        kv_pos = kv_pos0 + jnp.arange(kvb)
        mask = kv_pos[None, :] < valid_kv
        if causal:
            mask = mask & (q_pos[:, None] >= kv_pos[None, :])
        else:
            mask = jnp.broadcast_to(mask, (qblk.shape[1], kvb))
        s = jnp.where(mask[None, None, :, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, :, :], p, 0.0)
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(qblk.dtype), ve,
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    def one_qblock(args):
        qblk, iq = args                              # (B, qb, H, D)
        qblk = constrain(qblk, "dp", None, "model", None)
        q_pos = q_offset + iq * qb + jnp.arange(qb)

        def step(carry, inp):
            kblk, vblk, jb = inp                     # (B, kvb, Hkv, D)
            return one_block(qblk, q_pos, kblk, vblk, jb * kvb, carry), None

        m0 = jnp.full((B, H, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, qb), jnp.float32)
        a0 = jnp.zeros((B, H, qb, D), jnp.float32)
        (m, l, acc), _ = lax.scan(step, (m0, l0, a0),
                                  (kb_t, vb_t, jnp.arange(nkb)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, H, qb, D)
        return jnp.moveaxis(out, 1, 2)                 # (B, qb, H, D)

    def causal_skip_path():
        """Process only the ~nqb*nkb/2 unmasked (q, kv) tile pairs: one
        scan over the valid-pair list, carrying (m, l, acc) for ALL q
        blocks and updating the pair's q tile in place.  Halves the HLO
        attention flops vs masked-full (EXPERIMENTS.md §Perf H-causal)."""
        if qb != kvb:
            raise ValueError(
                f"causal_skip needs q_block == kv_block, got {qb} != {kvb}")
        pairs = [(i, j) for i in range(nqb) for j in range(nkb)
                 if j * kvb <= (i + 1) * qb - 1]       # any overlap with mask
        pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
        pj = jnp.asarray([p[1] for p in pairs], jnp.int32)
        qg_all = jnp.moveaxis(qg, 1, 0)                # (nqb, B, qb, H, D)

        def step(carry, inp):
            m, l, acc = carry                          # (nqb, B, H, qb[,D])
            i, j = inp
            qblk = qg_all[i]
            q_pos = q_offset + i * qb + jnp.arange(qb)
            sub = (m[i], l[i], acc[i])
            m_i, l_i, acc_i = one_block(qblk, q_pos, kb_t[j], vb_t[j],
                                        j * kvb, sub)
            return (m.at[i].set(m_i), l.at[i].set(l_i),
                    acc.at[i].set(acc_i)), None

        m0 = jnp.full((nqb, B, H, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((nqb, B, H, qb), jnp.float32)
        a0 = jnp.zeros((nqb, B, H, qb, D), jnp.float32)
        (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (pi, pj))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (nqb, B, H, qb, D)
        return jnp.moveaxis(out, 2, 3)                 # (nqb, B, qb, H, D)

    if causal and CAUSAL_SKIP and nqb > 1 and qb == kvb:
        out = causal_skip_path()
    else:
        qg_t = jnp.moveaxis(qg, 1, 0)                # (nqb, B, qb, H, D)
        out = lax.map(one_qblock, (qg_t, jnp.arange(nqb)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq_pad, H, D)
    return out[:, :Sq].astype(q.dtype)


def _direct_attn(q, k, v, *, causal: bool, q_offset, kv_len=None):
    """Unblocked attention for tiny Sq (decode): one (B, Sq, H, Skv) score
    tensor, einsum-only — stays efficient under GSPMD when the cache is
    sharded along Skv (context parallelism: partial max/sum + all-reduce,
    flash-decoding style)."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = kv_pos[None, :] < (Skv if kv_len is None else kv_len)
    if causal:
        mask = mask & (q_pos[:, None] >= kv_pos[None, :])
    else:
        mask = jnp.broadcast_to(mask, (Sq, Skv))
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_apply(params, x, *, n_heads, n_kv, head_dim,
                    positions=None, positions3=None,
                    rope: str = "rope", rope_theta: float = 10000.0,
                    mrope_sections=(16, 24, 24),
                    causal: bool = True,
                    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                    cache_len=None,
                    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    kv_block: int = 1024):
    """GQA attention.  Returns (out, new_kv) where new_kv is the updated
    cache (decode) or the fresh K/V (train/prefill)."""
    B, S, dm = x.shape
    q = jnp.einsum("bsd,df->bsf", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, S, n_heads, head_dim)

    if cross_kv is not None:
        k, v = cross_kv
        new_kv = None
        q_offset = 0
        kv_len = None
        causal = False
    else:
        k = jnp.einsum("bsd,df->bsf", x, params["wk"])
        vv = jnp.einsum("bsd,df->bsf", x, params["wv"])
        if "bk" in params:
            k = k + params["bk"]
            vv = vv + params["bv"]
        k = k.reshape(B, S, n_kv, head_dim)
        vv = vv.reshape(B, S, n_kv, head_dim)
        if rope == "rope":
            pos = positions if positions is not None else (
                jnp.zeros((B, 1), jnp.int32) + jnp.arange(S)[None, :])
            q = apply_rope(q, pos, rope_theta)
            k = apply_rope(k, pos, rope_theta)
        elif rope == "mrope":
            if positions3 is None:
                raise ValueError("rope='mrope' needs positions3 (B, S, 3)")
            q = apply_mrope(q, positions3, mrope_sections, rope_theta)
            k = apply_mrope(k, positions3, mrope_sections, rope_theta)
        # (sinusoidal / none: positions handled at the embedding level)

        if kv_cache is not None:
            ck, cv = kv_cache
            ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_len, 1)
            cv = lax.dynamic_update_slice_in_dim(cv, vv.astype(cv.dtype), cache_len, 1)
            k, v = ck, cv
            new_kv = (ck, cv)
            q_offset = cache_len
            kv_len = cache_len + S
        else:
            v = vv
            new_kv = (k, vv)
            q_offset = 0
            kv_len = None

    if S <= 4:       # decode path: direct einsum attention (GSPMD-friendly)
        out = _direct_attn(q, k, v, causal=causal, q_offset=q_offset,
                           kv_len=kv_len)
    else:
        out = _online_attn(q, k, v, causal=causal, q_offset=q_offset,
                           kv_len=kv_len, kv_block=kv_block)
    out = out.reshape(B, S, n_heads * head_dim)
    out = jnp.einsum("bsf,fd->bsd", out, params["wo"])
    return out, new_kv


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, *, act="swiglu", dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wi": dense_init(ks[0], d_model, (d_model, d_ff), dtype),
            "wg": dense_init(ks[1], d_model, (d_model, d_ff), dtype),
            "wo": dense_init(ks[2], d_ff, (d_ff, d_model), dtype),
        }
    return {
        "wi": dense_init(ks[0], d_model, (d_model, d_ff), dtype),
        "wo": dense_init(ks[2], d_ff, (d_ff, d_model), dtype),
        "bi": jnp.zeros((d_ff,), dtype),
        "bo": jnp.zeros((d_model,), dtype),
    }


def mlp_apply(params, x, *, act="swiglu"):
    if act == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, params["wi"])
        g = jnp.einsum("bsd,df->bsf", x, params["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["wi"]) + params["bi"]
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"])
    if "bo" in params:
        out = out + params["bo"]
    return out


# ---------------------------------------------------------------------------
# embedding / lm head
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d_model, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 0.02).astype(dtype)}


def embed_apply(params, tokens):
    return params["table"][tokens]


def lm_head_apply(embed_params, x, head_params=None):
    """Tied (default) or untied LM head; returns f32 logits."""
    table = head_params["w"] if head_params is not None else embed_params["table"]
    if head_params is not None:
        return jnp.einsum("bsd,dv->bsv", x, table).astype(jnp.float32)
    return jnp.einsum("bsd,vd->bsv", x, table).astype(jnp.float32)
