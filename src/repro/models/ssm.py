"""Selective state-space (Mamba/S6) block — the sub-quadratic mixer of the
jamba hybrid architecture.

Train path: time scan in remat'ed chunks (state checkpoints at chunk
boundaries keep activation memory at O(B * d_inner * d_state * nchunks)
instead of O(B * L * d_inner * d_state)).
Decode path: O(1) per token via the carried (conv window, SSM state).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init

__all__ = ["SSMConfig", "mamba_init", "mamba_apply", "mamba_decode_init",
           "mamba_decode_step"]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None     # default d_model // 16
    # §Perf H3: "materialized" (baseline: dA/dBx tensors of shape
    # (B,S,di,N) built up front), "chunked" (recomputed per chunk inside
    # the scan — no (B,S,di,N) materialization), "pallas" (state-resident
    # TPU kernel, kernels/mamba_scan.py; forward/serve path)
    scan_impl: str = "materialized"

    def inner(self, d_model):
        return self.expand * d_model

    def rank(self, d_model):
        return self.dt_rank if self.dt_rank is not None else max(1, d_model // 16)


def mamba_init(key, d_model, cfg: SSMConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    di = cfg.inner(d_model)
    dr = cfg.rank(d_model)
    N = cfg.d_state
    # S4D-real initialization of A
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": dense_init(ks[0], d_model, (d_model, 2 * di), dtype),
        "conv_w": dense_init(ks[1], cfg.d_conv, (cfg.d_conv, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, (di, dr + 2 * N), dtype),
        "dt_proj": dense_init(ks[3], dr, (dr, di), dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),   # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, (di, d_model), dtype),
    }


def _ssm_params(params, x, cfg: SSMConfig, d_model):
    """Input-dependent (delta, B, C) from the post-conv activations."""
    dr = cfg.rank(d_model)
    N = cfg.d_state
    dbc = jnp.einsum("...d,de->...e", x, params["x_proj"])
    dt, Bc, Cc = jnp.split(dbc, [dr, dr + N], axis=-1)
    dt = jnp.einsum("...r,rd->...d", dt, params["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])
    return dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32)


def _scan_chunk(A, xs):
    """Sequential SSM recurrence over one time chunk.

    xs: (dA, dBx) with shapes (L, B, di, N); initial state (B, di, N).
    """
    def step(h, inp):
        dA, dBx = inp
        h = dA * h + dBx
        return h, h

    return lax.scan(step, A, xs)


def mamba_apply(params, x, cfg: SSMConfig, *, chunk: int = 256):
    """x: (B, S, d_model) -> (B, S, d_model)."""
    B, S, d_model = x.shape
    di = cfg.inner(d_model)
    N = cfg.d_state

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                  # (B, S, di)

    # depthwise causal conv, kernel d_conv
    K = cfg.d_conv
    xpad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S, :] * params["conv_w"][i][None, None, :]
             for i in range(K)) + params["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    dt, Bc, Cc = _ssm_params(params, xc, cfg, d_model)  # (B,S,di),(B,S,N)x2
    A = -jnp.exp(params["A_log"])                       # (di, N)

    nch = max(1, (S + chunk - 1) // chunk)
    Sp = nch * chunk
    impl = cfg.scan_impl

    if impl == "pallas":
        from repro.kernels.ops import mamba_scan as mamba_scan_op
        dtp = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0))) if Sp != S else dt
        xcp = (jnp.pad(xc.astype(jnp.float32), ((0, 0), (0, Sp - S), (0, 0)))
               if Sp != S else xc.astype(jnp.float32))
        Bcp = jnp.pad(Bc, ((0, 0), (0, Sp - S), (0, 0))) if Sp != S else Bc
        Ccp = jnp.pad(Cc, ((0, 0), (0, Sp - S), (0, 0))) if Sp != S else Cc
        y = mamba_scan_op(dtp, xcp, Bcp, Ccp, A)[:, :S]
    elif impl == "chunked":
        # §Perf H3: never materialize (B, S, di, N) — dA/dBx are built
        # per chunk inside the remat'ed body from the (B, chunk, di) slices
        def padt(a, cv=0.0):
            return (jnp.pad(a, ((0, 0), (0, Sp - S)) + ((0, 0),) * (a.ndim - 2),
                            constant_values=cv) if Sp != S else a)

        dt_c = jnp.moveaxis(padt(dt).reshape(B, nch, chunk, di), 1, 0)
        xc_c = jnp.moveaxis(padt(xc.astype(jnp.float32)
                                 ).reshape(B, nch, chunk, di), 1, 0)
        Bc_c = jnp.moveaxis(padt(Bc).reshape(B, nch, chunk, N), 1, 0)
        Cc_c = jnp.moveaxis(padt(Cc).reshape(B, nch, chunk, N), 1, 0)

        @jax.checkpoint
        def chunk_body(h0, inp):
            dt_k, xc_k, Bc_k, Cc_k = inp

            def step(h, t_in):
                dt_t, xc_t, Bc_t, Cc_t = t_in          # (B,di),(B,di),(B,N)
                dA_t = jnp.exp(dt_t[..., None] * A[None])
                dBx_t = (dt_t * xc_t)[..., None] * Bc_t[:, None, :]
                h = dA_t * h + dBx_t
                y_t = jnp.einsum("bdn,bn->bd", h, Cc_t)
                return h, y_t

            tseq = tuple(jnp.moveaxis(a, 1, 0)
                         for a in (dt_k, xc_k, Bc_k, Cc_k))
            h, ys = lax.scan(step, h0, tseq)
            return h, jnp.moveaxis(ys, 0, 1)           # (B, chunk, di)

        h0 = jnp.zeros((B, di, N), jnp.float32)
        _, ys = lax.scan(chunk_body, h0, (dt_c, xc_c, Bc_c, Cc_c))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, di)[:, :S]
    else:
        # baseline: materialized transition tensors
        dA = jnp.exp(dt[..., None] * A[None, None])     # (B,S,di,N)
        dBx = (dt * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]
        if Sp != S:
            pad4 = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
            dA = jnp.pad(dA, pad4, constant_values=1.0)
            dBx = jnp.pad(dBx, pad4)
        dA_c = jnp.moveaxis(dA.reshape(B, nch, chunk, di, N), 1, 0)
        dBx_c = jnp.moveaxis(dBx.reshape(B, nch, chunk, di, N), 1, 0)

        @jax.checkpoint
        def chunk_body(h0, inp):
            dA_k, dBx_k = inp                          # (B, chunk, di, N)
            h, hs = _scan_chunk(h0, (jnp.moveaxis(dA_k, 1, 0),
                                     jnp.moveaxis(dBx_k, 1, 0)))
            return h, jnp.moveaxis(hs, 0, 1)           # (B, chunk, di, N)

        h0 = jnp.zeros((B, di, N), jnp.float32)
        _, hs = lax.scan(chunk_body, h0, (dA_c, dBx_c))
        hs = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, di, N)[:, :S]
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cc)

    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"])


# ----------------------------------------------------------------- decode
def mamba_decode_init(B, d_model, cfg: SSMConfig, dtype=jnp.bfloat16):
    di = cfg.inner(d_model)
    return {
        "conv": jnp.zeros((B, cfg.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((B, di, cfg.d_state), jnp.float32),
    }


def mamba_decode_step(params, x, state, cfg: SSMConfig):
    """x: (B, 1, d_model); state from mamba_decode_init.  O(1)/token."""
    B, _, d_model = x.shape
    di = cfg.inner(d_model)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                  # (B, 1, di)

    window = jnp.concatenate([state["conv"], xs], axis=1)  # (B, K, di)
    xc = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)[:, None, :]

    dt, Bc, Cc = _ssm_params(params, xc, cfg, d_model)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A[None, None])[:, 0]       # (B, di, N)
    dBx = ((dt * xc.astype(jnp.float32))[..., None]
           * Bc[:, :, None, :])[:, 0]
    h = dA * state["ssm"] + dBx

    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])
    y = y + params["D"] * xc[:, 0].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(
        z[:, 0].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None, :]
    new_state = {"conv": window[:, 1:], "ssm": h}
    return out, new_state
