"""Sharding rules: GHOST's data-parallel, weight-proportional distribution
philosophy (paper C4) mapped onto the pod mesh.

Mesh axes: ``("pod", "data", "model")`` multi-pod / ``("data", "model")``
single-pod.  Strategy:

* batch over ``(pod, data)`` (pure DP across pods — gradient sync over DCN
  is hierarchical, see train/optimizer.py);
* FSDP: every weight matrix shards one dim over ``data``;
* TP: attention head projections / MLP d_ff / mLSTM inner dim over
  ``model``;
* EP: MoE experts over ``model`` when E % tp == 0, else TP-inside-expert
  (grok's 8 experts on a 16-way axis);
* decode caches: batch over DP when it divides, otherwise *sequence*
  sharding (context parallelism) — the long_500k cells shard the 500k-token
  KV cache across every mesh axis.

Every proposed axis is divisibility-guarded: a dim that does not divide the
mesh axis is replicated instead (e.g. llama3.2's 24 heads on tp=16 -> the
head dim stays unsharded, exactly what the note in its config records).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import ModelConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "dp_axes",
           "named", "guard_spec"]


# ---------------------------------------------------------------------------
# layout policy (see EXPERIMENTS.md §Perf H1/H2):
#   "tp"    — default: FSDP over 'data' x TP over 'model'
#   "fsdp"  — treat 'model' as extra data parallelism (params sharded over
#             all 256 chips; per-layer all-gather): right for <10B dense
#             models where TP all-reduces dominate
#   "zero1" — params replicated, optimizer state sharded, grads
#             all-reduced: minimum wire volume (~2N bytes/step) when the
#             replicated params + temps fit HBM
_LAYOUT = "tp"


def set_layout(layout: str) -> None:
    global _LAYOUT
    if layout not in ("tp", "fsdp", "zero1"):
        raise ValueError(f"unknown layout {layout!r} "
                         f"(expected tp/fsdp/zero1)")
    _LAYOUT = layout


def get_layout() -> str:
    return _LAYOUT


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    base = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if _LAYOUT in ("fsdp", "zero1") and "model" in mesh.axis_names:
        return base + ("model",)
    return base


def ambient_mesh() -> Optional[Mesh]:
    """The mesh installed by a surrounding ``with mesh:`` block (None in
    plain single-device tests)."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except (ImportError, AttributeError):              # pragma: no cover
        # jax internals moved (the _src import is version-coupled)
        return None


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the ambient mesh, with the same
    divisibility guard as the param specs; no-op when no mesh is active.

    ``axes``: one entry per dim — None, an axis name, 'dp' (expands to the
    data-parallel axes present in the mesh), or a tuple of names.
    """
    mesh = ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    resolved = []
    for a in axes:
        if a == "dp":
            a = dp_axes(mesh)
            a = a if a else None
        elif a == "model" and _LAYOUT in ("fsdp", "zero1"):
            a = None                      # 'model' is data parallelism now
        if isinstance(a, str) and a not in names:
            a = None
        resolved.append(a)
    spec = guard_spec(P(*resolved), x.shape, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):                    # pragma: no cover
        # constraint incompatible with the trace context — stay unsharded
        return x


def tp_size(default: int = 1) -> int:
    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return default
    return mesh.shape["model"]


def guard_spec(spec: P, shape, mesh: Mesh) -> P:
    """Replace axes that don't divide the corresponding dim with None."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _rule(cfg: ModelConfig, path: Tuple[str, ...], ndim: int) -> P:
    """Base spec (without period prefix) for one param leaf."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""

    if parent == "embed" and name == "table":
        # vocab-parallel (Megatron-style): vocab over 'model', d replicated.
        # Sharding d over 'data' made every batch-sharded matmul against the
        # table a conflicting-axis contraction -> GSPMD replicated the batch
        # (measured in the dry-run HLO); vocab-parallel keeps the lm head
        # collective-free and the loss reduction small.
        return P("model", None)
    if parent == "lm_head":
        return P(None, "model")
    if name == "scale" or name == "bias" or name == "b":
        return P(None)

    if parent in ("attn", "xattn"):
        if name in ("wq", "wk", "wv"):
            return P("data", "model")
        if name == "wo":
            return P("model", "data")
        return P("model")                       # biases (out-dim sharded)
    if parent == "mlp":
        if name in ("wi", "wg"):
            return P("data", "model")
        if name == "wo":
            return P("model", "data")
        return P(None)
    if parent == "moe":
        if name == "router":
            return P("data", None)
        # EP spec; param_specs falls back to TP-inside-expert when the
        # expert count does not divide the model axis (e.g. grok's 8e@16)
        if name in ("wi", "wg"):
            return P("model", "data", None)
        if name == "wo":
            return P("model", None, "data")
    if parent == "mamba":
        table = {
            "in_proj": P("data", "model"),
            "conv_w": P(None, "model"),
            "conv_b": P("model"),
            "x_proj": P("model", None),
            "dt_proj": P(None, "model"),
            "dt_bias": P("model"),
            "A_log": P("model", None),
            "D": P("model"),
            "out_proj": P("model", "data"),
        }
        return table[name]
    if parent == "mlstm":
        table = {
            "up": P("data", "model"),
            "wq": P("data", "model"),
            "wk": P("data", "model"),
            "wv": P("data", "model"),
            "wi": P("model", None),
            "wf": P("model", None),
            "bi": P(None),
            "bf": P(None),
            "down": P("model", "data"),
            "skip_scale": P("model"),
        }
        return table[name]
    if parent == "slstm":
        table = {
            "wx": P("data", "model"),
            # contraction-dim sharding: fwd psum is a tiny (B, 4d)
            # activation; the weight grad accumulates shard-locally
            "r": P("model", None),
            "b": P(None),
            "out": P("data", "model"),
        }
        return table[name]
    return P(*([None] * ndim))


def _ep(cfg: ModelConfig, E: int) -> bool:
    return True   # resolved against the mesh by the divisibility guard


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "name"):
            out.append(p.name)
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return tuple(out)


def param_specs(cfg: ModelConfig, params_shape, mesh: Mesh):
    """Pytree of PartitionSpec matching ``params_shape`` (from eval_shape)."""
    def one(path, leaf):
        names = _path_names(path)
        in_stack = names and names[0] in ("decoder", "encoder")
        ndim = leaf.ndim - (1 if in_stack else 0)
        spec = _rule(cfg, names, ndim)
        # MoE fallback: if EP can't shard the expert dim (E % tp != 0),
        # use TP-inside-expert so the weights never replicate over 'model'
        # (replicated expert grads showed up as ~28 GB all-reduces in the
        # grok dry-run HLO)
        if (len(names) >= 2 and names[-2] == "moe"
                and names[-1] in ("wi", "wg", "wo")):
            E = leaf.shape[1] if in_stack else leaf.shape[0]
            if _LAYOUT == "tp" and E % mesh.shape.get("model", 1) != 0:
                spec = (P(None, "data", "model") if names[-1] in ("wi", "wg")
                        else P(None, "model", "data"))
        if _LAYOUT == "fsdp":
            spec = _to_fsdp(spec)
        elif _LAYOUT == "zero1":
            spec = P(*([None] * ndim))            # replicated params
        spec = P(*((None,) + tuple(spec))) if in_stack else spec
        return guard_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def _to_fsdp(spec: P) -> P:
    """Remap a TP-layout spec to pure FSDP: the first sharded dim takes the
    whole pod (('data','model')), everything else replicates."""
    out, used = [], False
    for ax in spec:
        if ax is not None and not used:
            out.append(("data", "model"))
            used = True
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch_shape, mesh: Mesh):
    dp = dp_axes(mesh)

    def one(path, leaf):
        spec = P(dp, *([None] * (leaf.ndim - 1)))
        return guard_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh,
                *, seq_shard: bool = False):
    """Decode-cache specs.  ``seq_shard=True``: context parallelism — the KV
    sequence axis is sharded across every mesh axis (long_500k, batch=1)."""
    dp = dp_axes(mesh)
    all_axes = tuple(mesh.axis_names)

    def one(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        last = names[-1] if names else ""
        if last == "C" and leaf.ndim == 5:           # mLSTM (per,B,H,dh,dh)
            spec = P(None, dp, None, "model", None)
        elif leaf.ndim == 5:                          # KV (per,B,S,kv,hd)
            if seq_shard:
                spec = P(None, None, all_axes, None, None)
            else:
                spec = P(None, dp, "model", None, None)
        elif last == "conv" and leaf.ndim == 4:       # mamba (per,B,K-1,di)
            spec = P(None, dp, None, "model")
        elif last == "n" and leaf.ndim == 4:          # mLSTM (per,B,H,dh)
            spec = P(None, dp, None, "model")
        elif leaf.ndim == 4:                          # mamba ssm (per,B,di,N)
            spec = P(None, dp, "model", None)
        elif leaf.ndim == 3:                          # slstm / mLSTM m
            spec = P(None, dp, "model")
        else:
            spec = P(*([None] * leaf.ndim))
        return guard_spec(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def opt_specs(pspecs, o_shape, mesh: Mesh):
    """Optimizer slots inherit the parameter spec where shapes match
    (factored Adafactor rows drop the trailing axis).  Under the "zero1"
    layout, slots are instead sharded over the whole pod on their largest
    divisible dim (params stay replicated — ZeRO stage 1)."""
    if _LAYOUT == "zero1":
        pod = tuple(a for a in mesh.axis_names)
        size = 1
        for a in pod:
            size *= mesh.shape[a]

        def z1(path, leaf):
            dims = [(d, i) for i, d in enumerate(leaf.shape)
                    if d % size == 0]
            if not dims:
                return P(*([None] * leaf.ndim))
            _, best = max(dims)
            spec = [None] * leaf.ndim
            spec[best] = pod
            return P(*spec)

        return jax.tree_util.tree_map_with_path(z1, o_shape)

    flat_p = {tuple(_path_names(p)): s for p, s in
              jax.tree_util.tree_leaves_with_path(
                  pspecs, is_leaf=lambda x: isinstance(x, P))}

    def one(path, leaf):
        names = tuple(_path_names(path))
        for k, spec in flat_p.items():
            if names[-len(k) - 1:-1] == k or names[-len(k):] == k:
                if len(spec) == leaf.ndim:
                    return guard_spec(spec, leaf.shape, mesh)
                if len(spec) == leaf.ndim + 1:      # factored slot
                    return guard_spec(P(*tuple(spec)[:-1]), leaf.shape, mesh)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, o_shape)


def named(mesh: Mesh, tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
