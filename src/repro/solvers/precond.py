"""Preconditioners on GHOST building blocks (block-Jacobi + Chebyshev).

GHOST positions its kernels as the building blocks *under* preconditioned
Krylov stacks (the paper's case study runs them beneath PHIST/Trilinos
iteration layers).  This module supplies the two preconditioners that
need nothing beyond what the repo already has — and keeps their apply on
the same execution path as the SpMV instead of bolting it on host-side:

* :class:`BlockJacobiPreconditioner` — the aligned diagonal blocks are
  extracted **directly from SELL-C-sigma storage** in permuted space
  (``rowids``/``cols``/``valid_slots`` — the sigma-sort row permutation
  is respected because both indices of every stored entry already live
  in the sorted space; see ``docs/preconditioning.md``), factorized
  host-side once (Cholesky with an LU/pseudo-inverse fallback for
  indefinite or singular blocks), and applied via the Pallas batched
  block-diagonal kernel (``kernels/block_diag.py``) routed through the
  :mod:`repro.core.execution` cascade like every other kernel.

* :class:`ChebyshevPreconditioner` — a fixed-degree Chebyshev polynomial
  in the operator, built from the spectral bounds
  :class:`~repro.runtime.service.MatrixRegistry` already caches, applied
  as a short fused-SpMV recurrence (``mv_fused`` with ``alpha=-1,
  beta=1``).  Because it only ever calls ``op.mv_fused``, it composes
  with :class:`~repro.solvers.operator.DistOperator` and the overlapped
  halo pipeline unchanged — the preconditioner scales out with the
  matvec for free.

Both expose ``apply(r)`` on ``(n,)``/``(n, b)`` block vectors in the
operator's (permuted) space — the protocol ``cg``/``minres`` expect from
their ``M=`` argument — and are fixed linear SPD operators, so PCG /
preconditioned MINRES theory applies.  Build via :func:`make_preconditioner`
or the spec-string path of ``MatrixRegistry.preconditioner``.
"""
from __future__ import annotations

import weakref
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sellcs import SellCS
from repro.core.spmv import SpmvOpts, as2d

__all__ = [
    "BlockJacobiPreconditioner", "ChebyshevPreconditioner",
    "extract_block_diag", "factorize_blocks", "make_preconditioner",
    "parse_precond_spec",
]


# ------------------------------------------------------- block extraction
def extract_block_diag(A: SellCS, block_size: int) -> np.ndarray:
    """Aligned diagonal blocks of ``A`` in **permuted** space (host-side).

    Returns ``(nrows_pad // block_size, block_size, block_size)`` dense
    blocks of the row/column-permuted matrix ``P A P^T`` — the matrix the
    solvers actually iterate on, since vectors live in permuted space.
    The extraction reads the SELL-C-sigma arrays directly: ``rowids``
    are already sorted-space rows, and ``cols`` are sorted-space columns
    when ``permuted_cols`` is set (otherwise they are mapped through
    ``iperm`` here).  Slot validity comes from the construction-recorded
    row lengths, so explicitly stored zeros keep their structural slot.

    ``block_size`` must divide ``nrows_pad``; choosing a divisor of ``C``
    keeps blocks from straddling chunk boundaries (the "aligned" in
    aligned blocks), but any divisor of ``nrows_pad`` is accepted.
    """
    if A.nrows != A.ncols:
        raise ValueError(
            f"block-Jacobi needs a square matrix, got {A.shape}")
    bs = int(block_size)
    if bs <= 0 or A.nrows_pad % bs != 0:
        raise ValueError(
            f"block_size ({bs}) must divide nrows_pad ({A.nrows_pad}); "
            f"divisors of C={A.C} are the aligned choices")
    mask = A.valid_slots()
    rows = np.asarray(A.rowids, np.int64)[mask]          # permuted space
    cols = np.asarray(A.cols, np.int64)[mask]
    vals = np.asarray(A.vals)[mask]
    wdt = np.complex128 if np.iscomplexobj(vals) else np.float64
    vals = vals.astype(wdt)
    if not A.permuted_cols:
        cols = np.asarray(A.iperm, np.int64)[cols]       # -> permuted space
    nb = A.nrows_pad // bs
    blocks = np.zeros((nb, bs, bs), wdt)
    same = (rows // bs) == (cols // bs)
    np.add.at(blocks, (rows[same] // bs, rows[same] % bs, cols[same] % bs),
              vals[same])
    return blocks


def factorize_blocks(blocks: np.ndarray, *,
                     absolute: bool = False) -> np.ndarray:
    """Invert the diagonal blocks host-side, once (the setup phase).

    Structurally empty rows (zero diagonal and zero row/column — e.g.
    the padding rows above ``nrows``) get a unit diagonal so the block
    stays invertible and the preconditioner acts as the identity there.
    SPD blocks go through Cholesky; indefinite ones (MINRES matrices)
    fall back to LU, singular ones to the pseudo-inverse.

    ``absolute=True`` inverts the matrix absolute value ``|B_k|``
    instead (symmetrize, eigendecompose, flip negative eigenvalues) —
    the canonical way to stay **SPD** over an indefinite matrix, which
    preconditioned MINRES requires of ``M``.

    Complex blocks stay complex (Hermitian Cholesky / eigh; conjugate
    transposes throughout) — casting to real here would silently build
    the wrong preconditioner for complex Hermitian matrices.
    """
    blocks = np.asarray(blocks)
    wdt = np.complex128 if np.iscomplexobj(blocks) else np.float64
    blocks = blocks.astype(wdt)
    nb, bs, _ = blocks.shape
    empty = (np.abs(blocks).sum(axis=2) == 0)            # (nb, bs) zero rows
    if empty.any():
        kb, kr = np.nonzero(empty)
        blocks[kb, kr, kr] = 1.0
    if absolute:
        herm = (blocks + blocks.conj().transpose(0, 2, 1)) / 2.0
        w, Q = np.linalg.eigh(herm)                      # batched; w real
        wmax = np.abs(w).max(axis=1, keepdims=True)
        w = np.maximum(np.abs(w), 1e-12 * np.maximum(wmax, 1.0))
        return np.einsum("kij,kj,klj->kil", Q, 1.0 / w, Q.conj())

    def _chol_inv(stack):
        ch = np.linalg.cholesky(stack)                   # batched HPD
        ident = np.broadcast_to(np.eye(bs, dtype=wdt), stack.shape)
        half = np.linalg.solve(ch, ident)
        return half.conj().transpose(0, 2, 1) @ half     # (L L^H)^-1

    try:
        return _chol_inv(blocks)             # one batched call, common case
    except np.linalg.LinAlgError:
        pass                                 # some block not HPD: per-block
    inv = np.empty_like(blocks)
    for k in range(nb):
        blk = blocks[k]
        try:
            inv[k] = _chol_inv(blk[None])[0]
        except np.linalg.LinAlgError:
            try:
                inv[k] = np.linalg.inv(blk)
            except np.linalg.LinAlgError:
                inv[k] = np.linalg.pinv(blk)
    return inv


class BlockJacobiPreconditioner:
    """``M = diag(B_0, ..., B_{k-1})^{-1}`` over aligned permuted-space blocks.

    ``apply`` runs the Pallas batched block-diagonal matmul through the
    execution-policy cascade (compiled on TPU, interpreter/jnp reference
    elsewhere) — one fused sweep over ``r`` per application.
    """

    def __init__(self, A: SellCS, block_size: Optional[int] = None, *,
                 absolute: bool = False):
        if not isinstance(A, SellCS):
            raise TypeError(
                "block-Jacobi extracts blocks from SELL-C-sigma storage; "
                f"got {type(A).__name__}.  Engine-backed (distributed) "
                "matrices should use the Chebyshev preconditioner, which "
                "only needs the operator's matvec.")
        bs = int(block_size) if block_size is not None else int(A.C)
        self.A = A
        self.block_size = bs
        self.absolute = bool(absolute)
        self.dtype = jnp.dtype(A.dtype)
        self.n = A.nrows_pad
        # extraction upcasts to f64/c128 before factorization (see
        # extract_block_diag/factorize_blocks), so a narrow store_dtype
        # never degrades the factorization; the factored inverses land in
        # the *compute* dtype — preconditioner quality is storage-agnostic
        inv = factorize_blocks(extract_block_diag(A, bs), absolute=absolute)
        self.inv_blocks = jnp.asarray(inv).astype(self.dtype)

    def apply(self, r: jax.Array) -> jax.Array:
        """``z = M r`` for ``(n,)`` or ``(n, b)`` permuted-space vectors."""
        from repro.kernels import ops
        return ops.block_jacobi_apply(self.inv_blocks, r)

    def __repr__(self) -> str:
        return (f"BlockJacobiPreconditioner(n={self.n}, "
                f"bs={self.block_size}, dtype={self.dtype})")


# ------------------------------------------------------------- Chebyshev
class ChebyshevPreconditioner:
    """Fixed-degree Chebyshev polynomial preconditioner ``M ~ A^{-1}``.

    ``degree`` steps of the Chebyshev iteration for ``A y = r`` from
    ``y0 = 0`` (Saad, *Iterative Methods*, Alg. 12.1), targeting the
    interval ``[lo, hi]`` — use the Lanczos bounds the registry caches.
    The result is a *fixed* polynomial ``y = p(A) r`` with ``p`` positive
    on ``[lo, hi]``: a linear SPD operator whenever ``A`` is SPD, so the
    outer CG recurrence stays valid (no flexible-CG caveats).

    Each apply costs ``degree - 1`` SpMVs, issued through ``mv_fused``
    (``r_k = r_{k-1} - A d_k`` as one fused ``alpha=-1, beta=1`` sweep),
    so the recurrence rides the operator's own execution path — including
    :class:`~repro.solvers.operator.DistOperator`'s overlapped halo
    pipeline for sharded matrices.

    The Lanczos bracket the registry caches is safety-*widened* for
    KPM/ChebFD and can dip below zero for ill-conditioned SPD matrices;
    a non-positive ``lo`` is therefore clamped to ``hi / min_ratio``
    (AMG-smoother practice: target the upper end of the spectrum rather
    than insist on an accurate ``lambda_min``).

    The operator is held through a **weak** reference: the stepper chunk
    cache (``solvers/stepper.py``) is weakly keyed on the operator but
    its cached jitted chunks close over ``M`` — an ``M`` holding its
    operator strongly would turn that cache entry into an immortal
    value->key cycle, pinning the operator and every compiled chunk for
    the process lifetime.  Keep the operator alive for as long as you
    use the preconditioner (the registry does).
    """

    def __init__(self, op, spectrum: Tuple[float, float], degree: int = 4,
                 *, min_ratio: float = 30.0):
        lo, hi = float(spectrum[0]), float(spectrum[1])
        if hi <= 0.0:
            raise ValueError(
                f"Chebyshev preconditioning needs an SPD operator "
                f"(lambda_max > 0), got bounds ({lo:g}, {hi:g})")
        lo = max(lo, hi / float(min_ratio))
        if degree < 1:
            raise ValueError(f"degree must be >= 1, got {degree}")
        self._op_ref = weakref.ref(op)
        self.lo, self.hi = lo, hi
        self.degree = int(degree)
        self.dtype = jnp.dtype(op.dtype)
        self.n = op.n

    @property
    def op(self):
        o = self._op_ref()
        if o is None:
            raise ReferenceError(
                "the operator behind this ChebyshevPreconditioner has "
                "been garbage-collected; rebuild the preconditioner")
        return o

    def apply(self, r: jax.Array) -> jax.Array:
        r2, was1d = as2d(r)
        theta = (self.hi + self.lo) / 2.0
        delta = (self.hi - self.lo) / 2.0
        sigma1 = theta / delta
        rho = 1.0 / sigma1
        d = r2 / theta
        y = d
        resid = None
        fuse = SpmvOpts(alpha=-1.0, beta=1.0)
        if self.degree > 1:
            resid, _, _ = self.op.mv_fused(y, y=r2, opts=fuse)  # r - A y
        for k in range(1, self.degree):
            rho_new = 1.0 / (2.0 * sigma1 - rho)
            d = (rho_new * rho) * d + (2.0 * rho_new / delta) * resid
            y = y + d
            if k < self.degree - 1:
                resid, _, _ = self.op.mv_fused(d, y=resid, opts=fuse)
            rho = rho_new
        return y[:, 0] if was1d else y

    def __repr__(self) -> str:
        return (f"ChebyshevPreconditioner(degree={self.degree}, "
                f"interval=({self.lo:g}, {self.hi:g}))")


# ------------------------------------------------------------ spec parsing
def parse_precond_spec(spec: str) -> Tuple[str, Optional[int]]:
    """Normalize a preconditioner spec string.

    ``"block_jacobi"`` / ``"block_jacobi:<bs>"`` (``block_jacobi_abs``
    for the SPD absolute-value variant over indefinite matrices) /
    ``"chebyshev"`` / ``"chebyshev:<degree>"`` →
    ``(kind, param_or_None)``.  Raises on anything else so a typo fails
    at submit time, not at batch-open time.

    Resolvable defaults are filled in here so equivalent specs normalize
    identically — ``"chebyshev"`` and ``"chebyshev:4"`` must share one
    registry cache entry and one service batch key.  The block-Jacobi
    default stays ``None`` (it is the *matrix'* ``C``, unknown here).
    """
    if not isinstance(spec, str) or not spec:
        raise ValueError(f"preconditioner spec must be a non-empty string, "
                         f"got {spec!r}")
    kind, _, arg = spec.partition(":")
    if kind not in ("block_jacobi", "block_jacobi_abs", "chebyshev"):
        raise ValueError(
            f"unknown preconditioner {kind!r} "
            f"(have: block_jacobi[:<block_size>], "
            f"block_jacobi_abs[:<block_size>], chebyshev[:<degree>])")
    if not arg:
        return kind, (4 if kind == "chebyshev" else None)
    try:
        val = int(arg)
    except ValueError:
        raise ValueError(
            f"preconditioner parameter must be an integer, got {arg!r} "
            f"in {spec!r}") from None
    if val <= 0:
        raise ValueError(f"preconditioner parameter must be positive "
                         f"({spec!r})")
    return kind, val


def make_preconditioner(spec: str, *, matrix=None, op=None,
                        spectrum: Optional[Tuple[float, float]] = None):
    """Build a preconditioner from a spec string.

    ``block_jacobi`` needs ``matrix`` (a :class:`SellCS`); ``chebyshev``
    needs ``op`` and ``spectrum``.  The registry wires these up from its
    cached entries (``MatrixRegistry.preconditioner``).
    """
    kind, param = parse_precond_spec(spec)
    if kind in ("block_jacobi", "block_jacobi_abs"):
        return BlockJacobiPreconditioner(matrix, block_size=param,
                                         absolute=kind.endswith("_abs"))
    if op is None or spectrum is None:
        raise ValueError("chebyshev preconditioner needs op= and spectrum=")
    return ChebyshevPreconditioner(op, spectrum,
                                   degree=param if param else 4)
