"""Chebyshev filter diagonalization (paper section 1.3 / [38]).

Computes eigenpairs inside a target interval [lo_t, hi_t] of a symmetric
operator by repeatedly applying a Chebyshev polynomial filter to a block of
vectors (SpMMV -> paper C2) followed by Rayleigh-Ritz using the tall-skinny
kernels (tsmttsm / tsmm -> paper C2), i.e. the exact kernel mix the paper
optimizes.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockvec
from repro.core.spmv import SpmvOpts


class ChebFDResult(NamedTuple):
    eigenvalues: np.ndarray
    eigenvectors: jax.Array
    residuals: np.ndarray
    sweeps: int


def _cheb_filter(op, V, degree: int, a: float, gamma: float,
                 lo_t: float, hi_t: float):
    """Apply the [lo_t, hi_t]-bandpass Chebyshev filter of given degree to
    block V via the fused augmented SpMV recurrence."""
    # filter coefficients of the ideal bandpass on the scaled spectrum
    tl = (lo_t - gamma) / a
    tu = (hi_t - gamma) / a
    m = np.arange(degree + 1)
    with np.errstate(invalid="ignore"):
        coef = (np.arccos(np.clip(tl, -1, 1)) - np.arccos(np.clip(tu, -1, 1))) / np.pi
        coef = np.where(
            m == 0, coef,
            2.0 / np.pi / np.maximum(m, 1)
            * (np.sin(m * np.arccos(np.clip(tl, -1, 1)))
               - np.sin(m * np.arccos(np.clip(tu, -1, 1)))))
    g = _jackson(degree + 1)
    coef = coef * g

    w0 = V
    w1, _, _ = op.mv_fused(w0, opts=SpmvOpts(alpha=1.0 / a, gamma=gamma))
    acc = coef[0] * w0 + coef[1] * w1
    for k in range(2, degree + 1):
        w2, _, _ = op.mv_fused(
            w1, y=w0, opts=SpmvOpts(alpha=2.0 / a, beta=-1.0, gamma=gamma))
        acc = acc + coef[k] * w2
        w0, w1 = w1, w2
    return acc


def _jackson(M: int) -> np.ndarray:
    m = np.arange(M)
    return ((M - m + 1) * np.cos(np.pi * m / (M + 1))
            + np.sin(np.pi * m / (M + 1)) / np.tan(np.pi / (M + 1))) / (M + 1)


def chebfd(op, target: Tuple[float, float], block_size: int = 8, *,
           degree: int = 60, sweeps: int = 4, seed: int = 0,
           spectrum: Tuple[float, float] | None = None,
           use_pallas_tsm: bool = False) -> ChebFDResult:
    """Find eigenpairs in ``target`` = (lo_t, hi_t)."""
    if spectrum is None:
        from repro.solvers.lanczos import lanczos_extrema
        lo, hi = lanczos_extrema(op)
    else:
        lo, hi = spectrum
    a = (hi - lo) / 2.0
    gamma = (hi + lo) / 2.0

    n = op.n
    from repro.solvers.lanczos import randn
    V = randn(jax.random.PRNGKey(seed), (n, block_size), op.dtype)

    if use_pallas_tsm:
        from repro.kernels import ops as kops
        _tsmttsm = lambda A, B: kops.tsmttsm(A, B)
        _tsmm = lambda A, X: kops.tsmm(A, X)
    else:
        _tsmttsm = lambda A, B: blockvec.tsmttsm(A, B)
        _tsmm = lambda A, X: blockvec.tsmm(A, X)

    for s in range(sweeps):
        V = _cheb_filter(op, V, degree, a, gamma, *target)
        # orthonormalize: QR via Cholesky of the tall-skinny Gram matrix
        G = _tsmttsm(V, V)                       # (b, b)
        L = jnp.linalg.cholesky(G + 1e-12 * jnp.eye(G.shape[0]))
        V = _tsmm(V, jnp.linalg.inv(L).T.astype(V.dtype))
        # Rayleigh-Ritz
        AV = op.mv(V)
        H = _tsmttsm(V, AV)                      # (b, b) projected operator
        w, Q = jnp.linalg.eigh((H + H.T) / 2)
        V = _tsmm(V, Q.astype(V.dtype))

    AV = op.mv(V)
    H = _tsmttsm(V, AV)
    w = jnp.diag(H)
    R = AV - V * w[None, :]
    res = jnp.sqrt(jnp.sum(R * R, axis=0))
    order = np.argsort(np.asarray(w))
    return ChebFDResult(np.asarray(w)[order], V[:, order],
                        np.asarray(res)[order], sweeps)
