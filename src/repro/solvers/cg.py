"""Conjugate Gradient solvers on GHOST building blocks.

* ``cg``: (block) CG for SPD systems, one system per block-vector column
  (multiple right-hand sides).  Uses the paper's fusion features: the
  matvec is chained with the <p, Ap> dot (GHOST_SPMV_DOT_XY) — the
  communication/memory structure of the paper's augmented SpMV (C3).
* ``pipelined_cg``: Ghysels & Vanroose pipelined CG (paper section 1.1,
  category "hide communication"): the reduction bundle of an iteration is
  independent of the matvec ``q = A w``, so the two can overlap — exactly
  the dependency structure GHOST tasks were built to exploit (C5).

Vectors are ``(n, b)`` in operator (permuted) space.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.spmv import SpmvOpts


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array          # total iteration count
    resnorm: jax.Array        # per-column final ||r||
    converged: jax.Array      # per-column bool


def _colsum(v):
    return jnp.sum(v * v, axis=0)


def _maybe_1d(res: CGResult, was1d: bool) -> CGResult:
    if not was1d:
        return res
    return CGResult(res.x[:, 0], res.iters, res.resnorm[0], res.converged[0])


def cg(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
       tol: float = 1e-8, maxiter: int = 500) -> CGResult:
    """Block CG (independent columns).  op must be SPD."""
    was1d = b.ndim == 1
    b2 = b[:, None] if was1d else b
    x = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)
    r = b2 - op.mv(x)
    p = r
    rr = _colsum(r)
    bnorm2 = jnp.maximum(_colsum(b2), jnp.finfo(jnp.float32).tiny)
    tol2 = (tol * tol) * bnorm2

    def cond(state):
        _, _, _, _, it, done = state
        return jnp.logical_and(it < maxiter, ~jnp.all(done))

    def body(state):
        x, r, p, rr, it, done = state
        # fused: q = A p and <p, q> in one sweep (GHOST_SPMV_DOT_XY)
        q, _, dots = op.mv_fused(p, opts=SpmvOpts(dot_xy=True))
        # dots may accumulate wider than the vectors (f64 under x64);
        # cast the recurrence scalar back so the loop carry stays stable
        pq = dots[1].astype(rr.dtype)
        alpha = jnp.where(done, 0.0, rr / jnp.where(pq == 0, 1.0, pq))
        x = x + alpha[None, :] * p
        r = r - alpha[None, :] * q
        rr_new = _colsum(r)
        beta = rr_new / jnp.where(rr == 0, 1.0, rr)
        p = jnp.where(done[None, :], p, r + beta[None, :] * p)
        return (x, r, p, rr_new, it + 1, done | (rr_new <= tol2))

    state = (x, r, p, rr, jnp.asarray(0), rr <= tol2)
    x, r, p, rr, it, done = jax.lax.while_loop(cond, body, state)
    return _maybe_1d(CGResult(x, it, jnp.sqrt(rr), done), was1d)


def pipelined_cg(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
                 tol: float = 1e-8, maxiter: int = 500) -> CGResult:
    """Pipelined CG (Ghysels & Vanroose 2013, Alg. 3, identity precond.)."""
    was1d = b.ndim == 1
    b2 = b[:, None] if was1d else b
    x = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)
    r = b2 - op.mv(x)
    w = op.mv(r)
    bnorm2 = jnp.maximum(_colsum(b2), jnp.finfo(jnp.float32).tiny)
    tol2 = (tol * tol) * bnorm2
    zeros = jnp.zeros_like(b2)
    zcol = jnp.zeros(b2.shape[1], r.dtype)

    # carry: x r w z s p gamma_prev alpha_prev it done
    def cond(st):
        return jnp.logical_and(st[-2] < maxiter, ~jnp.all(st[-1]))

    def body(st):
        x, r, w, z, s, p, gamma_prev, alpha_prev, it, done = st
        gamma = jnp.sum(r * r, axis=0)
        delta = jnp.sum(w * r, axis=0)
        q = op.mv(w)                      # overlaps the reduction bundle
        first = it == 0
        beta = jnp.where(first, 0.0,
                         gamma / jnp.where(gamma_prev == 0, 1.0, gamma_prev))
        denom = jnp.where(
            first, delta,
            delta - beta * gamma / jnp.where(alpha_prev == 0, 1.0, alpha_prev))
        alpha = gamma / jnp.where(denom == 0, 1.0, denom)
        z = q + beta[None] * z
        s = w + beta[None] * s
        p = r + beta[None] * p
        a = jnp.where(done, 0.0, alpha)
        x = x + a[None] * p
        r = r - a[None] * s
        w = w - a[None] * z
        done = done | (_colsum(r) <= tol2)
        return (x, r, w, z, s, p, gamma, alpha, it + 1, done)

    st = (x, r, w, zeros, zeros, zeros, zcol, zcol,
          jnp.asarray(0), _colsum(r) <= tol2)
    st = jax.lax.while_loop(cond, body, st)
    x, r, it, done = st[0], st[1], st[-2], st[-1]
    return _maybe_1d(CGResult(x, it, jnp.sqrt(_colsum(r)), done), was1d)
