"""Conjugate Gradient solvers on GHOST building blocks.

* ``cg``: (block) CG for SPD systems, one system per block-vector column
  (multiple right-hand sides).  Uses the paper's fusion features: the
  matvec is chained with the <p, Ap> dot (GHOST_SPMV_DOT_XY) — the
  communication/memory structure of the paper's augmented SpMV (C3).
* ``pipelined_cg``: Ghysels & Vanroose pipelined CG (paper section 1.1,
  category "hide communication"): the reduction bundle of an iteration is
  independent of the matvec ``q = A w``, so the two can overlap — exactly
  the dependency structure GHOST tasks were built to exploit (C5).

Both solvers are **resumable steppers**: ``cg_init`` builds a
:class:`CGState`, ``cg_step`` advances it by a jitted k-iteration chunk
(per-column ``done`` carried in the state), ``cg_finalize`` reads out a
:class:`CGResult`.  The classic entry points are thin compositions of the
three and bit-identical to a single monolithic solve; the chunked form is
what :class:`repro.runtime.service.SolverService` drives for continuous
batching (retire converged columns between chunks, refill from a queue).

``cg`` accepts an optional SPD preconditioner ``M`` (see
:mod:`repro.solvers.precond`): ``M=None`` runs the *exact* PR-3 state and
body — bit-identical, pinned in ``tests/test_steppers.py`` — while a
preconditioner switches to the :class:`PrecondCGState` stepper whose
``z = M r`` recurrence rides in the state.  Convergence is still tested
on the true residual ``||r||`` with the same per-column ``done``/``tol``
semantics, so the service's retire/refill logic is oblivious to ``M``.
``pipelined_cg`` is unpreconditioned and raises on ``M`` (the Ghysels &
Vanroose preconditioned variant needs an extra carry, not yet built).

Vectors are ``(n, b)`` in operator (permuted) space.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.spmv import SpmvOpts, as2d
from repro.solvers.block import BlockCGState, block_cg_body, block_cg_init
from repro.solvers.stepper import run_chunk


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array          # total iteration count
    resnorm: jax.Array        # per-column final ||r||
    converged: jax.Array      # per-column bool


class CGState(NamedTuple):
    """Resumable block-CG state (columns = independent systems)."""

    x: jax.Array              # (n, b) iterate
    r: jax.Array              # (n, b) residual
    p: jax.Array              # (n, b) search direction
    rr: jax.Array             # (b,)   <r, r> recurrence
    tol2: jax.Array           # (b,)   per-column squared abs tolerance
    it: jax.Array             # ()     block iteration counter
    maxiter: jax.Array        # ()     block iteration cap
    done: jax.Array           # (b,)   per-column convergence flag


class PrecondCGState(NamedTuple):
    """Resumable preconditioned block-CG state (``z = M r`` recurrence).

    ``rr`` (true squared residual norm, always real) drives the
    ``done``/``tol`` test exactly like plain CG; ``rz = <r, z>`` is the
    PCG recurrence scalar.  Column layout matches :class:`CGState`, so
    :func:`repro.solvers.stepper.merge_columns_masked` splices refills
    identically.
    """

    x: jax.Array              # (n, b) iterate
    r: jax.Array              # (n, b) residual
    z: jax.Array              # (n, b) preconditioned residual M r
    p: jax.Array              # (n, b) search direction
    rz: jax.Array             # (b,)   <r, z> recurrence
    rr: jax.Array             # (b,)   true ||r||^2 (real)
    tol2: jax.Array           # (b,)   per-column squared abs tolerance
    it: jax.Array             # ()     block iteration counter
    maxiter: jax.Array        # ()     block iteration cap
    done: jax.Array           # (b,)   per-column convergence flag


class PCGState(NamedTuple):
    """Resumable pipelined-CG state (Ghysels & Vanroose carries)."""

    x: jax.Array
    r: jax.Array
    w: jax.Array
    z: jax.Array
    s: jax.Array
    p: jax.Array
    gamma_prev: jax.Array     # (b,)
    alpha_prev: jax.Array     # (b,)
    tol2: jax.Array           # (b,)
    fresh: jax.Array          # (b,)  column has not taken its first step yet
    it: jax.Array             # ()
    maxiter: jax.Array        # ()
    done: jax.Array           # (b,)


def _colsum(v):
    """Per-column squared norm, always real.

    The complex branch is a trace-time Python switch: the real-dtype
    expression is character-identical to PR 3, preserving the pinned
    bit-identity of every real solve.
    """
    if jnp.iscomplexobj(v):
        return jnp.sum((jnp.conj(v) * v).real, axis=0)
    return jnp.sum(v * v, axis=0)


def _inner(a, b):
    """Per-column <a, b> with the conjugate-linear first argument."""
    if jnp.iscomplexobj(a) or jnp.iscomplexobj(b):
        return jnp.sum(jnp.conj(a) * b, axis=0)
    return jnp.sum(a * b, axis=0)


def _maybe_1d(res: CGResult, was1d: bool) -> CGResult:
    if not was1d:
        return res
    return CGResult(res.x[:, 0], res.iters, res.resnorm[0], res.converged[0])


def _tol2(tol, bnorm2):
    """Squared relative tolerance, per column (``tol`` scalar or (b,)).

    Floored at ``tiny``: a (near-)zero rhs column would otherwise yield
    ``tol2 = 0`` — a threshold only an exactly-zero residual can meet —
    and stall its whole service block until maxiter.
    """
    t = jnp.broadcast_to(jnp.asarray(tol, bnorm2.dtype), bnorm2.shape)
    return jnp.maximum((t * t) * bnorm2, jnp.finfo(bnorm2.dtype).tiny)


# ------------------------------------------------------------------ plain CG
def cg_init(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
            tol=1e-8, maxiter: int = 500, M=None, block: bool = False):
    """Initial stepper state.  ``tol`` may be a scalar or per-column (b,).

    ``M=None`` returns the plain :class:`CGState` (the unchanged PR-3
    path); an SPD preconditioner (``M.apply(r)`` in operator space, see
    :mod:`repro.solvers.precond`) returns a :class:`PrecondCGState`.

    ``block=True`` returns a :class:`repro.solvers.block.BlockCGState`
    whose columns share **one Krylov space** (Gram matrices through the
    compensated tsmttsm kernel, updates through tsmm) — fewer SpMV
    sweeps per converged column on multi-rhs workloads.  A one-column
    rhs delegates to the plain stepper (trivially bit-identical), and
    ``block=True`` with a preconditioner is not implemented.
    """
    b2, _ = as2d(b)
    if block and b2.shape[1] > 1:
        if M is not None:
            raise NotImplementedError(
                "cg(block=True) does not support preconditioning yet; "
                "drop M or use the column-wise block=False stepper")
        return block_cg_init(op, b2, x0, tol=tol, maxiter=maxiter)
    x = jnp.zeros_like(b2) if x0 is None else as2d(x0)[0]
    bnorm2 = _colsum(b2)
    # zero-rhs columns are solved by x = 0 on the spot: any relative
    # tolerance is met by the exact solution, and the zeroed iterate
    # makes the residual exactly zero so done is set at init
    bzero = bnorm2 <= 0
    x = jnp.where(bzero[None, :], jnp.zeros((), b2.dtype), x)
    r = b2 - op.mv(x)
    rr = _colsum(r)
    bnorm2 = jnp.maximum(bnorm2, jnp.finfo(b2.dtype).tiny)
    tol2 = _tol2(tol, bnorm2)
    if M is None:
        return CGState(x=x, r=r, p=r, rr=rr, tol2=tol2,
                       it=jnp.asarray(0), maxiter=jnp.asarray(int(maxiter)),
                       done=rr <= tol2)
    z = M.apply(r)
    return PrecondCGState(x=x, r=r, z=z, p=z, rz=_inner(r, z), rr=rr,
                          tol2=tol2, it=jnp.asarray(0),
                          maxiter=jnp.asarray(int(maxiter)),
                          done=rr <= tol2)


def _cg_body(op, st: CGState) -> CGState:
    # fused: q = A p and <p, q> in one sweep (GHOST_SPMV_DOT_XY)
    q, _, dots = op.mv_fused(st.p, opts=SpmvOpts(dot_xy=True))
    # dots may accumulate wider than the vectors (f64 under x64);
    # cast the recurrence scalar back so the loop carry stays stable.
    # rr is always real; for Hermitian PD complex operators <p, Ap> is
    # real up to rounding — take .real explicitly (complex->real astype
    # is deprecated), a no-op branch for real dtypes
    pq = dots[1]
    if jnp.iscomplexobj(pq):
        pq = pq.real
    pq = pq.astype(st.rr.dtype)
    alpha = jnp.where(st.done, 0.0, st.rr / jnp.where(pq == 0, 1.0, pq))
    x = st.x + alpha[None, :] * st.p
    r = st.r - alpha[None, :] * q
    rr_new = _colsum(r)
    beta = rr_new / jnp.where(st.rr == 0, 1.0, st.rr)
    p = jnp.where(st.done[None, :], st.p, r + beta[None, :] * st.p)
    return CGState(x=x, r=r, p=p, rr=rr_new, tol2=st.tol2,
                   it=st.it + 1, maxiter=st.maxiter,
                   done=st.done | (rr_new <= st.tol2))


def _cg_precond_body(op, M, st: PrecondCGState) -> PrecondCGState:
    # fused: q = A p and <p, q> in one sweep (GHOST_SPMV_DOT_XY)
    q, _, dots = op.mv_fused(st.p, opts=SpmvOpts(dot_xy=True))
    pq = dots[1].astype(st.rz.dtype)
    alpha = jnp.where(st.done, 0.0, st.rz / jnp.where(pq == 0, 1.0, pq))
    x = st.x + alpha[None, :] * st.p
    r = st.r - alpha[None, :] * q
    rr_new = _colsum(r)
    z = M.apply(r)
    rz_new = _inner(r, z)
    beta = rz_new / jnp.where(st.rz == 0, 1.0, st.rz)
    p = jnp.where(st.done[None, :], st.p, z + beta[None, :] * st.p)
    return PrecondCGState(x=x, r=r, z=z, p=p, rz=rz_new, rr=rr_new,
                          tol2=st.tol2, it=st.it + 1, maxiter=st.maxiter,
                          done=st.done | (rr_new <= st.tol2))


def cg_step(op, state, k: int, M=None):
    """Advance up to ``k`` iterations (jitted chunk, early-exits when all
    columns are done or ``maxiter`` is reached).  Pass the same ``M`` the
    state was initialized with (``None`` for a plain :class:`CGState`)."""
    if isinstance(state, BlockCGState):
        if M is not None:
            raise ValueError("block CG states are unpreconditioned; "
                             "M must be None")
        return run_chunk(op, "block_cg", k, state, block_cg_body)
    if M is None:
        if isinstance(state, PrecondCGState):
            raise ValueError("state was initialized with a preconditioner; "
                             "pass the same M to cg_step")
        return run_chunk(op, "cg", k, state, _cg_body)
    if not isinstance(state, PrecondCGState):
        raise ValueError("state was initialized without a preconditioner; "
                         "call cg_init(..., M=M) first")
    return run_chunk(op, "cg_precond", k, state,
                     lambda o, s: _cg_precond_body(o, M, s), extra_key=M)


def cg_finalize(state) -> CGResult:
    return CGResult(state.x, state.it, jnp.sqrt(state.rr), state.done)


def cg(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
       tol: float = 1e-8, maxiter: int = 500, M=None,
       block: bool = False) -> CGResult:
    """Block (P)CG.  op must be SPD; ``M`` too.  ``block=False`` solves
    the columns independently; ``block=True`` shares one Krylov space
    across them (see :func:`cg_init`)."""
    was1d = b.ndim == 1
    state = cg_init(op, b, x0, tol=tol, maxiter=maxiter, M=M, block=block)
    state = cg_step(op, state, maxiter, M=M)
    return _maybe_1d(cg_finalize(state), was1d)


# -------------------------------------------------------------- pipelined CG
def _no_pipelined_precond(M) -> None:
    if M is not None:
        raise NotImplementedError(
            "pipelined_cg does not support preconditioning: the Ghysels & "
            "Vanroose preconditioned variant needs an extra u = M r carry "
            "that this stepper does not yet implement.  Use cg(..., M=M) "
            "(preconditioned CG) or drop the preconditioner.")


def pipelined_cg_init(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
                      tol=1e-8, maxiter: int = 500, M=None,
                      block: bool = False) -> PCGState:
    """Initial pipelined-CG stepper state.

    ``M`` is accepted for signature parity with :func:`cg_init` only and
    must be ``None``: any preconditioner raises
    :class:`NotImplementedError` here (and in ``pipelined_cg_step`` /
    ``pipelined_cg``) — the Ghysels & Vanroose preconditioned variant
    needs an extra ``u = M r`` carry this stepper does not implement.
    ``block`` likewise exists for signature parity only: there is no
    shared-Krylov pipelined variant.
    """
    _no_pipelined_precond(M)
    if block:
        raise NotImplementedError(
            "pipelined_cg has no block (shared Krylov space) mode; use "
            "cg(..., block=True) or minres(..., block=True)")
    b2, _ = as2d(b)
    x = jnp.zeros_like(b2) if x0 is None else as2d(x0)[0]
    # zero-rhs columns: x = 0 is the solution — without this, a nonzero
    # x0 leaves a residual no (floored) relative tolerance ever meets
    bzero = _colsum(b2) <= 0
    x = jnp.where(bzero[None, :], jnp.zeros((), b2.dtype), x)
    r = b2 - op.mv(x)
    w = op.mv(r)
    bnorm2 = jnp.maximum(_colsum(b2), jnp.finfo(b2.dtype).tiny)
    tol2 = _tol2(tol, bnorm2)
    zeros = jnp.zeros_like(b2)
    zcol = jnp.zeros(b2.shape[1], r.dtype)
    return PCGState(x=x, r=r, w=w, z=zeros, s=zeros, p=zeros,
                    gamma_prev=zcol, alpha_prev=zcol, tol2=tol2,
                    fresh=jnp.ones(b2.shape[1], bool),
                    it=jnp.asarray(0), maxiter=jnp.asarray(int(maxiter)),
                    done=_colsum(r) <= tol2)


def _pcg_body(op, st: PCGState) -> PCGState:
    gamma = jnp.sum(st.r * st.r, axis=0)
    delta = jnp.sum(st.w * st.r, axis=0)
    q = op.mv(st.w)                      # overlaps the reduction bundle
    # per-column first-step flag (not ``it == 0``): a column refilled into
    # a running block by the SolverService starts its own recurrence
    first = st.fresh
    beta = jnp.where(
        first, 0.0,
        gamma / jnp.where(st.gamma_prev == 0, 1.0, st.gamma_prev))
    denom = jnp.where(
        first, delta,
        delta - beta * gamma
        / jnp.where(st.alpha_prev == 0, 1.0, st.alpha_prev))
    alpha = gamma / jnp.where(denom == 0, 1.0, denom)
    z = q + beta[None] * st.z
    s = st.w + beta[None] * st.s
    p = st.r + beta[None] * st.p
    a = jnp.where(st.done, 0.0, alpha)
    x = st.x + a[None] * p
    r = st.r - a[None] * s
    w = st.w - a[None] * z
    done = st.done | (_colsum(r) <= st.tol2)
    return PCGState(x=x, r=r, w=w, z=z, s=s, p=p,
                    gamma_prev=gamma, alpha_prev=alpha, tol2=st.tol2,
                    fresh=jnp.zeros_like(st.fresh),
                    it=st.it + 1, maxiter=st.maxiter, done=done)


def pipelined_cg_step(op, state: PCGState, k: int, M=None) -> PCGState:
    """Advance up to ``k`` iterations.  ``M`` must be ``None`` (raises
    :class:`NotImplementedError` otherwise — see
    :func:`pipelined_cg_init`)."""
    _no_pipelined_precond(M)
    return run_chunk(op, "pipelined_cg", k, state, _pcg_body)


def pipelined_cg_finalize(state: PCGState) -> CGResult:
    return CGResult(state.x, state.it, jnp.sqrt(_colsum(state.r)),
                    state.done)


def pipelined_cg(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
                 tol: float = 1e-8, maxiter: int = 500, M=None) -> CGResult:
    """Pipelined CG (Ghysels & Vanroose 2013, Alg. 3), **unpreconditioned**.

    Passing a preconditioner raises :class:`NotImplementedError` (it used
    to be silently impossible to request one); use :func:`cg` with ``M=``
    for preconditioned solves.
    """
    _no_pipelined_precond(M)
    was1d = b.ndim == 1
    state = pipelined_cg_init(op, b, x0, tol=tol, maxiter=maxiter)
    state = pipelined_cg_step(op, state, maxiter)
    return _maybe_1d(pipelined_cg_finalize(state), was1d)
