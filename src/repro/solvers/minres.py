"""MINRES for symmetric (possibly indefinite) systems.

The paper's application matrices "may be completely indefinite" (section
1.3); PHIST ships blocked MinRes on top of GHOST.  Standard Lanczos-based
MINRES with Givens rotations, block-vector columns solved independently.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class MinresResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    resnorm: jax.Array
    converged: jax.Array


def minres(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
           tol: float = 1e-8, maxiter: int = 500) -> MinresResult:
    was1d = b.ndim == 1
    b2 = b[:, None] if was1d else b
    x = jnp.zeros_like(b2) if x0 is None else (x0[:, None] if x0.ndim == 1 else x0)
    r = b2 - op.mv(x)
    bnorm = jnp.sqrt(jnp.maximum(jnp.sum(b2 * b2, 0), jnp.finfo(jnp.float32).tiny))

    beta1 = jnp.sqrt(jnp.sum(r * r, 0))
    safe_beta1 = jnp.where(beta1 == 0, 1.0, beta1)
    v = r / safe_beta1[None]

    zeros = jnp.zeros_like(b2)
    zcol = jnp.zeros(b2.shape[1], b2.dtype)

    # carry: x, v, v_old, w, w_old, beta, eta, c, c_old, s, s_old, resn, it, done
    def cond(st):
        return jnp.logical_and(st[-2] < maxiter, ~jnp.all(st[-1]))

    def body(st):
        (x, v, v_old, w, w_old, beta, eta,
         c, c_old, s, s_old, resn, it, done) = st
        Av = op.mv(v)
        alpha = jnp.sum(v * Av, 0)
        r1 = Av - alpha[None] * v - beta[None] * v_old
        beta_new = jnp.sqrt(jnp.sum(r1 * r1, 0))
        v_new = r1 / jnp.where(beta_new == 0, 1.0, beta_new)[None]

        # previous rotations applied to the new column of T
        delta = c * alpha - c_old * s * beta
        rho2 = s * alpha + c_old * c * beta
        rho3 = s_old * beta
        rho1 = jnp.sqrt(delta * delta + beta_new * beta_new)
        rho1s = jnp.where(rho1 == 0, 1.0, rho1)
        c_new = delta / rho1s
        s_new = beta_new / rho1s

        w_new = (v - rho3[None] * w_old - rho2[None] * w) / rho1s[None]
        upd = jnp.where(done, 0.0, c_new * eta)
        x = x + upd[None] * w_new
        eta_new = -s_new * eta
        resn_new = jnp.where(done, resn, jnp.abs(eta_new))
        done = done | (resn_new <= tol * bnorm)
        return (x, v_new, v, w_new, w, beta_new, eta_new,
                c_new, c, s_new, s, resn_new, it + 1, done)

    st = (x, v, zeros, zeros, zeros, zcol, beta1,
          jnp.ones_like(zcol), jnp.ones_like(zcol), zcol, zcol,
          beta1, jnp.asarray(0), beta1 <= tol * bnorm)
    st = jax.lax.while_loop(cond, body, st)
    x, resn, it, done = st[0], st[-3], st[-2], st[-1]
    if was1d:
        return MinresResult(x[:, 0], it, resn[0], done[0])
    return MinresResult(x, it, resn, done)
