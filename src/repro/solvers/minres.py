"""MINRES for symmetric (possibly indefinite) systems.

The paper's application matrices "may be completely indefinite" (section
1.3); PHIST ships blocked MinRes on top of GHOST.  Standard Lanczos-based
MINRES with Givens rotations, block-vector columns solved independently.

Like CG, the solver is a **resumable stepper** (``minres_init`` /
``minres_step`` / ``minres_finalize``): per-column convergence rides in
the state, so :class:`repro.runtime.service.SolverService` can retire
finished columns between jitted k-iteration chunks and refill the freed
slots with queued right-hand sides.  The classic ``minres`` entry point
composes the three and is bit-identical to one monolithic solve.

An optional **SPD** preconditioner ``M`` (the matrix may stay
indefinite) switches to the preconditioned Lanczos recurrence of
Elman/Silvester/Wathen: the Krylov space is built for ``M A`` with
``M``-inner products, and convergence is tested on the ``M``-norm
residual estimate ``sqrt(<r, M r>)`` against ``tol * sqrt(<b, M b>)`` —
the natural norm of the preconditioned problem.  ``M=None`` runs the
*exact* PR-3 state and body (bit-identity pinned in
``tests/test_steppers.py``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.spmv import as2d
from repro.solvers.block import (BlockMinresState, block_minres_body,
                                 block_minres_init)
from repro.solvers.stepper import run_chunk


def _colnorm2(v):
    """Per-column squared norm, always real; real path is PR-3 identical."""
    if jnp.iscomplexobj(v):
        return jnp.sum((jnp.conj(v) * v).real, axis=0)
    return jnp.sum(v * v, axis=0)


def _inner_real(a, b):
    """Real part of per-column <a, b> (conjugate-linear first argument)."""
    if jnp.iscomplexobj(a) or jnp.iscomplexobj(b):
        return jnp.sum(jnp.conj(a) * b, axis=0).real
    return jnp.sum(a * b, axis=0)


class MinresResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    resnorm: jax.Array
    converged: jax.Array


class MinresState(NamedTuple):
    """Resumable block-MINRES state (columns = independent systems)."""

    x: jax.Array              # (n, b) iterate
    v: jax.Array              # (n, b) current Lanczos vector
    v_old: jax.Array          # (n, b)
    w: jax.Array              # (n, b) update direction
    w_old: jax.Array          # (n, b)
    beta: jax.Array           # (b,)   Lanczos off-diagonal
    eta: jax.Array            # (b,)   rotated rhs residual coefficient
    c: jax.Array              # (b,)   Givens cosines / sines
    c_old: jax.Array
    s: jax.Array
    s_old: jax.Array
    resn: jax.Array           # (b,)   residual-norm estimate
    tolb: jax.Array           # (b,)   per-column absolute tolerance
    it: jax.Array             # ()
    maxiter: jax.Array        # ()
    done: jax.Array           # (b,)


class PrecondMinresState(NamedTuple):
    """Resumable preconditioned block-MINRES state (M-inner products).

    Carries the *unnormalized* Lanczos residuals ``v`` and their
    preconditioned images ``z = M v`` (Elman/Silvester/Wathen Alg. 6.1);
    ``gamma = sqrt(<z, v>)`` replaces the plain Lanczos ``beta``.
    Per-column fields keep the block column as the last axis so
    :func:`repro.solvers.stepper.merge_columns_masked` splices refills
    exactly like every other stepper state.
    """

    x: jax.Array              # (n, b) iterate
    v: jax.Array              # (n, b) current (unnormalized) Lanczos vector
    v_old: jax.Array          # (n, b)
    z: jax.Array              # (n, b) M v
    w: jax.Array              # (n, b) update direction
    w_old: jax.Array          # (n, b)
    gamma: jax.Array          # (b,)   sqrt(<z, v>) — M-norm of v
    gamma_old: jax.Array      # (b,)
    eta: jax.Array            # (b,)   rotated rhs residual coefficient
    c: jax.Array              # (b,)   Givens cosines / sines
    c_old: jax.Array
    s: jax.Array
    s_old: jax.Array
    resn: jax.Array           # (b,)   M-norm residual estimate
    tolb: jax.Array           # (b,)   per-column absolute tolerance (M-norm)
    it: jax.Array             # ()
    maxiter: jax.Array        # ()
    done: jax.Array           # (b,)


def minres_init(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
                tol=1e-8, maxiter: int = 500, M=None, block: bool = False):
    """Initial stepper state.  ``tol`` may be a scalar or per-column (b,).

    ``M=None`` returns the plain :class:`MinresState` (unchanged PR-3
    path); an SPD preconditioner returns a :class:`PrecondMinresState`.

    ``block=True`` returns a
    :class:`repro.solvers.block.BlockMinresState` whose columns share
    one Lanczos space (SVQB-orthonormalized block basis, band QR of the
    block tridiagonal).  A one-column rhs delegates to the plain stepper
    (trivially bit-identical); ``block=True`` with a preconditioner is
    not implemented.
    """
    b2, _ = as2d(b)
    if block and b2.shape[1] > 1:
        if M is not None:
            raise NotImplementedError(
                "minres(block=True) does not support preconditioning yet; "
                "drop M or use the column-wise block=False stepper")
        return block_minres_init(op, b2, x0, tol=tol, maxiter=maxiter)
    x = jnp.zeros_like(b2) if x0 is None else as2d(x0)[0]
    # zero-rhs columns are solved by x = 0 on the spot (their residual
    # is then exactly zero, so they converge at init — a relative
    # tolerance against ||b|| = 0 could otherwise never be met)
    bzero = _colnorm2(b2) <= 0
    x = jnp.where(bzero[None, :], jnp.zeros((), b2.dtype), x)
    r = b2 - op.mv(x)
    if M is not None:
        return _minres_precond_init(op, M, b2, x, r, tol, maxiter)
    bnorm = jnp.sqrt(jnp.maximum(_colnorm2(b2),
                                 jnp.finfo(b2.dtype).tiny))
    # floored: a zero-b column's absolute tolerance must stay positive
    tolb = jnp.maximum(
        jnp.broadcast_to(jnp.asarray(tol, bnorm.dtype),
                         bnorm.shape) * bnorm,
        jnp.finfo(b2.dtype).tiny)

    beta1 = jnp.sqrt(_colnorm2(r))
    safe_beta1 = jnp.where(beta1 == 0, 1.0, beta1)
    v = r / safe_beta1[None]

    zeros = jnp.zeros_like(b2)
    zcol = jnp.zeros(b2.shape[1], bnorm.dtype)
    return MinresState(
        x=x, v=v, v_old=zeros, w=zeros, w_old=zeros,
        beta=zcol, eta=beta1,
        c=jnp.ones_like(zcol), c_old=jnp.ones_like(zcol),
        s=zcol, s_old=zcol, resn=beta1, tolb=tolb,
        it=jnp.asarray(0), maxiter=jnp.asarray(int(maxiter)),
        done=beta1 <= tolb)


def _minres_precond_init(op, M, b2, x, r, tol, maxiter) -> PrecondMinresState:
    zb = M.apply(b2)
    bnormM = jnp.sqrt(jnp.maximum(_inner_real(b2, zb),
                                  jnp.finfo(b2.dtype).tiny))
    # floored like the plain path: zero-b columns keep a positive bar
    tolb = jnp.maximum(
        jnp.broadcast_to(jnp.asarray(tol, bnormM.dtype),
                         bnormM.shape) * bnormM,
        jnp.finfo(b2.dtype).tiny)
    z = M.apply(r)
    gamma1 = jnp.sqrt(jnp.maximum(_inner_real(r, z), 0.0))

    zeros = jnp.zeros_like(b2)
    zcol = jnp.zeros(b2.shape[1], bnormM.dtype)
    return PrecondMinresState(
        x=x, v=r, v_old=zeros, z=z, w=zeros, w_old=zeros,
        gamma=gamma1, gamma_old=jnp.ones_like(zcol), eta=gamma1,
        c=jnp.ones_like(zcol), c_old=jnp.ones_like(zcol),
        s=zcol, s_old=zcol, resn=gamma1, tolb=tolb,
        it=jnp.asarray(0), maxiter=jnp.asarray(int(maxiter)),
        done=gamma1 <= tolb)


def _minres_body(op, st: MinresState) -> MinresState:
    Av = op.mv(st.v)
    alpha = _inner_real(st.v, Av)
    r1 = Av - alpha[None] * st.v - st.beta[None] * st.v_old
    beta_new = jnp.sqrt(_colnorm2(r1))
    v_new = r1 / jnp.where(beta_new == 0, 1.0, beta_new)[None]

    # previous rotations applied to the new column of T
    delta = st.c * alpha - st.c_old * st.s * st.beta
    rho2 = st.s * alpha + st.c_old * st.c * st.beta
    rho3 = st.s_old * st.beta
    rho1 = jnp.sqrt(delta * delta + beta_new * beta_new)
    rho1s = jnp.where(rho1 == 0, 1.0, rho1)
    c_new = delta / rho1s
    s_new = beta_new / rho1s

    w_new = (st.v - rho3[None] * st.w_old - rho2[None] * st.w) / rho1s[None]
    upd = jnp.where(st.done, 0.0, c_new * st.eta)
    x = st.x + upd[None] * w_new
    eta_new = -s_new * st.eta
    resn_new = jnp.where(st.done, st.resn, jnp.abs(eta_new))
    return MinresState(
        x=x, v=v_new, v_old=st.v, w=w_new, w_old=st.w,
        beta=beta_new, eta=eta_new,
        c=c_new, c_old=st.c, s=s_new, s_old=st.s,
        resn=resn_new, tolb=st.tolb,
        it=st.it + 1, maxiter=st.maxiter,
        done=st.done | (resn_new <= st.tolb))


def _minres_precond_body(op, M, st: PrecondMinresState) -> PrecondMinresState:
    gs = jnp.where(st.gamma == 0, 1.0, st.gamma)
    q = st.z / gs[None]                      # normalized search direction
    Aq = op.mv(q)
    delta = _inner_real(q, Aq)
    v_new = (Aq - (delta / gs)[None] * st.v
             - (st.gamma / jnp.where(st.gamma_old == 0, 1.0,
                                     st.gamma_old))[None] * st.v_old)
    z_new = M.apply(v_new)
    gamma_new = jnp.sqrt(jnp.maximum(_inner_real(v_new, z_new), 0.0))

    # previous rotations applied to the new column of T
    alpha0 = st.c * delta - st.c_old * st.s * st.gamma
    alpha1 = jnp.sqrt(alpha0 * alpha0 + gamma_new * gamma_new)
    alpha2 = st.s * delta + st.c_old * st.c * st.gamma
    alpha3 = st.s_old * st.gamma
    a1s = jnp.where(alpha1 == 0, 1.0, alpha1)
    c_new = alpha0 / a1s
    s_new = gamma_new / a1s

    w_new = (q - alpha3[None] * st.w_old - alpha2[None] * st.w) / a1s[None]
    upd = jnp.where(st.done, 0.0, c_new * st.eta)
    x = st.x + upd[None] * w_new
    eta_new = -s_new * st.eta
    resn_new = jnp.where(st.done, st.resn, jnp.abs(eta_new))
    return PrecondMinresState(
        x=x, v=v_new, v_old=st.v, z=z_new, w=w_new, w_old=st.w,
        gamma=gamma_new, gamma_old=st.gamma, eta=eta_new,
        c=c_new, c_old=st.c, s=s_new, s_old=st.s,
        resn=resn_new, tolb=st.tolb,
        it=st.it + 1, maxiter=st.maxiter,
        done=st.done | (resn_new <= st.tolb))


def minres_step(op, state, k: int, M=None):
    """Advance up to ``k`` iterations (jitted chunk, early-exits when all
    columns are done or ``maxiter`` is reached).  Pass the same ``M`` the
    state was initialized with (``None`` for a plain :class:`MinresState`)."""
    if isinstance(state, BlockMinresState):
        if M is not None:
            raise ValueError("block MINRES states are unpreconditioned; "
                             "M must be None")
        return run_chunk(op, "block_minres", k, state, block_minres_body)
    if M is None:
        if isinstance(state, PrecondMinresState):
            raise ValueError("state was initialized with a preconditioner; "
                             "pass the same M to minres_step")
        return run_chunk(op, "minres", k, state, _minres_body)
    if not isinstance(state, PrecondMinresState):
        raise ValueError("state was initialized without a preconditioner; "
                         "call minres_init(..., M=M) first")
    return run_chunk(op, "minres_precond", k, state,
                     lambda o, s: _minres_precond_body(o, M, s), extra_key=M)


def minres_finalize(state) -> MinresResult:
    return MinresResult(state.x, state.it, state.resn, state.done)


def minres(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
           tol: float = 1e-8, maxiter: int = 500, M=None,
           block: bool = False) -> MinresResult:
    """Block (preconditioned) MINRES.  ``M`` must be SPD when given; the
    convergence test then runs in the ``M``-norm (see module docstring).
    ``block=True`` shares one Lanczos space across the columns (see
    :func:`minres_init`)."""
    was1d = b.ndim == 1
    state = minres_init(op, b, x0, tol=tol, maxiter=maxiter, M=M,
                        block=block)
    state = minres_step(op, state, maxiter, M=M)
    res = minres_finalize(state)
    if was1d:
        return MinresResult(res.x[:, 0], res.iters, res.resnorm[0],
                            res.converged[0])
    return res
