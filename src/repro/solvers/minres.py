"""MINRES for symmetric (possibly indefinite) systems.

The paper's application matrices "may be completely indefinite" (section
1.3); PHIST ships blocked MinRes on top of GHOST.  Standard Lanczos-based
MINRES with Givens rotations, block-vector columns solved independently.

Like CG, the solver is a **resumable stepper** (``minres_init`` /
``minres_step`` / ``minres_finalize``): per-column convergence rides in
the state, so :class:`repro.runtime.service.SolverService` can retire
finished columns between jitted k-iteration chunks and refill the freed
slots with queued right-hand sides.  The classic ``minres`` entry point
composes the three and is bit-identical to one monolithic solve.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.spmv import as2d
from repro.solvers.stepper import run_chunk


class MinresResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    resnorm: jax.Array
    converged: jax.Array


class MinresState(NamedTuple):
    """Resumable block-MINRES state (columns = independent systems)."""

    x: jax.Array              # (n, b) iterate
    v: jax.Array              # (n, b) current Lanczos vector
    v_old: jax.Array          # (n, b)
    w: jax.Array              # (n, b) update direction
    w_old: jax.Array          # (n, b)
    beta: jax.Array           # (b,)   Lanczos off-diagonal
    eta: jax.Array            # (b,)   rotated rhs residual coefficient
    c: jax.Array              # (b,)   Givens cosines / sines
    c_old: jax.Array
    s: jax.Array
    s_old: jax.Array
    resn: jax.Array           # (b,)   residual-norm estimate
    tolb: jax.Array           # (b,)   per-column absolute tolerance
    it: jax.Array             # ()
    maxiter: jax.Array        # ()
    done: jax.Array           # (b,)


def minres_init(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
                tol=1e-8, maxiter: int = 500) -> MinresState:
    """Initial stepper state.  ``tol`` may be a scalar or per-column (b,)."""
    b2, _ = as2d(b)
    x = jnp.zeros_like(b2) if x0 is None else as2d(x0)[0]
    r = b2 - op.mv(x)
    bnorm = jnp.sqrt(jnp.maximum(jnp.sum(b2 * b2, 0),
                                 jnp.finfo(b2.dtype).tiny))
    tolb = jnp.broadcast_to(jnp.asarray(tol, bnorm.dtype),
                            bnorm.shape) * bnorm

    beta1 = jnp.sqrt(jnp.sum(r * r, 0))
    safe_beta1 = jnp.where(beta1 == 0, 1.0, beta1)
    v = r / safe_beta1[None]

    zeros = jnp.zeros_like(b2)
    zcol = jnp.zeros(b2.shape[1], b2.dtype)
    return MinresState(
        x=x, v=v, v_old=zeros, w=zeros, w_old=zeros,
        beta=zcol, eta=beta1,
        c=jnp.ones_like(zcol), c_old=jnp.ones_like(zcol),
        s=zcol, s_old=zcol, resn=beta1, tolb=tolb,
        it=jnp.asarray(0), maxiter=jnp.asarray(int(maxiter)),
        done=beta1 <= tolb)


def _minres_body(op, st: MinresState) -> MinresState:
    Av = op.mv(st.v)
    alpha = jnp.sum(st.v * Av, 0)
    r1 = Av - alpha[None] * st.v - st.beta[None] * st.v_old
    beta_new = jnp.sqrt(jnp.sum(r1 * r1, 0))
    v_new = r1 / jnp.where(beta_new == 0, 1.0, beta_new)[None]

    # previous rotations applied to the new column of T
    delta = st.c * alpha - st.c_old * st.s * st.beta
    rho2 = st.s * alpha + st.c_old * st.c * st.beta
    rho3 = st.s_old * st.beta
    rho1 = jnp.sqrt(delta * delta + beta_new * beta_new)
    rho1s = jnp.where(rho1 == 0, 1.0, rho1)
    c_new = delta / rho1s
    s_new = beta_new / rho1s

    w_new = (st.v - rho3[None] * st.w_old - rho2[None] * st.w) / rho1s[None]
    upd = jnp.where(st.done, 0.0, c_new * st.eta)
    x = st.x + upd[None] * w_new
    eta_new = -s_new * st.eta
    resn_new = jnp.where(st.done, st.resn, jnp.abs(eta_new))
    return MinresState(
        x=x, v=v_new, v_old=st.v, w=w_new, w_old=st.w,
        beta=beta_new, eta=eta_new,
        c=c_new, c_old=st.c, s=s_new, s_old=st.s,
        resn=resn_new, tolb=st.tolb,
        it=st.it + 1, maxiter=st.maxiter,
        done=st.done | (resn_new <= st.tolb))


def minres_step(op, state: MinresState, k: int) -> MinresState:
    """Advance up to ``k`` iterations (jitted chunk, early-exits when all
    columns are done or ``maxiter`` is reached)."""
    return run_chunk(op, "minres", k, state, _minres_body)


def minres_finalize(state: MinresState) -> MinresResult:
    return MinresResult(state.x, state.it, state.resn, state.done)


def minres(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
           tol: float = 1e-8, maxiter: int = 500) -> MinresResult:
    was1d = b.ndim == 1
    state = minres_init(op, b, x0, tol=tol, maxiter=maxiter)
    state = minres_step(op, state, maxiter)
    res = minres_finalize(state)
    if was1d:
        return MinresResult(res.x[:, 0], res.iters, res.resnorm[0],
                            res.converged[0])
    return res
