"""Lanczos tridiagonalization and extremal eigenvalue estimation.

A GHOST sample application (the paper ships "a Lanczos eigensolver" with the
library) and the engine behind the spectral-interval estimation that KPM and
Chebyshev filter diagonalization require.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class LanczosResult(NamedTuple):
    alphas: jax.Array      # (k,)   entries past nvalid are zero padding
    betas: jax.Array       # (k-1,) entries past nvalid-1 are zero padding
    V: Optional[jax.Array]  # (n, k) basis if kept (zero columns past nvalid)
    nvalid: Optional[jax.Array] = None  # () number of valid Lanczos steps
    #                                     (< k after a happy breakdown)


def randn(key, shape, dtype) -> jax.Array:
    """Gaussian start block in the operator's dtype (complex-aware).

    Internally generated Lanczos/ChebFD start vectors must match
    ``op.dtype`` — a hardcoded float32 start silently downcasts an f64
    operator's whole Krylov recurrence.
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.complexfloating):
        rdt = jnp.finfo(dtype).dtype            # matching real dtype
        kre, kim = jax.random.split(key)
        return (jax.random.normal(kre, shape, rdt)
                + 1j * jax.random.normal(kim, shape, rdt)).astype(dtype)
    return jax.random.normal(key, shape, dtype)


def lanczos(op, v0: jax.Array, k: int, *, reorth: bool = False,
            keep_basis: bool = False, seed: int = 0) -> LanczosResult:
    """k-step Lanczos on symmetric/Hermitian op.  v0 (n,) start (or None)."""
    n = op.n
    if v0 is None:
        v0 = randn(jax.random.PRNGKey(seed), (n,), op.dtype)
    v = v0 / jnp.linalg.norm(v0)

    rdt = jnp.finfo(v.dtype).dtype              # real dtype of the recurrence
    alphas = jnp.zeros(k, rdt)
    betas = jnp.zeros(max(k - 1, 1), rdt)
    V = jnp.zeros((n, k), v.dtype) if (keep_basis or reorth) else None

    v_prev = jnp.zeros_like(v)
    beta = jnp.asarray(0.0, rdt)
    # breakdown tracking: once beta hits 0 the Krylov space is exhausted
    # (happy breakdown) — recurring on w = 0 would keep appending garbage
    # zero alphas/betas that poison the tridiagonal's spectrum.  The loop
    # stays unrolled/traceable, so "stop" is a mask: frozen steps write
    # nothing and nvalid reports the usable prefix.
    alive = jnp.asarray(True)
    nvalid = jnp.asarray(0, jnp.int32)
    for j in range(k):                      # unrolled: k is small & static
        if V is not None:
            V = V.at[:, j].set(jnp.where(alive, v, jnp.zeros_like(v)))
        w = op.mv(v[:, None])[:, 0]
        alpha = jnp.vdot(v, w)
        w = w - alpha * v - beta * v_prev
        if reorth and V is not None:
            # conjugate transpose: for complex Hermitian operators the
            # projector is V V^H, not V V^T
            w = w - V @ (V.conj().T @ w)
        alphas = alphas.at[j].set(jnp.where(alive, alpha.real, 0.0))
        nvalid = nvalid + alive.astype(jnp.int32)
        beta_new = jnp.linalg.norm(w).astype(rdt)
        step_alive = alive & (beta_new > 0)
        if j < k - 1:
            betas = betas.at[j].set(jnp.where(step_alive, beta_new, 0.0))
        v_prev = v
        v = jnp.where(step_alive,
                      w / jnp.where(beta_new == 0, 1.0, beta_new), v)
        beta = jnp.where(step_alive, beta_new, jnp.zeros((), rdt))
        alive = step_alive
    return LanczosResult(alphas, betas[: max(k - 1, 0)], V, nvalid)


def tridiag_eigh(alphas, betas) -> Tuple[np.ndarray, np.ndarray]:
    """Eigendecomposition of the Lanczos tridiagonal (host-side)."""
    try:
        from scipy.linalg import eigh_tridiagonal
        return eigh_tridiagonal(np.asarray(alphas), np.asarray(betas))
    except ImportError:                      # pragma: no cover
        a = np.asarray(alphas)
        b = np.asarray(betas)
        T = np.diag(a) + np.diag(b, 1) + np.diag(b, -1)
        return np.linalg.eigh(T)


def lanczos_extrema(op, *, k: int = 30, seed: int = 0,
                    safety: float = 1.05) -> Tuple[float, float]:
    """Estimate (lambda_min, lambda_max) with a short Lanczos run, widened
    by ``safety`` — the spectral scaling KPM/ChebFD need.  Only the
    valid prefix of the recurrence enters the tridiagonal: after a happy
    breakdown the padded zero alphas would drag a spurious 0 into the
    spectrum estimate."""
    res = lanczos(op, None, k, seed=seed)
    nv = k if res.nvalid is None else max(int(res.nvalid), 1)
    ev, _ = tridiag_eigh(np.asarray(res.alphas)[:nv],
                         np.asarray(res.betas)[:max(nv - 1, 0)])
    lo, hi = float(ev[0]), float(ev[-1])
    mid, rad = (hi + lo) / 2, (hi - lo) / 2
    rad = max(rad * safety, 1e-12)
    return mid - rad, mid + rad
