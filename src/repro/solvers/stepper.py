"""Shared machinery for resumable stepper-form Krylov solvers.

Each solver in this package factors into ``*_init(op, b, x0) -> State``,
``*_step(op, state, k) -> State`` and ``*_finalize(state) -> Result``.
A *State* is a NamedTuple of arrays whose per-column fields carry the
block-vector column as their **last** axis (``(n, b)`` vectors, ``(b,)``
recurrence scalars, ``(b,)`` bool ``done``) plus scalar bookkeeping
(``it``, ``maxiter``).  That layout is what makes continuous batching
possible: :func:`merge_columns` can splice freshly initialized columns
into a running state without touching the survivors.

``*_step`` runs a bounded ``lax.while_loop`` — up to ``k`` applications
of the *same* iteration body the monolithic solver uses, stopping early
at ``maxiter`` or when every column has converged.  Composing chunks is
therefore bit-identical to one monolithic solve: the body sees the same
carries in the same order, only the Python-level chunk boundaries move.

:func:`run_chunk` caches one jitted chunk per ``(operator, solver, k)``
(weakly keyed on the operator), so a long-lived
:class:`repro.runtime.service.SolverService` pays for tracing once per
batch shape, not once per request.
"""
from __future__ import annotations

import weakref
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["run_chunk", "merge_columns", "merge_columns_masked",
           "snap_chunk", "clear_chunk_cache"]

# op -> {(solver_name, k): jitted chunk}; weak so dropping an operator
# (e.g. a registry eviction) frees its compiled chunks too
_chunk_cache: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def run_chunk(op, name: str, k: int, state, body: Callable, *,
              extra_key=None):
    """Advance ``state`` by up to ``k`` iterations of ``body(op, state)``.

    The loop stops early once ``state.it`` reaches ``state.maxiter`` or
    every column's ``done`` flag is set — exactly the monolithic solver's
    termination test, so chunking never changes the iterate sequence.

    ``extra_key`` distinguishes otherwise same-named chunks whose bodies
    close over different auxiliary objects (e.g. the preconditioner ``M``
    of ``cg_step(..., M=M)``): two M's on the same operator must not
    share a compiled chunk.  It is held weakly in the cache key, so a
    dead preconditioner's entry can never collide with a new object.
    """
    k = int(k)
    if k <= 0:
        return state
    try:
        per_op = _chunk_cache[op]
    except KeyError:
        per_op = _chunk_cache[op] = {}
    cache_key = ((name, k) if extra_key is None
                 else (name, k, weakref.ref(extra_key)))
    fn = per_op.get(cache_key)
    if fn is None:
        # close over a weakref, not the operator: the cached jitted fn is
        # a *value* of the WeakKeyDictionary — a strong reference back to
        # its key would make the entry immortal.  The ref is live whenever
        # tracing happens (run_chunk holds ``op``), so resolution is safe.
        op_ref = weakref.ref(op)

        def chunk(st):
            o = op_ref()
            if o is None:
                raise ReferenceError(
                    "operator died while its chunk traced")

            def cond(carry):
                i, s = carry
                return jnp.logical_and(
                    i < k,
                    jnp.logical_and(s.it < s.maxiter, ~jnp.all(s.done)))

            def step(carry):
                i, s = carry
                return i + 1, body(o, s)

            _, out = jax.lax.while_loop(cond, step, (jnp.asarray(0), st))
            return out

        fn = jax.jit(chunk)
        per_op[cache_key] = fn
    return fn(state)


def snap_chunk(k, k_max: int) -> int:
    """Clamp a desired chunk length to ``[1, k_max]``, snapped down to a
    power of two.

    :func:`run_chunk` compiles one program per ``(operator, solver, k)``,
    so a scheduler that derived ``k`` from a continuous quantity (time
    to a deadline / seconds per iteration) would compile an unbounded
    family of chunks.  Snapping to powers of two keeps the family at
    ``log2(k_max) + 1`` variants while staying within a factor of two of
    the requested length — good enough for deadline work, bounded enough
    for the jit cache.
    """
    k_max = int(k_max)
    if k_max < 1:
        raise ValueError(f"k_max must be >= 1, got {k_max}")
    k = int(k)
    if k >= k_max:
        return k_max
    if k < 1:
        return 1
    return 1 << (k.bit_length() - 1)


def merge_columns_masked(old_state, fresh_state, mask):
    """:func:`merge_columns` with the selection as a ``(b,)`` bool array.

    Pure function of arrays — jit it once and every refill pattern reuses
    the same trace (the mask is data, not structure).

    Block-Krylov states (``BLOCK_COUPLED``) cannot be column-spliced:
    their carried ``(b, b)`` Gram/reflection blocks couple every column,
    so a per-column mask would stitch together inconsistent Krylov
    spaces.  The service refills those with a warm restart instead (see
    ``SolverService._refill_block``).
    """
    if getattr(old_state, "BLOCK_COUPLED", False):
        raise ValueError(
            f"{type(old_state).__name__} carries cross-column (b, b) "
            f"blocks and cannot be column-spliced; refill block-Krylov "
            f"batches with a warm restart (re-init with carried x0)")

    def pick(old, fresh):
        if jnp.ndim(old) == 0:
            return old
        sel = mask if jnp.ndim(old) == 1 else mask[None, :]
        return jnp.where(sel, fresh, old)

    return type(old_state)(*(pick(o, f) for o, f in zip(old_state,
                                                        fresh_state)))


def merge_columns(old_state, fresh_state, cols):
    """Splice columns ``cols`` of ``fresh_state`` into ``old_state``.

    Per-column fields (last axis = block column) take the fresh values at
    ``cols`` and keep the running values elsewhere; scalar bookkeeping
    (``it``, ``maxiter``) always keeps the running values, so the block
    iteration counter keeps counting across refills.
    """
    width = old_state.done.shape[0]
    mask = np.zeros(width, bool)
    mask[list(cols)] = True
    return merge_columns_masked(old_state, fresh_state, jnp.asarray(mask))


def clear_chunk_cache() -> None:
    """Drop every cached jitted chunk (tests / backend resets)."""
    _chunk_cache.clear()
