"""Linear operator abstraction for the solvers.

``GhostOperator`` wraps a SELL-C-sigma matrix and exposes the fused
augmented SpM(M)V; ``MatrixFreeOperator`` is the paper's function-pointer
hook (section 5.1: "a user can replace this function pointer by a custom
function that performs the SpMV in any (possibly matrix-free) way").

All solver vectors live in the operator's *permuted* space with shape
``(n, b)`` (block vectors); use :meth:`to_op_space` / :meth:`from_op_space`
at the boundaries.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.sellcs import SellCS
from repro.core.spmv import SpmvOpts, spmv


class GhostOperator:
    def __init__(self, A: SellCS, *, impl: str = "ref", interpret: bool = True):
        self.A = A
        self.impl = impl
        self.interpret = interpret
        self.n = A.nrows_pad
        self.dtype = A.vals.dtype

    def mv(self, x: jax.Array) -> jax.Array:
        y, _, _ = spmv(self.A, x, impl=self.impl, interpret=self.interpret)
        return y

    def mv_fused(self, x, y=None, z=None, opts: SpmvOpts = SpmvOpts()):
        return spmv(self.A, x, y, z, opts, impl=self.impl,
                    interpret=self.interpret)

    def to_op_space(self, v):
        return self.A.permute(v)

    def from_op_space(self, v):
        return self.A.unpermute(v)


class MatrixFreeOperator:
    """Matrix-free SpMV hook (paper section 5.1)."""

    def __init__(self, fn: Callable[[jax.Array], jax.Array], n: int, dtype):
        self.fn = fn
        self.n = n
        self.dtype = jnp.dtype(dtype)

    def mv(self, x):
        return self.fn(x)

    def mv_fused(self, x, y=None, z=None, opts: SpmvOpts = SpmvOpts()):
        Ax = self.fn(x)
        if opts.gamma is not None:
            Ax = Ax - jnp.asarray(opts.gamma) * x
        ynew = opts.alpha * Ax
        if y is not None:
            ynew = ynew + opts.beta * y
        znew = None
        if opts.chain_axpby:
            delta = 0.0 if opts.delta is None else opts.delta
            eta = 0.0 if opts.eta is None else opts.eta
            znew = delta * z + eta * ynew
        dots = None
        if opts.any_dot:
            b = ynew.shape[1] if ynew.ndim > 1 else 1
            y2 = ynew if ynew.ndim > 1 else ynew[:, None]
            x2 = x if x.ndim > 1 else x[:, None]
            zero = jnp.zeros((b,), y2.dtype)
            dots = jnp.stack([
                jnp.sum(y2 * y2, 0) if opts.dot_yy else zero,
                jnp.sum(x2 * y2, 0) if opts.dot_xy else zero,
                jnp.sum(x2 * x2, 0) if opts.dot_xx else zero,
            ])
        return ynew, znew, dots

    def to_op_space(self, v):
        return v

    def from_op_space(self, v):
        return v


def make_operator(A, **kw):
    if isinstance(A, SellCS):
        return GhostOperator(A, **kw)
    raise TypeError(f"cannot wrap {type(A)}")
