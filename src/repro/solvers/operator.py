"""Linear operator abstraction for the solvers.

``GhostOperator`` wraps a SELL-C-sigma matrix and exposes the fused
augmented SpM(M)V; ``MatrixFreeOperator`` is the paper's function-pointer
hook (section 5.1: "a user can replace this function pointer by a custom
function that performs the SpMV in any (possibly matrix-free) way");
``DistOperator`` runs the matvec on the heterogeneous execution engine
(:class:`repro.runtime.engine.HeterogeneousEngine`) so the same solvers
scale out over a device mesh with task-mode overlap.

All solver vectors live in the operator's *permuted* space with shape
``(n, b)`` (block vectors); use :meth:`to_op_space` / :meth:`from_op_space`
at the boundaries.  For ``DistOperator`` the operator space is the
flattened stack of shard-local slices (``n = nshards * m_pad``); padding
slots are kept at zero so norms and dot products are exact.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sellcs import SellCS
from repro.core.spmv import SpmvOpts, as2d, fused_dots, pack_coefs, spmv


class GhostOperator:
    def __init__(self, A: SellCS, *, impl: str = "ref",
                 interpret: Optional[bool] = None):
        # interpret=None defers to repro.core.execution at call time, so
        # the operator follows `execution.force(...)` scopes automatically
        self.A = A
        self.impl = impl
        self.interpret = interpret
        self.n = A.nrows_pad
        # solver vectors/states live in the *compute* dtype; a narrower
        # store_dtype only changes what the kernels stream from memory
        self.dtype = A.dtype
        self.store_dtype = A.store_dtype

    def mv(self, x: jax.Array) -> jax.Array:
        y, _, _ = spmv(self.A, x, impl=self.impl, interpret=self.interpret)
        return y

    def mv_fused(self, x, y=None, z=None, opts: SpmvOpts = SpmvOpts()):
        return spmv(self.A, x, y, z, opts, impl=self.impl,
                    interpret=self.interpret)

    def to_op_space(self, v):
        return self.A.permute(v)

    def from_op_space(self, v):
        return self.A.unpermute(v)


class MatrixFreeOperator:
    """Matrix-free SpMV hook (paper section 5.1)."""

    def __init__(self, fn: Callable[[jax.Array], jax.Array], n: int, dtype):
        self.fn = fn
        self.n = n
        self.dtype = jnp.dtype(dtype)

    def mv(self, x):
        return self.fn(x)

    def mv_fused(self, x, y=None, z=None, opts: SpmvOpts = SpmvOpts()):
        Ax = self.fn(x)
        if opts.gamma is not None:
            Ax = Ax - jnp.asarray(opts.gamma) * x
        ynew = opts.alpha * Ax
        if y is not None:
            ynew = ynew + opts.beta * y
        znew = None
        if opts.chain_axpby:
            if z is None:
                raise ValueError(
                    "SpmvOpts requested a chained AXPBY (delta/eta set) but "
                    "no z vector was passed to mv_fused")
            delta = 0.0 if opts.delta is None else opts.delta
            eta = 0.0 if opts.eta is None else opts.eta
            znew = delta * z + eta * ynew
        dots = None
        if opts.any_dot:
            # same widened/compensated (and conjugated) accumulation as
            # spmv_ref — a matrix-free swap must not change solver numerics
            dots = fused_dots(as2d(x)[0], as2d(ynew)[0], opts)
        return ynew, znew, dots

    def to_op_space(self, v):
        return v

    def from_op_space(self, v):
        return v


class DistOperator:
    """Distributed operator over a :class:`HeterogeneousEngine`.

    Solver vectors are the flattened shard stack ``(nshards * m_pad, b)``.
    Inputs are masked to the valid (non-padding) slots on entry and the
    matvec keeps padding at zero, so the solvers' dot products and norms
    see exactly the original operator embedded in a zero block.  Build
    right-hand sides with :meth:`to_op_space` (global -> operator space).

    Matrix state is read through the engine on every access, so the
    operator follows ``engine.rebalance()`` automatically.  NOTE that a
    rebalance changes the operator-space *layout* (and possibly ``n``):
    vectors built before it are stale — round-trip them through
    ``from_op_space`` before / ``to_op_space`` after the rebalance.
    """

    def __init__(self, engine, *, overlap: bool = True, impl: str = "ref",
                 interpret: Optional[bool] = None):
        self.engine = engine
        self.overlap = overlap
        self.impl = impl
        self.interpret = interpret
        self._mask_cache = (None, None)     # (A object, its mask)

    # ------------------------------------------------------------ helpers
    @property
    def A(self):
        return self.engine.A

    @property
    def n(self) -> int:
        return self.A.nshards * self.A.m_pad

    @property
    def dtype(self):
        # compute dtype: what solver vectors and dot products use.  The
        # value shards themselves may be stored narrower (store_dtype).
        return self.A.dtype

    @property
    def store_dtype(self):
        return self.A.store_dtype

    @property
    def _mask(self):
        # (n, 1) validity mask: g2l == -1 marks padding slots.  Built
        # host-side (numpy) so it is a concrete constant even when first
        # touched under a jit trace — caching a traced value here would
        # leak the tracer into later calls.
        A = self.A
        key, mask = self._mask_cache
        if key is not A:
            host = (np.asarray(A.g2l) >= 0).reshape(self.n, 1)
            mask = jnp.asarray(host.astype(np.dtype(self.dtype)))
            if not isinstance(mask, jax.core.Tracer):
                self._mask_cache = (A, mask)
        return mask

    def _stack(self, v):
        return v.reshape(self.A.nshards, self.A.m_pad, v.shape[1])

    def _flat(self, v):
        return v.reshape(self.n, v.shape[2])

    def _apply(self, x, y, opts: SpmvOpts):
        x2, was1d = as2d(x)
        x2 = x2 * self._mask
        y2 = None
        if y is not None:
            y2 = as2d(y)[0] * self._mask
        nvecs = x2.shape[1]
        run = self.engine.make_matvec(
            overlap=self.overlap, impl=self.impl, interpret=self.interpret,
            nvecs=nvecs, with_y=y is not None, dot_yy=opts.dot_yy,
            dot_xy=opts.dot_xy, dot_xx=opts.dot_xx,
            has_gamma=opts.gamma is not None)
        coefs = pack_coefs(opts, nvecs, self.dtype)
        ys, dots, _ = run(self._stack(x2),
                          self._stack(y2) if y2 is not None else None, coefs)
        out = self._flat(ys)
        if was1d:
            out = out[:, 0]
        return out, dots

    # ---------------------------------------------------------- operator API
    def mv(self, x: jax.Array) -> jax.Array:
        y, _ = self._apply(x, None, SpmvOpts())
        return y

    def mv_fused(self, x, y=None, z=None, opts: SpmvOpts = SpmvOpts()):
        ynew, dots = self._apply(x, y, opts)
        znew = None
        if opts.chain_axpby:
            if z is None:
                raise ValueError("chained axpby requires z")
            delta = 0.0 if opts.delta is None else opts.delta
            eta = 0.0 if opts.eta is None else opts.eta
            znew = delta * z + eta * ynew
        return ynew, znew, dots

    def to_op_space(self, v):
        v2, was1d = as2d(v)
        out = self._flat(self.A.distribute_vec(v2))
        return out[:, 0] if was1d else out

    def from_op_space(self, v):
        v2, was1d = as2d(v)
        out = self.A.collect_vec(self._stack(v2))
        return out[:, 0] if was1d else out


def make_operator(A, **kw):
    if isinstance(A, SellCS):
        return GhostOperator(A, **kw)
    from repro.runtime.engine import HeterogeneousEngine
    if isinstance(A, HeterogeneousEngine):
        return DistOperator(A, **kw)
    raise TypeError(f"cannot wrap {type(A)}")
