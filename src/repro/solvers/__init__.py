"""Sparse iterative solvers built on the GHOST building blocks (paper C7)."""
from repro.solvers.operator import (DistOperator, GhostOperator,
                                    MatrixFreeOperator, make_operator)
from repro.solvers.cg import cg, pipelined_cg
from repro.solvers.minres import minres
from repro.solvers.lanczos import lanczos, lanczos_extrema
from repro.solvers.kpm import kpm_dos_moments, jackson_kernel
from repro.solvers.chebfd import chebfd

__all__ = [
    "DistOperator", "GhostOperator", "MatrixFreeOperator", "make_operator",
    "cg", "pipelined_cg", "minres", "lanczos", "lanczos_extrema",
    "kpm_dos_moments", "jackson_kernel", "chebfd",
]
