"""Sparse iterative solvers built on the GHOST building blocks (paper C7)."""
from repro.solvers.operator import (DistOperator, GhostOperator,
                                    MatrixFreeOperator, make_operator)
from repro.solvers.block import BlockCGState, BlockMinresState
from repro.solvers.cg import (CGResult, CGState, PCGState, PrecondCGState,
                              cg, cg_finalize, cg_init, cg_step,
                              pipelined_cg, pipelined_cg_finalize,
                              pipelined_cg_init, pipelined_cg_step)
from repro.solvers.minres import (MinresResult, MinresState,
                                  PrecondMinresState, minres,
                                  minres_finalize, minres_init, minres_step)
from repro.solvers.precond import (BlockJacobiPreconditioner,
                                   ChebyshevPreconditioner,
                                   make_preconditioner)
from repro.solvers.stepper import merge_columns, run_chunk
from repro.solvers.lanczos import lanczos, lanczos_extrema
from repro.solvers.kpm import kpm_dos_moments, jackson_kernel
from repro.solvers.chebfd import chebfd

__all__ = [
    "DistOperator", "GhostOperator", "MatrixFreeOperator", "make_operator",
    "BlockCGState", "BlockMinresState",
    "CGResult", "CGState", "PCGState", "PrecondCGState", "cg", "cg_init",
    "cg_step", "cg_finalize", "pipelined_cg", "pipelined_cg_init",
    "pipelined_cg_step", "pipelined_cg_finalize",
    "MinresResult", "MinresState", "PrecondMinresState", "minres",
    "minres_init", "minres_step", "minres_finalize",
    "BlockJacobiPreconditioner", "ChebyshevPreconditioner",
    "make_preconditioner", "merge_columns", "run_chunk",
    "lanczos", "lanczos_extrema",
    "kpm_dos_moments", "jackson_kernel", "chebfd",
]
