"""True block-Krylov steppers on the tall-skinny GEMM kernels.

The SolverService packs independent right-hand sides into width-``b``
column blocks, but the column-independent ``cg``/``minres`` steppers
treat that block as a batching trick.  This module is the block
*method*: all columns share **one Krylov space per block**, so every
iteration costs one block SpMV sweep for the whole batch and the
remaining work is tall-skinny dense algebra — Gram matrices ``Vᴴ·W``
through the Kahan-compensated :func:`repro.kernels.ops.tsmttsm` kernel
and basis updates ``V·X`` through :func:`repro.kernels.ops.tsmm`
(the paper's §5.2–5.3 case for row-major block vectors; Kreutzer et
al.'s KPM work shows the node-level win).

* **Block CG** (O'Leary 1980): the step/projection coefficients become
  small ``(b, b)`` systems ``α = (PᴴAP)⁻¹(RᴴR)`` and
  ``β = S_old⁻¹ S_new`` solved by Cholesky with an eigh-pinv fallback —
  clipped eigenvalues *are* the deflation of rank-deficient search
  directions.
* **Block MINRES**: block Lanczos with SVQB orthonormalization of the
  candidate block (Stathopoulos & Wu 2002) and an incremental band QR
  of the block tridiagonal via ``2b×2b`` orthogonal reflections — the
  block generalization of MINRES' Givens recurrence.

Converged columns are **deflated, not dropped**: their residual columns
are masked to zero inside the shared space and the small systems carry
an identity block on their indices, so the live columns keep iterating
in a thinner effective space while the block shape (and the compiled
chunk program) stays fixed.  That is what lets the service's
retire/refill machinery treat block batches like any other batch.

States are stepper-shaped (``it``/``maxiter``/``done`` fields) so
:func:`repro.solvers.stepper.run_chunk` drives them unchanged, and the
field names ``x``/``rr``/``resn`` line up with ``cg_finalize`` /
``minres_finalize``.  Because the carried ``(b, b)`` Gram/reflection
blocks couple all columns, these states can **not** be column-spliced
by ``merge_columns_masked`` — the service refills block batches with a
warm restart instead (see ``runtime/service.py``).

Entry points are not public API: use ``cg(..., block=True)`` /
``minres(..., block=True)`` or ``SolverService.submit(..., block=True)``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.spmv import as2d
from repro.kernels import ops

__all__ = ["BlockCGState", "BlockMinresState",
           "block_cg_init", "block_minres_init",
           "block_cg_body", "block_minres_body"]


# ------------------------------------------------------------- small helpers
def _colsum(v):
    """Per-column squared norm, always real (matches cg._colsum)."""
    if jnp.iscomplexobj(v):
        return jnp.sum((jnp.conj(v) * v).real, axis=0)
    return jnp.sum(v * v, axis=0)


def _mask_cols(v, done):
    """Zero the converged columns of a block vector (deflation mask)."""
    return jnp.where(done[None, :], jnp.zeros((), v.dtype), v)


def _done_eye(done, dtype):
    """Identity block on the converged indices: keeps the small Gram
    systems nonsingular and — because masked columns make the
    cross-terms exactly zero — decoupled from the live columns."""
    return jnp.diag(done.astype(dtype))


def _gram(V, W):
    """``VᴴW`` through the Kahan-compensated tall-skinny kernel."""
    return ops.tsmttsm(V, W, kahan=True)


def _diag_real(G):
    d = jnp.diagonal(G)
    return d.real if jnp.iscomplexobj(d) else d


def _herm(G):
    return 0.5 * (G + jnp.conj(G.T))


def _eigh_pinv_apply(G, B, *, rel_eps):
    """``G⁺ B`` with eigenvalues below ``rel_eps * λ_max`` clipped to a
    zero inverse — rank-deficient directions receive zero weight (the
    deflation half of the Cholesky fallback)."""
    w, U = jnp.linalg.eigh(_herm(G))
    wmax = jnp.maximum(jnp.max(jnp.abs(w)), jnp.finfo(w.dtype).tiny)
    inv = jnp.where(w > rel_eps * wmax, 1.0 / jnp.where(w == 0, 1.0, w), 0.0)
    return U @ (inv[:, None] * (jnp.conj(U.T) @ B))


def _spd_solve(G, B):
    """Solve ``G X = B`` for Hermitian positive semidefinite ``G``.

    Cholesky first (the common well-conditioned case); if the factor or
    the solve is non-finite, a clipped eigh pseudo-inverse takes over —
    both branches are computed under jit, ``jnp.where`` selects.
    """
    L = jnp.linalg.cholesky(G)
    sol_c = jax.scipy.linalg.cho_solve((L, True), B)
    ok = jnp.all(jnp.isfinite(sol_c))
    m = G.shape[0]
    rel_eps = jnp.finfo(_diag_real(G).dtype).eps * m
    sol_e = _eigh_pinv_apply(G, B, rel_eps=rel_eps)
    return jnp.where(ok, sol_c, sol_e)


def _svqb(W, *, rel_eps):
    """SVQB orthonormalization: ``W = V B`` with ``VᴴV ≈ I``.

    Gram through the compensated tsmttsm kernel, eigendecomposition of
    the scaled Gram, basis update through tsmm.  Eigenvalues below
    ``rel_eps * λ_max`` are clipped: the corresponding directions are
    deflated (zero columns in ``V``, zero rows in ``B``), which is how
    a rank-deficient Lanczos candidate block sheds exhausted directions
    without changing the block shape.  A fully zero ``W`` yields
    ``V = 0``, ``B = 0`` (happy breakdown).
    """
    G = _gram(W, W)                               # (m, m) Hermitian PSD
    d = _diag_real(G)
    ds = jnp.where(d <= 0, 1.0, d) ** -0.5        # Jacobi scaling
    dsc = ds.astype(G.dtype)
    Gs = _herm(dsc[:, None] * G * dsc[None, :])
    w, U = jnp.linalg.eigh(Gs)
    wmax = jnp.max(jnp.abs(w))
    keep = w > rel_eps * jnp.maximum(wmax, jnp.finfo(w.dtype).tiny)
    inv_sqrt = jnp.where(keep, jnp.where(w == 0, 1.0, w) ** -0.5, 0.0)
    sqrt_w = jnp.where(keep, jnp.sqrt(jnp.abs(w)), 0.0)
    T = (dsc[:, None] * U) * inv_sqrt[None, :].astype(G.dtype)
    V = ops.tsmm(W, T)                            # orthonormal basis
    B = (sqrt_w[:, None].astype(G.dtype) * jnp.conj(U.T)
         * (1.0 / dsc)[None, :])                  # W ≈ V B
    return V, B


def _rel_eps(dtype, m):
    import numpy as np
    return float(np.finfo(np.dtype(jnp.zeros((), dtype).real.dtype)).eps) * m


# ------------------------------------------------------------------ block CG
class BlockCGState(NamedTuple):
    """Resumable block-CG state (one shared Krylov space per block).

    Dubrulle's residual-orthonormalized variant (BCGrQ): the residual
    block is carried in factored form ``R_k = V_k C_k`` with ``V_k``
    SVQB-orthonormal and ``C_k`` a cumulative ``(b, b)`` triangular-ish
    coefficient — re-orthonormalizing every step is what keeps f32
    blocks from stalling on ill-conditioned operators (vanilla O'Leary
    loses conjugacy).  The ``(b, b)`` carry couples the columns, which
    is why this state cannot be column-spliced (the service
    warm-restarts instead).  ``x``/``rr``/``it``/``done`` line up with
    :class:`repro.solvers.cg.CGState` so ``cg_finalize`` and the
    service's retire bookkeeping work unchanged.
    """

    x: jax.Array              # (n, b) iterate
    v: jax.Array              # (n, b) orthonormal residual basis V_k
    p: jax.Array              # (n, b) scaled search-direction block P~_k
    cmat: jax.Array           # (b, b) cumulative coefficient C_k (R = V C)
    rr: jax.Array             # (b,)   true ||r||^2 (real)
    tol2: jax.Array           # (b,)   per-column squared abs tolerance
    it: jax.Array             # ()     block iteration counter
    maxiter: jax.Array        # ()     block iteration cap
    done: jax.Array           # (b,)   per-column convergence flag


# block states must never be column-spliced: the (b, b) carries couple
# every column (see merge_columns_masked's guard)
BlockCGState.BLOCK_COUPLED = True


def _tol2_floored(tol, b2):
    """Squared relative tolerance with the zero-rhs floor (matches the
    fixed ``cg._tol2`` semantics: a zero column must not yield 0)."""
    tiny = jnp.finfo(b2.dtype).tiny
    bnorm2 = jnp.maximum(_colsum(b2), tiny)
    t = jnp.broadcast_to(jnp.asarray(tol, bnorm2.dtype), bnorm2.shape)
    return jnp.maximum((t * t) * bnorm2, tiny)


def _start_block(op, b, x0):
    """Shared init plumbing: 2-d views, zero-rhs columns solved by
    ``x = 0`` immediately (their residual is then exactly zero)."""
    b2, _ = as2d(b)
    x = jnp.zeros_like(b2) if x0 is None else as2d(x0)[0]
    bzero = _colsum(b2) <= 0
    x = _mask_cols(x, bzero)
    r = b2 - op.mv(x)
    return b2, x, r


def block_cg_init(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
                  tol=1e-8, maxiter: int = 500) -> BlockCGState:
    """Initial block-CG state (op must be SPD; all columns share one
    Krylov space).  ``tol`` may be a scalar or per-column ``(b,)``."""
    b2, x, r = _start_block(op, b, x0)
    tol2 = _tol2_floored(tol, b2)
    V, C = _svqb(r, rel_eps=_rel_eps(r.dtype, b2.shape[1]))
    rr = _colsum(C)                                # ||R e_j||^2 = ||C e_j||^2
    done = rr <= tol2
    return BlockCGState(x=x, v=V, p=V, cmat=C, rr=rr, tol2=tol2,
                        it=jnp.asarray(0),
                        maxiter=jnp.asarray(int(maxiter)), done=done)


def block_cg_body(op, st: BlockCGState) -> BlockCGState:
    """One block-CG iteration (Dubrulle's BCGrQ): one block SpMV, two
    compensated Grams (step Gram + SVQB), three tall-skinny updates, one
    ``(b, b)`` SPD solve.

    With ``R_k = V_k C_k`` the O'Leary recurrences collapse to the
    orthonormal-basis form: ``γ = (P~ᴴAP~)⁻¹``, ``X += P~ (γ C)``,
    ``V_{k+1} ρ = V_k − (AP~) γ`` (SVQB), ``C_{k+1} = ρ C_k``,
    ``P~_{k+1} = V_{k+1} + P~ ρᴴ``.  SVQB's eigenvalue clipping deflates
    exhausted directions (zero columns in ``V``, zero rows in ``ρ``) and
    the Cholesky→eigh-pinv fallback in ``γ`` gives them zero weight, so
    a rank-deficient block keeps iterating in a thinner space."""
    dn = st.done
    m = st.cmat.shape[0]
    rel = _rel_eps(st.v.dtype, m)
    T = op.mv(st.p)                                # one sweep for the block
    G = _herm(_gram(st.p, T))                      # P~ᴴAP~
    gamma = _spd_solve(G, jnp.eye(m, dtype=G.dtype))
    upd = gamma @ st.cmat                          # γ C — per-column steps
    upd = jnp.where(dn[None, :], jnp.zeros((), upd.dtype), upd)
    x = ops.tsmm(st.p, upd, st.x, 1.0, 1.0)        # X += P~ (γ C)
    W = ops.tsmm(T, gamma, st.v, -1.0, 1.0)        # V − (AP~) γ
    Vn, rho = _svqb(W, rel_eps=rel)
    cn = rho @ st.cmat                             # C_{k+1} = ρ C_k
    rr_new = jnp.where(dn, st.rr, _colsum(cn).astype(st.rr.dtype))
    p = ops.tsmm(st.p, jnp.conj(rho.T), Vn, 1.0, 1.0)  # P~' = V' + P~ ρᴴ
    return BlockCGState(x=x, v=Vn, p=p, cmat=cn, rr=rr_new, tol2=st.tol2,
                        it=st.it + 1, maxiter=st.maxiter,
                        done=dn | (rr_new <= st.tol2))


# -------------------------------------------------------------- block MINRES
class BlockMinresState(NamedTuple):
    """Resumable block-MINRES state (block Lanczos + incremental band QR).

    The Lanczos space is shared by every column; the scalar Givens
    cosines/sines of column MINRES become carried ``(b, b)`` blocks of
    the last two orthogonal reflections (``ta``..``td``, ``tb_old``,
    ``td_old``), the rotated rhs ``eta`` becomes the ``(b, b)`` carry
    ``h``, and the per-column residual estimate is the column norm of
    the rejected part ``h_next``.  ``x``/``resn``/``it``/``done`` line
    up with :class:`repro.solvers.minres.MinresState` so
    ``minres_finalize`` works unchanged.
    """

    x: jax.Array              # (n, b) iterate
    v: jax.Array              # (n, b) current Lanczos block V_j
    v_old: jax.Array          # (n, b) V_{j-1}
    w: jax.Array              # (n, b) update-direction block W_j
    w_old: jax.Array          # (n, b) W_{j-1}
    cmat: jax.Array           # (b, b) subdiagonal block C_{j-1}
    ta: jax.Array             # (b, b) reflection blocks of step j-1 ...
    tb: jax.Array
    tc: jax.Array
    td: jax.Array
    tb_old: jax.Array         # (b, b) ... and of step j-2
    td_old: jax.Array
    h: jax.Array              # (b, b) rotated rhs carry
    resn: jax.Array           # (b,)   residual-norm estimate
    tolb: jax.Array           # (b,)   per-column absolute tolerance
    it: jax.Array             # ()
    maxiter: jax.Array        # ()
    done: jax.Array           # (b,)


BlockMinresState.BLOCK_COUPLED = True


def block_minres_init(op, b: jax.Array, x0: Optional[jax.Array] = None, *,
                      tol=1e-8, maxiter: int = 500) -> BlockMinresState:
    """Initial block-MINRES state (op symmetric/Hermitian, possibly
    indefinite).  ``tol`` may be a scalar or per-column ``(b,)``."""
    b2, x, r = _start_block(op, b, x0)
    m = b2.shape[1]
    tiny = jnp.finfo(b2.dtype).tiny
    bnorm = jnp.sqrt(jnp.maximum(_colsum(b2), tiny))
    tolb = jnp.maximum(
        jnp.broadcast_to(jnp.asarray(tol, bnorm.dtype), bnorm.shape) * bnorm,
        tiny)
    V1, B0 = _svqb(r, rel_eps=_rel_eps(r.dtype, m))
    resn = jnp.sqrt(_colsum(B0))                   # true ||r_j|| column-wise
    done = resn <= tolb
    zeros = jnp.zeros_like(b2)
    eye = jnp.eye(m, dtype=B0.dtype)
    zb = jnp.zeros_like(eye)
    return BlockMinresState(
        x=x, v=V1, v_old=zeros, w=zeros, w_old=zeros,
        cmat=zb, ta=eye, tb=zb, tc=zb, td=eye, tb_old=zb, td_old=eye,
        h=B0, resn=resn, tolb=tolb,
        it=jnp.asarray(0), maxiter=jnp.asarray(int(maxiter)), done=done)


def block_minres_body(op, st: BlockMinresState) -> BlockMinresState:
    """One block-MINRES iteration: block Lanczos step (SVQB-orthonormal
    candidate), the new block column of T pushed through the two carried
    reflections, one fresh ``2b×2b`` reflection from a complete QR, and
    the tall-skinny update of the direction block and iterate."""
    m = st.h.shape[0]
    rel = _rel_eps(st.v.dtype, m)
    Q = op.mv(st.v)                                # one sweep for the block
    Aj = _herm(_gram(st.v, Q))                     # diagonal block T_jj
    U = (Q - ops.tsmm(st.v, Aj)
         - ops.tsmm(st.v_old, jnp.conj(st.cmat.T)))
    # local reorthogonalization (second classical Gram-Schmidt pass
    # against the two in-band blocks): without it the f32 block Lanczos
    # basis drifts and the residual stalls an order above tol.  The
    # V_j correction folds into the diagonal block to keep T consistent.
    Ac = _gram(st.v, U)
    U = U - ops.tsmm(st.v, Ac)
    Aj = _herm(Aj + Ac)
    U = U - ops.tsmm(st.v_old, _gram(st.v_old, U))
    Vn, Cj = _svqb(U, rel_eps=rel)                 # U = V_{j+1} C_j

    # band column j of T through the two carried reflections
    CprevH = jnp.conj(st.cmat.T)
    tmp = st.td_old @ CprevH
    R3 = st.tb_old @ CprevH
    R2 = st.ta @ tmp + st.tb @ Aj
    d = st.tc @ tmp + st.td @ Aj
    # fresh reflection annihilating C_j under d (block Givens)
    M2 = jnp.concatenate([d, Cj], axis=0)          # (2b, b)
    Qc, Rfull = jnp.linalg.qr(M2, mode="complete")
    R1 = Rfull[:m]
    QH = jnp.conj(Qc.T)
    ta_n, tb_n = QH[:m, :m], QH[:m, m:]
    tc_n, td_n = QH[m:, :m], QH[m:, m:]
    h_keep = ta_n @ st.h
    h_next = tc_n @ st.h

    # W_j = (V_j - W_{j-1} R2 - W_{j-2} R3) R1^{-1}; a rank-deficient R1
    # (exhausted directions) gets unit diagonal stand-ins — their h_keep
    # weight is zero because the QR put nothing on those rows
    dg = _diag_real(R1)
    good = jnp.abs(dg) > rel * jnp.maximum(jnp.max(jnp.abs(dg)),
                                           jnp.finfo(dg.dtype).tiny)
    R1s = R1 + jnp.diag(jnp.where(good, 0.0, 1.0).astype(R1.dtype))
    R1inv = jax.scipy.linalg.solve_triangular(
        R1s, jnp.eye(m, dtype=R1.dtype), lower=False)
    R1inv = jnp.where(good[:, None] & good[None, :], R1inv,
                      jnp.zeros((), R1inv.dtype))
    cand = st.v - ops.tsmm(st.w, R2) - ops.tsmm(st.w_old, R3)
    Wn = ops.tsmm(cand, R1inv)

    upd = jnp.where(st.done[None, :], jnp.zeros((), st.h.dtype), h_keep)
    x = ops.tsmm(Wn, upd, st.x, 1.0, 1.0)          # X += W_j (kept rhs part)
    resn_col = jnp.sqrt(_colsum(h_next))
    resn = jnp.where(st.done, st.resn, resn_col.astype(st.resn.dtype))
    return BlockMinresState(
        x=x, v=Vn, v_old=st.v, w=Wn, w_old=st.w,
        cmat=Cj, ta=ta_n, tb=tb_n, tc=tc_n, td=td_n,
        tb_old=st.tb, td_old=st.td, h=h_next,
        resn=resn, tolb=st.tolb,
        it=st.it + 1, maxiter=st.maxiter,
        done=st.done | (resn <= st.tolb))
