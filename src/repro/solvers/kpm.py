"""Kernel Polynomial Method (paper section 1.3 / [24]).

KPM computes the spectral density (DOS) of a large sparse Hamiltonian from
Chebyshev moments mu_m = <v| T_m(As) |v> of the *scaled* operator
As = (A - gamma I) / a with spectrum in [-1, 1].

This is THE showcase for the paper's fused augmented SpMV: the Chebyshev
recurrence

    w_{m+1} = 2 As w_m - w_{m-1}
            = (2/a) (A - gamma I) w_m - w_{m-1}

is exactly ``y = alpha (A - gamma I) x + beta y`` with alpha = 2/a,
beta = -1, and the two moments per sweep come from the chained dots
<y, y> (-> mu_{2m+2}) and <x, y> (-> mu_{2m+1}).  The paper reports a 2.5x
solver-level gain from this fusion + block vectors; our roofline study
reproduces the traffic accounting (benchmarks/fig_kpm_fusion.py).

Block vectors: R stochastic probe vectors are processed per sweep
(SpMMV), the standard KPM estimator for the DOS.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spmv import SpmvOpts


def kpm_dos_moments(op, n_moments: int, *, n_probes: int = 4,
                    spectrum: Optional[Tuple[float, float]] = None,
                    seed: int = 0, fused: bool = True) -> jax.Array:
    """Stochastic Chebyshev moments mu_0..mu_{M-1} (averaged over probes).

    ``fused=True`` uses the augmented SpMV (two moments per sweep);
    ``fused=False`` runs the naive three-kernel variant (for the fusion
    benchmark).
    """
    if spectrum is None:
        from repro.solvers.lanczos import lanczos_extrema
        lo, hi = lanczos_extrema(op)
    else:
        lo, hi = spectrum
    a = (hi - lo) / 2.0
    gamma = (hi + lo) / 2.0
    alpha2 = 2.0 / a

    n = op.n
    key = jax.random.PRNGKey(seed)
    # Rademacher probes
    v0 = jnp.where(jax.random.bernoulli(key, 0.5, (n, n_probes)), 1.0, -1.0
                   ).astype(jnp.float32) / np.sqrt(n)

    M = n_moments
    half = (M + 1) // 2
    mus = jnp.zeros((M + 2, n_probes), jnp.float32)

    # w0 = v, w1 = As v  (alpha = 1/a for the first application)
    w0 = v0
    w1, _, d = op.mv_fused(
        w0, opts=SpmvOpts(alpha=1.0 / a, gamma=gamma, dot_xx=True, dot_xy=True))
    # dots may accumulate wider than the vectors (f64 under x64); cast
    # back to the moment dtype at this boundary
    mus = mus.at[0].set(d[2].astype(mus.dtype))            # <v,v>
    mus = mus.at[1].set(d[1].astype(mus.dtype))            # <v, As v>

    def step(carry, _):
        w0, w1, mu0, mu1 = carry
        if fused:
            w2, _, dots = op.mv_fused(
                w1, y=w0,
                opts=SpmvOpts(alpha=alpha2, beta=-1.0, gamma=gamma,
                              dot_yy=True, dot_xy=True))
            m_odd = 2.0 * dots[1].astype(mu1.dtype) - mu1   # mu_{2m+1}
            m_even = 2.0 * dots[0].astype(mu0.dtype) - mu0  # mu_{2m+2}
            return (w1, w2, mu0, mu1), (m_odd, m_even)
        else:
            Aw = op.mv(w1)
            w2 = alpha2 * (Aw - gamma * w1) - w0
            m_odd = 2.0 * jnp.sum(w1 * w2, 0) - mu1
            m_even = 2.0 * jnp.sum(w2 * w2, 0) - mu0
            return (w1, w2, mu0, mu1), (m_odd, m_even)

    carry = (w0, w1, mus[0], mus[1])
    _, (m_odds, m_evens) = jax.lax.scan(step, carry, None, length=half)
    # interleave: mu_3, mu_4, mu_5, mu_6, ... starting at index 3? careful:
    # iteration m=1..half produces mu_{2m+1}, mu_{2m+2}
    idx_odd = 2 * jnp.arange(half) + 3
    idx_even = 2 * jnp.arange(half) + 4
    # mu_2 = 2<w1,w1> - mu_0
    w1n = jnp.sum(w1 * w1, 0)
    mus = mus.at[2].set((2.0 * w1n - mus[0]).astype(mus.dtype))
    mus = mus.at[idx_odd].set(m_odds)
    mus = mus.at[idx_even].set(m_evens)
    return jnp.mean(mus[:M], axis=1)


def jackson_kernel(M: int) -> np.ndarray:
    """Jackson damping factors g_m (standard KPM smoothing)."""
    m = np.arange(M)
    return ((M - m + 1) * np.cos(np.pi * m / (M + 1))
            + np.sin(np.pi * m / (M + 1)) / np.tan(np.pi / (M + 1))) / (M + 1)


def kpm_dos(op, n_moments: int = 64, n_bins: int = 128, **kw):
    """Reconstruct the DOS on a grid from damped moments."""
    if "spectrum" in kw and kw["spectrum"] is not None:
        lo, hi = kw["spectrum"]
    else:
        from repro.solvers.lanczos import lanczos_extrema
        lo, hi = lanczos_extrema(op)
        kw["spectrum"] = (lo, hi)
    mus = np.asarray(kpm_dos_moments(op, n_moments, **kw))
    g = jackson_kernel(n_moments)
    xg = np.linspace(-0.999, 0.999, n_bins)
    tm = np.cos(np.arange(n_moments)[:, None] * np.arccos(xg)[None, :])
    mu0 = mus[0] if mus[0] != 0 else 1.0
    rho = (mus[0] * tm[0] + 2 * (g[1:, None] * mus[1:, None] * tm[1:]).sum(0))
    rho /= (np.pi * np.sqrt(1 - xg**2)) * mu0
    a = (hi - lo) / 2
    energies = xg * a + (hi + lo) / 2
    return energies, rho / a          # Jacobian: rho(E) dE = rho(x) dx
