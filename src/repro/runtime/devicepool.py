"""Device classification and roofline-weighted throughput (GHOST 4.1).

GHOST assigns each process a *weight* proportional to the device's
attainable memory bandwidth, because SpMV is bandwidth-bound at its code
balance (6 bytes/flop for double + 32-bit indices).  ``DevicePool``
reproduces that policy on a jax platform: it groups ``jax.devices()`` into
classes by ``device_kind``, attaches per-class bandwidth/peak-flop specs
(known parts from a table, unknown parts from a conservative default), and
turns :func:`repro.launch.costmodel.spmv_cost` roofline terms into
per-device throughput estimates -> split weights.

The weights are *estimates to start from*; the engine's rebalance loop
(:meth:`repro.runtime.split.SplitPlan.rebalance`) refines them online from
measured per-shard SpMV times, which is how GHOST tolerates model error
("automatic performance-model-guided data distribution ... corrected by
runtime measurements").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.launch.costmodel import Cost, spmv_cost
from repro.launch.mesh import HW

__all__ = ["DeviceClass", "DevicePool", "KNOWN_DEVICE_SPECS"]


# Attainable (not peak-datasheet) numbers: mem_bw in B/s, peak_flops in
# FLOP/s.  The TPU entries come from launch.mesh.HW; the CPU/GPU/PHI
# entries are the paper's Table 1 reference node (Emmy: SNB socket 50 GB/s,
# K20 GPU and Xeon Phi ~150 GB/s each) so the paper's experiments are
# expressible as a synthetic pool.  Matching is by substring of the
# device_kind, case-insensitive, longest match wins.
KNOWN_DEVICE_SPECS: Dict[str, Dict[str, float]] = {
    "tpu v5":  dict(mem_bw=HW["hbm_bw"], peak_flops=HW["peak_flops_bf16"]),
    "tpu v4":  dict(mem_bw=1.2e12, peak_flops=275e12),
    "tpu":     dict(mem_bw=HW["hbm_bw"], peak_flops=HW["peak_flops_bf16"]),
    "gpu":     dict(mem_bw=150e9, peak_flops=1.17e12),   # paper's K20
    "phi":     dict(mem_bw=150e9, peak_flops=1.0e12),    # paper's Xeon Phi
    "cpu":     dict(mem_bw=50e9, peak_flops=0.43e12),    # paper's SNB socket
}
_DEFAULT_SPEC = dict(mem_bw=50e9, peak_flops=0.5e12)


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """One class of identical devices inside a pool."""

    name: str                 # e.g. "TPU v5e", "cpu", "gpu"
    count: int                # devices of this class (contiguous in pool order)
    mem_bw: float             # attainable HBM bandwidth, B/s
    peak_flops: float         # peak compute, FLOP/s

    def time_for(self, cost: Cost) -> float:
        """Roofline execution-time estimate of ``cost`` on ONE device."""
        t_mem = cost.hbm_bytes / self.mem_bw
        t_cmp = cost.flops / self.peak_flops
        return max(t_mem, t_cmp)

    def spmv_throughput(self, cost: Cost) -> float:
        """Attainable flop rate on ``cost`` (bandwidth-bound for SpMV)."""
        return cost.flops / max(self.time_for(cost), 1e-30)


def _lookup_spec(kind: str, platform: str = "") -> Dict[str, float]:
    """Longest substring match on device_kind, then on platform.

    Real accelerator kind strings rarely contain their platform name
    (e.g. CUDA reports 'NVIDIA A100-SXM4-40GB'), so the platform
    fallback is what routes unknown GPUs to the 'gpu' spec instead of
    the conservative default.
    """
    for probe in (kind.lower(), platform.lower()):
        best = None
        for key in KNOWN_DEVICE_SPECS:
            if probe and key in probe and (best is None or
                                           len(key) > len(best)):
                best = key
        if best:
            return KNOWN_DEVICE_SPECS[best]
    return dict(_DEFAULT_SPEC)


class DevicePool:
    """An ordered pool of devices grouped into weighted classes.

    Order matters: device ``i`` of the pool is device ``i`` of the mesh
    axis the engine shards over, so ``device_weights()`` lines up with
    shard ids.
    """

    def __init__(self, classes: Sequence[DeviceClass]):
        if not classes:
            raise ValueError("empty device pool")
        self.classes = tuple(classes)

    # ------------------------------------------------------------- build
    @classmethod
    def detect(cls, devices=None) -> "DevicePool":
        """Classify ``jax.devices()`` (or an explicit list) by device_kind."""
        import jax
        devices = list(jax.devices()) if devices is None else list(devices)
        classes: List[DeviceClass] = []
        for d in devices:
            kind = getattr(d, "device_kind", None) or d.platform
            if classes and classes[-1].name == kind:
                classes[-1] = dataclasses.replace(
                    classes[-1], count=classes[-1].count + 1)
            else:
                spec = _lookup_spec(kind, getattr(d, "platform", ""))
                classes.append(DeviceClass(name=kind, count=1, **spec))
        return cls(classes)

    @classmethod
    def from_bandwidths(cls, bws: Sequence[float], *,
                        names: Optional[Sequence[str]] = None,
                        peak_flops: float = 1e12) -> "DevicePool":
        """Synthetic pool, one device per bandwidth entry (GB/s accepted:
        values < 1e6 are treated as GB/s).  Used by benchmarks/tests to
        reproduce the paper's CPU(50) + GPU(150) + PHI(150) node."""
        classes = []
        for i, bw in enumerate(bws):
            bw = float(bw) * (1e9 if bw < 1e6 else 1.0)
            name = names[i] if names else f"dev{i}"
            classes.append(DeviceClass(name=name, count=1, mem_bw=bw,
                                       peak_flops=peak_flops))
        return cls(classes)

    # ------------------------------------------------------------ queries
    @property
    def ndevices(self) -> int:
        return sum(c.count for c in self.classes)

    def device_classes(self) -> List[DeviceClass]:
        """Per-device class, expanded in pool order (len == ndevices)."""
        out: List[DeviceClass] = []
        for c in self.classes:
            out.extend([c] * c.count)
        return out

    def device_weights(self, *, nnz: int = 0, nrows: int = 0,
                       val_bytes: int = 4, idx_bytes: int = 4,
                       nvecs: int = 1) -> np.ndarray:
        """Per-device split weights ~ attainable SpMV throughput.

        With no matrix statistics this degrades to pure bandwidth
        proportionality (the paper's default).  With ``nnz``/``nrows`` the
        weight uses the full roofline (a compute-starved device class can
        cap below its bandwidth share for very wide block vectors).
        """
        if nnz and nrows:
            cost = spmv_cost(nnz, nrows, val_bytes=val_bytes,
                             idx_bytes=idx_bytes, nvecs=nvecs)
            w = [c.spmv_throughput(cost) for c in self.device_classes()]
        else:
            w = [c.mem_bw for c in self.device_classes()]
        w = np.asarray(w, np.float64)
        return w / w.sum()

    def aggregate_spmv_gflops(self, *, val_bytes: int = 8,
                              idx_bytes: int = 4, nvecs: int = 1,
                              nnzr: float = 64.0) -> float:
        """Predicted aggregate Gflop/s at the SpMV code balance — the
        paper's Table 1 prediction (sum of bw / 6 bytes-per-flop)."""
        nnz = int(nnzr * 1000)
        cost = spmv_cost(nnz, 1000, val_bytes=val_bytes,
                         idx_bytes=idx_bytes, nvecs=nvecs)
        return sum(c.spmv_throughput(cost) for c in self.device_classes()) / 1e9

    def __repr__(self) -> str:
        parts = ", ".join(f"{c.count}x{c.name}@{c.mem_bw / 1e9:.0f}GB/s"
                          for c in self.classes)
        return f"DevicePool({parts})"
