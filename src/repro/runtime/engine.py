"""The heterogeneous execution engine (GHOST sections 4.1 + 4.2).

``HeterogeneousEngine`` is the piece that *decides* and *schedules*: it
classifies the available devices (:class:`DevicePool`), derives
roofline-proportional split weights, builds the C-aligned
:class:`SplitPlan` and the distributed SELL-C-sigma matrix for it, and
exposes pipelined (task-mode-overlapped) matvecs that the solvers consume
through :class:`repro.solvers.operator.DistOperator` unchanged.

Rebalance loop: ``engine.rebalance(times)`` takes measured per-shard SpMV
times, performs one hill-climb step on the weights and redistributes the
matrix.  With no measurements it falls back to the pool's roofline model,
making the call idempotent on a perfectly modeled pool (a property the
tests pin down).

Typical use::

    eng = HeterogeneousEngine.from_coo(r, c, v, n, mesh=mesh, C=32)
    y, dots = eng.spmv(x, opts=SpmvOpts(dot_xy=True))     # global space
    res = cg(eng.operator(), b_op)                        # solver, unchanged
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execution
from repro.core.distributed import DistSellCS, dist_from_coo
from repro.core.spmv import SpmvOpts, as2d, pack_coefs
from repro.launch.costmodel import spmv_cost
from repro.runtime.devicepool import DevicePool
from repro.runtime.pipeline import init_staging, make_pipeline_spmv
from repro.runtime.split import SplitPlan, plan_split

__all__ = ["HeterogeneousEngine"]


class HeterogeneousEngine:
    """Cost-model-driven work splitting + overlapped halo pipeline."""

    def __init__(self, rows, cols, vals, nrows: int, *,
                 mesh=None, axis: str = "data",
                 pool: Optional[DevicePool] = None,
                 weights: Optional[Sequence[float]] = None,
                 nshards: Optional[int] = None,
                 C: int = 32, sigma: int = 1, w_align: int = 1,
                 by_nnz: bool = True, dtype=None, store_dtype=None):
        self._rows = np.asarray(rows, np.int64)
        self._cols = np.asarray(cols, np.int64)
        self._vals = np.asarray(vals) if dtype is None else \
            np.asarray(vals).astype(dtype)
        self.nrows = int(nrows)
        self.C, self.sigma, self.w_align = C, sigma, w_align
        # matrix values shard-stored narrower than the compute dtype
        # (None = single-dtype); vectors/halo stay in the compute dtype
        self.store_dtype = None if store_dtype is None \
            else jnp.dtype(store_dtype)
        self.axis = axis

        self.pool = pool if pool is not None else DevicePool.detect()
        if mesh is None:
            ndev = nshards or self.pool.ndevices
            devs = np.array(jax.devices()[:ndev])
            mesh = jax.sharding.Mesh(devs, (axis,))
        self.mesh = mesh
        self.nshards = (int(nshards) if nshards
                        else int(np.prod(mesh.devices.shape)))
        mesh_size = int(np.prod(mesh.devices.shape))
        if self.nshards != mesh_size:
            raise ValueError(
                f"nshards={self.nshards} must equal the mesh size "
                f"({mesh_size} devices); pass a matching mesh or run in a "
                f"process with enough devices "
                f"(XLA_FLAGS=--xla_force_host_platform_device_count=N)")

        vb = self._val_bytes()
        if weights is None:
            w = self.pool.device_weights(nnz=len(self._vals),
                                         nrows=self.nrows, val_bytes=vb)
            # pool size and shard count may differ (e.g. tests); tile/trim
            w = np.resize(w, self.nshards)
        else:
            w = np.asarray(weights, np.float64)
            if len(w) != self.nshards:
                raise ValueError(f"expected {self.nshards} shard weights, "
                                 f"got {len(w)}")
        rowlen = None
        if by_nnz:
            rowlen = np.zeros(self.nrows, np.int64)
            np.add.at(rowlen, self._rows, 1)
        self.plan: SplitPlan = plan_split(self.nrows, w, align=C,
                                          rowlen=rowlen)
        self._build()

    # ------------------------------------------------------------ plumbing
    @classmethod
    def from_coo(cls, rows, cols, vals, nrows, **kw) -> "HeterogeneousEngine":
        return cls(rows, cols, vals, nrows, **kw)

    def _val_bytes(self) -> int:
        """Bytes per stored matrix value — the roofline traffic number.

        Uses the *storage* dtype: a bf16-stored matrix moves half the
        value bytes of its f32 compute dtype, and the cost-model split
        weights must see that.
        """
        if self.store_dtype is not None:
            return int(jnp.dtype(self.store_dtype).itemsize)
        return int(self._vals.dtype.itemsize)

    def _build(self) -> None:
        self.A: DistSellCS = dist_from_coo(
            self._rows, self._cols, self._vals, self.nrows,
            nshards=self.plan.nshards, C=self.C, sigma=self.sigma,
            w_align=self.w_align, store_dtype=self.store_dtype,
            ranges=self.plan.ranges)
        self._matvec_cache: Dict[tuple, object] = {}

    def make_matvec(self, *, overlap: bool = True, impl: str = "ref",
                    interpret: Optional[bool] = None, nvecs: int = 1,
                    with_y: bool = False, dot_yy: bool = False,
                    dot_xy: bool = False, dot_xx: bool = False,
                    has_gamma: bool = False, double_buffer: bool = False):
        """Cached, jitted pipelined matvec (see make_pipeline_spmv).

        ``interpret=None`` resolves through the central execution policy
        *here*, before the cache key, so an ``execution.force`` scope (or
        the backend auto-detection) picks the right compiled variant and
        distinct modes never share a trace.  The policy's ``fallback``
        flag is part of the key too: it changes the traced program (the
        shard stages' degrade-to-reference decision), so a
        ``force(fallback=False)`` scope must not reuse a degraded trace.
        The value-shard storage dtype and the compute dtype join the key
        for the same reason: they change the traced program (in-register
        upcast vs native accumulate) and must never share a trace.
        """
        interpret = execution.resolve_interpret(interpret)
        key = (overlap, impl, interpret,
               execution.current_policy().fallback, nvecs, with_y,
               dot_yy, dot_xy, dot_xx, has_gamma, double_buffer,
               str(self.A.store_dtype), str(self.A.dtype))
        fn = self._matvec_cache.get(key)
        if fn is None:
            fn = make_pipeline_spmv(
                self.A, self.mesh, self.axis, overlap=overlap, impl=impl,
                interpret=interpret, nvecs=nvecs, with_y=with_y,
                dot_yy=dot_yy, dot_xy=dot_xy, dot_xx=dot_xx,
                has_gamma=has_gamma, double_buffer=double_buffer)
            self._matvec_cache[key] = fn
        return fn

    def init_staging(self, nvecs: int = 1, dtype=None) -> jax.Array:
        # staging holds *vector* (halo) data: compute dtype, never the
        # narrower matrix storage dtype
        return init_staging(self.A, nvecs, dtype or self.A.dtype)

    # ------------------------------------------------------------- spmv API
    def spmv(self, x: jax.Array, y: Optional[jax.Array] = None, *,
             opts: SpmvOpts = SpmvOpts(), overlap: bool = True,
             impl: str = "ref", interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, Optional[jax.Array]]:
        """Global original-space fused SpM(M)V through the pipeline.

        Convenience mirror of ``core.distributed.dist_spmv`` running on the
        engine's split + overlap schedule.  Returns (y, dots).
        """
        x2, was1d = as2d(x)
        nvecs = x2.shape[1]
        xs = self.A.distribute_vec(x2)
        ys = None
        if y is not None:
            ys = self.A.distribute_vec(as2d(y)[0])
        run = self.make_matvec(overlap=overlap, impl=impl,
                               interpret=interpret, nvecs=nvecs,
                               with_y=y is not None,
                               dot_yy=opts.dot_yy, dot_xy=opts.dot_xy,
                               dot_xx=opts.dot_xx,
                               has_gamma=opts.gamma is not None)
        coefs = pack_coefs(opts, nvecs, self.A.dtype)
        ys_out, dots, _ = run(xs, ys, coefs)
        out = self.A.collect_vec(ys_out)
        if was1d:
            out = out[:, 0]
        return out, dots

    def operator(self, **kw):
        """Solver-facing distributed operator (CG/Lanczos/KPM unchanged)."""
        from repro.solvers.operator import DistOperator
        return DistOperator(self, **kw)

    # ------------------------------------------------------- rebalance loop
    def modeled_shard_times(self, nvecs: int = 1) -> np.ndarray:
        """Roofline time of each shard's SpMV on its assigned device."""
        classes = self.pool.device_classes()
        vb = self._val_bytes()
        times = []
        for i, (s, e) in enumerate(self.plan.ranges):
            cost = spmv_cost(int(self.A.shard_nnz[i]), max(e - s, 1),
                             val_bytes=vb, nvecs=nvecs)
            times.append(classes[i % len(classes)].time_for(cost))
        return np.asarray(times)

    def modeled_iter_seconds(self, nvecs: int = 1) -> float:
        """Roofline estimate of one block-SpMV sweep: the slowest shard.

        The halo pipeline overlaps remote staging with local compute, so
        one distributed matvec takes (about) the critical-path shard
        time.  One Krylov iteration is one sweep plus vector work the
        sweep dominates, which makes this a serviceable *cold-start*
        seconds-per-iteration hint for deadline scheduling — the serving
        frontend replaces it with measured chunk times as soon as it has
        any (see ``SolverService._run_chunk``).
        """
        return float(np.max(self.modeled_shard_times(nvecs=nvecs)))

    def rebalance(self, measured_times: Optional[Sequence[float]] = None, *,
                  step: float = 0.5) -> "HeterogeneousEngine":
        """One hill-climb step on the split weights; redistributes A.

        ``measured_times[i]`` = observed SpMV seconds of shard ``i`` under
        the current plan (e.g. timed around ``make_matvec`` calls, or from
        a profiler).  Falls back to :meth:`modeled_shard_times`.  Returns
        ``self`` (mutated) for chaining.
        """
        t = (np.asarray(measured_times, np.float64)
             if measured_times is not None else self.modeled_shard_times())
        new_plan = self.plan.rebalance(t, step=step)
        if new_plan.ranges == self.plan.ranges:
            # at the fixed point (block granularity absorbed the weight
            # nudge): keep the matrix and the compiled matvecs
            self.plan = new_plan
            return self
        self.plan = new_plan
        self._build()
        return self

    def __repr__(self) -> str:
        shares = "/".join(f"{w:.3f}" for w in self.plan.weights)
        return (f"HeterogeneousEngine(n={self.nrows}, shards={self.nshards}, "
                f"gen={self.plan.generation}, weights={shares}, "
                f"pool={self.pool!r})")
