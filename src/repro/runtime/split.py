"""Weight-proportional work splitting with online rebalance (GHOST 4.1).

``plan_split`` turns per-device weights (usually from
:meth:`repro.runtime.devicepool.DevicePool.device_weights`) into contiguous,
C-aligned, non-empty row ranges via the apportionment partitions added to
:mod:`repro.core.partition`.  ``SplitPlan.rebalance`` performs ONE
hill-climb step (:func:`repro.launch.hillclimb.proportional_step`) on the
weights given measured per-shard SpMV times — call it once per solver
outer-iteration and the split converges to equal per-shard time, which is
GHOST's bandwidth-weighted ideal discovered online instead of assumed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import partition as part

__all__ = ["SplitPlan", "plan_split"]


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """A concrete assignment of row blocks to pool devices."""

    nrows: int
    weights: Tuple[float, ...]            # per-shard, sum == 1
    ranges: Tuple[Tuple[int, int], ...]   # contiguous [start, end) per shard
    align: int                            # boundary alignment (SELL C)
    by_nnz: bool                          # nnz- vs row-proportional
    rowlen: Optional[np.ndarray] = None   # kept for nnz-aware re-splits
    generation: int = 0                   # rebalance steps taken so far

    # ------------------------------------------------------------ queries
    @property
    def nshards(self) -> int:
        return len(self.ranges)

    @property
    def sizes(self) -> np.ndarray:
        return np.array([e - s for (s, e) in self.ranges], np.int64)

    def shard_nnz(self) -> np.ndarray:
        if self.rowlen is None:
            raise ValueError("plan was built without rowlen")
        return np.array([int(self.rowlen[s:e].sum()) for s, e in self.ranges],
                        np.int64)

    def imbalance(self, times: Sequence[float]) -> float:
        """max/mean of per-shard times — 1.0 is a perfect split."""
        t = np.asarray(times, np.float64)
        return float(t.max() / t.mean())

    # ---------------------------------------------------------- rebalance
    def rebalance(self, measured_times: Sequence[float], *,
                  step: float = 0.5) -> "SplitPlan":
        """One hill-climb step toward equal per-shard time.

        ``measured_times[i]`` is the observed SpMV time of shard ``i``
        under THIS plan.  Returns a new plan; the matrix must be
        redistributed to follow it (the engine does this lazily).
        """
        from repro.launch.hillclimb import proportional_step
        w = proportional_step(np.asarray(self.weights, np.float64),
                              measured_times, step=step)
        return plan_split(self.nrows, w, align=self.align,
                          rowlen=self.rowlen if self.by_nnz else None,
                          generation=self.generation + 1)


def plan_split(nrows: int, weights: Sequence[float], *, align: int = 1,
               rowlen: Optional[np.ndarray] = None,
               generation: int = 0) -> SplitPlan:
    """Build a :class:`SplitPlan`.

    ``rowlen`` (per-row nonzero counts) switches to the paper's
    nnz-proportional criterion; otherwise rows are apportioned directly.
    """
    w = np.asarray(weights, np.float64)
    if (w <= 0).any():
        raise ValueError("weights must be positive")
    w = w / w.sum()
    if rowlen is not None:
        rowlen = np.asarray(rowlen)
        ranges: List[Tuple[int, int]] = part.apportioned_nnz_partition(
            rowlen, w, align=align)
    else:
        ranges = part.apportioned_row_partition(nrows, w, align=align)
    return SplitPlan(nrows=nrows, weights=tuple(float(x) for x in w),
                     ranges=tuple(ranges), align=align,
                     by_nnz=rowlen is not None, rowlen=rowlen,
                     generation=generation)
