"""Heterogeneous execution engine (GHOST sections 4.1-4.2).

The paper's headline capability — *truly heterogeneous* sparse linear
algebra — is the combination of three pieces, reproduced here:

* :mod:`repro.runtime.devicepool` — classify available devices into
  weighted classes with roofline-derived SpMV throughput estimates
  (GHOST's bandwidth-weighted work distribution, Table 1);
* :mod:`repro.runtime.split` — weight-proportional, C-aligned row-block
  splitting with a measured-time auto-rebalance hook (one hill-climb
  step per call);
* :mod:`repro.runtime.pipeline` / :mod:`repro.runtime.engine` — the
  overlapped halo pipeline (paper task-mode, Fig. 5) with double-buffered
  halo staging, wrapped in :class:`HeterogeneousEngine` so the solvers
  run on a distributed operator unchanged.
"""
from repro.runtime.devicepool import DeviceClass, DevicePool
from repro.runtime.split import SplitPlan, plan_split
from repro.runtime.engine import HeterogeneousEngine

__all__ = [
    "DeviceClass", "DevicePool", "SplitPlan", "plan_split",
    "HeterogeneousEngine",
]
