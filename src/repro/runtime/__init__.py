"""Heterogeneous execution engine (GHOST sections 4.1-4.2).

The paper's headline capability — *truly heterogeneous* sparse linear
algebra — is the combination of three pieces, reproduced here:

* :mod:`repro.runtime.devicepool` — classify available devices into
  weighted classes with roofline-derived SpMV throughput estimates
  (GHOST's bandwidth-weighted work distribution, Table 1);
* :mod:`repro.runtime.split` — weight-proportional, C-aligned row-block
  splitting with a measured-time auto-rebalance hook (one hill-climb
  step per call);
* :mod:`repro.runtime.pipeline` / :mod:`repro.runtime.engine` — the
  overlapped halo pipeline (paper task-mode, Fig. 5) with double-buffered
  halo staging, wrapped in :class:`HeterogeneousEngine` so the solvers
  run on a distributed operator unchanged;
* :mod:`repro.runtime.service` — :class:`SolverService`, a
  continuous-batching solve frontend (queued requests coalesced into
  fixed-width block solves, converged columns retired and refilled
  between stepper chunks) over a :class:`MatrixRegistry` that caches the
  per-matrix setup (SELL-C-sigma build, operator, tile knobs, spectral
  bounds).  See ``docs/serving.md``.
"""
from repro.runtime.devicepool import DeviceClass, DevicePool
from repro.runtime.split import SplitPlan, plan_split
from repro.runtime.engine import HeterogeneousEngine
from repro.runtime.service import (MatrixRegistry, ServiceResult,
                                   SolverService, SolveTicket)

__all__ = [
    "DeviceClass", "DevicePool", "SplitPlan", "plan_split",
    "HeterogeneousEngine", "MatrixRegistry", "ServiceResult",
    "SolverService", "SolveTicket",
]
