"""SLO-aware continuous-batching solver service on the stepper solvers.

GHOST's pitch (C2 + C5) is that many independent sparse solves should be
fed through one high-intensity block-vector kernel stream with the
runtime doing intelligent resource management.  This module is that
runtime for the solve workload:

* :class:`MatrixRegistry` caches the expensive per-matrix setup —
  SELL-C-sigma conversion/permutation (or a prebuilt
  :class:`~repro.runtime.engine.HeterogeneousEngine` for sharded
  matrices), the solver-facing operator, optional autotuned tile knobs
  via :mod:`repro.core.execution`, and Lanczos spectral bounds for
  KPM/ChebFD requests.  Registering the same name twice is a cache hit.
  The cached bounds double as a *free difficulty signal*:
  :meth:`MatrixRegistry.predicted_iters` turns ``(kappa, tol)`` into an
  iteration-count estimate the service buckets and schedules by.

* :class:`SolverService` accepts asynchronous solve requests (matrix
  handle, right-hand side, solver kind, tolerance, optional
  preconditioner spec, optional ``deadline=`` / ``priority=``) and
  coalesces them into block solves per
  ``(matrix, solver, dtype, precond, store_dtype, block, bucket)`` key —
  preconditioned and plain requests on the same matrix batch
  separately, requests against different value-*storage* dtypes batch
  separately, block-Krylov and column batches never mix, and (with
  ``admission="bucketed"``) requests with very different *predicted
  difficulty* never share a batch either, so a 30-iteration easy solve
  is never scheduled behind a 10k-iteration straggler.
  Each :meth:`~SolverService.step` advances one (bucketed) or every
  (fifo) active batch by one jitted k-iteration chunk, retires
  converged / cancelled / deadline-expired columns, and refills the
  freed slots from the queue — *continuous batching*, possible because
  per-column convergence is independent in column CG/MINRES and the
  stepper state carries it.

Request lifecycle (each ticket takes exactly one terminal transition)::

                 submit()
                    │  full per-key queue?
                    ├────────────────────► rejected
                    ▼
                 queued  ──cancel()──────► cancelled
                    │  deadline passed
                    │  at a refill? ─────► expired
                    ▼
                 running ──cancel()──┐ (at the next chunk boundary)
                    │                └───► cancelled
                    │  deadline passed
                    │  at retire? ───────► expired   (best-effort x)
                    ▼
                  done   (converged or maxiter-exhausted)

Typical use::

    reg = MatrixRegistry()
    reg.register("laplace", rows=r, cols=c, vals=v, shape=(n, n), C=16)
    svc = SolverService(reg, block_width=8, chunk_iters=16,
                        admission="bucketed", max_queue=256)
    t1 = svc.submit("laplace", b1, solver="cg", tol=1e-7,
                    deadline=0.5, priority=1)
    t2 = svc.submit("laplace", b2, solver="minres", tol=1e-5)
    svc.drain()                      # or svc.step() under your own loop
    t1.status                        # "done" | "expired" | ...
    x1 = t1.result.x                 # original (unpermuted) space

Everything is synchronous under the hood (one Python thread drives the
chunks); "asynchronous" refers to the request lifecycle — submit never
blocks, cancellation and deadlines take effect at chunk boundaries,
results materialize as the service is stepped.  All timing (latency,
deadlines, chunk-size hints) flows through an injectable monotonic
``clock`` so scheduling logic is testable on a virtual clock without
sleeping (see ``tests/service_harness.py``).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
import weakref
from collections import deque
from contextlib import nullcontext
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execution
from repro.core.sellcs import SellCS, from_coo
from repro.solvers.cg import (cg_finalize, cg_init, cg_step,
                              pipelined_cg_finalize, pipelined_cg_init,
                              pipelined_cg_step)
from repro.solvers.minres import minres_finalize, minres_init, minres_step
from repro.solvers.operator import make_operator
from repro.solvers.stepper import merge_columns_masked, snap_chunk

__all__ = ["MatrixRegistry", "SolverService", "SolveTicket", "ServiceResult",
           "SOLVERS", "TERMINAL_STATES"]

#: solver kind -> (init, step, finalize) stepper triple
SOLVERS = {
    "cg": (cg_init, cg_step, cg_finalize),
    "pipelined_cg": (pipelined_cg_init, pipelined_cg_step,
                     pipelined_cg_finalize),
    "minres": (minres_init, minres_step, minres_finalize),
}

_BLOCK_MAXITER = np.iinfo(np.int32).max // 2   # block counter never binds

#: ticket states from which no further transition happens
TERMINAL_STATES = frozenset({"done", "cancelled", "rejected", "expired"})

#: effective condition number assumed when the Lanczos bracket includes
#: zero or negative eigenvalues (indefinite / singular-looking systems
#: give no usable kappa; predict "hard" rather than guessing)
_INDEFINITE_KAPPA = 1e8

#: Ritz values below this fraction of the spectral radius are treated as
#: float32-Lanczos ghosts (loss of orthogonality manufactures spurious
#: near-zero Ritz values on well-conditioned SPD matrices) and skipped
#: when estimating the condition number.  This also caps the estimated
#: kappa at ~1/floor — fine for an order-of-magnitude difficulty signal.
_GHOST_RITZ_FLOOR = 1e-3


# ---------------------------------------------------------------- registry
@dataclasses.dataclass
class _Entry:
    name: str
    matrix: object                    # SellCS | HeterogeneousEngine | op
    op: object                        # solver-facing operator
    nglobal: int                      # original-space rhs length
    build_seconds: float
    tuned: dict                       # execution-policy knobs (may be empty)
    store_dtype: str = ""             # resolved value-storage dtype name
    fingerprint: Optional[tuple] = None   # COO identity (shape/nnz/sums)
    bounds: Optional[Tuple[float, float]] = None
    ritz: Optional[np.ndarray] = None     # raw Ritz values of the one run
    preconds: dict = dataclasses.field(default_factory=dict)  # spec -> M


def _resolved_store_dtype(vals, dtype, store_dtype) -> str:
    """The storage dtype a ``from_coo(dtype=, store_dtype=)`` build ends
    up with — ``store_dtype=None`` resolves to the (canonicalized)
    compute dtype, matching ``SellCS.store_dtype``, so an explicit
    ``store_dtype`` equal to the compute dtype fingerprints identically
    to the default (the two builds are pinned bit-identical)."""
    if store_dtype is not None:
        return str(jnp.dtype(store_dtype))
    base = dtype if dtype is not None else np.asarray(vals).dtype
    return str(jnp.zeros((0,), base).dtype)


def _coo_fingerprint(rows, cols, vals, shape, store: str = "") -> tuple:
    import hashlib
    h = hashlib.sha256()
    for a in (np.ascontiguousarray(rows), np.ascontiguousarray(cols),
              np.ascontiguousarray(vals)):
        h.update(a.tobytes())
    v = np.asarray(vals)
    # the *resolved* storage dtype is part of the matrix identity: the
    # same COO payload at a different storage width is a different
    # registered matrix (see _resolved_store_dtype)
    return (tuple(shape), int(v.size), str(v.dtype), store, h.hexdigest())


def _storage_dtype_of(matrix, op) -> str:
    """Resolved value-storage dtype of a registered matrix/operator."""
    sd = getattr(matrix, "store_dtype", None)       # SellCS | engine
    if sd is None:
        inner = getattr(op, "A", None)              # DistOperator et al.
        sd = getattr(inner, "store_dtype", None)
    if sd is None:
        sd = getattr(op, "dtype", "")               # bare operator: compute
    return str(sd)


class MatrixRegistry:
    """Cache of per-matrix setup shared across solver requests.

    The expensive work a request must *not* repay: SELL-C-sigma
    conversion and permutation vectors, operator construction (including
    a :class:`DistOperator` over a heterogeneous engine), autotuned tile
    knobs, and the short Lanczos run that brackets the spectrum for
    KPM/ChebFD.  ``stats`` counts builds vs. cache hits so a service can
    report its cache effectiveness.
    """

    def __init__(self):
        self._entries: Dict[str, _Entry] = {}
        self.stats = {"builds": 0, "hits": 0,
                      "bounds_computed": 0, "bounds_hits": 0,
                      "precond_builds": 0, "precond_hits": 0}

    # -------------------------------------------------------------- admin
    def register(self, name: str, matrix=None, *,
                 rows=None, cols=None, vals=None, shape=None,
                 C: int = 32, sigma: int = 1, w_align: int = 1, dtype=None,
                 store_dtype=None,
                 impl: str = "ref", interpret: Optional[bool] = None,
                 autotune_tiles: bool = False) -> str:
        """Register a matrix under ``name`` (idempotent — reuse is a hit).

        ``matrix`` may be a prebuilt :class:`SellCS`, a
        :class:`~repro.runtime.engine.HeterogeneousEngine` (sharded
        matrices run through :class:`DistOperator` unchanged), or an
        operator implementing the full solver protocol (``mv``,
        ``mv_fused``, ``n``, ``dtype``, ``to_op_space``,
        ``from_op_space`` — e.g. :class:`MatrixFreeOperator`).
        Alternatively pass COO triplets (``rows``/``cols``/``vals``/
        ``shape``) and the SELL-C-sigma build happens here, once.
        ``store_dtype`` narrows the stored values (mixed-precision SpMV;
        see :func:`repro.core.sellcs.from_coo`) and is part of the matrix
        identity — the same COO data at two storage widths must be two
        registrations, and their requests batch separately.

        Re-registering a name with the *same* payload is a cache hit;
        with a different matrix (different COO bytes *or* a different
        ``store_dtype``) it raises — silently serving a stale operator
        would return converged answers to the wrong system.
        """
        if name in self._entries:
            e = self._entries[name]
            if matrix is not None:
                if matrix is not e.matrix:
                    raise ValueError(
                        f"matrix {name!r} is already registered with a "
                        f"different object; use a new name")
            elif vals is not None:
                sd = _resolved_store_dtype(vals, dtype, store_dtype)
                if _coo_fingerprint(rows, cols, vals, shape,
                                    sd) != e.fingerprint:
                    raise ValueError(
                        f"matrix {name!r} is already registered with "
                        f"different COO data or storage dtype; use a "
                        f"new name")
            self.stats["hits"] += 1
            return name
        t0 = time.perf_counter()
        fingerprint = None
        if matrix is None:
            if rows is None or cols is None or vals is None or shape is None:
                raise ValueError(
                    "register() needs either a prebuilt matrix/operator or "
                    "COO triplets rows/cols/vals plus shape")
            fingerprint = _coo_fingerprint(
                rows, cols, vals, shape,
                _resolved_store_dtype(vals, dtype, store_dtype))
            matrix = from_coo(rows, cols, vals, tuple(shape), C=C,
                              sigma=sigma, w_align=w_align, dtype=dtype,
                              store_dtype=store_dtype)
        if hasattr(matrix, "mv") and hasattr(matrix, "mv_fused"):
            missing = [a for a in ("n", "dtype", "to_op_space",
                                   "from_op_space") if not hasattr(matrix, a)]
            if missing:
                raise TypeError(
                    f"operator for {name!r} is missing {missing}; the "
                    f"service needs the full solver protocol (mv, mv_fused, "
                    f"n, dtype, to_op_space, from_op_space)")
            op = matrix                               # already an operator
        else:
            op = make_operator(matrix, impl=impl, interpret=interpret)
        # original-space rhs length: the matrix knows it; a bare operator
        # falls back to its wrapped matrix/engine, then to op.n
        nglobal = getattr(matrix, "nrows", None)
        if nglobal is None:
            inner = getattr(op, "A", None) or getattr(op, "engine", None)
            nglobal = getattr(inner, "nrows", None) or op.n
        sdt = _storage_dtype_of(matrix, op)
        tuned: dict = {}
        if autotune_tiles:
            probe = jnp.zeros((op.n, 8), op.dtype)
            def _run(t):
                with execution.force(row_tile=t):
                    return op.mv(probe)
            # storage + compute dtype both key the tuned tile: a narrower
            # value stream shifts the bandwidth balance
            best = execution.autotune(
                "service.row_tile", (name, op.n),
                (256, 512, 1024), _run,
                dtype=(sdt, str(jnp.dtype(op.dtype))))
            tuned = {"row_tile": int(best)}
        self._entries[name] = _Entry(
            name=name, matrix=matrix, op=op, nglobal=int(nglobal),
            build_seconds=time.perf_counter() - t0, tuned=tuned,
            store_dtype=sdt, fingerprint=fingerprint)
        self.stats["builds"] += 1
        return name

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> List[str]:
        return list(self._entries)

    # ------------------------------------------------------------- lookups
    def entry(self, name: str) -> _Entry:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(f"matrix {name!r} is not registered "
                           f"(have: {sorted(self._entries)})") from None

    def operator(self, name: str):
        return self.entry(name).op

    def tuned(self, name: str) -> dict:
        return dict(self.entry(name).tuned)

    def _lanczos_ritz(self, name: str, *, k: int = 30,
                      seed: int = 0) -> np.ndarray:
        """The cached raw Ritz values of ONE short Lanczos run per matrix
        — :meth:`spectral_bounds` widens their extremes for KPM/ChebFD
        scaling, :meth:`predicted_iters` reads a condition number off
        them; neither pays a second run."""
        e = self.entry(name)
        if e.ritz is None:
            from repro.solvers.lanczos import lanczos, tridiag_eigh
            res = lanczos(e.op, None, k, seed=seed)
            nv = k if res.nvalid is None else max(int(res.nvalid), 1)
            ev, _ = tridiag_eigh(np.asarray(res.alphas)[:nv],
                                 np.asarray(res.betas)[:max(nv - 1, 0)])
            e.ritz = np.asarray(ev, np.float64)
            self.stats["bounds_computed"] += 1
        else:
            self.stats["bounds_hits"] += 1
        return e.ritz

    def spectral_bounds(self, name: str, *, k: int = 30, seed: int = 0,
                        safety: float = 1.05) -> Tuple[float, float]:
        """Cached Lanczos (lambda_min, lambda_max) bracket for KPM/ChebFD.

        Identical to :func:`repro.solvers.lanczos.lanczos_extrema` on the
        registered operator (same run, same widening), but the underlying
        Ritz values are cached so :meth:`predicted_iters` shares them.
        """
        e = self.entry(name)
        if e.bounds is None:
            ritz = self._lanczos_ritz(name, k=k, seed=seed)
            lo, hi = float(ritz[0]), float(ritz[-1])
            mid, rad = (hi + lo) / 2, (hi - lo) / 2
            rad = max(rad * safety, 1e-12)
            e.bounds = (mid - rad, mid + rad)
        else:
            self.stats["bounds_hits"] += 1
        return e.bounds

    def predicted_iters(self, name: str, *, solver: str = "cg",
                        tol: float = 1e-8,
                        maxiter: Optional[int] = None) -> int:
        """Predicted Krylov iteration count — the free difficulty signal.

        Uses the Ritz values of the registry-cached Lanczos run (one
        short run per matrix, ever — shared with
        :meth:`spectral_bounds`) and the classic CG error bound: the
        iteration count to reach a relative residual ``tol`` on an SPD
        system is about ``sqrt(kappa)/2 * ln(2/tol)``.  MINRES on
        (near-)definite systems tracks the same square-root law, so
        every solver kind currently shares the formula.  Ritz values
        below ``_GHOST_RITZ_FLOOR`` of the spectral radius are skipped —
        float32 Lanczos manufactures spurious near-zero Ritz values on
        perfectly well-conditioned matrices, and trusting one would
        misclassify every easy solve as a straggler.  A spectrum with no
        usable positive part pessimistically predicts *hard*
        (``_INDEFINITE_KAPPA``) — misclassifying a hard solve as easy is
        what reintroduces head-of-line blocking, the failure mode
        bucketed admission exists to prevent.

        The estimate is intentionally coarse: the service only consumes
        its *order of magnitude* (a log-scale bucket id and a
        shortest-job-first rank), never the raw number.  Clamped to
        ``[1, maxiter]`` when ``maxiter`` is given.
        """
        if solver not in SOLVERS:
            raise ValueError(f"unknown solver {solver!r} "
                             f"(have: {sorted(SOLVERS)})")
        ritz = self._lanczos_ritz(name)
        if float(ritz[-1]) <= 0:          # negative-definite: flip the sign
            ritz = -ritz[::-1]
        hi = float(ritz[-1])
        genuine = ritz[ritz > hi * _GHOST_RITZ_FLOOR] if hi > 0 else ritz[:0]
        kappa = (hi / float(genuine[0])) if genuine.size \
            else _INDEFINITE_KAPPA
        kappa = max(float(kappa), 1.0)
        tol = float(tol)
        if not tol > 0:
            raise ValueError(f"tol must be > 0, got {tol!r}")
        decay = max(math.log(2.0 / tol), 1.0)
        pred = int(math.ceil(0.5 * math.sqrt(kappa) * decay))
        pred = max(pred, 1)
        if maxiter is not None:
            pred = min(pred, max(int(maxiter), 1))
        return pred

    def preconditioner(self, name: str, spec: str):
        """Cached preconditioner for matrix ``name`` (the setup a request
        must not repay: block extraction + factorization, or the Lanczos
        bounds run behind a Chebyshev polynomial).

        ``spec`` is ``"block_jacobi[:<block_size>]"`` (needs a SELL-C-σ
        matrix — the blocks come straight out of its storage) or
        ``"chebyshev[:<degree>]"`` (works for *any* registered operator,
        including engine-backed :class:`DistOperator` matrices, because
        it only calls ``mv_fused``).  Same spec twice is a cache hit.
        """
        from repro.solvers.precond import (make_preconditioner,
                                           parse_precond_spec)
        kind, param = parse_precond_spec(spec)         # normalize + validate
        norm = kind if param is None else f"{kind}:{param}"
        e = self.entry(name)
        M = e.preconds.get(norm)
        if M is not None:
            self.stats["precond_hits"] += 1
            return M
        if kind.startswith("block_jacobi"):
            A = e.matrix if isinstance(e.matrix, SellCS) else \
                getattr(e.op, "A", None)
            if not isinstance(A, SellCS):
                raise ValueError(
                    f"matrix {name!r} is not SELL-C-σ backed "
                    f"({type(e.matrix).__name__}); block_jacobi needs the "
                    f"stored blocks — use chebyshev for engine-backed or "
                    f"matrix-free operators")
            M = make_preconditioner(norm, matrix=A)
        else:
            M = make_preconditioner(norm, op=e.op,
                                    spectrum=self.spectral_bounds(name))
        e.preconds[norm] = M
        self.stats["precond_builds"] += 1
        return M


# ----------------------------------------------------------------- requests
class ServiceResult(NamedTuple):
    x: np.ndarray                     # solution, original (unpermuted) space
    iters: int                        # block iterations spent on this column
    resnorm: float
    converged: bool


class SolveTicket:
    """Handle for one submitted request (fills in as the service steps).

    ``status`` walks ``queued -> running -> <terminal>`` where the
    terminal states are ``done`` (result present, ``converged`` True or
    False), ``cancelled`` (no result), ``rejected`` (admission control
    refused it, no result), and ``expired`` (deadline passed — a
    best-effort result is present if the solve had started).  The
    service guarantees exactly one terminal transition per ticket.

    All timestamps come from the *service's* injected monotonic clock,
    so latency and deadline arithmetic is deterministic under a virtual
    clock (``tests/service_harness.py``).
    """

    def __init__(self, req_id: int, matrix: str, solver: str, b, tol: float,
                 maxiter: int, precond: Optional[str] = None, *,
                 deadline: Optional[float] = None, priority: int = 0,
                 clock: Callable[[], float] = time.perf_counter):
        self.id = req_id
        self.matrix = matrix
        self.solver = solver
        self.precond = precond
        self.b = b
        self.tol = float(tol)
        self.maxiter = int(maxiter)
        self.priority = int(priority)
        self.submitted_at = clock()
        # relative seconds in, absolute clock time stored — every later
        # comparison is then a plain ``clock() >= deadline``
        self.deadline: Optional[float] = (
            None if deadline is None else self.submitted_at + float(deadline))
        self.status = "queued"
        self.key: Optional[tuple] = None       # batch key, set at submit
        self.pred_iters: Optional[int] = None  # difficulty estimate, if any
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.result: Optional[ServiceResult] = None
        self._cancel_requested = False
        self._terminal_transitions = 0         # invariant: ends at exactly 1

    # ------------------------------------------------------------- queries
    @property
    def done(self) -> bool:
        """A result is present (converged, maxiter-exhausted, or the
        best-effort iterate of an expired-while-running request)."""
        return self.result is not None

    @property
    def resolved(self) -> bool:
        """The ticket took its terminal transition (any terminal state)."""
        return self.status in TERMINAL_STATES

    @property
    def rejected(self) -> bool:
        return self.status == "rejected"

    @property
    def cancelled(self) -> bool:
        return self.status == "cancelled"

    @property
    def expired(self) -> bool:
        return self.status == "expired"

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def queue_wait(self) -> Optional[float]:
        """Seconds spent queued before the first chunk (None while
        queued; for never-started terminals it spans submit->finish)."""
        if self.started_at is not None:
            return self.started_at - self.submitted_at
        if self.finished_at is not None:
            return self.finished_at - self.submitted_at
        return None

    # ----------------------------------------------------- service-internal
    def _finish(self, status: str, now: float) -> None:
        """Take the terminal transition (service-internal, exactly once)."""
        if status not in TERMINAL_STATES:
            raise ValueError(f"not a terminal status: {status!r}")
        if self.status in TERMINAL_STATES:
            raise RuntimeError(
                f"ticket #{self.id} already resolved as {self.status!r}; "
                f"second transition to {status!r} is a service bug")
        self.status = status
        self.finished_at = now
        self._terminal_transitions += 1

    def __repr__(self) -> str:
        pc = f" precond={self.precond}" if self.precond else ""
        dl = f" deadline={self.deadline:.3f}" if self.deadline is not None \
            else ""
        pr = f" prio={self.priority}" if self.priority else ""
        return (f"SolveTicket(#{self.id} {self.solver}@{self.matrix} "
                f"tol={self.tol:g}{pc}{dl}{pr} {self.status})")


class _AdmissionQueue:
    """Bounded priority queue for one batch key.

    Orders by ``(-priority, deadline, arrival)`` — higher priority first,
    then earliest deadline (requests without one sort last), then FIFO.
    With the default ``priority=0`` / ``deadline=None`` this is exactly
    the old FIFO deque.  Cancelled tickets are removed lazily at pop
    (the heap keeps the dead entry, ``live`` does not), so ``cancel()``
    is O(1).
    """

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()
        self.live = 0                  # entries still in "queued" status

    def push(self, ticket: SolveTicket) -> None:
        dl = ticket.deadline if ticket.deadline is not None else math.inf
        heapq.heappush(self._heap,
                       (-ticket.priority, dl, next(self._seq), ticket))
        self.live += 1

    def pop(self) -> Optional[SolveTicket]:
        """Next still-queued ticket, or None (skips dead entries)."""
        while self._heap:
            ticket = heapq.heappop(self._heap)[3]
            if ticket.status != "queued":
                continue               # cancelled while queued: lazy removal
            self.live -= 1
            return ticket
        return None

    def note_removed(self) -> None:
        """A queued ticket left without pop (cancel while queued)."""
        self.live -= 1

    def __len__(self) -> int:
        return self.live

    def __bool__(self) -> bool:
        return self.live > 0


@dataclasses.dataclass
class _Batch:
    key: tuple   # (matrix, solver, dtype, precond, store_dtype, block, bkt)
    op: object
    tuned: dict
    init: object                      # jitted (B, tols[, X0]) -> fresh state
    step: object
    finalize: object                  # jitted state -> solver Result
    merge: object                     # jitted (old, fresh, mask) -> state
    width: int = 0                    # column count of this batch's state
    M: object = None                  # preconditioner (None = plain)
    state: object = None
    slots: List[Optional[SolveTicket]] = dataclasses.field(
        default_factory=list)
    insert_it: List[int] = dataclasses.field(default_factory=list)
    block: bool = False               # shared-Krylov block batch
    est_iter_s: Optional[float] = None   # EWMA seconds per block iteration

    @property
    def active(self) -> int:
        return sum(t is not None for t in self.slots)

    def live_tickets(self) -> List[SolveTicket]:
        return [t for t in self.slots if t is not None]


def _pow2ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


# ------------------------------------------------------------------ service
class SolverService:
    """Coalesce independent solve requests into continuous block solves.

    ``block_width`` caps the block-vector width of every batch;
    ``chunk_iters`` is the number of solver iterations run between
    retire/refill opportunities — small values react faster to mixed
    tolerances, large values amortize Python overhead.

    **Admission** (``admission=``):

    * ``"fifo"`` (default) — the legacy policy: one queue per batch key,
      every active batch advances one chunk per :meth:`step`.
    * ``"bucketed"`` — requests additionally carry a log-scale
      *difficulty bucket* (from :meth:`MatrixRegistry.predicted_iters`)
      in their batch key, so predicted-short solves never share a batch
      with predicted stragglers; :meth:`step` becomes a *dispatcher*
      advancing the most urgent batch (earliest deadline slack, then
      highest priority, then shortest predicted job), with aging so no
      batch starves; and batch width adapts to queue depth
      (power-of-two, capped at ``block_width``) instead of always
      running full-width.

    ``max_queue`` bounds every per-key queue: a submit beyond the bound
    returns a ticket already resolved as ``rejected`` instead of growing
    the queue without limit (explicit admission control).  ``clock`` is
    the monotonic time source for every timestamp, deadline, and
    chunk-size decision — inject a virtual clock for deterministic
    scheduling tests; the default is ``time.perf_counter``, unchanged
    behavior.  ``iter_time_hint(key) -> seconds`` seeds the
    per-iteration time estimate a batch uses to shrink chunks toward
    deadlines before any chunk has been measured (engine-backed matrices
    default to the engine's roofline hint,
    :meth:`HeterogeneousEngine.modeled_iter_seconds`).
    """

    def __init__(self, registry: MatrixRegistry, *, block_width: int = 8,
                 chunk_iters: int = 16, completed_log: int = 4096,
                 admission: str = "fifo", max_queue: Optional[int] = None,
                 adaptive_width: Optional[bool] = None,
                 bucket_base: float = 8.0, starvation_limit: int = 8,
                 clock: Optional[Callable[[], float]] = None,
                 iter_time_hint: Optional[Callable[[tuple], float]] = None):
        if block_width < 1:
            raise ValueError("block_width must be >= 1")
        if chunk_iters < 1:
            raise ValueError("chunk_iters must be >= 1")
        if admission not in ("fifo", "bucketed"):
            raise ValueError(f"admission must be 'fifo' or 'bucketed', "
                             f"got {admission!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        if bucket_base <= 1.0:
            raise ValueError("bucket_base must be > 1")
        if starvation_limit < 1:
            raise ValueError("starvation_limit must be >= 1")
        self.registry = registry
        self.block_width = int(block_width)
        self.chunk_iters = int(chunk_iters)
        self.admission = admission
        self.max_queue = None if max_queue is None else int(max_queue)
        self.adaptive_width = (admission == "bucketed"
                               if adaptive_width is None
                               else bool(adaptive_width))
        self.bucket_base = float(bucket_base)
        self.starvation_limit = int(starvation_limit)
        self.clock: Callable[[], float] = (
            clock if clock is not None else time.perf_counter)
        self._iter_time_hint = iter_time_hint
        self._queues: Dict[tuple, _AdmissionQueue] = {}
        self._batches: Dict[tuple, _Batch] = {}
        self._jit_cache: Dict[tuple, tuple] = {}   # key -> (init, fin, merge)
        self._age: Dict[tuple, int] = {}           # dispatcher aging counters
        self._ids = itertools.count()
        # recently resolved *admitted* tickets, newest last; bounded so a
        # long-lived service does not pin every rhs/solution ever served
        # (callers hold their own tickets — this is a convenience log).
        # Rejected tickets were never admitted and are not logged here.
        self.completed: deque = deque(
            maxlen=completed_log if completed_log > 0 else None)
        self.stats = {"submitted": 0, "retired": 0, "converged": 0,
                      "chunks": 0, "refills": 0, "batches_opened": 0,
                      "cancelled": 0, "expired": 0, "rejected": 0,
                      "deadline_chunks": 0}

    # -------------------------------------------------------------- submit
    def submit(self, matrix: str, b, *, solver: str = "cg",
               tol: float = 1e-8, maxiter: int = 500,
               precond: Optional[str] = None,
               block: bool = False,
               deadline: Optional[float] = None,
               priority: int = 0) -> SolveTicket:
        """Enqueue one solve of ``A x = b`` (``b`` in original space).

        Returns immediately with a :class:`SolveTicket`; the solve runs
        as the service is stepped.  If the per-key queue is full
        (``max_queue``), the returned ticket is already resolved as
        ``rejected`` — check ``ticket.rejected`` (or ``status``) before
        waiting on it.

        ``deadline`` is a relative latency target in clock seconds: a
        request that has not converged when it expires is retired at the
        next scheduling boundary as ``expired`` (with its best-effort
        iterate if it had started).  ``priority`` (higher = sooner)
        orders the queue and, under ``admission="bucketed"``, the
        dispatcher; ties keep FIFO order, so defaults preserve the
        legacy behavior exactly.

        ``precond`` is a spec string (``"block_jacobi[:<bs>]"`` or
        ``"chebyshev[:<degree>]"``, see
        :meth:`MatrixRegistry.preconditioner`) or ``None``.  It is part
        of the batch key, so preconditioned and plain requests on the
        same matrix coalesce into *separate* block solves — the stepper
        states have different shapes and must never share a block.

        ``block=True`` routes the request into a **block-Krylov** batch
        (``cg``/``minres`` only, unpreconditioned): all columns of that
        batch share one Krylov space per block — fewer SpMV sweeps per
        converged request on shared-matrix multi-rhs traffic, at the
        cost of a warm restart whenever the batch refills (see
        ``docs/block_krylov.md``).  Block and column-wise requests on
        the same matrix batch separately.
        """
        if solver not in SOLVERS:
            raise ValueError(f"unknown solver {solver!r} "
                             f"(have: {sorted(SOLVERS)})")
        entry = self.registry.entry(matrix)         # validates the handle
        if block:
            if solver not in ("cg", "minres"):
                raise NotImplementedError(
                    f"block=True supports solver='cg'/'minres', "
                    f"not {solver!r}")
            if precond is not None:
                raise NotImplementedError(
                    "block=True with a preconditioner is not implemented; "
                    "drop precond= or submit with block=False")
        if precond is not None:
            if solver == "pipelined_cg":
                raise NotImplementedError(
                    "pipelined_cg does not support preconditioning; "
                    "use solver='cg' with precond=, or drop precond")
            from repro.solvers.precond import parse_precond_spec
            kind, param = parse_precond_spec(precond)   # fail at submit,
            precond = kind if param is None else f"{kind}:{param}"
        if deadline is not None and not float(deadline) > 0:
            raise ValueError(
                f"deadline must be a positive relative latency target in "
                f"seconds, got {deadline!r}")
        if not float(tol) > 0:
            raise ValueError(f"tol must be > 0, got {tol!r}")
        # validate the rhs here: a malformed b discovered at refill time
        # would already have dequeued (and would lose) sibling requests
        b = np.asarray(b)
        if b.ndim != 1 or b.shape[0] != entry.nglobal:
            raise ValueError(
                f"rhs for {matrix!r} must be 1-d of length {entry.nglobal} "
                f"(original space), got shape {b.shape}")
        ticket = SolveTicket(next(self._ids), matrix, solver, b, tol,
                             maxiter, precond, deadline=deadline,
                             priority=priority, clock=self.clock)
        # storage dtype, block mode, and (bucketed admission only) the
        # difficulty bucket are the trailing key components: requests
        # against f32-stored and bf16-stored matrices never share a
        # block solve (their compiled matvecs — and their numerics —
        # differ), block-Krylov batches never mix with column-wise ones
        # (their stepper states differ), and predicted-short solves
        # never share a batch with predicted stragglers
        bucket = ""
        if self.admission == "bucketed":
            pred = self.registry.predicted_iters(
                matrix, solver=solver, tol=ticket.tol,
                maxiter=ticket.maxiter)
            ticket.pred_iters = pred
            bucket = f"d{int(math.log(pred, self.bucket_base))}"
        key = (matrix, solver, str(jnp.dtype(entry.op.dtype)),
               precond or "", entry.store_dtype,
               "block" if block else "", bucket)
        ticket.key = key
        self.stats["submitted"] += 1
        queue = self._queues.setdefault(key, _AdmissionQueue())
        if self.max_queue is not None and len(queue) >= self.max_queue:
            # explicit rejection instead of unbounded queue growth; the
            # ticket comes back already terminal, never enqueued
            ticket._finish("rejected", self.clock())
            self.stats["rejected"] += 1
            return ticket
        queue.push(ticket)
        return ticket

    def cancel(self, ticket: SolveTicket) -> bool:
        """Cancel a request.  Returns True iff the cancellation sticks.

        A queued ticket resolves as ``cancelled`` immediately; a running
        one is marked and retired (without a result) at the next chunk
        boundary — cancellation wins over a convergence observed at the
        same boundary, so ``cancel() == True`` always means the ticket
        ends ``cancelled``.  An already-resolved ticket returns False.
        """
        if ticket.resolved:
            return False
        if ticket.status == "queued":
            queue = self._queues.get(ticket.key)
            ticket._finish("cancelled", self.clock())
            if queue is not None:
                queue.note_removed()   # heap entry dies lazily at pop
            self.completed.append(ticket)
            self.stats["cancelled"] += 1
            return True
        ticket._cancel_requested = True        # running: chunk boundary
        return True

    @property
    def pending(self) -> int:
        """Requests submitted but not yet resolved."""
        queued = sum(len(q) for q in self._queues.values())
        running = sum(b.active for b in self._batches.values())
        return queued + running

    # --------------------------------------------------------------- steps
    def step(self) -> int:
        """Advance the service by one scheduling round; returns chunks run.

        ``admission="fifo"``: every active batch advances one chunk (the
        legacy policy).  ``admission="bucketed"``: the dispatcher picks
        the single most urgent batch (deadline slack, then priority,
        then shortest predicted job, with anti-starvation aging) and
        advances only it — stragglers no longer tax every other
        request's latency on every round.
        """
        for key, queue in self._queues.items():
            if queue and key not in self._batches:
                self._open_batch(key)
        if not self._batches:
            return 0
        if self.admission == "fifo":
            keys = list(self._batches)
        else:
            picked = self._select_key()
            keys = [picked] if picked is not None else []
        chunks = 0
        for key in keys:
            batch = self._batches.get(key)
            if batch is None:
                continue
            chunks += self._run_chunk(batch)
            self._retire_and_refill(batch)
            if batch.active == 0 and not self._queues.get(key):
                del self._batches[key]
                self._age.pop(key, None)
        return chunks

    def drain(self, max_steps: int = 100_000) -> "deque":
        """Step until every submitted request has been resolved."""
        steps = 0
        while self.pending:
            if steps >= max_steps:
                raise RuntimeError(
                    f"service did not drain in {max_steps} steps "
                    f"({self.pending} requests pending)")
            self.step()
            steps += 1
        return self.completed

    # --------------------------------------------------------- dispatcher
    def _select_key(self) -> Optional[tuple]:
        """Pick the batch to advance this round (bucketed admission).

        Urgency order: smallest deadline *slack* (time to deadline minus
        estimated time to finish) first, then highest priority, then
        shortest predicted remaining work (SJF — this is what keeps easy
        solves from queuing behind stragglers).  Any batch skipped for
        ``starvation_limit`` consecutive rounds is served next
        regardless, so no admitted request starves.
        """
        keys = list(self._batches)
        if not keys:
            return None
        now = self.clock()
        starved = [k for k in keys
                   if self._age.get(k, 0) >= self.starvation_limit]
        if starved:
            pick = max(starved, key=lambda k: self._age.get(k, 0))
        else:
            def score(key):
                batch = self._batches[key]
                live = batch.live_tickets()
                block_it = (int(batch.state.it)
                            if batch.state is not None else 0)
                slack = math.inf
                prio = 0
                shortest = math.inf
                for j, t in enumerate(batch.slots):
                    if t is None:
                        continue
                    spent = block_it - batch.insert_it[j]
                    pred = t.pred_iters if t.pred_iters else t.maxiter
                    remaining = max(pred - spent, 1)
                    shortest = min(shortest, remaining)
                    prio = max(prio, t.priority)
                    if t.deadline is not None:
                        est = (remaining * batch.est_iter_s
                               if batch.est_iter_s else 0.0)
                        slack = min(slack, t.deadline - now - est)
                if not live:
                    shortest = 1.0         # empty batch with queued work
                return (slack, -prio, shortest)
            pick = min(keys, key=score)
        for k in keys:
            self._age[k] = 0 if k == pick else self._age.get(k, 0) + 1
        return pick

    # ------------------------------------------------------------ internals
    def _pick_width(self, need: int, queued: int) -> int:
        """Batch width from demand: power-of-two, >= need, <= block_width."""
        if not self.adaptive_width:
            return self.block_width
        want = max(need + queued, 1)
        # pow2ceil(want) >= need and block_width >= need (callers never ask
        # for more slots than the cap), so the min always fits the demand
        return min(_pow2ceil(want), self.block_width)

    def _open_batch(self, key: tuple) -> None:
        matrix, solver, _, precond, _store, blk, _bucket = key
        blk = bool(blk)
        entry = self.registry.entry(matrix)
        init, step, fin = SOLVERS[solver]
        op = entry.op
        # built (or cache-hit) once per batch key — block extraction /
        # factorization and the Lanczos bounds are registry-cached setup
        M = (self.registry.preconditioner(matrix, precond)
             if precond else None)
        # difficulty buckets of one (matrix, solver, ...) share the same
        # compiled init/finalize/merge — only the scheduling differs
        jit_key = key[:6]
        jitted = self._jit_cache.get(jit_key)
        if jitted is None:
            # init / finalize / merge are the between-chunk glue; jitting
            # them (cached across batch reopenings) keeps the service's
            # per-refill cost at one compiled call instead of a stream of
            # eager dispatches.  The cached closure must not own the
            # operator or preconditioner (the registry controls their
            # lifetime) — hold weakrefs and fail loudly if the entry is
            # evicted out from under the cache.
            op_ref = weakref.ref(op)
            M_ref = weakref.ref(M) if M is not None else None

            def _init(B, tols, X0=None):
                o = op_ref()
                if o is None:
                    raise ReferenceError(
                        "operator evicted while its batch init was cached")
                m = M_ref() if M_ref is not None else None
                if M_ref is not None and m is None:
                    raise ReferenceError("preconditioner evicted while "
                                         "its batch init was cached")
                if blk:
                    return init(o, B, X0, tol=tols, maxiter=_BLOCK_MAXITER,
                                M=m, block=True)
                return init(o, B, tol=tols, maxiter=_BLOCK_MAXITER, M=m)

            jitted = (
                jax.jit(_init),
                jax.jit(fin),
                jax.jit(merge_columns_masked),
            )
            self._jit_cache[jit_key] = jitted
        width = self._pick_width(1, len(self._queues.get(key) or ()) - 1)
        batch = _Batch(key=key, op=op, tuned=entry.tuned,
                       init=jitted[0], step=step, finalize=jitted[1],
                       merge=jitted[2], M=M, block=blk, width=width,
                       slots=[None] * width, insert_it=[0] * width,
                       est_iter_s=self._cold_iter_hint(key, entry, width))
        self._batches[key] = batch
        self.stats["batches_opened"] += 1
        self._refill(batch)

    def _cold_iter_hint(self, key: tuple, entry: _Entry,
                        width: int) -> Optional[float]:
        """Seconds-per-iteration estimate before any chunk was measured.

        An explicit ``iter_time_hint`` wins; engine-backed matrices fall
        back to the engine's roofline critical path
        (:meth:`HeterogeneousEngine.modeled_iter_seconds`); otherwise
        None until the first measured chunk feeds the EWMA.
        """
        if self._iter_time_hint is not None:
            return float(self._iter_time_hint(key))
        modeled = getattr(entry.matrix, "modeled_iter_seconds", None)
        if callable(modeled):
            return float(modeled(nvecs=width))
        return None

    def _policy_scope(self, batch: _Batch):
        return (execution.force(**batch.tuned) if batch.tuned
                else nullcontext())

    def _pop_live(self, queue: _AdmissionQueue,
                  now: float) -> Optional[SolveTicket]:
        """Next admissible queued ticket; expires stale ones on the way.

        This is the queued-side deadline gate: a request whose deadline
        passed while it waited is resolved as ``expired`` here — at
        refill time, on both the column and the block warm-restart path
        — instead of wasting a batch slot on an answer nobody is waiting
        for.
        """
        while True:
            ticket = queue.pop()
            if ticket is None:
                return None
            if ticket.deadline is not None and now >= ticket.deadline:
                ticket._finish("expired", now)
                self.completed.append(ticket)
                self.stats["expired"] += 1
                continue
            return ticket

    def _refill(self, batch: _Batch) -> None:
        """Pull queued requests into the batch's free column slots."""
        if batch.block:
            self._refill_block(batch)
            return
        queue = self._queues.get(batch.key)
        free = [j for j, t in enumerate(batch.slots) if t is None]
        if not queue or not free:
            return
        op, w = batch.op, batch.width
        dtype = jnp.dtype(op.dtype)
        rdt = jnp.finfo(dtype).dtype               # tolerance dtype
        taken: List[Tuple[int, SolveTicket]] = []
        now = self.clock()
        Bg = None
        tols = np.ones(w, rdt)
        for j in free:
            ticket = self._pop_live(queue, now)
            if ticket is None:
                break
            ticket.started_at = now
            ticket.status = "running"
            col = np.asarray(ticket.b)
            if Bg is None:                          # global-space rhs block
                Bg = np.zeros((col.shape[0], w), dtype)
            Bg[:, j] = col
            tols[j] = ticket.tol
            taken.append((j, ticket))
        if not taken:
            return
        with self._policy_scope(batch):
            Bop = op.to_op_space(jnp.asarray(Bg))   # one permute per refill
            fresh = batch.init(Bop, jnp.asarray(tols))
        if batch.state is None:
            batch.state = fresh        # empty slots: zero rhs, done at init
            block_it = 0
        else:
            mask = np.zeros(w, bool)
            mask[[j for j, _ in taken]] = True
            batch.state = batch.merge(batch.state, fresh, jnp.asarray(mask))
            block_it = int(batch.state.it)
        for j, ticket in taken:
            batch.slots[j] = ticket
            batch.insert_it[j] = block_it
        self.stats["refills"] += 1

    def _refill_block(self, batch: _Batch) -> None:
        """Refill a block-Krylov batch with a warm restart.

        Block states carry cross-column ``(b, b)`` Gram/reflection blocks,
        so columns cannot be spliced (``merge_columns_masked`` raises on
        them).  Instead the whole batch re-inits: survivors keep their
        current iterate as ``x0`` (a warm restart — their built-up Krylov
        information lives on in ``x``), newcomers start from zero, and
        empty slots get a zero rhs, which the zero-b fast path marks done
        at init so SVQB deflates them immediately.  ``insert_it`` goes
        negative for survivors to keep per-ticket iteration accounting
        exact across the restart (the fresh state's ``it`` is 0).

        Because the restart rebuilds the whole state anyway, this is
        also where adaptive width happens: survivors are repacked into
        the leading columns and the new width is chosen from demand
        (survivors + queue depth, power-of-two, capped at
        ``block_width``), so a draining batch shrinks instead of
        dragging converged-and-deflated zero columns through every
        remaining sweep.
        """
        queue = self._queues.get(batch.key)
        free = [j for j, t in enumerate(batch.slots) if t is None]
        if not queue or not free:
            return
        op = batch.op
        dtype = jnp.dtype(op.dtype)
        rdt = jnp.finfo(dtype).dtype
        now = self.clock()
        # survivors keep their iterate; measure iterations already spent
        # before the restart resets the block counter
        survivors: List[Tuple[int, SolveTicket, int]] = []  # (old_j, t, spent)
        if batch.state is not None:
            block_it = int(batch.state.it)
            for j, t in enumerate(batch.slots):
                if t is not None:
                    survivors.append((j, t, block_it - batch.insert_it[j]))
        newcomers: List[SolveTicket] = []
        while len(survivors) + len(newcomers) < self.block_width:
            ticket = self._pop_live(queue, now)
            if ticket is None:
                break
            ticket.started_at = now
            ticket.status = "running"
            newcomers.append(ticket)
        if not newcomers:
            return          # nothing admitted (stale queue): keep iterating
        m = len(survivors) + len(newcomers)
        w = self._pick_width(m, len(queue))
        n0 = np.asarray((survivors[0][1] if survivors
                         else newcomers[0]).b).shape[0]
        Bg = np.zeros((n0, w), dtype)
        tols = np.ones(w, rdt)
        ordered = [t for _, t, _ in survivors] + newcomers
        for i, ticket in enumerate(ordered):
            Bg[:, i] = np.asarray(ticket.b)
            tols[i] = ticket.tol
        with self._policy_scope(batch):
            Bop = op.to_op_space(jnp.asarray(Bg))
            X0 = None
            if survivors:
                xs = batch.state.x[:, [j for j, _, _ in survivors]]
                pad = jnp.zeros((xs.shape[0], w - xs.shape[1]), xs.dtype)
                X0 = jnp.concatenate([xs, pad], axis=1)
            batch.state = batch.init(Bop, jnp.asarray(tols), X0)
        batch.width = w
        batch.slots = [None] * w
        batch.insert_it = [0] * w
        for i, (_, ticket, spent) in enumerate(survivors):
            batch.slots[i] = ticket
            batch.insert_it[i] = -spent if spent else 0
        for i, ticket in enumerate(newcomers, start=len(survivors)):
            batch.slots[i] = ticket
        self.stats["refills"] += 1

    def _chunk_k(self, batch: _Batch, now: float) -> int:
        """Iterations for the next chunk, shrunk toward the tightest
        live deadline.

        Convergence, cancellation, and expiry are only observable at
        chunk boundaries, so a full ``chunk_iters`` chunk can overshoot
        a deadline by its whole length.  When a live column carries a
        deadline and the batch has a seconds-per-iteration estimate, the
        chunk is cut so the boundary lands near the deadline —
        snapped to a power of two (:func:`repro.solvers.stepper.
        snap_chunk`) so the set of compiled chunk programs stays
        bounded at ``log2(chunk_iters)`` variants per batch key.
        """
        deadlines = [t.deadline for t in batch.slots
                     if t is not None and t.deadline is not None
                     and not t._cancel_requested]
        if not deadlines or not batch.est_iter_s:
            return self.chunk_iters
        remaining = min(deadlines) - now
        if remaining <= 0:
            k = 1                       # expired: reach the boundary asap
        else:
            k = int(remaining / batch.est_iter_s)
        k = snap_chunk(k, self.chunk_iters)
        if k < self.chunk_iters:
            self.stats["deadline_chunks"] += 1
        return k

    def _run_chunk(self, batch: _Batch) -> int:
        if batch.state is None:
            return 0                    # refill admitted nothing (expiry)
        now = self.clock()
        k = self._chunk_k(batch, now)
        it0 = int(batch.state.it)
        with self._policy_scope(batch):
            batch.state = batch.step(batch.op, batch.state, k, M=batch.M)
        advanced = int(batch.state.it) - it0
        wall = self.clock() - now
        if wall > 0 and advanced > 0:
            # EWMA of measured per-iteration time feeds deadline slack
            # and chunk shrinking; a virtual clock that does not advance
            # inside the step leaves the cold hint in place
            per_iter = wall / advanced
            batch.est_iter_s = (per_iter if batch.est_iter_s is None
                                else 0.7 * batch.est_iter_s + 0.3 * per_iter)
        self.stats["chunks"] += 1
        return 1

    def _retire_and_refill(self, batch: _Batch) -> None:
        if batch.state is None:
            self._refill(batch)
            return
        now = self.clock()
        state = batch.state
        done = np.asarray(state.done)
        block_it = int(state.it)
        # (slot, ticket, spent, status) for tickets that get a result;
        # cancellations resolve without one.  Cancellation wins over a
        # convergence observed at the same boundary (cancel() promised).
        retiring: List[Tuple[int, SolveTicket, int, str]] = []
        for j, ticket in enumerate(batch.slots):
            if ticket is None:
                continue
            spent = block_it - batch.insert_it[j]
            if ticket._cancel_requested:
                batch.slots[j] = None
                ticket._finish("cancelled", now)
                self.completed.append(ticket)
                self.stats["cancelled"] += 1
            elif done[j] or spent >= ticket.maxiter:
                retiring.append((j, ticket, spent, "done"))
            elif ticket.deadline is not None and now >= ticket.deadline:
                # running past its deadline: retire with the best-effort
                # iterate (column and block batches alike)
                retiring.append((j, ticket, spent, "expired"))
        if retiring:
            res = batch.finalize(state)              # one readout per sweep
            idx = [j for j, _, _, _ in retiring]
            xs = np.asarray(batch.op.from_op_space(res.x[:, idx]))
            resn = np.asarray(res.resnorm)
            for m, (j, ticket, spent, status) in enumerate(retiring):
                ticket.result = ServiceResult(
                    x=xs[:, m], iters=spent, resnorm=float(resn[j]),
                    converged=bool(done[j]))
                ticket._finish(status, now)
                batch.slots[j] = None
                self.completed.append(ticket)
                if status == "done":
                    self.stats["retired"] += 1
                    self.stats["converged"] += int(done[j])
                else:
                    self.stats["expired"] += 1
        self._refill(batch)

    # ------------------------------------------- spectral (KPM/ChebFD) side
    def kpm_moments(self, matrix: str, n_moments: int, **kw):
        """KPM DOS moments using the registry's cached spectral bounds."""
        from repro.solvers.kpm import kpm_dos_moments
        op = self.registry.operator(matrix)
        spectrum = kw.pop("spectrum", None) or \
            self.registry.spectral_bounds(matrix)
        return kpm_dos_moments(op, n_moments, spectrum=spectrum, **kw)

    def chebfd(self, matrix: str, target: Tuple[float, float], **kw):
        """Chebyshev filter diagonalization with cached spectral bounds."""
        from repro.solvers.chebfd import chebfd
        op = self.registry.operator(matrix)
        spectrum = kw.pop("spectrum", None) or \
            self.registry.spectral_bounds(matrix)
        return chebfd(op, target, spectrum=spectrum, **kw)

    def describe(self) -> str:
        qs = {"/".join(map(str, k)): len(q)
              for k, q in self._queues.items() if q}
        return (f"SolverService(width={self.block_width}, "
                f"chunk={self.chunk_iters}, admission={self.admission}, "
                f"batches={len(self._batches)}, "
                f"queued={qs}, stats={self.stats})")
