"""Continuous-batching solver service on the stepper-form Krylov solvers.

GHOST's pitch (C2 + C5) is that many independent sparse solves should be
fed through one high-intensity block-vector kernel stream with the
runtime doing intelligent resource management.  This module is that
runtime for the solve workload:

* :class:`MatrixRegistry` caches the expensive per-matrix setup —
  SELL-C-sigma conversion/permutation (or a prebuilt
  :class:`~repro.runtime.engine.HeterogeneousEngine` for sharded
  matrices), the solver-facing operator, optional autotuned tile knobs
  via :mod:`repro.core.execution`, and Lanczos spectral bounds for
  KPM/ChebFD requests.  Registering the same name twice is a cache hit.

* :class:`SolverService` accepts asynchronous solve requests (matrix
  handle, right-hand side, solver kind, tolerance, optional
  preconditioner spec) and coalesces them into fixed-width block solves
  per ``(matrix, solver, dtype, precond, store_dtype)`` key —
  preconditioned and plain requests on the same matrix batch
  separately, because their stepper states differ; requests against
  matrices with different value-*storage* dtypes (mixed-precision
  SELL-C-σ) batch separately too, because their compiled matvecs and
  numerics differ.  Preconditioners themselves (block-Jacobi
  factorization, Chebyshev spectral bounds) are registry-cached setup,
  shared across every request that names the same spec.
  Each :meth:`~SolverService.step` advances every active block by one
  jitted k-iteration chunk (``cg_step`` / ``minres_step`` / ...),
  retires converged columns, and refills the freed slots from the queue
  — *continuous batching*, possible because per-column convergence is
  independent in block CG/MINRES and the stepper state carries it.

Typical use::

    reg = MatrixRegistry()
    reg.register("laplace", rows=r, cols=c, vals=v, shape=(n, n), C=16)
    svc = SolverService(reg, block_width=8, chunk_iters=16)
    t1 = svc.submit("laplace", b1, solver="cg", tol=1e-7)
    t2 = svc.submit("laplace", b2, solver="minres", tol=1e-5)
    svc.drain()                      # or svc.step() under your own loop
    x1 = t1.result.x                 # original (unpermuted) space

Everything is synchronous under the hood (one Python thread drives the
chunks); "asynchronous" refers to the request lifecycle — submit never
blocks, results materialize as the service is stepped.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
import weakref
from collections import deque
from contextlib import nullcontext
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execution
from repro.core.sellcs import SellCS, from_coo
from repro.solvers.cg import (cg_finalize, cg_init, cg_step,
                              pipelined_cg_finalize, pipelined_cg_init,
                              pipelined_cg_step)
from repro.solvers.minres import minres_finalize, minres_init, minres_step
from repro.solvers.operator import make_operator
from repro.solvers.stepper import merge_columns_masked

__all__ = ["MatrixRegistry", "SolverService", "SolveTicket", "ServiceResult",
           "SOLVERS"]

#: solver kind -> (init, step, finalize) stepper triple
SOLVERS = {
    "cg": (cg_init, cg_step, cg_finalize),
    "pipelined_cg": (pipelined_cg_init, pipelined_cg_step,
                     pipelined_cg_finalize),
    "minres": (minres_init, minres_step, minres_finalize),
}

_BLOCK_MAXITER = np.iinfo(np.int32).max // 2   # block counter never binds


# ---------------------------------------------------------------- registry
@dataclasses.dataclass
class _Entry:
    name: str
    matrix: object                    # SellCS | HeterogeneousEngine | op
    op: object                        # solver-facing operator
    nglobal: int                      # original-space rhs length
    build_seconds: float
    tuned: dict                       # execution-policy knobs (may be empty)
    store_dtype: str = ""             # resolved value-storage dtype name
    fingerprint: Optional[tuple] = None   # COO identity (shape/nnz/sums)
    bounds: Optional[Tuple[float, float]] = None
    preconds: dict = dataclasses.field(default_factory=dict)  # spec -> M


def _resolved_store_dtype(vals, dtype, store_dtype) -> str:
    """The storage dtype a ``from_coo(dtype=, store_dtype=)`` build ends
    up with — ``store_dtype=None`` resolves to the (canonicalized)
    compute dtype, matching ``SellCS.store_dtype``, so an explicit
    ``store_dtype`` equal to the compute dtype fingerprints identically
    to the default (the two builds are pinned bit-identical)."""
    if store_dtype is not None:
        return str(jnp.dtype(store_dtype))
    base = dtype if dtype is not None else np.asarray(vals).dtype
    return str(jnp.zeros((0,), base).dtype)


def _coo_fingerprint(rows, cols, vals, shape, store: str = "") -> tuple:
    import hashlib
    h = hashlib.sha256()
    for a in (np.ascontiguousarray(rows), np.ascontiguousarray(cols),
              np.ascontiguousarray(vals)):
        h.update(a.tobytes())
    v = np.asarray(vals)
    # the *resolved* storage dtype is part of the matrix identity: the
    # same COO payload at a different storage width is a different
    # registered matrix (see _resolved_store_dtype)
    return (tuple(shape), int(v.size), str(v.dtype), store, h.hexdigest())


def _storage_dtype_of(matrix, op) -> str:
    """Resolved value-storage dtype of a registered matrix/operator."""
    sd = getattr(matrix, "store_dtype", None)       # SellCS | engine
    if sd is None:
        inner = getattr(op, "A", None)              # DistOperator et al.
        sd = getattr(inner, "store_dtype", None)
    if sd is None:
        sd = getattr(op, "dtype", "")               # bare operator: compute
    return str(sd)


class MatrixRegistry:
    """Cache of per-matrix setup shared across solver requests.

    The expensive work a request must *not* repay: SELL-C-sigma
    conversion and permutation vectors, operator construction (including
    a :class:`DistOperator` over a heterogeneous engine), autotuned tile
    knobs, and the short Lanczos run that brackets the spectrum for
    KPM/ChebFD.  ``stats`` counts builds vs. cache hits so a service can
    report its cache effectiveness.
    """

    def __init__(self):
        self._entries: Dict[str, _Entry] = {}
        self.stats = {"builds": 0, "hits": 0,
                      "bounds_computed": 0, "bounds_hits": 0,
                      "precond_builds": 0, "precond_hits": 0}

    # -------------------------------------------------------------- admin
    def register(self, name: str, matrix=None, *,
                 rows=None, cols=None, vals=None, shape=None,
                 C: int = 32, sigma: int = 1, w_align: int = 1, dtype=None,
                 store_dtype=None,
                 impl: str = "ref", interpret: Optional[bool] = None,
                 autotune_tiles: bool = False) -> str:
        """Register a matrix under ``name`` (idempotent — reuse is a hit).

        ``matrix`` may be a prebuilt :class:`SellCS`, a
        :class:`~repro.runtime.engine.HeterogeneousEngine` (sharded
        matrices run through :class:`DistOperator` unchanged), or an
        operator implementing the full solver protocol (``mv``,
        ``mv_fused``, ``n``, ``dtype``, ``to_op_space``,
        ``from_op_space`` — e.g. :class:`MatrixFreeOperator`).
        Alternatively pass COO triplets (``rows``/``cols``/``vals``/
        ``shape``) and the SELL-C-sigma build happens here, once.
        ``store_dtype`` narrows the stored values (mixed-precision SpMV;
        see :func:`repro.core.sellcs.from_coo`) and is part of the matrix
        identity — the same COO data at two storage widths must be two
        registrations, and their requests batch separately.

        Re-registering a name with the *same* payload is a cache hit;
        with a different matrix (different COO bytes *or* a different
        ``store_dtype``) it raises — silently serving a stale operator
        would return converged answers to the wrong system.
        """
        if name in self._entries:
            e = self._entries[name]
            if matrix is not None:
                if matrix is not e.matrix:
                    raise ValueError(
                        f"matrix {name!r} is already registered with a "
                        f"different object; use a new name")
            elif vals is not None:
                sd = _resolved_store_dtype(vals, dtype, store_dtype)
                if _coo_fingerprint(rows, cols, vals, shape,
                                    sd) != e.fingerprint:
                    raise ValueError(
                        f"matrix {name!r} is already registered with "
                        f"different COO data or storage dtype; use a "
                        f"new name")
            self.stats["hits"] += 1
            return name
        t0 = time.perf_counter()
        fingerprint = None
        if matrix is None:
            if rows is None or cols is None or vals is None or shape is None:
                raise ValueError(
                    "register() needs either a prebuilt matrix/operator or "
                    "COO triplets rows/cols/vals plus shape")
            fingerprint = _coo_fingerprint(
                rows, cols, vals, shape,
                _resolved_store_dtype(vals, dtype, store_dtype))
            matrix = from_coo(rows, cols, vals, tuple(shape), C=C,
                              sigma=sigma, w_align=w_align, dtype=dtype,
                              store_dtype=store_dtype)
        if hasattr(matrix, "mv") and hasattr(matrix, "mv_fused"):
            missing = [a for a in ("n", "dtype", "to_op_space",
                                   "from_op_space") if not hasattr(matrix, a)]
            if missing:
                raise TypeError(
                    f"operator for {name!r} is missing {missing}; the "
                    f"service needs the full solver protocol (mv, mv_fused, "
                    f"n, dtype, to_op_space, from_op_space)")
            op = matrix                               # already an operator
        else:
            op = make_operator(matrix, impl=impl, interpret=interpret)
        # original-space rhs length: the matrix knows it; a bare operator
        # falls back to its wrapped matrix/engine, then to op.n
        nglobal = getattr(matrix, "nrows", None)
        if nglobal is None:
            inner = getattr(op, "A", None) or getattr(op, "engine", None)
            nglobal = getattr(inner, "nrows", None) or op.n
        sdt = _storage_dtype_of(matrix, op)
        tuned: dict = {}
        if autotune_tiles:
            probe = jnp.zeros((op.n, 8), op.dtype)
            def _run(t):
                with execution.force(row_tile=t):
                    return op.mv(probe)
            # storage + compute dtype both key the tuned tile: a narrower
            # value stream shifts the bandwidth balance
            best = execution.autotune(
                "service.row_tile", (name, op.n),
                (256, 512, 1024), _run,
                dtype=(sdt, str(jnp.dtype(op.dtype))))
            tuned = {"row_tile": int(best)}
        self._entries[name] = _Entry(
            name=name, matrix=matrix, op=op, nglobal=int(nglobal),
            build_seconds=time.perf_counter() - t0, tuned=tuned,
            store_dtype=sdt, fingerprint=fingerprint)
        self.stats["builds"] += 1
        return name

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self) -> List[str]:
        return list(self._entries)

    # ------------------------------------------------------------- lookups
    def entry(self, name: str) -> _Entry:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(f"matrix {name!r} is not registered "
                           f"(have: {sorted(self._entries)})") from None

    def operator(self, name: str):
        return self.entry(name).op

    def tuned(self, name: str) -> dict:
        return dict(self.entry(name).tuned)

    def spectral_bounds(self, name: str, *, k: int = 30, seed: int = 0,
                        safety: float = 1.05) -> Tuple[float, float]:
        """Cached Lanczos (lambda_min, lambda_max) bracket for KPM/ChebFD."""
        e = self.entry(name)
        if e.bounds is None:
            from repro.solvers.lanczos import lanczos_extrema
            e.bounds = lanczos_extrema(e.op, k=k, seed=seed, safety=safety)
            self.stats["bounds_computed"] += 1
        else:
            self.stats["bounds_hits"] += 1
        return e.bounds

    def preconditioner(self, name: str, spec: str):
        """Cached preconditioner for matrix ``name`` (the setup a request
        must not repay: block extraction + factorization, or the Lanczos
        bounds run behind a Chebyshev polynomial).

        ``spec`` is ``"block_jacobi[:<block_size>]"`` (needs a SELL-C-σ
        matrix — the blocks come straight out of its storage) or
        ``"chebyshev[:<degree>]"`` (works for *any* registered operator,
        including engine-backed :class:`DistOperator` matrices, because
        it only calls ``mv_fused``).  Same spec twice is a cache hit.
        """
        from repro.solvers.precond import (make_preconditioner,
                                           parse_precond_spec)
        kind, param = parse_precond_spec(spec)         # normalize + validate
        norm = kind if param is None else f"{kind}:{param}"
        e = self.entry(name)
        M = e.preconds.get(norm)
        if M is not None:
            self.stats["precond_hits"] += 1
            return M
        if kind.startswith("block_jacobi"):
            A = e.matrix if isinstance(e.matrix, SellCS) else \
                getattr(e.op, "A", None)
            if not isinstance(A, SellCS):
                raise ValueError(
                    f"matrix {name!r} is not SELL-C-σ backed "
                    f"({type(e.matrix).__name__}); block_jacobi needs the "
                    f"stored blocks — use chebyshev for engine-backed or "
                    f"matrix-free operators")
            M = make_preconditioner(norm, matrix=A)
        else:
            M = make_preconditioner(norm, op=e.op,
                                    spectrum=self.spectral_bounds(name))
        e.preconds[norm] = M
        self.stats["precond_builds"] += 1
        return M


# ----------------------------------------------------------------- requests
class ServiceResult(NamedTuple):
    x: np.ndarray                     # solution, original (unpermuted) space
    iters: int                        # block iterations spent on this column
    resnorm: float
    converged: bool


class SolveTicket:
    """Handle for one submitted request (fills in as the service steps)."""

    def __init__(self, req_id: int, matrix: str, solver: str, b, tol: float,
                 maxiter: int, precond: Optional[str] = None):
        self.id = req_id
        self.matrix = matrix
        self.solver = solver
        self.precond = precond
        self.b = b
        self.tol = float(tol)
        self.maxiter = int(maxiter)
        self.submitted_at = time.perf_counter()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.result: Optional[ServiceResult] = None

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def __repr__(self) -> str:
        state = "done" if self.done else (
            "running" if self.started_at else "queued")
        pc = f" precond={self.precond}" if self.precond else ""
        return (f"SolveTicket(#{self.id} {self.solver}@{self.matrix} "
                f"tol={self.tol:g}{pc} {state})")


@dataclasses.dataclass
class _Batch:
    key: tuple       # (matrix, solver, dtype, precond, store_dtype, block)
    op: object
    tuned: dict
    init: object                      # jitted (B, tols[, X0]) -> fresh state
    step: object
    finalize: object                  # jitted state -> solver Result
    merge: object                     # jitted (old, fresh, mask) -> state
    M: object = None                  # preconditioner (None = plain)
    state: object = None
    slots: List[Optional[SolveTicket]] = dataclasses.field(
        default_factory=list)
    insert_it: List[int] = dataclasses.field(default_factory=list)
    block: bool = False               # shared-Krylov block batch
    # block batches re-init on refill (their states cannot be column-
    # spliced), so the whole rhs block and tolerances are carried here
    Bg: Optional[np.ndarray] = None   # (nglobal, w) original-space rhs
    tols_np: Optional[np.ndarray] = None

    @property
    def active(self) -> int:
        return sum(t is not None for t in self.slots)


# ------------------------------------------------------------------ service
class SolverService:
    """Coalesce independent solve requests into continuous block solves.

    ``block_width`` fixes the block-vector width of every batch (one
    compiled chunk program per ``(operator, solver, chunk_iters)``);
    ``chunk_iters`` is the number of solver iterations run between
    retire/refill opportunities — small values react faster to mixed
    tolerances, large values amortize Python overhead.
    """

    def __init__(self, registry: MatrixRegistry, *, block_width: int = 8,
                 chunk_iters: int = 16, completed_log: int = 4096):
        if block_width < 1:
            raise ValueError("block_width must be >= 1")
        if chunk_iters < 1:
            raise ValueError("chunk_iters must be >= 1")
        self.registry = registry
        self.block_width = int(block_width)
        self.chunk_iters = int(chunk_iters)
        self._queues: Dict[tuple, deque] = {}
        self._batches: Dict[tuple, _Batch] = {}
        self._jit_cache: Dict[tuple, tuple] = {}   # key -> (init, fin, merge)
        self._ids = itertools.count()
        # recently retired tickets, newest last; bounded so a long-lived
        # service does not pin every rhs/solution ever served (callers
        # hold their own tickets — this is a convenience log)
        self.completed: deque = deque(
            maxlen=completed_log if completed_log > 0 else None)
        self.stats = {"submitted": 0, "retired": 0, "converged": 0,
                      "chunks": 0, "refills": 0, "batches_opened": 0}

    # -------------------------------------------------------------- submit
    def submit(self, matrix: str, b, *, solver: str = "cg",
               tol: float = 1e-8, maxiter: int = 500,
               precond: Optional[str] = None,
               block: bool = False) -> SolveTicket:
        """Enqueue one solve of ``A x = b`` (``b`` in original space).

        ``precond`` is a spec string (``"block_jacobi[:<bs>]"`` or
        ``"chebyshev[:<degree>]"``, see
        :meth:`MatrixRegistry.preconditioner`) or ``None``.  It is part
        of the batch key, so preconditioned and plain requests on the
        same matrix coalesce into *separate* block solves — the stepper
        states have different shapes and must never share a block.

        ``block=True`` routes the request into a **block-Krylov** batch
        (``cg``/``minres`` only, unpreconditioned): all columns of that
        batch share one Krylov space per block — fewer SpMV sweeps per
        converged request on shared-matrix multi-rhs traffic, at the
        cost of a warm restart whenever the batch refills (see
        ``docs/block_krylov.md``).  Block and column-wise requests on
        the same matrix batch separately.
        """
        if solver not in SOLVERS:
            raise ValueError(f"unknown solver {solver!r} "
                             f"(have: {sorted(SOLVERS)})")
        entry = self.registry.entry(matrix)         # validates the handle
        if block:
            if solver not in ("cg", "minres"):
                raise NotImplementedError(
                    f"block=True supports solver='cg'/'minres', "
                    f"not {solver!r}")
            if precond is not None:
                raise NotImplementedError(
                    "block=True with a preconditioner is not implemented; "
                    "drop precond= or submit with block=False")
        if precond is not None:
            if solver == "pipelined_cg":
                raise NotImplementedError(
                    "pipelined_cg does not support preconditioning; "
                    "use solver='cg' with precond=, or drop precond")
            from repro.solvers.precond import parse_precond_spec
            kind, param = parse_precond_spec(precond)   # fail at submit,
            precond = kind if param is None else f"{kind}:{param}"
        # validate the rhs here: a malformed b discovered at refill time
        # would already have dequeued (and would lose) sibling requests
        b = np.asarray(b)
        if b.ndim != 1 or b.shape[0] != entry.nglobal:
            raise ValueError(
                f"rhs for {matrix!r} must be 1-d of length {entry.nglobal} "
                f"(original space), got shape {b.shape}")
        ticket = SolveTicket(next(self._ids), matrix, solver, b, tol,
                             maxiter, precond)
        # storage dtype and block mode are the trailing key components:
        # requests against f32-stored and bf16-stored matrices never
        # share a block solve (their compiled matvecs — and their
        # numerics — differ), and block-Krylov batches never mix with
        # column-wise ones (their stepper states differ)
        key = (matrix, solver, str(jnp.dtype(entry.op.dtype)),
               precond or "", entry.store_dtype,
               "block" if block else "")
        self._queues.setdefault(key, deque()).append(ticket)
        self.stats["submitted"] += 1
        return ticket

    @property
    def pending(self) -> int:
        """Requests submitted but not yet retired."""
        queued = sum(len(q) for q in self._queues.values())
        running = sum(b.active for b in self._batches.values())
        return queued + running

    # --------------------------------------------------------------- steps
    def step(self) -> int:
        """Advance every active batch by one chunk; returns chunks run."""
        for key, queue in self._queues.items():
            if queue and key not in self._batches:
                self._open_batch(key)
        chunks = 0
        for key in list(self._batches):
            batch = self._batches[key]
            self._run_chunk(batch)
            chunks += 1
            self._retire_and_refill(batch)
            if batch.active == 0 and not self._queues.get(key):
                del self._batches[key]
        return chunks

    def drain(self, max_steps: int = 100_000) -> "deque":
        """Step until every submitted request has been retired."""
        steps = 0
        while self.pending:
            if steps >= max_steps:
                raise RuntimeError(
                    f"service did not drain in {max_steps} steps "
                    f"({self.pending} requests pending)")
            self.step()
            steps += 1
        return self.completed

    # ------------------------------------------------------------ internals
    def _open_batch(self, key: tuple) -> None:
        matrix, solver, _, precond, _store, blk = key
        blk = bool(blk)
        entry = self.registry.entry(matrix)
        init, step, fin = SOLVERS[solver]
        op = entry.op
        # built (or cache-hit) once per batch key — block extraction /
        # factorization and the Lanczos bounds are registry-cached setup
        M = (self.registry.preconditioner(matrix, precond)
             if precond else None)
        jitted = self._jit_cache.get(key)
        if jitted is None:
            # init / finalize / merge are the between-chunk glue; jitting
            # them (cached across batch reopenings) keeps the service's
            # per-refill cost at one compiled call instead of a stream of
            # eager dispatches.  The cached closure must not own the
            # operator or preconditioner (the registry controls their
            # lifetime) — hold weakrefs and fail loudly if the entry is
            # evicted out from under the cache.
            op_ref = weakref.ref(op)
            M_ref = weakref.ref(M) if M is not None else None

            def _init(B, tols, X0=None):
                o = op_ref()
                if o is None:
                    raise ReferenceError(
                        "operator evicted while its batch init was cached")
                m = M_ref() if M_ref is not None else None
                if M_ref is not None and m is None:
                    raise ReferenceError("preconditioner evicted while "
                                         "its batch init was cached")
                if blk:
                    return init(o, B, X0, tol=tols, maxiter=_BLOCK_MAXITER,
                                M=m, block=True)
                return init(o, B, tol=tols, maxiter=_BLOCK_MAXITER, M=m)

            jitted = (
                jax.jit(_init),
                jax.jit(fin),
                jax.jit(merge_columns_masked),
            )
            self._jit_cache[key] = jitted
        batch = _Batch(key=key, op=op, tuned=entry.tuned,
                       init=jitted[0], step=step, finalize=jitted[1],
                       merge=jitted[2], M=M, block=blk,
                       slots=[None] * self.block_width,
                       insert_it=[0] * self.block_width)
        self._batches[key] = batch
        self.stats["batches_opened"] += 1
        self._refill(batch)

    def _policy_scope(self, batch: _Batch):
        return (execution.force(**batch.tuned) if batch.tuned
                else nullcontext())

    def _refill(self, batch: _Batch) -> None:
        """Pull queued requests into the batch's free column slots."""
        if batch.block:
            self._refill_block(batch)
            return
        queue = self._queues.get(batch.key)
        free = [j for j, t in enumerate(batch.slots) if t is None]
        if not queue or not free:
            return
        op, w = batch.op, self.block_width
        dtype = jnp.dtype(op.dtype)
        rdt = jnp.finfo(dtype).dtype               # tolerance dtype
        taken: List[Tuple[int, SolveTicket]] = []
        now = time.perf_counter()
        Bg = None
        tols = np.ones(w, rdt)
        for j in free:
            if not queue:
                break
            ticket = queue.popleft()
            ticket.started_at = now
            col = np.asarray(ticket.b)
            if Bg is None:                          # global-space rhs block
                Bg = np.zeros((col.shape[0], w), dtype)
            Bg[:, j] = col
            tols[j] = ticket.tol
            taken.append((j, ticket))
        if not taken:
            return
        with self._policy_scope(batch):
            Bop = op.to_op_space(jnp.asarray(Bg))   # one permute per refill
            fresh = batch.init(Bop, jnp.asarray(tols))
        if batch.state is None:
            batch.state = fresh        # empty slots: zero rhs, done at init
            block_it = 0
        else:
            mask = np.zeros(w, bool)
            mask[[j for j, _ in taken]] = True
            batch.state = batch.merge(batch.state, fresh, jnp.asarray(mask))
            block_it = int(batch.state.it)
        for j, ticket in taken:
            batch.slots[j] = ticket
            batch.insert_it[j] = block_it
        self.stats["refills"] += 1

    def _refill_block(self, batch: _Batch) -> None:
        """Refill a block-Krylov batch with a warm restart.

        Block states carry cross-column ``(b, b)`` Gram/reflection blocks,
        so columns cannot be spliced (``merge_columns_masked`` raises on
        them).  Instead the whole batch re-inits: survivors keep their
        current iterate as ``x0`` (a warm restart — their built-up Krylov
        information lives on in ``x``), newcomers start from zero, and
        empty slots get a zero rhs, which the zero-b fast path marks done
        at init so SVQB deflates them immediately.  ``insert_it`` goes
        negative for survivors to keep per-ticket iteration accounting
        exact across the restart (the fresh state's ``it`` is 0).
        """
        queue = self._queues.get(batch.key)
        free = [j for j, t in enumerate(batch.slots) if t is None]
        if not queue or not free:
            return
        op, w = batch.op, self.block_width
        dtype = jnp.dtype(op.dtype)
        rdt = jnp.finfo(dtype).dtype
        if batch.Bg is None:
            n0 = np.asarray(queue[0].b).shape[0]
            batch.Bg = np.zeros((n0, w), dtype)
            batch.tols_np = np.ones(w, rdt)
        # per-slot iterations already spent by surviving tickets, measured
        # before the restart resets the block counter
        spent = [0] * w
        if batch.state is not None:
            block_it = int(batch.state.it)
            for j, t in enumerate(batch.slots):
                if t is not None:
                    spent[j] = block_it - batch.insert_it[j]
        taken: List[Tuple[int, SolveTicket]] = []
        now = time.perf_counter()
        for j in free:
            batch.Bg[:, j] = 0          # stale rhs of a retired ticket
            batch.tols_np[j] = 1.0
            if not queue:
                continue
            ticket = queue.popleft()
            ticket.started_at = now
            batch.Bg[:, j] = np.asarray(ticket.b)
            batch.tols_np[j] = ticket.tol
            taken.append((j, ticket))
        if not taken and batch.state is not None:
            return                      # nothing queued: keep iterating
        with self._policy_scope(batch):
            Bop = op.to_op_space(jnp.asarray(batch.Bg))
            if batch.state is None:
                X0 = None
            else:
                free_mask = np.zeros(w, bool)
                free_mask[free] = True
                X0 = jnp.where(jnp.asarray(free_mask)[None, :], 0,
                               batch.state.x)
            batch.state = batch.init(Bop, jnp.asarray(batch.tols_np), X0)
        for j, ticket in taken:
            batch.slots[j] = ticket
        for j, t in enumerate(batch.slots):
            batch.insert_it[j] = -spent[j] if (t is not None and
                                               spent[j]) else 0
        self.stats["refills"] += 1

    def _run_chunk(self, batch: _Batch) -> None:
        with self._policy_scope(batch):
            batch.state = batch.step(batch.op, batch.state,
                                     self.chunk_iters, M=batch.M)
        self.stats["chunks"] += 1

    def _retire_and_refill(self, batch: _Batch) -> None:
        state = batch.state
        done = np.asarray(state.done)
        block_it = int(state.it)
        retiring: List[Tuple[int, SolveTicket, int]] = []
        for j, ticket in enumerate(batch.slots):
            if ticket is None:
                continue
            spent = block_it - batch.insert_it[j]
            if done[j] or spent >= ticket.maxiter:
                retiring.append((j, ticket, spent))
        if retiring:
            res = batch.finalize(state)              # one readout per sweep
            idx = [j for j, _, _ in retiring]
            xs = np.asarray(batch.op.from_op_space(res.x[:, idx]))
            resn = np.asarray(res.resnorm)
            now = time.perf_counter()
            for m, (j, ticket, spent) in enumerate(retiring):
                ticket.result = ServiceResult(
                    x=xs[:, m], iters=spent, resnorm=float(resn[j]),
                    converged=bool(done[j]))
                ticket.finished_at = now
                batch.slots[j] = None
                self.completed.append(ticket)
                self.stats["retired"] += 1
                self.stats["converged"] += int(done[j])
        self._refill(batch)

    # ------------------------------------------- spectral (KPM/ChebFD) side
    def kpm_moments(self, matrix: str, n_moments: int, **kw):
        """KPM DOS moments using the registry's cached spectral bounds."""
        from repro.solvers.kpm import kpm_dos_moments
        op = self.registry.operator(matrix)
        spectrum = kw.pop("spectrum", None) or \
            self.registry.spectral_bounds(matrix)
        return kpm_dos_moments(op, n_moments, spectrum=spectrum, **kw)

    def chebfd(self, matrix: str, target: Tuple[float, float], **kw):
        """Chebyshev filter diagonalization with cached spectral bounds."""
        from repro.solvers.chebfd import chebfd
        op = self.registry.operator(matrix)
        spectrum = kw.pop("spectrum", None) or \
            self.registry.spectral_bounds(matrix)
        return chebfd(op, target, spectrum=spectrum, **kw)

    def describe(self) -> str:
        qs = {"/".join(map(str, k)): len(q)
              for k, q in self._queues.items() if q}
        return (f"SolverService(width={self.block_width}, "
                f"chunk={self.chunk_iters}, batches={len(self._batches)}, "
                f"queued={qs}, stats={self.stats})")
