"""Overlapped halo pipeline with double-buffered staging (GHOST 4.2, Fig. 5).

GHOST hides the halo exchange behind the local SpMV by putting the
communication in a *task* that runs concurrently with the local compute
kernel.  The XLA mapping of that idea is data-flow independence: the local
stage consumes only ``x_local`` while the ``all_to_all`` runs, so the async
collective scheduler may overlap them; ``overlap=False`` reinstates the
paper's "No Overlap" baseline with an optimization barrier.

What this module adds over ``core.distributed.dist_spmv_shard``:

* the shard step is recomposed from the *named stages* exported by
  ``core.distributed`` (pack / exchange+unpack / local / remote /
  epilogue) so schedules can be rearranged without touching the math;
* **double-buffered halo staging**: each call packs its send buffer into
  slot 0 of a two-slot staging array while slot 1 keeps the previous
  call's buffer alive.  Across a chained sequence of SpMVs (CG sweeps,
  KPM recurrences) iteration ``k+1``'s pack therefore never write-after-
  read depends on iteration ``k``'s possibly in-flight exchange — the
  invariant GHOST's MPI task-mode needs two buffers for.  Under XLA's
  SSA semantics that invariant already holds implicitly, so today the
  staging array is *structural*: it materializes the buffer rotation as
  a carried value (a measurable copy per call — fig5 reports it as
  ``staging_overhead``) and is the hook where a future Pallas RDMA
  exchange would pin its landing buffers, which is when the two slots
  become load-bearing;
* traced coefficients: alpha/beta/gamma arrive as a ``(3, b)`` operand so
  solvers can change them every iteration without retracing;
* dtype contract: the halo/staging buffers carry *vector* data and stay
  in the compute dtype; the matrix value shards (``l_vals``/``r_vals``)
  stay in their **storage** dtype end-to-end — a mixed-precision matrix
  streams narrow values through both the local and the remote stage and
  upcasts in-register only (``docs/mixed_precision.md``).

All functions here run *inside* ``shard_map`` except
:func:`make_pipeline_spmv`, which builds the jitted SPMD callable.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import execution
from repro.core.distributed import (
    DistSellCS, _shard_view, shard_map, spmv_shard_stages,
)
from repro.core.spmv import SpmvOpts

__all__ = ["make_pipeline_spmv", "init_staging"]


def init_staging(A: DistSellCS, nvecs: int, dtype) -> jax.Array:
    """Fresh double-buffer halo staging: (nshards, 2, P, max_msg, nvecs)."""
    return jnp.zeros((A.nshards, 2, A.nshards, A.max_msg, nvecs),
                     jnp.dtype(dtype))


def make_pipeline_spmv(
    A: DistSellCS,
    mesh: Mesh,
    axis: str = "data",
    *,
    overlap: bool = True,
    impl: str = "ref",
    interpret: Optional[bool] = None,
    nvecs: int = 1,
    with_y: bool = False,
    dot_yy: bool = False,
    dot_xy: bool = False,
    dot_xx: bool = False,
    has_gamma: bool = False,
    double_buffer: bool = False,
):
    """Build the jitted SPMD pipelined SpMV over stacked shard vectors.

    Returns ``run(x_stacked, y_stacked=None, coefs=None, staging=None)``
    mapping ``(P, m_pad, nvecs)`` inputs to ``(y_stacked, dots, staging')``.
    ``coefs`` is a ``(3, nvecs)`` array of per-column (alpha, beta, gamma)
    — traced, so solvers vary them iteration-to-iteration for free.  The
    static flags (``with_y``, dot selection, ``has_gamma``) pick the
    specialized kernel, mirroring GHOST's compile-time codegen (C6).
    ``interpret=None`` resolves through the central execution policy once
    at build time — the returned callable is pinned to that mode.
    """
    interpret = execution.resolve_interpret(interpret)
    sh = _shard_view(A)
    pspec = {k: P(axis, *([None] * (v.ndim - 1))) for k, v in sh.items()}
    vec = P(axis, None, None)
    stg = P(axis, None, None, None, None)

    in_specs = [pspec, vec]
    if with_y:
        in_specs.append(vec)
    in_specs.append(P(None, None))                 # coefs, replicated
    if double_buffer:
        in_specs.append(stg)

    out_specs = (vec, vec) + ((stg,) if double_buffer else ())

    def fn(shard, x, *rest):
        shard = {k: v[0] for k, v in shard.items()}
        rest = list(rest)
        y_local = rest.pop(0)[0] if with_y else None
        coefs = rest.pop(0)
        staging = rest.pop(0)[0] if double_buffer else None
        opts = SpmvOpts(alpha=coefs[0], beta=coefs[1],
                        gamma=coefs[2] if has_gamma else None,
                        dot_yy=dot_yy, dot_xy=dot_xy, dot_xx=dot_xx)
        y, dots, staging = spmv_shard_stages(
            A, shard, x[0], axis, overlap=overlap, impl=impl,
            interpret=interpret, opts=opts, y_local=y_local, staging=staging)
        dots_out = (jnp.zeros((1, 3, nvecs), y.dtype) if dots is None
                    else dots[None].astype(y.dtype))
        out = (y[None], dots_out)
        if double_buffer:
            out = out + (staging[None],)
        return out

    mapped = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs))
    any_dot = dot_yy or dot_xy or dot_xx

    def run(x_stacked, y_stacked=None, coefs=None, staging=None):
        if coefs is None:
            coefs = jnp.zeros((3, nvecs), x_stacked.dtype).at[0].set(1.0)
        args = [sh, x_stacked]
        if with_y:
            if y_stacked is None:
                raise ValueError(
                    "pipeline built with with_y=True needs y_stacked")
            args.append(y_stacked)
        args.append(coefs)
        if double_buffer:
            if staging is None:
                staging = init_staging(A, nvecs, x_stacked.dtype)
            args.append(staging)
        out = mapped(*args)
        y, dots = out[0], (out[1][0] if any_dot else None)
        return y, dots, (out[2] if double_buffer else None)

    return run
