"""Pallas TPU kernel: tall-skinny^H x tall-skinny GEMM (paper C2, Fig. 7).

``X = alpha * V^H W + beta * X`` with V ``(n, m)``, W ``(n, k)``, m,k << n.

The paper's observation: vendor GEMMs are built for square blocking and
collapse on tall & skinny shapes, where the kernel is *memory bound* (2n(m+k)
words moved for 2nmk flops).  The TPU-native design streams ``(Tn, m)`` /
``(Tn, k)`` row slabs through VMEM, runs an ``(m, Tn) @ (Tn, k)`` MXU matmul
per slab, and accumulates the tiny ``(m, k)`` result in a float32 VMEM
scratch across the sequential grid — one HBM sweep, no re-reads.

A Kahan-compensated variant keeps a second ``(m, k)`` compensation buffer in
VMEM (paper section 5.2: compensated tsmttsm at negligible flop overhead).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import execution
from repro.core.spmv import storage_acc_dtype as _acc_dtype

__all__ = ["tsmttsm_pallas"]


def _kernel(v_ref, w_ref, coef_ref, xin_ref, out_ref, acc_ref, comp_ref,
            *, kahan: bool, conj: bool, has_xin: bool, out_dtype):
    i = pl.program_id(0)
    nsteps = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if kahan:
            comp_ref[...] = jnp.zeros_like(comp_ref)

    acc_dt = acc_ref.dtype
    v = v_ref[...].astype(acc_dt)
    if conj:
        v = jnp.conj(v)
    w = w_ref[...].astype(acc_dt)

    if kahan:
        # Compensation can only absorb error *between* summands, so a
        # single (row_tile)-deep dot would leave its internal rounding
        # uncompensated.  Walk the slab in 8-row micro-slabs (8 = VPU
        # sublane height; smaller divisor for odd tiles) and Kahan-
        # accumulate one 2-D dot per micro-slab — plain 2-D dots and
        # aligned dynamic_slice so Mosaic lowers it (batched rank-3
        # dot_general would not).  The uncompensated window shrinks
        # from row_tile to g rows.
        g = next(d for d in (8, 4, 2, 1) if v.shape[0] % d == 0)
        G = v.shape[0] // g

        def body(j, carry):
            acc, comp = carry
            vs = jax.lax.dynamic_slice_in_dim(v, j * g, g, 0)
            ws = jax.lax.dynamic_slice_in_dim(w, j * g, g, 0)
            part = jax.lax.dot_general(
                vs, ws, (((0,), (0,)), ((), ())),
                preferred_element_type=acc_dt)
            y = part - comp
            t = acc + y
            return t, (t - acc) - y

        acc, comp = jax.lax.fori_loop(
            0, G, body, (acc_ref[...], comp_ref[...]))
        acc_ref[...] = acc
        comp_ref[...] = comp
    else:
        term = jax.lax.dot_general(
            v, w, (((0,), (0,)), ((), ())), preferred_element_type=acc_dt)
        acc_ref[...] = acc_ref[...] + term

    @pl.when(i == nsteps - 1)
    def _fin():
        alpha = coef_ref[0, 0]
        beta = coef_ref[0, 1]
        res = alpha * acc_ref[...]
        if has_xin:
            res = res + beta * xin_ref[...].astype(acc_dt)
        out_ref[...] = res.astype(out_dtype)


def tsmttsm_pallas(
    V: jax.Array,
    W: jax.Array,
    X: Optional[jax.Array] = None,
    alpha=1.0,
    beta=0.0,
    *,
    row_tile: int = 512,
    kahan: bool = False,
    conj: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """X = alpha * V^H W + beta * X.  Requires n % row_tile == 0 (ops.py pads).

    ``interpret=None`` defers to :mod:`repro.core.execution`.
    """
    from repro.core.blockvec import check_beta_needs_out
    check_beta_needs_out(beta, X, "tsmttsm_pallas")  # beta*X with X=None
    interpret = execution.resolve_interpret(interpret)  # would vanish
    n, m = V.shape
    n2, k = W.shape
    if n != n2:
        raise ValueError(
            f"tsmttsm: row counts disagree: V{V.shape} W{W.shape}")
    if n % row_tile != 0:
        raise ValueError(f"tsmttsm: n={n} not a multiple of "
                         f"row_tile={row_tile} (ops.py pads)")
    out_dtype = jnp.result_type(V.dtype, W.dtype)
    acc_dt = _acc_dtype(out_dtype)
    do_conj = conj and jnp.iscomplexobj(V)

    coefs = jnp.stack([jnp.asarray(alpha, acc_dt),
                       jnp.asarray(beta, acc_dt)]).reshape(1, 2)
    has_xin = X is not None
    xin = X if has_xin else jnp.zeros((m, k), out_dtype)

    grid = (n // row_tile,)
    kern = functools.partial(
        _kernel, kahan=kahan, conj=do_conj, has_xin=has_xin,
        out_dtype=out_dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, m), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((m, k), acc_dt),
            pltpu.VMEM((m, k), acc_dt),
        ],
        interpret=interpret,
    )(V, W, coefs, xin)
    return out
