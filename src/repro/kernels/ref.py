"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests ``assert_allclose`` against
(shape/dtype sweeps in ``tests/test_kernels.py``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import blockvec
from repro.core.sellcs import SellCS
from repro.core.spmv import SpmvOpts, spmv_ref, storage_acc_dtype

__all__ = ["sellcs_spmv_ref", "tsmttsm_ref", "tsmm_ref",
           "fused_axpby_dots_ref", "mamba_scan_ref", "block_diag_matmul_ref"]


def block_diag_matmul_ref(blocks: jax.Array, x: jax.Array) -> jax.Array:
    """Oracle for the batched block-diagonal matmul kernel.

    ``blocks`` is ``(nblocks, bs, bs)``, ``x`` is ``(nblocks*bs, b)``;
    returns ``y`` with ``y[k*bs:(k+1)*bs] = blocks[k] @ x[k*bs:(k+1)*bs]``.
    """
    nb, bs, _ = blocks.shape
    xb = x.reshape(nb, bs, x.shape[1])
    y = jnp.einsum("kij,kjb->kib", blocks, xb)
    return y.reshape(nb * bs, x.shape[1])


def mamba_scan_ref(dt, xc, Bc, Cc, A):
    """Oracle for the state-resident Mamba scan kernel: plain lax.scan."""
    B, S, di = dt.shape

    def step(h, t_in):
        dt_t, xc_t, Bc_t, Cc_t = t_in
        dA = jnp.exp(dt_t[..., None] * A[None])
        dBx = (dt_t * xc_t)[..., None] * Bc_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Cc_t)
        return h, y

    h0 = jnp.zeros((B, di, A.shape[1]), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0, tuple(jnp.moveaxis(a, 1, 0) for a in (dt, xc, Bc, Cc)))
    return jnp.moveaxis(ys, 0, 1)


def sellcs_spmv_ref(A: SellCS, x, y=None, z=None, opts: SpmvOpts = SpmvOpts()):
    """Delegates to the core segment-sum oracle."""
    return spmv_ref(A, x, y, z, opts)


def tsmttsm_ref(V, W, X=None, alpha=1.0, beta=0.0, *, conj=True):
    return blockvec.tsmttsm(V, W, X, alpha=alpha, beta=beta, conj=conj)


def tsmm_ref(V, X, W=None, alpha=1.0, beta=0.0):
    return blockvec.tsmm(V, X, W, alpha=alpha, beta=beta)


def fused_axpby_dots_ref(
    x: jax.Array, y: jax.Array, a=1.0, b=1.0,
    *, dot_yy=False, dot_xy=False, dot_xx=False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    acc = storage_acc_dtype(x.dtype)   # shared storage-vs-compute contract
    xf = x.astype(acc)
    yf = y.astype(acc)
    ynew = jnp.asarray(a, acc) * xf + jnp.asarray(b, acc) * yf
    dots = None
    if dot_yy or dot_xy or dot_xx:
        bw = x.shape[1]
        zero = jnp.zeros((bw,), acc)
        dots = jnp.stack([
            jnp.sum(ynew * ynew, axis=0) if dot_yy else zero,
            jnp.sum(xf * ynew, axis=0) if dot_xy else zero,
            jnp.sum(xf * xf, axis=0) if dot_xx else zero,
        ])
    return ynew.astype(x.dtype), dots
