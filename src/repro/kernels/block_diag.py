"""Pallas TPU kernel: batched block-diagonal matmul (preconditioner apply).

The block-Jacobi preconditioner (``solvers/precond.py``) factorizes the
aligned diagonal blocks of a SELL-C-sigma matrix host-side, once, into an
explicit ``(nblocks, bs, bs)`` stack of inverse blocks.  Every PCG/PMINRES
iteration then applies ``z = diag(B_0^{-1}, ..., B_{k-1}^{-1}) r`` — a
batched small-matmul sweep with perfect locality: block ``k`` touches only
rows ``[k*bs, (k+1)*bs)`` of ``r``.

Kernel layout: one grid step owns ``row_tile`` rows (= ``row_tile/bs``
blocks).  The block stack and the vector tile stream through VMEM in
matched slabs and the batched contraction runs as one ``dot_general`` per
tile, so the apply costs a single fused sweep over ``r`` — the same
memory-bound profile as the AXPBY-class kernels (paper C2), keeping the
preconditioner on the accelerator next to the SpMV instead of bouncing to
the host.

Requires ``row_tile % bs == 0`` and inputs padded to a ``row_tile``
multiple (the :func:`repro.kernels.ops.block_jacobi_apply` wrapper pads).
Validated in interpret mode against ``block_diag_matmul_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import execution
from repro.core.spmv import storage_acc_dtype as _acc_dtype

__all__ = ["block_diag_matmul_pallas"]


def _kernel(blocks_ref, x_ref, o_ref, *, nbt: int, bs: int, b: int,
            out_dtype):
    acc_dt = _acc_dtype(out_dtype)
    bl = blocks_ref[...].astype(acc_dt)                  # (nbt, bs, bs)
    xb = x_ref[...].astype(acc_dt).reshape(nbt, bs, b)   # (nbt, bs, b)
    # batched small matmul: y[k] = B_k @ x[k]
    y = jax.lax.dot_general(
        bl, xb,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=acc_dt)
    o_ref[...] = y.reshape(nbt * bs, b).astype(out_dtype)


def block_diag_matmul_pallas(
    blocks: jax.Array,            # (nblocks, bs, bs)
    x: jax.Array,                 # (nblocks * bs, b)
    *,
    row_tile: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``y[k*bs:(k+1)*bs] = blocks[k] @ x[k*bs:(k+1)*bs]`` for every block.

    ``row_tile`` must be a multiple of ``bs`` and divide the (padded) row
    count; the :func:`repro.kernels.ops.block_jacobi_apply` wrapper
    handles the padding.  ``interpret=None`` defers to
    :mod:`repro.core.execution`.
    """
    interpret = execution.resolve_interpret(interpret)
    nb, bs, bs2 = blocks.shape
    if bs != bs2:
        raise ValueError(f"blocks must be square, got ({bs}, {bs2})")
    n, b = x.shape
    if n != nb * bs:
        raise ValueError(f"x rows ({n}) != nblocks*bs ({nb}*{bs})")
    if row_tile % bs != 0 or row_tile <= 0:
        raise ValueError(f"row_tile ({row_tile}) must be a positive "
                         f"multiple of bs ({bs})")
    if n % row_tile != 0:
        raise ValueError(f"rows ({n}) must be a multiple of row_tile "
                         f"({row_tile}); pad first")
    nbt = row_tile // bs
    out_dtype = jnp.result_type(blocks.dtype, x.dtype)

    kern = functools.partial(_kernel, nbt=nbt, bs=bs, b=b,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(n // row_tile,),
        in_specs=[
            pl.BlockSpec((nbt, bs, bs), lambda t: (t, 0, 0)),
            pl.BlockSpec((row_tile, b), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, b), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), out_dtype),
        interpret=interpret,
    )(blocks, x)
