"""Pallas TPU kernel: state-resident selective-SSM (Mamba) scan (§Perf H3).

XLA keeps a ``lax.scan`` carry in HBM, so the recurrent Mamba state
(B, d_inner, N) — megabytes — is read AND written every timestep:
2*S*B*di*N*4 bytes of pure state traffic per layer.  This kernel pins the
state in VMEM for the whole sequence: the grid tiles (batch x d_inner),
each program streams its (S, tile, ...) input slabs and touches HBM only
for inputs and outputs — the same memory-hierarchy move the paper makes
for SpMV (keep the hot working set in the fast tier, stream the rest).

Forward/serve path (the train path uses the 'chunked' JAX form; a custom
VJP pairing is the standard TPU deployment).  Validated in interpret mode
against ``ref.mamba_scan_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import execution

__all__ = ["mamba_scan_pallas"]


def _kernel(dt_ref, xc_ref, b_ref, c_ref, a_ref, y_ref, h_ref, *,
            S: int, s_blk: int):
    h_ref[...] = jnp.zeros_like(h_ref)
    nb = S // s_blk

    def blk(ib, _):
        dt = dt_ref[0, pl.ds(ib * s_blk, s_blk), :]     # (s_blk, dt_tile)
        xc = xc_ref[0, pl.ds(ib * s_blk, s_blk), :]
        bc = b_ref[0, pl.ds(ib * s_blk, s_blk), :]      # (s_blk, N)
        cc = c_ref[0, pl.ds(ib * s_blk, s_blk), :]
        a = a_ref[...]                                  # (tile, N)

        def step(t, carry):
            h = h_ref[...]                              # (tile, N) VMEM
            dA = jnp.exp(dt[t][:, None] * a)
            dBx = (dt[t] * xc[t])[:, None] * bc[t][None, :]
            h = dA * h + dBx
            h_ref[...] = h
            y_ref[0, ib * s_blk + t, :] = jnp.sum(h * cc[t][None, :], axis=1)
            return carry

        return lax.fori_loop(0, s_blk, step, _)

    lax.fori_loop(0, nb, blk, 0)


def mamba_scan_pallas(dt, xc, Bc, Cc, A, *, d_tile: int = 512,
                      s_blk: int = 64, interpret: Optional[bool] = None):
    """y[b,s,d] = sum_n h[b,s,d,n] * Cc[b,s,n] with
    h = exp(dt*A) h + dt*xc*Bc  (recurrent over s; h stays in VMEM).

    dt, xc: (B, S, di) f32; Bc, Cc: (B, S, N) f32; A: (di, N) f32.
    ``interpret=None`` defers to :mod:`repro.core.execution`.
    """
    interpret = execution.resolve_interpret(interpret)
    B, S, di = dt.shape
    N = A.shape[1]
    dtile = min(d_tile, di)
    if di % dtile != 0:
        raise ValueError(
            f"mamba_scan: d_inner={di} not a multiple of d_tile={dtile}")
    if S % min(s_blk, S) != 0:
        raise ValueError(
            f"mamba_scan: seq len {S} not a multiple of s_blk={s_blk}")
    sb = min(s_blk, S)
    grid = (B, di // dtile)

    kern = functools.partial(_kernel, S=S, s_blk=sb)
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, dtile), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, S, dtile), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, S, N), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((dtile, N), lambda b, d: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, dtile), lambda b, d: (b, 0, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dtile, N), jnp.float32)],
        interpret=interpret,
    )(dt, xc, Bc, Cc, A)
    return y
