"""Pallas TPU kernel: tall-skinny times small GEMM (paper C2, Fig. 7).

``W = alpha * V X + beta * W`` with V ``(n, m)``, X ``(m, k)``, m,k << n.
Embarrassingly row-parallel: the small X stays VMEM-resident across the
whole sweep, each grid step streams one ``(Tn, m)`` slab of V in and one
``(Tn, k)`` slab of W out — one read + one write per element, the memory-
bound optimum the paper's model prescribes.

The in-place variant (``tsmm_inplace``) is realised functionally with input/
output aliasing (donation) at the ops layer.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import execution
from repro.core.spmv import storage_acc_dtype as _acc_dtype

__all__ = ["tsmm_pallas"]


def _kernel(v_ref, x_ref, coef_ref, win_ref, out_ref, *,
            has_win: bool, out_dtype):
    acc_dt = _acc_dtype(out_dtype)
    v = v_ref[...].astype(acc_dt)
    x = x_ref[...].astype(acc_dt)
    prod = jax.lax.dot_general(
        v, x, (((1,), (0,)), ((), ())), preferred_element_type=acc_dt)
    alpha = coef_ref[0, 0]
    res = alpha * prod
    if has_win:
        beta = coef_ref[0, 1]
        res = res + beta * win_ref[...].astype(acc_dt)
    out_ref[...] = res.astype(out_dtype)


def tsmm_pallas(
    V: jax.Array,
    X: jax.Array,
    W: Optional[jax.Array] = None,
    alpha=1.0,
    beta=0.0,
    *,
    row_tile: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """W = alpha * V @ X + beta * W.  Requires n % row_tile == 0 (ops.py pads).

    ``interpret=None`` defers to :mod:`repro.core.execution`.
    """
    from repro.core.blockvec import check_beta_needs_out
    check_beta_needs_out(beta, W, "tsmm_pallas")   # beta*W with W=None would
    interpret = execution.resolve_interpret(interpret)   # silently vanish
    n, m = V.shape
    m2, k = X.shape
    if m != m2:
        raise ValueError(f"tsmm: inner dims disagree: V{V.shape} X{X.shape}")
    if n % row_tile != 0:
        raise ValueError(f"tsmm: n={n} not a multiple of "
                         f"row_tile={row_tile} (ops.py pads)")
    out_dtype = jnp.result_type(V.dtype, X.dtype)
    acc_dt = _acc_dtype(out_dtype)
    has_win = W is not None
    win = W if has_win else jnp.zeros((1, k), out_dtype)

    coefs = jnp.stack([jnp.asarray(alpha, acc_dt),
                       jnp.asarray(beta, acc_dt)]).reshape(1, 2)
    grid = (n // row_tile,)
    kern = functools.partial(_kernel, has_win=has_win, out_dtype=out_dtype)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            (pl.BlockSpec((row_tile, k), lambda i: (i, 0)) if has_win
             else pl.BlockSpec((1, k), lambda i: (0, 0))),
        ],
        out_specs=pl.BlockSpec((row_tile, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), out_dtype),
        interpret=interpret,
    )(V, X, coefs, win)
    return out
