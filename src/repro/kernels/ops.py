"""Jit-ready wrappers around the Pallas kernels.

Handles padding to tile multiples, the SellCS object plumbing, and the
GHOST-style *specialization cascade* (paper section 5.4): a kernel call
first tries the specialized Pallas path and falls back to the general jnp
implementation when the shape/dtype is outside the specialized envelope
(e.g. complex SpMMV stays on the XLA path, exactly like GHOST falling back
from intrinsics kernels to generic C).

Execution mode (compiled vs interpret) and tile sizes resolve through the
central :mod:`repro.core.execution` policy: no wrapper hardcodes a mode,
and a compiled-path failure falls back to the jnp reference with a
one-time warning (``execution.cascade``) instead of crashing the caller.

Dtype contract: wrappers pass the matrix' **compute** dtype down to the
kernels (``compute_dtype=A.dtype``) so a narrower ``store_dtype`` value
stream upcasts in-register and accumulates at full width (see
``docs/mixed_precision.md``).  Anything that caches a traced/tuned
artifact per matrix must key on *both* dtypes — ``execution.autotune``
takes a ``dtype=`` key component, and ``runtime.engine.make_matvec``
folds ``(store_dtype, compute_dtype)`` into its matvec cache key — since
storage width changes the traced program and the optimal tiles.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import blockvec, execution
from repro.core.sellcs import SellCS
from repro.core.spmv import SpmvOpts, spmv_ref
from repro.kernels.block_diag import block_diag_matmul_pallas
from repro.kernels.fused_update import fused_axpby_dots_pallas
from repro.kernels.sellcs_spmv import sellcs_spmv_pallas
from repro.kernels.tsmm import tsmm_pallas
from repro.kernels.tsmttsm import tsmttsm_pallas

__all__ = ["sellcs_spmv", "tsmttsm", "tsmm", "fused_axpby_dots",
           "mamba_scan", "block_jacobi_apply"]


def mamba_scan(dt, xc, Bc, Cc, A, *, interpret: Optional[bool] = None):
    """State-resident selective-scan (jit wrapper; pads d_inner tiling)."""
    from repro.kernels.mamba_scan import mamba_scan_pallas
    interpret = execution.resolve_interpret(interpret)
    di = dt.shape[2]
    d_tile = di if di <= 512 else 512
    while di % d_tile != 0:
        d_tile //= 2
    S = dt.shape[1]
    s_blk = execution.resolve_s_blk()
    while S % s_blk != 0:
        s_blk //= 2

    def _pallas():
        return mamba_scan_pallas(dt, xc, Bc, Cc, A, d_tile=d_tile,
                                 s_blk=max(s_blk, 1), interpret=interpret)

    def _ref():
        from repro.kernels.ref import mamba_scan_ref
        return mamba_scan_ref(dt, xc, Bc, Cc, A)

    return execution.cascade("mamba_scan", _pallas, _ref, interpret=interpret)


def _pad_rows(v: jax.Array, mult: int) -> Tuple[jax.Array, int]:
    n = v.shape[0]
    pad = (-n) % mult
    if pad:
        v = jnp.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1))
    return v, n


def sellcs_spmv(
    A: SellCS,
    x: jax.Array,
    y: Optional[jax.Array] = None,
    z: Optional[jax.Array] = None,
    opts: SpmvOpts = SpmvOpts(),
    *,
    w_tile: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """Fused SELL-C-sigma SpM(M)V via Pallas.  Vectors in permuted space.

    Complex dtypes fall back to the jnp oracle (specialization cascade);
    a compiled-path failure cascades there too, with a one-time warning.
    ``A.vals`` may be stored narrower than ``A.dtype`` (mixed-precision
    storage): the kernel receives ``compute_dtype=A.dtype`` and upcasts
    the value slabs in-register, so outputs/dots are in compute dtype.
    """
    if jnp.iscomplexobj(A.vals) or jnp.iscomplexobj(x):
        return spmv_ref(A, x, y, z, opts)

    interpret = execution.resolve_interpret(interpret)
    wt = execution.resolve_w_tile(w_tile, A.w_align)
    if A.w_align % wt != 0 and wt % A.w_align != 0:
        raise ValueError(f"w_tile={wt} incompatible with w_align={A.w_align}")
    if wt > A.w_align:
        # widths only guaranteed multiple of w_align
        wt = A.w_align

    def _pallas():
        x2 = x[:, None] if x.ndim == 1 else x
        y2 = None if y is None else (y[:, None] if y.ndim == 1 else y)
        z2 = None if z is None else (z[:, None] if z.ndim == 1 else z)
        yk, zk, dots = sellcs_spmv_pallas(
            A.vals, A.cols, A.chunk_off, A.chunk_len,
            x2, y2, z2, opts.gamma,
            C=A.C, w_tile=wt,
            alpha=opts.alpha, beta=opts.beta,
            delta=opts.delta, eta=opts.eta,
            dot_yy=opts.dot_yy, dot_xy=opts.dot_xy, dot_xx=opts.dot_xx,
            compute_dtype=A.dtype,
            interpret=interpret,
        )
        if x.ndim == 1:
            yk = yk[:, 0]
            zk = None if zk is None else zk[:, 0]
        return yk, zk, dots

    return execution.cascade("sellcs_spmv", _pallas,
                             lambda: spmv_ref(A, x, y, z, opts),
                             interpret=interpret)


def block_jacobi_apply(
    blocks: jax.Array,
    x: jax.Array,
    *,
    row_tile: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Apply a block-diagonal operator: ``y[blk k] = blocks[k] @ x[blk k]``.

    ``blocks`` is ``(nblocks, bs, bs)``; ``x`` is ``(nblocks*bs,)`` or
    ``(nblocks*bs, b)`` in the matrix' permuted space — the block-Jacobi
    preconditioner apply.  Pads rows to the resolved tile (zero blocks on
    the pad, trimmed after), complex dtypes use the jnp oracle, and a
    compiled-path failure cascades there too.
    """
    from repro.kernels.ref import block_diag_matmul_ref

    nb, bs, _ = blocks.shape
    was1d = x.ndim == 1
    x2 = x[:, None] if was1d else x

    def _ref():
        out = block_diag_matmul_ref(blocks, x2)
        return out[:, 0] if was1d else out

    if jnp.iscomplexobj(blocks) or jnp.iscomplexobj(x):
        return _ref()
    interpret = execution.resolve_interpret(interpret)
    n = x2.shape[0]
    # the tile must hold whole blocks; snap the policy knob down to a
    # bs multiple (at least one block per grid step)
    rt = max(bs, (min(execution.resolve_row_tile(row_tile), n)
                  // bs) * bs)

    def _pallas():
        pad = (-n) % rt
        xp, _ = _pad_rows(x2, rt)
        bp = blocks
        if pad:
            bp = jnp.concatenate(
                [blocks, jnp.zeros((pad // bs, bs, bs), blocks.dtype)])
        out = block_diag_matmul_pallas(bp, xp, row_tile=rt,
                                       interpret=interpret)[:n]
        return out[:, 0] if was1d else out

    return execution.cascade("block_diag_matmul", _pallas, _ref,
                             interpret=interpret)


def tsmttsm(
    V: jax.Array,
    W: jax.Array,
    X: Optional[jax.Array] = None,
    alpha=1.0,
    beta=0.0,
    *,
    kahan: bool = False,
    conj: bool = True,
    row_tile: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """X = alpha V^H W + beta X (Pallas; pads n to the row tile)."""
    def _ref():
        if kahan:
            # tsmttsm_kahan conjugates complex V unconditionally; pre-
            # conjugate to honor conj=False (V^T W instead of V^H W)
            Vk = jnp.conj(V) if (not conj and jnp.iscomplexobj(V)) else V
            res = alpha * blockvec.tsmttsm_kahan(Vk, W)
            if X is not None:
                res = res + beta * X
            return res
        return blockvec.tsmttsm(V, W, X, alpha=alpha, beta=beta, conj=conj)

    if jnp.iscomplexobj(V) or jnp.iscomplexobj(W):
        return _ref()
    interpret = execution.resolve_interpret(interpret)
    n = V.shape[0]
    rt = min(execution.resolve_row_tile(row_tile),
             max(8, 1 << (max(n, 1) - 1).bit_length()))

    def _pallas():
        Vp, _ = _pad_rows(V, rt)
        Wp, _ = _pad_rows(W, rt)
        return tsmttsm_pallas(Vp, Wp, X, alpha, beta, row_tile=rt,
                              kahan=kahan, conj=conj, interpret=interpret)

    return execution.cascade("tsmttsm", _pallas, _ref, interpret=interpret)


def tsmm(
    V: jax.Array,
    X: jax.Array,
    W: Optional[jax.Array] = None,
    alpha=1.0,
    beta=0.0,
    *,
    row_tile: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """W = alpha V X + beta W (Pallas; pads n to the row tile)."""
    if jnp.iscomplexobj(V) or jnp.iscomplexobj(X):
        return blockvec.tsmm(V, X, W, alpha=alpha, beta=beta)
    interpret = execution.resolve_interpret(interpret)
    n = V.shape[0]
    rt = min(execution.resolve_row_tile(row_tile),
             max(8, 1 << (max(n, 1) - 1).bit_length()))

    def _pallas():
        Vp, n0 = _pad_rows(V, rt)
        Wp = None
        if W is not None:
            Wp, _ = _pad_rows(W, rt)
        out = tsmm_pallas(Vp, X, Wp, alpha, beta, row_tile=rt,
                          interpret=interpret)
        return out[:n0]

    return execution.cascade(
        "tsmm", _pallas,
        lambda: blockvec.tsmm(V, X, W, alpha=alpha, beta=beta),
        interpret=interpret)


def tsmm_inplace(V, X, alpha=1.0, beta=0.0, **kw):
    return tsmm(V, X, V, alpha=alpha, beta=beta, **kw)


def fused_axpby_dots(
    x: jax.Array,
    y: jax.Array,
    a=1.0,
    b=1.0,
    *,
    dot_yy: bool = False,
    dot_xy: bool = False,
    dot_xx: bool = False,
    row_tile: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """(a*x + b*y, dots) fused sweep (Pallas; pads rows)."""
    from repro.kernels.ref import fused_axpby_dots_ref

    was1d = x.ndim == 1
    x2 = x[:, None] if was1d else x
    y2 = y[:, None] if was1d else y

    def _ref():
        out, dots = fused_axpby_dots_ref(x2, y2, a, b, dot_yy=dot_yy,
                                         dot_xy=dot_xy, dot_xx=dot_xx)
        if was1d:
            out = out[:, 0]
            dots = None if dots is None else dots[:, 0]
        return out, dots

    if jnp.iscomplexobj(x) or jnp.iscomplexobj(y):
        return _ref()
    interpret = execution.resolve_interpret(interpret)
    n = x2.shape[0]
    rt = min(execution.resolve_row_tile(row_tile),
             max(8, 1 << (max(n, 1) - 1).bit_length()))

    def _pallas():
        xp, _ = _pad_rows(x2, rt)
        yp, _ = _pad_rows(y2, rt)
        out, dots = fused_axpby_dots_pallas(
            xp, yp, a, b, dot_yy=dot_yy, dot_xy=dot_xy, dot_xx=dot_xx,
            row_tile=rt, interpret=interpret)
        out = out[:n]
        if was1d:
            out = out[:, 0]
            dots = None if dots is None else dots[:, 0]
        return out, dots

    return execution.cascade("fused_axpby_dots", _pallas, _ref,
                             interpret=interpret)
