"""Pallas TPU kernel: fused SELL-C-sigma SpM(M)V (paper C1 + C3).

TPU adaptation of GHOST's SIMD SELL kernel:

* chunk height C = 128 (VPU lane count) by default; one grid step owns one
  chunk and produces a ``(C, b)`` output tile in VMEM.
* ``vals``/``cols`` live in ``pl.ANY`` (compiler-placed, HBM for large
  matrices) and are streamed in ``(w_tile, C)`` slabs — the chunk-column-
  major layout makes every slab load contiguous, exactly the property the
  paper engineered for wide SIMD.
* per-chunk ragged widths arrive via scalar prefetch (``chunk_off``,
  ``chunk_len``), the TPU-idiomatic replacement for GHOST's chunk pointer
  arithmetic; the inner ``fori_loop`` has a data-dependent trip count so
  short chunks do no wasted slab loads (this is what sigma-sorting buys).
* the gather ``x[cols]`` is the irreducible scatter/gather of SpMV.  On GPU
  the paper leans on the texture cache; on TPU we keep ``x`` compiler-placed
  and issue vector gathers.  In the *distributed* path the remote part
  gathers from a small compressed halo buffer that fits VMEM (see
  ``core/distributed.py``), which is the TPU-native analogue of GHOST's
  compressed remote columns (paper Fig. 3).

Fusion flags (alpha/beta/gamma shift, chained axpby, three dot products) are
*static* Python switches: each flag combination traces a specialized kernel,
mirroring GHOST's compile-time code generation (paper C6).  Scalar
coefficients arrive in a packed ``(1, 4)`` operand so they may be traced
values inside jitted solvers.

The same C6 specialization applies over *data types*: ``vals`` may be a
narrower **storage** dtype (bf16/f16) than the ``compute_dtype`` the caller
accumulates in — each ``(w_tile, C)`` value slab streams from HBM at the
storage width and is upcast in-register before the ``einsum``, halving the
dominant memory traffic of this bandwidth-bound kernel
(``docs/mixed_precision.md``).

Validated in ``interpret=True`` mode against ``core.spmv.spmv_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import execution
from repro.core.spmv import (compensated_sum0, dot_acc_dtype,
                             storage_acc_dtype as _acc_dtype)

__all__ = ["sellcs_spmv_pallas"]


def _kernel(
    # scalar prefetch
    off_ref, len_ref,
    # inputs
    vals_ref, cols_ref, x_ref, coef_ref, *rest,
    C: int, b: int, w_tile: int,
    has_yin: bool, has_gamma: bool, chain: bool,
    dot_yy: bool, dot_xy: bool, dot_xx: bool,
    out_dtype,
):
    ri = 0
    yin_ref = rest[ri] if has_yin else None
    ri += has_yin
    zin_ref = rest[ri] if chain else None
    ri += chain
    gamma_ref = rest[ri] if has_gamma else None
    ri += has_gamma
    outs = rest[ri:]
    y_ref = outs[0]
    oi = 1
    z_ref = outs[oi] if chain else None
    oi += chain
    dots_ref = outs[oi] if (dot_yy or dot_xy or dot_xx) else None

    c = pl.program_id(0)
    off = off_ref[c]
    ntiles = len_ref[c] // w_tile

    acc_dt = _acc_dtype(out_dtype)
    acc0 = jnp.zeros((C, b), acc_dt)

    def body(j, acc):
        base = (off + j * w_tile) * C
        cslab = pl.load(cols_ref, (pl.ds(base, w_tile * C),))
        vslab = pl.load(vals_ref, (pl.ds(base, w_tile * C),)).astype(acc_dt)
        xg = x_ref[cslab]                              # (w_tile*C, b) gather
        xg = xg.reshape(w_tile, C, b).astype(acc_dt)
        vslab = vslab.reshape(w_tile, C)
        return acc + jnp.einsum("wc,wcb->cb", vslab, xg)

    acc = lax.fori_loop(0, ntiles, body, acc0)

    alpha = coef_ref[0, 0]
    beta = coef_ref[0, 1]
    delta = coef_ref[0, 2]
    eta = coef_ref[0, 3]

    need_xrow = has_gamma or dot_xy or dot_xx
    if need_xrow:
        xrow = pl.load(x_ref, (pl.ds(c * C, C), slice(None))).astype(acc_dt)
    if has_gamma:
        g = gamma_ref[...].astype(acc_dt)              # (1, b) or (1, 1)
        acc = acc - g * xrow

    y = alpha * acc
    if has_yin:
        y = y + beta * yin_ref[...].astype(acc_dt)
    y_ref[...] = y.astype(out_dtype)

    if chain:
        z = delta * zin_ref[...].astype(acc_dt) + eta * y
        z_ref[...] = z.astype(out_dtype)

    if dots_ref is not None:
        dt = dots_ref.dtype
        zero = jnp.zeros((b,), dt)
        d_yy = jnp.sum(y * y, axis=0).astype(dt) if dot_yy else zero
        d_xy = jnp.sum(xrow * y, axis=0).astype(dt) if dot_xy else zero
        d_xx = jnp.sum(xrow * xrow, axis=0).astype(dt) if dot_xx else zero
        dots_ref[...] = jnp.stack([d_yy, d_xy, d_xx])[None]


def sellcs_spmv_pallas(
    vals: jax.Array,
    cols: jax.Array,
    chunk_off: jax.Array,
    chunk_len: jax.Array,
    x: jax.Array,                      # (n_pad, b), permuted space
    y_in: Optional[jax.Array] = None,  # (n_pad, b)
    z_in: Optional[jax.Array] = None,
    gamma: Optional[jax.Array] = None,  # (b,) or scalar shift
    *,
    C: int,
    w_tile: int,
    alpha=1.0,
    beta=0.0,
    delta=None,
    eta=None,
    dot_yy: bool = False,
    dot_xy: bool = False,
    dot_xx: bool = False,
    compute_dtype=None,
    interpret: Optional[bool] = None,
):
    """Run the fused SELL-C-sigma SpMMV kernel.

    Requires ``chunk_len % w_tile == 0`` (build the matrix with
    ``w_align=w_tile``) — validated host-side whenever ``chunk_len`` is
    concrete, because the kernel's ``len // w_tile`` trip count would
    otherwise silently drop the tail nonzeros of every ragged chunk.
    Returns ``(y, z, dots)`` where ``dots`` is ``(3, b)`` (yy, xy, xx)
    summed over chunks, or ``None``.  ``interpret=None`` defers to
    :mod:`repro.core.execution`.

    ``compute_dtype`` pins the output/accumulation dtype explicitly (the
    storage-vs-compute contract: pass ``SellCS.dtype`` when ``vals`` is
    stored narrower).  ``None`` falls back to type promotion over
    ``vals``/``x`` — identical for single-dtype matrices.  Either way a
    sub-32-bit value slab is upcast **in-register** (``(w_tile, C)`` tile
    cast inside the fori_loop body) so HBM traffic stays at the storage
    width while the accumulator is at least f32.
    """
    interpret = execution.resolve_interpret(interpret)
    if w_tile <= 0:
        raise ValueError(f"w_tile must be positive, got {w_tile}")
    if not isinstance(chunk_len, jax.core.Tracer):
        rem = np.asarray(chunk_len) % w_tile
        if rem.any():
            bad = np.nonzero(rem)[0]
            raise ValueError(
                f"chunk_len % w_tile != 0 for chunks {bad[:8].tolist()}"
                f"{'...' if len(bad) > 8 else ''} (w_tile={w_tile}): the "
                f"kernel would silently drop tail nonzeros — rebuild the "
                f"matrix with w_align={w_tile} or pass a compatible w_tile")
    b = x.shape[1]
    nchunks = int(chunk_off.shape[0])
    n_pad = nchunks * C                      # output rows (may differ from
    square = x.shape[0] == n_pad             # x rows for rectangular parts)
    if compute_dtype is None:
        out_dtype = jnp.result_type(vals.dtype, x.dtype)
    else:
        out_dtype = jnp.result_type(jnp.dtype(compute_dtype), x.dtype)
    acc_dt = _acc_dtype(out_dtype)
    has_yin = y_in is not None
    chain = delta is not None or eta is not None
    has_gamma = gamma is not None
    any_dot = dot_yy or dot_xy or dot_xx
    if (has_gamma or dot_xy or dot_xx) and not square:
        raise ValueError("gamma shift / x-dots need a square (diag-aligned) part")

    coefs = jnp.stack([
        jnp.asarray(alpha, acc_dt),
        jnp.asarray(beta, acc_dt),
        jnp.asarray(0.0 if delta is None else delta, acc_dt),
        jnp.asarray(0.0 if eta is None else eta, acc_dt),
    ]).reshape(1, 4)

    inputs = [vals, cols, x, coefs]
    in_specs = [
        pl.BlockSpec(memory_space=pl.ANY),
        pl.BlockSpec(memory_space=pl.ANY),
        pl.BlockSpec(memory_space=pl.ANY),
        pl.BlockSpec((1, 4), lambda c, off, ln: (0, 0)),
    ]
    tile_spec = pl.BlockSpec((C, b), lambda c, off, ln: (c, 0))
    if has_yin:
        inputs.append(y_in)
        in_specs.append(tile_spec)
    if chain:
        if z_in is None:
            raise ValueError("sellcs_spmv: chained axpby requires z_in")
        inputs.append(z_in)
        in_specs.append(tile_spec)
    if has_gamma:
        g = jnp.atleast_1d(jnp.asarray(gamma)).reshape(1, -1)
        if g.shape[1] not in (1, b):
            raise ValueError(f"gamma must be scalar or ({b},)")
        gw = g.shape[1]
        inputs.append(g)
        in_specs.append(pl.BlockSpec((1, gw), lambda c, off, ln: (0, 0)))

    out_shapes = [jax.ShapeDtypeStruct((n_pad, b), out_dtype)]
    out_specs = [tile_spec]
    if chain:
        out_shapes.append(jax.ShapeDtypeStruct((n_pad, b), out_dtype))
        out_specs.append(tile_spec)
    if any_dot:
        out_shapes.append(jax.ShapeDtypeStruct((nchunks, 3, b), acc_dt))
        out_specs.append(pl.BlockSpec((1, 3, b), lambda c, off, ln: (c, 0, 0)))

    kern = functools.partial(
        _kernel,
        C=C, b=b, w_tile=w_tile,
        has_yin=has_yin, has_gamma=has_gamma, chain=chain,
        dot_yy=dot_yy, dot_xy=dot_xy, dot_xx=dot_xx,
        out_dtype=out_dtype,
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nchunks,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )(chunk_off, chunk_len, *inputs)

    y = outs[0]
    oi = 1
    z = None
    if chain:
        z = outs[oi]
        oi += 1
    dots = None
    if any_dot:
        # per-chunk partials reduce in f64 when available, Kahan-
        # compensated otherwise (paper's augmented-SpMV accuracy claim;
        # cast at this boundary only)
        part = outs[oi].astype(dot_acc_dtype(acc_dt))        # (nchunks, 3, b)
        if jnp.finfo(part.dtype).bits >= 64:
            dots = part.sum(axis=0)
        else:
            dots = compensated_sum0(part, block=8)
    return y, z, dots
