"""Pallas TPU kernel: fused blocked AXPBY + dots (paper C3, BLAS-x.5).

``y = a*x + b*y`` chained with per-column dot products <y,y>, <x,y>, <x,x>
in a single memory sweep — the AXPY_DOT-style operator the updated BLAS
standard added and GHOST fuses into its solvers (CG: p-update + <r,r>).

Per-column coefficient vectors (GHOST's vaxpby) are supported: ``a``/``b``
may be scalars or ``(blockwidth,)``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import execution
from repro.core.spmv import storage_acc_dtype as _acc_dtype

__all__ = ["fused_axpby_dots_pallas"]


def _kernel(x_ref, y_ref, a_ref, b_ref, out_ref, dots_ref, *,
            dot_yy: bool, dot_xy: bool, dot_xx: bool, out_dtype):
    acc_dt = _acc_dtype(out_dtype)
    x = x_ref[...].astype(acc_dt)
    y = y_ref[...].astype(acc_dt)
    a = a_ref[...].astype(acc_dt)
    b = b_ref[...].astype(acc_dt)
    ynew = a * x + b * y
    out_ref[...] = ynew.astype(out_dtype)
    bw = x.shape[1]
    zero = jnp.zeros((bw,), acc_dt)
    d_yy = jnp.sum(ynew * ynew, axis=0) if dot_yy else zero
    d_xy = jnp.sum(x * ynew, axis=0) if dot_xy else zero
    d_xx = jnp.sum(x * x, axis=0) if dot_xx else zero
    dots_ref[...] = jnp.stack([d_yy, d_xy, d_xx])[None]


def fused_axpby_dots_pallas(
    x: jax.Array,
    y: jax.Array,
    a=1.0,
    b=1.0,
    *,
    dot_yy: bool = False,
    dot_xy: bool = False,
    dot_xx: bool = False,
    row_tile: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Returns (a*x + b*y, dots(3, bw) or None).  n % row_tile == 0.

    ``interpret=None`` defers to :mod:`repro.core.execution`.
    """
    interpret = execution.resolve_interpret(interpret)
    n, bw = x.shape
    if y.shape != (n, bw):
        raise ValueError(
            f"fused_axpby_dots: y{y.shape} must match x{x.shape}")
    if n % row_tile != 0:
        raise ValueError(f"fused_axpby_dots: n={n} not a multiple of "
                         f"row_tile={row_tile} (ops.py pads)")
    out_dtype = jnp.result_type(x.dtype, y.dtype)
    acc_dt = _acc_dtype(out_dtype)
    any_dot = dot_yy or dot_xy or dot_xx

    av = jnp.broadcast_to(jnp.asarray(a, acc_dt), (bw,)).reshape(1, bw)
    bv = jnp.broadcast_to(jnp.asarray(b, acc_dt), (bw,)).reshape(1, bw)
    grid = (n // row_tile,)
    kern = functools.partial(
        _kernel, dot_yy=dot_yy, dot_xy=dot_xy, dot_xx=dot_xx,
        out_dtype=out_dtype)
    out, dots = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, bw), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, bw), lambda i: (i, 0)),
            pl.BlockSpec((1, bw), lambda i: (0, 0)),
            pl.BlockSpec((1, bw), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_tile, bw), lambda i: (i, 0)),
            pl.BlockSpec((1, 3, bw), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, bw), out_dtype),
            jax.ShapeDtypeStruct((grid[0], 3, bw), acc_dt),
        ],
        interpret=interpret,
    )(x, y, av, bv)
    return out, (dots.sum(axis=0) if any_dot else None)
