"""SELL-C-sigma sparse matrix storage (paper C1).

The central data structure of GHOST.  A sparse matrix is cut into chunks of
``C`` rows (``C`` = SIMD/lane width — 128 matches the TPU VPU lane count,
but any value works; the Pallas kernel additionally tiles each chunk's
width in ``w_tile`` slabs, so chunk widths are padded to a multiple of
``w_align`` at construction time — pick ``w_align`` = the ``w_tile`` you
intend to run with).  Within a *sorting window* of ``sigma`` rows, rows are
sorted by descending nonzero count before chunk assembly, which minimizes
the zero-padding ``beta`` overhead.  Chunk entries are stored column-major
within the chunk so that one contiguous load feeds all C lanes.

**Storage vs compute dtype** (paper C6 over data types): SpMV is memory-
bandwidth-bound, so the value stream may be narrower than the arithmetic.
``store_dtype=`` keeps ``vals`` in ``bfloat16``/``float16``/``float32``
while the recorded ``compute_dtype`` (the ``dtype=`` argument) drives
every accumulation — kernels upcast the value tile in-register and the
accumulator stays f32/f64.  ``store_dtype=None`` (the default) keeps
``vals`` in the compute dtype, bit-identical to the single-dtype layout.
See ``docs/mixed_precision.md`` for the full contract.

Special cases (paper section 5.1):
    SELL-1-1          == CRS
    SELL-C-1          == unsorted SELL-C
    SELL-nrows-nrows  == (globally sorted) ELLPACK-ish
    SELL-C-sigma      == general case

Vectors are kept in *permuted* space (like GHOST, which permutes matrix
columns along with the rows); use :meth:`SellCS.permute` /
:meth:`SellCS.unpermute` at the boundaries.  For square matrices the column
indices are remapped through the inverse permutation at construction time so
that SpMV never needs to gather through the permutation.

Construction is host-side numpy (the paper constructs via a user callback on
the host as well); the result is a JAX pytree usable inside jit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SellCS",
    "from_coo",
    "from_csr",
    "from_dense",
    "from_callback",
    "to_dense",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SellCS:
    """SELL-C-sigma matrix.  Arrays are a JAX pytree; sizes are static."""

    # --- array leaves -----------------------------------------------------
    vals: jax.Array        # (cap,) chunk-column-major nonzero values (padded)
    cols: jax.Array        # (cap,) int32 column indices (permuted space)
    chunk_off: jax.Array   # (nchunks,) int32, chunk c spans vals[off*C:(off+len)*C]
    chunk_len: jax.Array   # (nchunks,) int32 padded width of chunk c
    rowids: jax.Array      # (cap,) int32 row id (permuted space) per slot; for ref path
    row_len: jax.Array     # (nrows_pad,) int32 stored entries per permuted row
    perm: jax.Array        # (nrows_pad,) int32 sorted-pos -> original row
    iperm: jax.Array       # (nrows_pad,) int32 original row -> sorted-pos

    # --- static metadata ---------------------------------------------------
    C: int = dataclasses.field(metadata=dict(static=True))
    sigma: int = dataclasses.field(metadata=dict(static=True))
    nrows: int = dataclasses.field(metadata=dict(static=True))
    ncols: int = dataclasses.field(metadata=dict(static=True))
    nnz: int = dataclasses.field(metadata=dict(static=True))
    w_align: int = dataclasses.field(metadata=dict(static=True))
    permuted_cols: bool = dataclasses.field(metadata=dict(static=True))
    # compute (accumulation) dtype name when ``vals`` is stored narrower;
    # None = vals *are* the compute dtype (the classic single-dtype layout)
    compute_dtype: Optional[str] = dataclasses.field(
        default=None, metadata=dict(static=True))

    # ------------------------------------------------------------------ api
    @property
    def nchunks(self) -> int:
        return (self.nrows_pad // self.C)

    @property
    def nrows_pad(self) -> int:
        return _ceil_to(self.nrows, self.C)

    @property
    def cap(self) -> int:
        return int(self.vals.shape[0])

    @property
    def beta(self) -> float:
        """Storage efficiency: nnz / padded slots (paper's beta)."""
        return self.nnz / max(1, self.cap)

    @property
    def dtype(self):
        """The *compute* dtype: what SpMV products accumulate in and what
        every solver vector should use.  Equals ``store_dtype`` unless the
        matrix was built with a narrower ``store_dtype=``."""
        if self.compute_dtype is not None:
            return jnp.dtype(self.compute_dtype)
        return self.vals.dtype

    @property
    def store_dtype(self):
        """The *storage* dtype of ``vals`` (the memory-traffic dtype)."""
        return self.vals.dtype

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.nrows, self.ncols)

    # -- vector permutation boundary helpers (paper: vectors live permuted) --
    def permute(self, v: jax.Array) -> jax.Array:
        """Original-space vector -> permuted (sorted) space, padded to nrows_pad."""
        v = jnp.asarray(v)
        pad = self.nrows_pad - self.nrows
        if v.ndim == 1:
            vp = jnp.pad(v, (0, pad))
        else:
            vp = jnp.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1))
        return vp[self.perm]

    def unpermute(self, v: jax.Array) -> jax.Array:
        """Permuted-space (padded) vector -> original space (trimmed)."""
        return v[self.iperm][: self.nrows]

    def nnz_per_row(self) -> np.ndarray:
        """Stored entries per permuted-space row.

        Derived from the per-row lengths recorded at construction — NOT
        from ``vals != 0``, so explicitly stored zeros (and duplicates
        that summed to 0.0) are counted.
        """
        return np.asarray(self.row_len, np.int64).copy()

    def valid_slots(self) -> np.ndarray:
        """Boolean (cap,) mask of slots holding a stored entry (host-side).

        Slot validity comes from the construction-recorded row lengths:
        slot ``(chunk_off[c] + k) * C + lane`` is valid iff
        ``k < row_len[c*C + lane]``.  Padding slots carry ``vals == 0``
        too, but the converse does not hold for explicitly stored zeros.
        """
        co = np.asarray(self.chunk_off, np.int64)
        rid = np.asarray(self.rowids, np.int64)
        slot = np.arange(self.cap, dtype=np.int64)
        k = slot // self.C - co[rid // self.C]
        return k < np.asarray(self.row_len, np.int64)[rid]


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def from_coo(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: Tuple[int, int],
    *,
    C: int = 32,
    sigma: int = 1,
    w_align: int = 1,
    dtype=None,
    store_dtype=None,
    row_perm: Optional[np.ndarray] = None,
    permute_columns: Optional[bool] = None,
) -> SellCS:
    """Build a SELL-C-sigma matrix from COO triplets (host-side).

    ``sigma`` must be a multiple of ``C`` (or 1).  ``w_align`` pads every
    chunk width to a multiple, which the Pallas kernel uses for its width
    tiling (trades a little beta for aligned slab loads).

    ``dtype`` is the **compute** dtype (accumulation, vectors, results);
    ``store_dtype`` optionally stores ``vals`` narrower (``bfloat16`` /
    ``float16`` / ``float32``) to halve the SpMV value traffic — kernels
    upcast in-register and accumulate in the compute dtype, so ``dtype``
    semantics are unchanged.  ``store_dtype=None`` keeps ``vals`` in the
    compute dtype, bit-identical to the pre-mixed-precision layout.

    ``row_perm`` imposes an externally chosen row permutation (sorted-pos ->
    original row, length nrows_pad) instead of sigma-sorting — used by the
    distributed layer so the remote matrix part shares the local part's
    permutation.  ``permute_columns`` overrides the default column remapping
    (default: remap iff the matrix is square and no external perm is given).
    """
    nrows, ncols = map(int, shape)
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    if dtype is not None:
        vals = vals.astype(dtype)
    if sigma != 1 and sigma % C != 0:
        raise ValueError(f"sigma ({sigma}) must be 1 or a multiple of C ({C})")
    if rows.size:
        if rows.min() < 0 or rows.max() >= nrows:
            raise ValueError("row index out of range")
        if cols.min() < 0 or cols.max() >= ncols:
            raise ValueError("col index out of range")

    # CSR-ify (sorted, deduplicated by summation like most sparse builders)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if rows.size:
        dup = np.zeros(rows.size, bool)
        dup[1:] = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        if dup.any():
            # sum duplicates
            keep = ~dup
            grp = np.cumsum(keep) - 1
            nv = np.zeros(keep.sum(), vals.dtype)
            np.add.at(nv, grp, vals)
            rows, cols, vals = rows[keep], cols[keep], nv
    nnz = int(rows.size)

    nrows_pad = _ceil_to(nrows, C)
    rowlen = np.zeros(nrows_pad, np.int64)
    np.add.at(rowlen, rows, 1)

    # --- sigma sorting: stable descending rowlen within each window --------
    if row_perm is not None:
        perm = np.asarray(row_perm, np.int64)
        if perm.shape != (nrows_pad,):
            raise ValueError(f"row_perm must have shape ({nrows_pad},)")
    else:
        perm = np.arange(nrows_pad, dtype=np.int64)
        if sigma > 1:
            win = sigma
            for s in range(0, nrows_pad, win):
                e = min(s + win, nrows_pad)
                seg = np.argsort(-rowlen[s:e], kind="stable") + s
                perm[s:e] = seg
    iperm = np.empty_like(perm)
    iperm[perm] = np.arange(nrows_pad)

    sorted_rowlen = rowlen[perm]

    nchunks = nrows_pad // C
    chunk_len = np.zeros(nchunks, np.int64)
    for c in range(nchunks):
        w = int(sorted_rowlen[c * C : (c + 1) * C].max(initial=0))
        chunk_len[c] = _ceil_to(max(w, 1), w_align)
    chunk_off = np.zeros(nchunks, np.int64)
    chunk_off[1:] = np.cumsum(chunk_len)[:-1]
    cap = int(chunk_len.sum()) * C

    # --- scatter CSR rows into chunk-column-major slots ---------------------
    out_vals = np.zeros(cap, vals.dtype if vals.size else np.float32)
    out_cols = np.zeros(cap, np.int64)
    out_rowid = np.zeros(cap, np.int64)
    # slot index for element k of (sorted) row s in chunk c:
    #   (chunk_off[c] + k) * C + (s - c*C)
    if nnz:
        sorted_pos = iperm[rows]              # per-nnz sorted row position
        chunk_of = sorted_pos // C
        lane = sorted_pos % C
        # k = running index within the row (rows are contiguous post-lexsort)
        starts = np.concatenate([[0], np.cumsum(rowlen[:nrows])[:-1]])
        k = np.arange(nnz, dtype=np.int64) - starts[rows]
        slot = (chunk_off[chunk_of] + k) * C + lane
        out_vals[slot] = vals
        out_cols[slot] = cols
    # rowids for every slot (padding slots get their row too, with val 0)
    slot_all = np.arange(cap, dtype=np.int64)
    # invert: which chunk does a slot belong to
    chunk_bounds = (chunk_off + chunk_len) * C
    chunk_of_slot = np.searchsorted(chunk_bounds, slot_all, side="right")
    lane_of_slot = (slot_all - chunk_off[chunk_of_slot] * C) % C
    out_rowid = chunk_of_slot * C + lane_of_slot

    # permuted column space for square matrices: col j -> iperm[j].
    # Validity is the slot occupancy recorded above — NOT ``vals != 0``,
    # which would skip explicitly stored zeros (their column must be
    # remapped too so structure round-trips through to_dense).
    if permute_columns is None:
        permuted_cols = (nrows == ncols) and row_perm is None
    else:
        permuted_cols = bool(permute_columns)
    if permuted_cols and nnz:
        valid_slot = np.zeros(cap, bool)
        valid_slot[slot] = True
        out_cols_p = out_cols.copy()
        out_cols_p[valid_slot] = iperm[out_cols[valid_slot]]
        out_cols = out_cols_p

    jvals = jnp.asarray(out_vals)               # canonicalized compute dtype
    compute_dtype = None
    if store_dtype is not None:
        sd = jnp.dtype(store_dtype)
        cd = jvals.dtype
        if not jnp.issubdtype(sd, jnp.floating):
            raise ValueError(
                f"store_dtype must be a real floating dtype, got {sd}")
        if jnp.issubdtype(cd, jnp.complexfloating):
            raise ValueError(
                f"store_dtype is not supported for complex values "
                f"(compute dtype {cd})")
        if not jnp.issubdtype(cd, jnp.floating):
            raise ValueError(
                f"store_dtype requires a floating compute dtype, got {cd}; "
                f"pass dtype= (float values would stream from storage into "
                f"integer solver states otherwise)")
        if jnp.finfo(sd).bits > jnp.finfo(cd).bits:
            raise ValueError(
                f"store_dtype {sd} is wider than the compute dtype {cd}; "
                f"storage may only narrow the value stream")
        compute_dtype = str(cd)
        jvals = jvals.astype(sd)

    return SellCS(
        vals=jvals,
        cols=jnp.asarray(out_cols, jnp.int32),
        chunk_off=jnp.asarray(chunk_off, jnp.int32),
        chunk_len=jnp.asarray(chunk_len, jnp.int32),
        rowids=jnp.asarray(out_rowid, jnp.int32),
        row_len=jnp.asarray(sorted_rowlen, jnp.int32),
        perm=jnp.asarray(perm, jnp.int32),
        iperm=jnp.asarray(iperm, jnp.int32),
        C=int(C),
        sigma=int(sigma),
        nrows=nrows,
        ncols=ncols,
        nnz=nnz,
        w_align=int(w_align),
        permuted_cols=bool(permuted_cols),
        compute_dtype=compute_dtype,
    )


def from_csr(indptr, indices, data, shape, **kw) -> SellCS:
    """Paper section 5.1: construct SELL-C-sigma from raw CRS arrays."""
    indptr = np.asarray(indptr, np.int64)
    rows = np.repeat(np.arange(len(indptr) - 1, dtype=np.int64), np.diff(indptr))
    return from_coo(rows, np.asarray(indices), np.asarray(data), shape, **kw)


def from_dense(a: np.ndarray, **kw) -> SellCS:
    a = np.asarray(a)
    r, c = np.nonzero(a)
    return from_coo(r, c, a[r, c], a.shape, **kw)


def from_callback(
    rowfunc: Callable[[int], Tuple[np.ndarray, np.ndarray]],
    nrows: int,
    ncols: Optional[int] = None,
    *,
    maxnz_per_row: int = 64,
    **kw,
) -> SellCS:
    """GHOST's preferred construction path: a per-row callback.

    ``rowfunc(i) -> (cols, vals)`` mirrors the paper's
    ``int mat(row, *len, *col, *val, *arg)`` C callback.
    """
    ncols = nrows if ncols is None else ncols
    rr, cc, vv = [], [], []
    for i in range(nrows):
        c, v = rowfunc(i)
        c = np.asarray(c, np.int64).ravel()
        v = np.asarray(v).ravel()
        if c.size > maxnz_per_row:
            raise ValueError(f"row {i}: {c.size} > maxnz_per_row={maxnz_per_row}")
        rr.append(np.full(c.size, i, np.int64))
        cc.append(c)
        vv.append(v)
    rows = np.concatenate(rr) if rr else np.zeros(0, np.int64)
    cols = np.concatenate(cc) if cc else np.zeros(0, np.int64)
    vals = np.concatenate(vv) if vv else np.zeros(0)
    return from_coo(rows, cols, vals, (nrows, ncols), **kw)


def to_dense(m: SellCS) -> np.ndarray:
    """Densify (original index space) — for tests / small matrices only.

    Slot validity comes from the construction-recorded row lengths
    (:meth:`SellCS.valid_slots`), so explicitly stored zeros keep their
    (correctly remapped) position instead of being treated as padding.
    Values are returned in the *compute* dtype (upcast from a narrower
    ``store_dtype`` storage; a no-op for single-dtype matrices).
    """
    vals = np.asarray(m.vals).astype(np.dtype(m.dtype))
    cols = np.asarray(m.cols)
    rowid = np.asarray(m.rowids)
    perm = np.asarray(m.perm)
    out = np.zeros((m.nrows_pad, m.ncols), vals.dtype)
    mask = m.valid_slots()
    r_orig = perm[rowid[mask]]
    c = cols[mask]
    if m.permuted_cols:
        c = perm[c]
    np.add.at(out, (r_orig, c), vals[mask])
    return out[: m.nrows]
