"""Distributed SELL-C-sigma SpMV (paper C4 + C5).

Row-wise, *weight-proportional* distribution of the system matrix across a
device mesh axis (GHOST section 4.1, Fig. 3), with the process-local matrix
split into a **local** part (columns owned by this shard) and a **remote**
part whose column indices are *compressed* into a dense halo buffer —
exactly the paper's remote-column compression, which on TPU doubles as the
trick that keeps the remote gather inside a small VMEM-resident buffer.

Communication is a static-pattern halo exchange realised with
``lax.all_to_all`` (pairwise send lists precomputed host-side, padded to the
maximum message size).  The *task-mode* overlap of GHOST (section 4.2) maps
to TPU as data-flow independence: the local SpMV consumes only ``x_local``
while the halo exchange runs, so XLA's async collective scheduler can
overlap them; ``overlap=False`` inserts an optimization barrier to force the
paper's "No Overlap" baseline for the Fig. 5 study.

Everything here is pure SPMD ``shard_map`` — the same code lowers to the
16x16 pod mesh and the 2x16x16 multi-pod mesh in the dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import execution
from repro.core import partition as part
from repro.core.sellcs import SellCS, from_coo
from repro.core.spmv import SpmvOpts, spmv_ref

# Newer jax exposes shard_map at top level; older releases keep it in
# jax.experimental.  The replication-check kwarg was also renamed along
# the way (check_rep= -> check_vma=), and both renames happened in
# different releases, so feature-detect each independently.  Resolved
# once here so every SPMD caller in the repo shares the shim.  The check
# is disabled because pallas_call runs inside our shard_maps.
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

try:
    import inspect
    _sm_params = inspect.signature(_shard_map_impl).parameters
    _SM_CHECK_KW = next((k for k in ("check_vma", "check_rep")
                         if k in _sm_params), None)
except (TypeError, ValueError):  # signature not introspectable
    _SM_CHECK_KW = "check_vma"


def shard_map(f, *, mesh, in_specs, out_specs):
    kw = {_SM_CHECK_KW: False} if _SM_CHECK_KW else {}
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)

__all__ = [
    "DistSellCS", "dist_from_coo", "dist_spmv", "make_dist_spmv",
    # pipeline stages (recomposed by repro.runtime.pipeline)
    "halo_pack", "halo_exchange_unpack", "local_stage", "remote_stage",
    "fused_epilogue", "spmv_shard_stages", "dist_spmv_shard",
]


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistSellCS:
    """Row-distributed SELL-C-sigma matrix over ``nshards`` shards.

    All per-shard arrays are stacked on a leading shard axis and padded to
    the max over shards so they form one shardable global array.
    """

    # local part (square, shard-sigma-permuted cols), stacked + padded
    l_vals: jax.Array      # (P, capL)
    l_cols: jax.Array      # (P, capL)
    l_off: jax.Array       # (P, ncks)
    l_len: jax.Array       # (P, ncks)
    l_rowids: jax.Array    # (P, capL)
    # remote part (cols index the halo buffer), same row perm as local
    r_vals: jax.Array      # (P, capR)
    r_cols: jax.Array      # (P, capR)
    r_off: jax.Array       # (P, ncks)
    r_len: jax.Array       # (P, ncks)
    r_rowids: jax.Array    # (P, capR)
    # halo exchange maps
    send_idx: jax.Array    # (P, P, max_msg) gather into x_local
    halo_idx: jax.Array    # (P, H_max) gather into flattened recv buffer
    # vector distribution maps
    g2l: jax.Array         # (P, m_pad) original global row per local slot (-1 pad)
    pos_of_global: jax.Array  # (nrows,) into flattened (P*m_pad)

    # partition bookkeeping (host-side; feeds the runtime's rebalance loop)
    row_ranges: Tuple[Tuple[int, int], ...] = dataclasses.field(
        metadata=dict(static=True))
    shard_nnz: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))

    # statics
    nshards: int = dataclasses.field(metadata=dict(static=True))
    C: int = dataclasses.field(metadata=dict(static=True))
    sigma: int = dataclasses.field(metadata=dict(static=True))
    w_align: int = dataclasses.field(metadata=dict(static=True))
    nrows: int = dataclasses.field(metadata=dict(static=True))
    m_pad: int = dataclasses.field(metadata=dict(static=True))
    max_msg: int = dataclasses.field(metadata=dict(static=True))
    h_max: int = dataclasses.field(metadata=dict(static=True))
    # compute (accumulation) dtype name when the value shards are stored
    # narrower; None = values are stored in the compute dtype
    compute_dtype: Optional[str] = dataclasses.field(
        default=None, metadata=dict(static=True))

    # ------------------------------------------------------------------
    @property
    def dtype(self):
        """Compute dtype — accumulation, vectors, halo buffers."""
        if self.compute_dtype is not None:
            return jnp.dtype(self.compute_dtype)
        return self.l_vals.dtype

    @property
    def store_dtype(self):
        """Storage dtype of the local/remote value shards (HBM traffic)."""
        return self.l_vals.dtype

    @property
    def comm_volume(self) -> int:
        """Worst-case halo words moved per shard per SpMV (padded)."""
        return self.nshards * self.max_msg

    def distribute_vec(self, x: jax.Array) -> jax.Array:
        """Global original-space (nrows[, b]) -> stacked shard-local (P, m_pad[, b])."""
        idx = jnp.clip(self.g2l, 0, self.nrows - 1)
        mask = (self.g2l >= 0)
        xv = x[idx]
        if x.ndim > 1:
            mask = mask[..., None]
        return jnp.where(mask, xv, 0)

    def collect_vec(self, xs: jax.Array) -> jax.Array:
        """Stacked shard-local (P, m_pad[, b]) -> global (nrows[, b])."""
        flat = xs.reshape((self.nshards * self.m_pad,) + xs.shape[2:])
        return flat[self.pos_of_global]


def dist_from_coo(
    rows, cols, vals, nrows: int, *,
    nshards: int,
    weights: Optional[Sequence[float]] = None,
    C: int = 32,
    sigma: int = 1,
    w_align: int = 1,
    by_nnz: bool = False,
    dtype=None,
    store_dtype=None,
    ranges: Optional[Sequence[Tuple[int, int]]] = None,
) -> DistSellCS:
    """Build a row-distributed SELL-C-sigma matrix from global COO (square).

    ``ranges`` overrides the internal weighted partition with precomputed
    contiguous row ranges (e.g. from :func:`repro.runtime.split.plan_split`,
    which produces C-aligned, non-empty, apportionment-balanced shards).

    ``store_dtype`` keeps every shard's local *and* remote value arrays in
    a narrower storage dtype end-to-end (the halo exchange itself moves
    vector data in the compute ``dtype``; only matrix values narrow) —
    see :func:`repro.core.sellcs.from_coo`.
    """
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    if dtype is not None:
        vals = vals.astype(dtype)
    weights = [1.0] * nshards if weights is None else list(weights)
    if len(weights) != nshards:
        raise ValueError(
            f"expected {nshards} weights, got {len(weights)}")

    if ranges is not None:
        ranges = [(int(s), int(e)) for (s, e) in ranges]
        if len(ranges) != nshards:
            raise ValueError(
                f"expected {nshards} ranges, got {len(ranges)}")
        if ranges[0][0] != 0 or ranges[-1][1] != nrows:
            raise ValueError(
                f"ranges must cover [0, {nrows}), got "
                f"[{ranges[0][0]}, {ranges[-1][1]})")
        if any(ranges[i][1] != ranges[i + 1][0]
               for i in range(nshards - 1)):
            raise ValueError("ranges must be contiguous (each end == "
                             "next start)")
    elif by_nnz:
        rowlen = np.zeros(nrows, np.int64)
        np.add.at(rowlen, rows, 1)
        ranges = part.weighted_nnz_partition(rowlen, weights, align=1)
    else:
        ranges = part.weighted_row_partition(nrows, weights, align=1)

    locals_: List[SellCS] = []
    remotes: List[SellCS] = []
    rcols_all: List[np.ndarray] = []
    for (s, e) in ranges:
        m = e - s
        sel = (rows >= s) & (rows < e)
        r_p, c_p, v_p = rows[sel] - s, cols[sel], vals[sel]
        is_local = (c_p >= s) & (c_p < e)
        # local square part: shard-level sigma sorting + permuted columns
        L = from_coo(r_p[is_local], c_p[is_local] - s, v_p[is_local],
                     (m, m), C=C, sigma=sigma, w_align=w_align,
                     store_dtype=store_dtype)
        # remote part: compressed halo columns, same row perm as local
        rg = c_p[~is_local]
        rcols = np.unique(rg)                          # sorted ascending
        h = len(rcols)
        hidx = np.searchsorted(rcols, rg)
        R = from_coo(r_p[~is_local], hidx, v_p[~is_local],
                     (m, max(h, 1)), C=C, sigma=1, w_align=w_align,
                     store_dtype=store_dtype,
                     row_perm=np.asarray(L.perm, np.int64),
                     permute_columns=False)
        locals_.append(L)
        remotes.append(R)
        rcols_all.append(rcols)

    m_pad = max(L.nrows_pad for L in locals_)
    ncks = m_pad // C
    capL = max(L.cap for L in locals_)
    capR = max(R.cap for R in remotes)

    # ---- halo exchange maps ------------------------------------------------
    starts = np.array([s for (s, _) in ranges], np.int64)
    ends = np.array([e for (_, e) in ranges], np.int64)
    owner_of = np.zeros(nrows, np.int64)
    for q, (s, e) in enumerate(ranges):
        owner_of[s:e] = q
    send_lists = [[np.zeros(0, np.int64) for _ in range(nshards)]
                  for _ in range(nshards)]            # [src][dst]
    halo_entries = []                                  # per shard: (owner, rank)
    cnt = np.zeros((nshards, nshards), np.int64)       # cnt[src][dst]
    for p in range(nshards):
        rcols = rcols_all[p]
        owners = owner_of[rcols] if len(rcols) else np.zeros(0, np.int64)
        ent = np.zeros((len(rcols), 2), np.int64)
        for q in range(nshards):
            sel = owners == q
            g = rcols[sel]
            # owner-local (permuted) positions, ascending in g
            ipq = np.asarray(locals_[q].iperm, np.int64)
            send_lists[q][p] = ipq[g - starts[q]]
            ent[sel, 0] = q
            ent[sel, 1] = np.arange(sel.sum())
            cnt[q, p] = sel.sum()
        halo_entries.append(ent)
    max_msg = max(1, int(cnt.max()))
    h_max = max(1, max(len(r) for r in rcols_all))

    send_idx = np.zeros((nshards, nshards, max_msg), np.int64)
    for q in range(nshards):
        for p in range(nshards):
            sl = send_lists[q][p]
            send_idx[q, p, : len(sl)] = sl
    halo_idx = np.zeros((nshards, h_max), np.int64)
    for p in range(nshards):
        ent = halo_entries[p]
        halo_idx[p, : len(ent)] = ent[:, 0] * max_msg + ent[:, 1]

    # ---- vector maps --------------------------------------------------------
    g2l = np.full((nshards, m_pad), -1, np.int64)
    pos_of_global = np.zeros(nrows, np.int64)
    for p, (s, e) in enumerate(ranges):
        m = e - s
        permp = np.asarray(locals_[p].perm, np.int64)
        # local permuted slot j holds original row s + permp[j] (if < m)
        valid = permp < m
        g2l[p, : len(permp)][valid] = s + permp[valid]
        slots = np.nonzero(valid)[0]
        pos_of_global[s + permp[valid]] = p * m_pad + slots

    def stack(arrs, cap, pad_val=0, dt=None):
        out = np.full((nshards, cap), pad_val,
                      dt if dt is not None else np.asarray(arrs[0]).dtype)
        for i, a in enumerate(arrs):
            a = np.asarray(a)
            out[i, : a.shape[0]] = a
        return out

    # chunk arrays padded with zero-length chunks at offset cap//C
    def stack_chunks(mats, cap):
        offs = np.zeros((nshards, ncks), np.int64)
        lens = np.zeros((nshards, ncks), np.int64)
        for i, M in enumerate(mats):
            o = np.asarray(M.chunk_off)
            l = np.asarray(M.chunk_len)
            offs[i, : len(o)] = o
            lens[i, : len(l)] = l
            # padding chunks: zero length, offset clamped inside cap
            offs[i, len(o):] = 0
        return offs, lens

    l_off, l_len = stack_chunks(locals_, capL)
    r_off, r_len = stack_chunks(remotes, capR)

    vdt = locals_[0].vals.dtype
    return DistSellCS(
        l_vals=jnp.asarray(stack([M.vals for M in locals_], capL, dt=vdt)),
        l_cols=jnp.asarray(stack([M.cols for M in locals_], capL, dt=np.int64), jnp.int32),
        l_off=jnp.asarray(l_off, jnp.int32),
        l_len=jnp.asarray(l_len, jnp.int32),
        l_rowids=jnp.asarray(stack([M.rowids for M in locals_], capL, dt=np.int64), jnp.int32),
        r_vals=jnp.asarray(stack([M.vals for M in remotes], capR, dt=vdt)),
        r_cols=jnp.asarray(stack([M.cols for M in remotes], capR, dt=np.int64), jnp.int32),
        r_off=jnp.asarray(r_off, jnp.int32),
        r_len=jnp.asarray(r_len, jnp.int32),
        r_rowids=jnp.asarray(stack([M.rowids for M in remotes], capR, dt=np.int64), jnp.int32),
        send_idx=jnp.asarray(send_idx, jnp.int32),
        halo_idx=jnp.asarray(halo_idx, jnp.int32),
        g2l=jnp.asarray(g2l, jnp.int32),
        pos_of_global=jnp.asarray(pos_of_global, jnp.int32),
        row_ranges=tuple((int(s), int(e)) for (s, e) in ranges),
        shard_nnz=tuple(int(L.nnz + R.nnz)
                        for L, R in zip(locals_, remotes)),
        nshards=nshards,
        C=C,
        sigma=sigma,
        w_align=w_align,
        nrows=nrows,
        m_pad=m_pad,
        max_msg=max_msg,
        h_max=h_max,
        compute_dtype=locals_[0].compute_dtype,
    )


# ---------------------------------------------------------------------------
# SPMD compute (runs inside shard_map; one shard's slice per device)
#
# The shard step is decomposed into named *stages* mirroring GHOST's
# task-mode SpMV (paper Fig. 5): pack -> exchange/unpack -> local -> remote
# -> epilogue.  ``dist_spmv_shard`` composes them for the classic one-shot
# path; ``repro.runtime.pipeline`` re-composes the same stages with
# double-buffered halo staging for the heterogeneous engine.
# ---------------------------------------------------------------------------

def _shard_spmv_ref(vals, cols, rowids, x, m_pad, acc_dt):
    contrib = vals[:, None].astype(acc_dt) * x[cols].astype(acc_dt)
    return jax.ops.segment_sum(contrib, rowids, num_segments=m_pad)


def _shard_spmv_pallas(vals, cols, off, ln, x, C, w_tile, interpret,
                       compute_dtype=None):
    from repro.kernels.sellcs_spmv import sellcs_spmv_pallas
    y, _, _ = sellcs_spmv_pallas(vals, cols, off, ln, x, C=C, w_tile=w_tile,
                                 compute_dtype=compute_dtype,
                                 interpret=interpret)
    return y


def halo_pack(shard: dict, x_local: jax.Array) -> jax.Array:
    """Stage 1: gather the owned rows each peer needs -> (P, max_msg, b)."""
    return x_local[shard["send_idx"]]


def halo_exchange_unpack(A: DistSellCS, shard: dict, sendbuf: jax.Array,
                         axis: str) -> jax.Array:
    """Stage 2: all_to_all the send buffer and compress the receive buffer
    into this shard's dense halo (remote-column compression, Fig. 3)."""
    b = sendbuf.shape[-1]
    recv = lax.all_to_all(sendbuf, axis, 0, 0, tiled=False)
    if recv.ndim == 4:                                  # (P,1,msg,b) squeeze
        recv = recv.reshape(A.nshards, A.max_msg, b)
    return recv.reshape(A.nshards * A.max_msg, b)[shard["halo_idx"]]


def local_stage(A: DistSellCS, shard: dict, x_local: jax.Array,
                *, impl: str, interpret: bool, acc_dt) -> jax.Array:
    """Stage 3: SpMV of the local (square) part — no communication.

    The value shard streams at its *storage* dtype; accumulation happens
    in ``acc_dt`` (the compute dtype joined with the vector dtype).
    """
    if impl == "pallas":
        return _shard_spmv_pallas(shard["l_vals"], shard["l_cols"],
                                  shard["l_off"], shard["l_len"], x_local,
                                  A.C, A.w_align, interpret,
                                  compute_dtype=acc_dt).astype(acc_dt)
    return _shard_spmv_ref(shard["l_vals"], shard["l_cols"],
                           shard["l_rowids"], x_local, A.m_pad, acc_dt)


def remote_stage(A: DistSellCS, shard: dict, halo: jax.Array,
                 *, impl: str, interpret: bool, acc_dt) -> jax.Array:
    """Stage 4: SpMV of the remote part against the compressed halo."""
    if impl == "pallas":
        return _shard_spmv_pallas(shard["r_vals"], shard["r_cols"],
                                  shard["r_off"], shard["r_len"], halo,
                                  A.C, A.w_align, interpret,
                                  compute_dtype=acc_dt).astype(acc_dt)
    return _shard_spmv_ref(shard["r_vals"], shard["r_cols"],
                           shard["r_rowids"], halo, A.m_pad, acc_dt)


def fused_epilogue(Ax: jax.Array, x_local: jax.Array, axis: str,
                   opts: SpmvOpts, acc_dt,
                   y_local: Optional[jax.Array] = None):
    """Stage 5: shift/scale/axpby + the fused dot products (psum'ed)."""
    b = x_local.shape[1]
    if opts.gamma is not None:
        Ax = Ax - jnp.asarray(opts.gamma, acc_dt) * x_local.astype(acc_dt)
    y = opts.alpha * Ax
    if y_local is not None:
        y = y + opts.beta * y_local.astype(acc_dt)

    dots = None
    if opts.any_dot:
        zero = jnp.zeros((b,), acc_dt)
        xl = x_local.astype(acc_dt)
        d = jnp.stack([
            jnp.sum(y * y, axis=0) if opts.dot_yy else zero,
            jnp.sum(xl * y, axis=0) if opts.dot_xy else zero,
            jnp.sum(xl * xl, axis=0) if opts.dot_xx else zero,
        ])
        dots = lax.psum(d, axis)
    return y, dots


def spmv_shard_stages(
    A: DistSellCS,
    shard: dict,
    x_local: jax.Array,            # (m_pad, b) shard-permuted
    axis: str,
    *,
    overlap: bool = True,
    impl: str = "ref",
    interpret: Optional[bool] = None,
    opts: SpmvOpts = SpmvOpts(),
    y_local: Optional[jax.Array] = None,
    staging: Optional[jax.Array] = None,   # (2, P, max_msg, b) double buffer
):
    """The full stage composition for one shard.  Returns (y, dots, staging').

    With ``staging`` the send buffer rotates through a two-slot array:
    slot 0 <- this call's packed rows, slot 1 <- the previous call's
    buffer (kept live until its exchange must have completed) — the
    double-buffered halo staging of the runtime pipeline.
    ``interpret=None`` defers to :mod:`repro.core.execution` (resolved at
    trace time).  A compiled-Pallas request on a backend that cannot
    lower it degrades to the ref stages with a one-time warning — the
    trace-time leg of the hardened cascade (a lowering error inside
    ``shard_map`` could not be caught later).
    """
    interpret = execution.resolve_interpret(interpret)
    if (impl == "pallas" and not interpret
            and execution.degrade_to_reference("dist_spmv[pallas]")):
        impl = "ref"
    # accumulate in the matrix' compute dtype (== value-shard dtype for
    # single-dtype matrices; wider when store_dtype narrows the shards)
    acc_dt = jnp.result_type(A.dtype, x_local.dtype)

    # --- stage 1: pack -----------------------------------------------------
    send = halo_pack(shard, x_local)
    if staging is not None:
        # rotate in the send buffer's own dtype: the retained slot 1 is
        # never computed on, so staging can never round the live halo
        # values (bit-identity with the unstaged schedule holds for any
        # initial staging dtype)
        staging = jnp.stack([send, staging[0].astype(send.dtype)])
        send = staging[0]

    # --- stage 2: halo exchange (independent of local compute) -------------
    halo = halo_exchange_unpack(A, shard, send, axis)

    # --- stage 3: local part (overlappable with the exchange) --------------
    if overlap:
        y_loc = local_stage(A, shard, x_local, impl=impl,
                            interpret=interpret, acc_dt=acc_dt)
    else:
        # paper Fig. 5 "No Overlap": force the exchange before local compute
        x_seq, halo = lax.optimization_barrier((x_local, halo))
        y_loc = local_stage(A, shard, x_seq, impl=impl,
                            interpret=interpret, acc_dt=acc_dt)

    # --- stage 4: remote part ----------------------------------------------
    y_rem = remote_stage(A, shard, halo, impl=impl, interpret=interpret,
                         acc_dt=acc_dt)

    # --- stage 5: fused epilogue -------------------------------------------
    y, dots = fused_epilogue(y_loc + y_rem, x_local, axis, opts, acc_dt,
                             y_local=y_local)
    return y, dots, staging


def dist_spmv_shard(
    A: DistSellCS,
    shard: dict,
    x_local: jax.Array,            # (m_pad, b) shard-permuted
    axis: str,
    *,
    overlap: bool = True,
    impl: str = "ref",
    interpret: Optional[bool] = None,
    opts: SpmvOpts = SpmvOpts(),
    y_local: Optional[jax.Array] = None,
):
    """One shard's fused distributed SpMV step (call inside shard_map).

    ``shard`` holds this shard's slices of the stacked arrays.  Returns
    (y_local, dots) with dots already psum'ed over ``axis``.
    """
    y, dots, _ = spmv_shard_stages(A, shard, x_local, axis, overlap=overlap,
                                   impl=impl, interpret=interpret, opts=opts,
                                   y_local=y_local)
    return y, dots


def _shard_view(A: DistSellCS) -> dict:
    """Names of the stacked arrays to pass through shard_map."""
    return dict(
        l_vals=A.l_vals, l_cols=A.l_cols, l_off=A.l_off, l_len=A.l_len,
        l_rowids=A.l_rowids,
        r_vals=A.r_vals, r_cols=A.r_cols, r_off=A.r_off, r_len=A.r_len,
        r_rowids=A.r_rowids,
        send_idx=A.send_idx, halo_idx=A.halo_idx,
    )


def make_dist_spmv(
    A: DistSellCS,
    mesh: Mesh,
    axis: str = "data",
    *,
    overlap: bool = True,
    impl: str = "ref",
    interpret: Optional[bool] = None,
    opts: SpmvOpts = SpmvOpts(),
    nvecs: int = 1,
) -> Callable[[jax.Array], Tuple[jax.Array, Optional[jax.Array]]]:
    """Build a jitted distributed SpMV over stacked shard-local vectors.

    The returned fn maps ``x_stacked (P, m_pad, nvecs)`` (see
    :meth:`DistSellCS.distribute_vec`) to ``(y_stacked, dots)``.
    ``interpret=None`` resolves through the central execution policy once
    at build time.
    """
    interpret = execution.resolve_interpret(interpret)
    sh = _shard_view(A)
    pspec = {k: P(axis, *([None] * (v.ndim - 1))) for k, v in sh.items()}

    def fn(shard, x):
        shard = {k: v[0] for k, v in shard.items()}
        y, dots = dist_spmv_shard(A, shard, x[0], axis, overlap=overlap,
                                  impl=impl, interpret=interpret, opts=opts)
        return y[None], (jnp.zeros((1, 3, nvecs), y.dtype) if dots is None
                         else dots[None].astype(y.dtype))

    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(pspec, P(axis, None, None)),
        out_specs=(P(axis, None, None), P(axis, None, None)),
    )

    @jax.jit
    def run(x_stacked):
        y, dots = mapped(sh, x_stacked)
        return y, dots[0]

    return run


def dist_spmv(
    A: DistSellCS,
    mesh: Mesh,
    x: jax.Array,
    axis: str = "data",
    **kw,
):
    """Convenience: global original-space x -> global y (test-friendly)."""
    x2 = x[:, None] if x.ndim == 1 else x
    xs = A.distribute_vec(x2)
    run = make_dist_spmv(A, mesh, axis, nvecs=x2.shape[1], **kw)
    ys, dots = run(xs)
    y = A.collect_vec(ys)
    if x.ndim == 1:
        y = y[:, 0]
    return y, dots
