"""Augmented, fused SpM(M)V (paper C1 + C3).

The single entry point mirrors GHOST's ``ghost_spmv(y, A, x, opts)``:

    y = alpha * (A - gamma*I) @ x + beta * y          (VSHIFT: gamma per column)
    z = delta * z + eta * y                            (chained AXPBY)
    dots = [<y,y>, <x,y>, <x,x>]  (per block-vector column, f64 or Kahan acc)

Every augmentation is individually switchable, exactly like the paper's
``GHOST_SPMV_*`` flags.  ``x``/``y``/``z`` may be single vectors ``(n,)`` or
block vectors ``(n, b)`` (row-major interleaved storage — paper section 5.2).

Two executors:
  * ``impl='ref'``     — pure jnp (segment-sum) oracle, runs anywhere.
  * ``impl='pallas'``  — the SELL-C-sigma Pallas TPU kernel (fused sweep).

All vectors live in the matrix' *permuted* space of length ``nrows_pad``
(see ``core.sellcs``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockvec
from repro.core.sellcs import SellCS

__all__ = ["SpmvOpts", "as2d", "pack_coefs", "spmv", "spmv_ref",
           "dot_acc_dtype", "storage_acc_dtype", "compensated_sum0",
           "fused_dots"]


def storage_acc_dtype(dt):
    """Accumulator dtype for a given operand/output dtype.

    The storage-vs-compute contract shared by every value-stream kernel
    (``sellcs_spmv``, ``block_diag``, ``fused_update``): sub-32-bit floats
    (``bfloat16``/``float16``) are *storage* formats — loads upcast
    in-register and the accumulator is at least ``float32``; 32/64-bit
    floats accumulate natively.  See ``docs/mixed_precision.md``.
    """
    dt = jnp.dtype(dt)
    if dt in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        return jnp.dtype(jnp.float32)
    return dt


@dataclasses.dataclass(frozen=True)
class SpmvOpts:
    """Fusion flags for the augmented SpMV (GHOST ``ghost_spmv_opts``)."""

    alpha: float | jax.Array = 1.0
    beta: float | jax.Array = 0.0         # y = alpha*Ax + beta*y
    gamma: Optional[jax.Array] = None     # scalar or (b,) per-column shift
    delta: Optional[jax.Array] = None     # z = delta*z + eta*y  (needs eta too)
    eta: Optional[jax.Array] = None
    dot_yy: bool = False
    dot_xy: bool = False
    dot_xx: bool = False

    @property
    def any_dot(self) -> bool:
        return self.dot_yy or self.dot_xy or self.dot_xx

    @property
    def chain_axpby(self) -> bool:
        return self.delta is not None or self.eta is not None


def pack_coefs(opts: SpmvOpts, nvecs: int, dtype) -> jax.Array:
    """Pack (alpha, beta, gamma) into a traced ``(3, nvecs)`` operand.

    Matvec builders that take coefficients as runtime arrays (so solvers
    can vary them per iteration without retracing — see
    ``repro.runtime.pipeline.make_pipeline_spmv``) use this layout; the
    static flags of ``opts`` stay trace-time switches.
    """
    c = jnp.zeros((3, nvecs), dtype)
    c = c.at[0].set(jnp.broadcast_to(jnp.asarray(opts.alpha, dtype), (nvecs,)))
    c = c.at[1].set(jnp.broadcast_to(jnp.asarray(opts.beta, dtype), (nvecs,)))
    if opts.gamma is not None:
        c = c.at[2].set(jnp.broadcast_to(jnp.asarray(opts.gamma, dtype),
                                         (nvecs,)))
    return c


def as2d(v: jax.Array) -> Tuple[jax.Array, bool]:
    """Promote a single vector to a 1-column block vector.

    Returns ``(v2d, was1d)`` — the shared promotion convention for every
    operator/engine entry point that accepts ``(n,)`` or ``(n, b)``.
    """
    if v.ndim == 1:
        return v[:, None], True
    return v, False


_as2d = as2d


def dot_acc_dtype(dt):
    """Accumulation dtype for the fused dot products (paper: f64 acc).

    64-bit when x64 is enabled (the paper's augmented-SpMV accuracy
    claim); otherwise the widest available float — callers then
    compensate via :func:`compensated_sum0` instead.  Always inexact:
    integer/bool inputs accumulate in float, as the dots are analytic
    quantities (norms, Rayleigh quotients), not counters.
    """
    dt = jnp.dtype(dt)
    x64 = jax.dtypes.canonicalize_dtype(np.float64) == np.dtype(np.float64)
    if jnp.issubdtype(dt, jnp.complexfloating):
        return jnp.dtype(jnp.complex128) if x64 else dt
    if not jnp.issubdtype(dt, jnp.floating):
        return jnp.dtype(jnp.float64 if x64 else jnp.float32)
    if x64:
        return jnp.dtype(jnp.float64)
    return jnp.dtype(jnp.float32) if jnp.finfo(dt).bits < 32 else dt


def compensated_sum0(p: jax.Array, block: int = 256) -> jax.Array:
    """Kahan-compensated sum over axis 0 (the "or Kahan acc" leg).

    Blocks of ``block`` rows are reduced natively, then the block
    partials are Kahan-accumulated (``blockvec._kahan_reduce``, the same
    compensation the paper's tsmttsm uses), shrinking the uncompensated
    window from ``n`` to ``block`` summands.  Used for the fused dots
    when float64 is unavailable.
    """
    n = p.shape[0]
    if n == 0:
        return jnp.zeros(p.shape[1:], p.dtype)
    pad = (-n) % block
    if pad:
        p = jnp.pad(p, ((0, pad),) + ((0, 0),) * (p.ndim - 1))
    parts = p.reshape(-1, block, *p.shape[1:]).sum(axis=1)
    return blockvec._kahan_reduce(parts)


def _acc_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """<a, b> per column, accumulated in f64 (or Kahan when x64 is off)."""
    ddt = dot_acc_dtype(jnp.result_type(a.dtype, b.dtype))
    if jnp.finfo(ddt).bits >= 64:              # 64-bit accumulator available
        return jnp.sum(jnp.conj(a.astype(ddt)) * b.astype(ddt), axis=0)
    return blockvec.dot_kahan(a.astype(ddt), b.astype(ddt))


def fused_dots(x2: jax.Array, y2: jax.Array, opts: SpmvOpts) -> jax.Array:
    """The ``(3, b)`` fused-dot bundle ``[<y,y>, <x,y>, <x,x>]``.

    Shared by every operator flavor (``spmv_ref``, the matrix-free hook)
    so the accumulation semantics — conjugated first argument, f64
    accumulation under x64, block-Kahan otherwise — are identical no
    matter which operator a solver runs on.  ``x2``/``y2`` are 2-d
    block vectors; rows not requested by ``opts`` stay zero.
    """
    ddt = dot_acc_dtype(jnp.result_type(y2.dtype, x2.dtype))
    b = y2.shape[1]
    dots = jnp.zeros((3, b), ddt)
    if opts.dot_yy:
        dots = dots.at[0].set(_acc_dot(y2, y2))
    if opts.dot_xy:
        dots = dots.at[1].set(_acc_dot(x2, y2))
    if opts.dot_xx:
        dots = dots.at[2].set(_acc_dot(x2, x2))
    return dots


def spmv_ref(
    A: SellCS,
    x: jax.Array,
    y: Optional[jax.Array] = None,
    z: Optional[jax.Array] = None,
    opts: SpmvOpts = SpmvOpts(),
):
    """Pure-jnp oracle for the fused SpMV.  Returns (y, z, dots).

    dots is a (3, b) array (rows: yy, xy, xx; zeros where not requested) or
    None if no dot was requested.  z is None unless chaining was requested.
    """
    x2, was1d = _as2d(x)
    n = A.nrows_pad
    if x2.shape[0] != n:
        raise ValueError(
            f"spmv: x must be permuted/padded to {n} rows, got {x2.shape}")
    # accumulate in the matrix' *compute* dtype (== vals dtype for single-
    # dtype matrices — that leg is bit-identical to the classic layout);
    # a narrower store_dtype upcasts per-element before the products
    acc_dt = jnp.result_type(A.dtype, x2.dtype)
    contrib = A.vals.astype(acc_dt)[:, None] * x2.astype(acc_dt)[A.cols]
    Ax = jax.ops.segment_sum(contrib, A.rowids, num_segments=n)

    if opts.gamma is not None:
        gamma = jnp.asarray(opts.gamma)
        Ax = Ax - gamma * x2                          # (A - gamma I) x
    ynew = opts.alpha * Ax
    if y is not None:
        y2, _ = _as2d(y)
        ynew = ynew + opts.beta * jnp.asarray(y2, acc_dt)

    znew = None
    if opts.chain_axpby:
        if z is None:
            raise ValueError("spmv: chained axpby requires z")
        z2, _ = _as2d(z)
        delta = 0.0 if opts.delta is None else opts.delta
        eta = 0.0 if opts.eta is None else opts.eta
        znew = delta * z2 + eta * ynew
        if was1d:
            znew = znew[:, 0]

    dots = None
    if opts.any_dot:
        # f64 accumulation (or Kahan when x64 is off) — the docstring's
        # "f64 or Kahan acc" promise; cast up at this boundary only.
        dots = fused_dots(x2, ynew, opts)

    if was1d:
        ynew = ynew[:, 0]
    return ynew, znew, dots


def spmv(
    A: SellCS,
    x: jax.Array,
    y: Optional[jax.Array] = None,
    z: Optional[jax.Array] = None,
    opts: SpmvOpts = SpmvOpts(),
    *,
    impl: str = "ref",
    interpret: Optional[bool] = None,
):
    """Dispatching fused SpMV (GHOST single-interface ``ghost_spmv``).

    ``interpret=None`` defers to :mod:`repro.core.execution` (compiled on
    TPU, interpret elsewhere, env/context overridable).
    """
    if impl == "ref":
        return spmv_ref(A, x, y, z, opts)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.sellcs_spmv(A, x, y, z, opts, interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")
