"""Centralized kernel execution policy (paper section 5.4, C6).

GHOST's specialization cascade promises that the *same* call site runs the
fastest kernel the hardware supports and degrades gracefully otherwise.
This module is the single place where that decision is made for every
Pallas kernel in the repo:

* **Backend auto-detection** — compiled Pallas on TPU, interpret mode
  everywhere else (``jax.default_backend()``), so the high-performance
  path engages automatically on real hardware while CPU development and
  CI keep working unchanged.
* **Overrides** — the ``REPRO_INTERPRET`` env var (``0``/``1``/``auto``)
  pins the mode process-wide; :func:`force` pins it (and any tile knob)
  for a lexical scope::

      with execution.force(interpret=True):
          y, _, _ = ops.sellcs_spmv(A, x)      # interpreter, regardless

* **Tile knobs** — per-kernel tile sizes (``w_tile``, ``row_tile``,
  ``s_blk``) ride on the policy with env-var overrides and a small
  :func:`autotune` measure-and-cache hook.
* **Hardened cascade** — :func:`cascade` runs the specialized kernel and,
  if the *compiled* path fails (e.g. mode forced on a backend without
  Pallas support), falls back to the jnp reference with a one-time
  warning instead of crashing.  Interpret-mode failures still raise:
  those are logic bugs, not capability gaps.

Resolution happens at trace time.  A function jitted under one policy
keeps its compiled mode until retraced; enter :func:`force` *before*
tracing (or build separate jitted callables per mode, as
``runtime.engine.make_matvec`` does via its cache key).  Likewise
:func:`cascade` can only catch failures that surface while the wrapper
runs — eager calls and the wrapper's own trace; a failure inside an
enclosing ``jax.jit`` surfaces at that jit's compile time.

Env vars: ``REPRO_INTERPRET``, ``REPRO_W_TILE``, ``REPRO_ROW_TILE``,
``REPRO_S_BLK``, ``REPRO_FALLBACK``.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import warnings
from contextlib import contextmanager
from typing import Any, Callable, Iterable, Optional, Sequence, TypeVar

import jax

__all__ = [
    "ExecutionPolicy", "default_policy", "current_policy", "force",
    "resolve_interpret", "resolve_w_tile", "resolve_row_tile",
    "resolve_s_blk", "cascade", "compiled_available",
    "degrade_to_reference", "autotune", "describe", "reset",
]

T = TypeVar("T")

ENV_INTERPRET = "REPRO_INTERPRET"
ENV_W_TILE = "REPRO_W_TILE"
ENV_ROW_TILE = "REPRO_ROW_TILE"
ENV_S_BLK = "REPRO_S_BLK"
ENV_FALLBACK = "REPRO_FALLBACK"

#: backends whose Pallas lowering we trust enough to compile by default
COMPILED_BACKENDS = ("tpu",)

_TRUE = frozenset({"1", "true", "yes", "on"})
_FALSE = frozenset({"0", "false", "no", "off"})


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """One resolved answer to "how should a kernel run right now?".

    ``interpret`` is the load-bearing bit; ``source`` records who decided
    (``auto`` backend detection, ``env`` override, or a ``forced``
    context) so benchmarks can report what actually ran.  The tile knobs
    are defaults only — an explicit keyword at a call site always wins.
    """

    interpret: bool
    backend: str
    source: str = "auto"                  # "auto" | "env" | "forced"
    w_tile: Optional[int] = None          # None -> per-matrix w_align
    row_tile: int = 512
    s_blk: int = 64
    fallback: bool = True                 # cascade to jnp ref on failure

    @property
    def mode(self) -> str:
        return "interpret" if self.interpret else "compiled"


def _env_bool(name: str) -> Optional[bool]:
    raw = os.environ.get(name, "").strip().lower()
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    return None                            # unset / "auto" / unparsable


def _env_int(name: str) -> Optional[int]:
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        v = int(raw)
    except ValueError:
        warnings.warn(f"ignoring non-integer {name}={raw!r}", RuntimeWarning)
        return None
    return v if v > 0 else None


class _Stack(threading.local):
    def __init__(self):
        self.policies: list = []


_stack = _Stack()
_default: Optional[ExecutionPolicy] = None
_warned: set = set()
_tune_cache: dict = {}
_compiled_ok: Optional[bool] = None


def default_policy() -> ExecutionPolicy:
    """The process-level policy: env overrides over backend detection.

    Cached after the first call (which initializes the JAX backend);
    :func:`reset` invalidates the cache, e.g. after monkeypatching env
    vars in tests.
    """
    global _default
    if _default is None:
        backend = jax.default_backend()
        env = _env_bool(ENV_INTERPRET)
        if env is None:
            interpret, source = backend not in COMPILED_BACKENDS, "auto"
        else:
            interpret, source = env, "env"
        _default = ExecutionPolicy(
            interpret=interpret,
            backend=backend,
            source=source,
            w_tile=_env_int(ENV_W_TILE),
            row_tile=_env_int(ENV_ROW_TILE) or 512,
            s_blk=_env_int(ENV_S_BLK) or 64,
            fallback=_env_bool(ENV_FALLBACK) is not False,
        )
    return _default


def current_policy() -> ExecutionPolicy:
    """The active policy: innermost :func:`force` scope, else the default."""
    if _stack.policies:
        return _stack.policies[-1]
    return default_policy()


@contextmanager
def force(interpret: Optional[bool] = None, *,
          w_tile: Optional[int] = None,
          row_tile: Optional[int] = None,
          s_blk: Optional[int] = None,
          fallback: Optional[bool] = None):
    """Pin policy fields for a lexical scope (thread-local, re-entrant)."""
    repl: dict = {"source": "forced"}
    for k, v in (("interpret", interpret), ("w_tile", w_tile),
                 ("row_tile", row_tile), ("s_blk", s_blk),
                 ("fallback", fallback)):
        if v is not None:
            repl[k] = v
    pol = dataclasses.replace(current_policy(), **repl)
    _stack.policies.append(pol)
    try:
        yield pol
    finally:
        _stack.policies.pop()


# ------------------------------------------------------------------ resolvers
def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Explicit call-site argument wins; ``None`` defers to the policy."""
    return current_policy().interpret if interpret is None else bool(interpret)


def resolve_w_tile(w_tile: Optional[int], w_align: int) -> int:
    """Call-site arg > policy knob (when compatible) > matrix w_align.

    A policy-sourced width that doesn't divide into the matrix alignment
    degrades to ``w_align`` rather than raising: the knob is a hint, the
    call-site argument a contract.
    """
    if w_tile is not None:
        return int(w_tile)
    pw = current_policy().w_tile
    if pw is not None and (w_align % pw == 0 or pw % w_align == 0):
        return int(pw)
    return int(w_align)


def resolve_row_tile(row_tile: Optional[int] = None) -> int:
    return int(current_policy().row_tile if row_tile is None else row_tile)


def resolve_s_blk(s_blk: Optional[int] = None) -> int:
    return int(current_policy().s_blk if s_blk is None else s_blk)


# ------------------------------------------------------------------- cascade
def compiled_available() -> bool:
    """Whether this backend can lower + run a compiled Pallas kernel.

    Probed once per process with a trivial eager ``pallas_call`` (result
    cached; :func:`reset` clears it).  The probe makes the cascade a
    Python-level branch at *trace* time, so a forced-compiled policy on a
    Pallas-less backend falls back cleanly even inside ``lax.while_loop``
    solver bodies, where a lowering error could not be caught.
    """
    global _compiled_ok
    if _compiled_ok is None:
        try:
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def _probe(x_ref, o_ref):
                o_ref[...] = x_ref[...] + 1.0

            # the probe *implements* the policy the cascade rule guards,
            # and must pin compiled mode to test it
            # ghostlint: disable=GL001
            call = pl.pallas_call(
                _probe,
                out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
                interpret=False,  # ghostlint: disable=GL002
            )
            # AOT lower+compile: never binds into an ambient trace, so
            # the probe is safe (and meaningful) even when first hit
            # while tracing a shard_map/jit body — an eager call there
            # would be staged out and "succeed" unexecuted.
            jax.jit(call).lower(
                jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
            _compiled_ok = True
        # any lowering/compile failure means "compiled unavailable" —
        # the probe's whole job is to swallow it
        # ghostlint: disable=GL008
        except Exception:                                   # noqa: BLE001
            _compiled_ok = False
    return _compiled_ok


def _warn_once(kernel: str, msg: str) -> None:
    if kernel not in _warned:
        _warned.add(kernel)
        warnings.warn(msg, RuntimeWarning, stacklevel=4)


def degrade_to_reference(kernel: str) -> bool:
    """True if a compiled-path request must degrade to the reference.

    The branch-only face of :func:`cascade`, for call sites that pick an
    implementation *before* tracing (e.g. the distributed shard stages,
    where a Pallas lowering error inside ``shard_map``/``jit`` could not
    be caught).  Warns once per kernel when it returns True; honors
    ``fallback=False`` by returning False so the failure stays fatal.
    """
    pol = current_policy()
    if not pol.fallback or compiled_available():
        return False
    _warn_once(kernel, (
        f"{kernel}: compiled Pallas is unavailable on backend "
        f"{pol.backend!r}; falling back to the jnp reference "
        f"(warned once per kernel)"))
    return True


def cascade(kernel: str,
            specialized: Callable[[], T],
            reference: Optional[Callable[[], T]] = None,
            *,
            interpret: Optional[bool] = None) -> T:
    """Hardened specialization cascade (paper 5.4).

    Runs ``specialized()``.  If the policy resolved to the *compiled*
    path and the backend can't take it — mode forced on a backend
    without Pallas lowering (checked up front via
    :func:`compiled_available`, so it also works under tracing), or a
    residual failure while the specialized call runs — falls back to
    ``reference()`` with a one-time ``RuntimeWarning`` per kernel name.
    Interpret-mode failures always propagate (they are correctness bugs).
    Set ``REPRO_FALLBACK=0`` (or ``force(fallback=False)``) to make
    compiled failures fatal, e.g. in a TPU CI job that must never
    silently degrade.
    """
    pol = current_policy()
    it = pol.interpret if interpret is None else bool(interpret)
    if it or not pol.fallback or reference is None:
        return specialized()
    if not compiled_available():
        _warn_once(kernel, (
            f"{kernel}: compiled Pallas is unavailable on backend "
            f"{pol.backend!r}; falling back to the jnp reference "
            f"(warned once per kernel)"))
        return reference()
    try:
        return specialized()
    # the hardening contract: a compiled-path failure of *any* kind
    # degrades to the reference instead of crashing the solve
    # ghostlint: disable=GL008
    except Exception as e:                                  # noqa: BLE001
        _warn_once(kernel, (
            f"{kernel}: compiled Pallas path failed on backend "
            f"{pol.backend!r} ({type(e).__name__}: {e}); falling back "
            f"to the jnp reference (warned once per kernel)"))
        return reference()


# ------------------------------------------------------------------ autotune
def autotune(kernel: str,
             key: Any,
             candidates: Sequence[T],
             run: Callable[[T], Any],
             *,
             dtype: Any = None,
             iters: int = 3) -> T:
    """Tiny measure-and-cache tile picker.

    Times ``run(c)`` (block_until_ready'd) for each candidate knob value
    and returns the fastest; the winner is cached per
    ``(kernel, key, dtype, backend, mode)`` for the life of the process.
    ``key`` should capture whatever shapes the decision (e.g.
    ``(n, b)``); ``dtype`` is a dedicated key component for the operand
    dtype(s) — pass *both* the storage and the compute dtype for
    mixed-precision matrices (e.g. ``(A.store_dtype, A.dtype)``), since a
    narrower value stream shifts the bandwidth balance and therefore the
    optimal tile.  Call sites use this opportunistically::

        rt = execution.autotune("tsmttsm", (n, m, k), (256, 512, 1024),
                                lambda t: ops.tsmttsm(V, W, row_tile=t),
                                dtype=str(V.dtype))
    """
    pol = current_policy()
    ck = (kernel, key, None if dtype is None else str(dtype),
          pol.backend, pol.interpret)
    hit = _tune_cache.get(ck)
    if hit is not None:
        return hit
    best, best_t = None, float("inf")
    for cand in candidates:
        jax.block_until_ready(run(cand))                    # warm/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(run(cand))
        dt = (time.perf_counter() - t0) / iters
        if dt < best_t:
            best, best_t = cand, dt
    _tune_cache[ck] = best
    return best


# ------------------------------------------------------------------- plumbing
def describe(pol: Optional[ExecutionPolicy] = None) -> str:
    """One-line policy summary for benchmark output."""
    p = pol if pol is not None else current_policy()
    knobs = f"row_tile={p.row_tile};s_blk={p.s_blk}"
    if p.w_tile is not None:
        knobs += f";w_tile={p.w_tile}"
    return (f"mode={p.mode};backend={p.backend};source={p.source};"
            f"fallback={p.fallback};{knobs}")


def reset() -> None:
    """Drop every process-level cache (default policy, warnings, autotune).

    For tests that monkeypatch ``REPRO_*`` env vars mid-process.
    """
    global _default, _compiled_ok
    _default = None
    _compiled_ok = None
    _warned.clear()
    _tune_cache.clear()
