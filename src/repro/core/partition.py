"""Work distribution & permutations (paper C4, sections 3.1 / 4.1).

* Weighted row-wise partitioning: processes (devices) receive a share of
  rows or nonzeros proportional to a per-device *weight* (GHOST uses
  attainable memory bandwidth; on a homogeneous TPU pod weights default to
  1 but remain the hook for straggler mitigation / elastic re-partition).
* Bandwidth reduction: built-in reverse Cuthill-McKee (replaces PT-SCOTCH's
  role of communication minimization, section 3.1).
* Greedy row coloring (replaces ColPack; for Kaczmarz / Gauss-Seidel).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "weighted_row_partition",
    "weighted_nnz_partition",
    "apportioned_row_partition",
    "apportioned_nnz_partition",
    "rcm_permutation",
    "greedy_coloring",
    "bandwidth",
]


def weighted_row_partition(
    nrows: int, weights: Sequence[float], *, align: int = 1
) -> List[Tuple[int, int]]:
    """Split ``nrows`` into contiguous ranges proportional to ``weights``.

    Returns [(start, end)) per process.  ``align`` rounds boundaries to a
    multiple (e.g. the SELL chunk height C) so each local part chunks
    cleanly.
    """
    w = np.asarray(weights, np.float64)
    if (w <= 0).any():
        raise ValueError("weights must be positive")
    cum = np.cumsum(w) / w.sum()
    bounds = [0]
    for f in cum[:-1]:
        b = int(round(f * nrows / align)) * align
        b = min(max(b, bounds[-1]), nrows)
        bounds.append(b)
    bounds.append(nrows)
    return [(bounds[i], bounds[i + 1]) for i in range(len(w))]


def weighted_nnz_partition(
    rowlen: np.ndarray, weights: Sequence[float], *, align: int = 1
) -> List[Tuple[int, int]]:
    """Like :func:`weighted_row_partition` but balances *nonzeros* (the
    paper's alternative criterion)."""
    w = np.asarray(weights, np.float64)
    rl = np.asarray(rowlen, np.float64)
    nrows = len(rl)
    total = rl.sum()
    targets = np.cumsum(w / w.sum()) * total
    cs = np.cumsum(rl)
    bounds = [0]
    for t in targets[:-1]:
        b = int(np.searchsorted(cs, t))
        b = (b // align) * align
        b = min(max(b, bounds[-1]), nrows)
        bounds.append(b)
    bounds.append(nrows)
    return [(bounds[i], bounds[i + 1]) for i in range(len(w))]


# --------------------------------------------------------------------------
# Apportionment partitions (used by the heterogeneous runtime).
#
# The cumsum-rounding partitions above are fine for near-uniform weights but
# can emit *empty* shards for strongly skewed weights and leave the final
# boundary unaligned.  The heterogeneous engine needs every device to own a
# non-empty, C-aligned row block (an empty shard would make the stacked
# shard_map arrays degenerate), so these variants apportion whole
# ``align``-row blocks by largest remainder (Hamilton's method) and
# guarantee at least one block per shard whenever enough blocks exist.
# --------------------------------------------------------------------------

def _steal_for_empty(cnt: np.ndarray, nblocks: int) -> np.ndarray:
    """Steal blocks from the largest shards until nobody is empty
    (possible only when there are at least as many blocks as shards)."""
    if nblocks >= len(cnt):
        while (cnt == 0).any():
            cnt[int(np.argmax(cnt == 0))] += 1
            cnt[int(np.argmax(cnt))] -= 1
    return cnt


def _apportion_blocks(shares: np.ndarray, nblocks: int) -> np.ndarray:
    """Integer block counts per shard: largest-remainder on ``shares``
    (positive, sum-normalized), each shard >= 1 block if nblocks >= nshards."""
    ideal = shares / shares.sum() * nblocks
    cnt = np.floor(ideal).astype(np.int64)
    rem = nblocks - int(cnt.sum())
    if rem > 0:
        order = np.argsort(-(ideal - cnt), kind="stable")
        cnt[order[:rem]] += 1
    return _steal_for_empty(cnt, nblocks)


def _counts_to_ranges(cnt: np.ndarray, align: int, nrows: int):
    bounds = np.concatenate([[0], np.cumsum(cnt)]) * align
    bounds = np.minimum(bounds, nrows)
    bounds[-1] = nrows
    return [(int(bounds[i]), int(bounds[i + 1])) for i in range(len(cnt))]


def apportioned_row_partition(
    nrows: int, weights: Sequence[float], *, align: int = 1
) -> List[Tuple[int, int]]:
    """Weight-proportional contiguous row ranges via block apportionment.

    Like :func:`weighted_row_partition` but boundaries are exact multiples
    of ``align`` (only the final boundary may be the unaligned ``nrows``)
    and no shard is empty as long as ``nrows >= nshards * align``.
    """
    w = np.asarray(weights, np.float64)
    if (w <= 0).any():
        raise ValueError("weights must be positive")
    nblocks = (nrows + align - 1) // align
    cnt = _apportion_blocks(w, nblocks)
    return _counts_to_ranges(cnt, align, nrows)


def apportioned_nnz_partition(
    rowlen: np.ndarray, weights: Sequence[float], *, align: int = 1
) -> List[Tuple[int, int]]:
    """Nonzero-proportional variant: apportions ``align``-row blocks so each
    shard's *nnz* share tracks its weight (GHOST's bandwidth-weighted
    criterion, section 4.1), boundaries aligned, shards non-empty."""
    w = np.asarray(weights, np.float64)
    if (w <= 0).any():
        raise ValueError("weights must be positive")
    rl = np.asarray(rowlen, np.float64)
    nrows = len(rl)
    nblocks = (nrows + align - 1) // align
    # nnz per block (the last, partial block included)
    pad = nblocks * align - nrows
    blk = np.concatenate([rl, np.zeros(pad)]).reshape(nblocks, align).sum(1)
    cs_blk = np.concatenate([[0.0], np.cumsum(blk)])
    total = cs_blk[-1]
    if total <= 0:
        return apportioned_row_partition(nrows, weights, align=align)
    # walk block boundaries to hit cumulative nnz targets, then fix empties
    targets = np.cumsum(w / w.sum()) * total
    bounds = np.searchsorted(cs_blk, targets[:-1], side="left")
    bounds = np.concatenate([[0], bounds, [nblocks]])
    bounds = np.maximum.accumulate(np.clip(bounds, 0, nblocks))
    cnt = _steal_for_empty(np.diff(bounds).astype(np.int64), nblocks)
    return _counts_to_ranges(cnt, align, nrows)


# --------------------------------------------------------------------------
def _adjacency(rows: np.ndarray, cols: np.ndarray, n: int):
    """CSR adjacency of the symmetrized pattern (host-side)."""
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    keep = r != c
    r, c = r[keep], c[keep]
    order = np.lexsort((c, r))
    r, c = r[order], c[order]
    if r.size:
        dup = np.zeros(r.size, bool)
        dup[1:] = (r[1:] == r[:-1]) & (c[1:] == c[:-1])
        r, c = r[~dup], c[~dup]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, r + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, c


def rcm_permutation(rows, cols, n: int) -> np.ndarray:
    """Reverse Cuthill-McKee ordering of the symmetrized pattern.

    Returns ``perm`` with ``perm[new] = old``.  BFS from a minimum-degree
    node of each connected component, neighbors visited by increasing
    degree; final order reversed.
    """
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    indptr, adj = _adjacency(rows, cols, n)
    deg = np.diff(indptr)
    visited = np.zeros(n, bool)
    order = np.empty(n, np.int64)
    pos = 0
    node_order = np.argsort(deg, kind="stable")
    for seed in node_order:
        if visited[seed]:
            continue
        # BFS
        visited[seed] = True
        queue = [seed]
        qi = 0
        while qi < len(queue):
            u = queue[qi]
            qi += 1
            order[pos] = u
            pos += 1
            nbrs = adj[indptr[u]:indptr[u + 1]]
            nbrs = [v for v in nbrs[np.argsort(deg[nbrs], kind="stable")]
                    if not visited[v]]
            for v in nbrs:
                visited[v] = True
            queue.extend(nbrs)
    if pos != n:
        raise RuntimeError(
            f"rcm: traversal covered {pos} of {n} vertices — adjacency "
            f"is inconsistent")
    return order[::-1].copy()


def bandwidth(rows, cols) -> int:
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    if rows.size == 0:
        return 0
    return int(np.abs(rows - cols).max())


def greedy_coloring(rows, cols, n: int) -> np.ndarray:
    """Greedy distance-1 row coloring (ColPack's role for GS/Kaczmarz)."""
    indptr, adj = _adjacency(np.asarray(rows, np.int64),
                             np.asarray(cols, np.int64), n)
    color = np.full(n, -1, np.int64)
    for u in range(n):
        used = set(color[adj[indptr[u]:indptr[u + 1]]].tolist())
        c = 0
        while c in used:
            c += 1
        color[u] = c
    return color
