"""GHOST core building blocks in JAX (paper contributions C1-C5)."""
from repro.core import blockvec, execution, partition, sellcs, spmv
from repro.core.execution import ExecutionPolicy
from repro.core.sellcs import SellCS, from_callback, from_coo, from_csr, from_dense, to_dense
from repro.core.spmv import SpmvOpts, spmv as ghost_spmv, spmv_ref

__all__ = [
    "blockvec", "execution", "partition", "sellcs", "spmv",
    "ExecutionPolicy",
    "SellCS", "from_callback", "from_coo", "from_csr", "from_dense",
    "to_dense", "SpmvOpts", "ghost_spmv", "spmv_ref",
]
