"""Block vectors — tall & skinny dense matrices (paper C2).

A block vector is ``(n, b)`` with small ``b``; "row-major" interleaved
storage is the JAX-native layout (last axis minor).  The paper's
column-major variant is represented as ``(b, n)`` and exists to reproduce
the layout study (Fig. 8); all compute prefers row-major.

Implements GHOST's tall-skinny kernels and blocked BLAS-1:

    tsmttsm : X = alpha * V^H W + beta * X      (inner product of blocks)
    tsmm    : W = alpha * V X + beta * W        (block times small matrix)
    tsmm_inplace
    axpy / axpby / scal / dot  (+ v-variants with per-column scalars)
    Kahan-compensated tsmttsm and dot (paper section 5.2)

Scattered views (column subsets) and compact clones mirror Fig. 2.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "tsmttsm", "tsmm", "tsmm_inplace", "axpy", "axpby", "scal", "dot",
    "vaxpy", "vaxpby", "vscal", "tsmttsm_kahan", "dot_kahan",
    "view_cols", "compact_clone", "to_col_major", "to_row_major",
]


# ----------------------------------------------------------------- views
def view_cols(v: jax.Array, cols: Sequence[int]) -> jax.Array:
    """A (possibly scattered) view of selected block-vector columns."""
    return v[:, jnp.asarray(list(cols))]


def compact_clone(v: jax.Array) -> jax.Array:
    """Force a compact copy (paper: clone a scattered view before compute)."""
    return jnp.array(v)


def to_col_major(v: jax.Array) -> jax.Array:
    return v.T


def to_row_major(v: jax.Array) -> jax.Array:
    return v.T


def check_beta_needs_out(beta, out, fn: str) -> None:
    """A nonzero ``beta`` without the output operand would silently drop
    the ``beta * out`` term — raise instead of computing the wrong thing.

    A traced ``beta`` cannot be proven zero, so it is rejected too: pass
    the output block, or a concrete ``beta=0``.
    """
    if out is not None:
        return
    try:
        beta_zero = bool(beta == 0)
    except jax.errors.ConcretizationTypeError:
        beta_zero = False
    if not beta_zero:
        raise ValueError(
            f"{fn}: beta != 0 (or traced beta) without the output operand "
            f"— the beta term would be silently dropped; pass the output "
            f"block or leave beta=0")


# ------------------------------------------------------- tall-skinny GEMMs
def tsmttsm(V: jax.Array, W: jax.Array, X: Optional[jax.Array] = None,
            alpha=1.0, beta=0.0, *, conj: bool = True) -> jax.Array:
    """X = alpha * V^H W + beta * X with f32->f32 / widened accumulation.

    V: (n, m), W: (n, k) -> (m, k).  The reduction runs in the widest of
    the input dtypes (f32 inputs accumulate in f32 here; the Pallas kernel
    accumulates in f32 VMEM scratch and the Kahan variant compensates).
    """
    check_beta_needs_out(beta, X, "tsmttsm")
    Vh = jnp.conj(V) if (conj and jnp.iscomplexobj(V)) else V
    prod = jnp.einsum("nm,nk->mk", Vh, W,
                      preferred_element_type=_acc_dtype(V.dtype, W.dtype))
    out = alpha * prod
    if X is not None:
        out = out + beta * X.astype(out.dtype)
    return out


def tsmm(V: jax.Array, X: jax.Array, W: Optional[jax.Array] = None,
         alpha=1.0, beta=0.0) -> jax.Array:
    """W = alpha * V X + beta * W.   V: (n, m), X: (m, k) -> (n, k)."""
    check_beta_needs_out(beta, W, "tsmm")
    prod = jnp.einsum("nm,mk->nk", V, X,
                      preferred_element_type=_acc_dtype(V.dtype, X.dtype))
    out = alpha * prod
    if W is not None:
        out = out + beta * W.astype(out.dtype)
    return out.astype(jnp.result_type(V.dtype, X.dtype))


def tsmm_inplace(V: jax.Array, X: jax.Array, alpha=1.0, beta=0.0) -> jax.Array:
    """V = alpha * V X + beta * V (functional 'in-place': donate V at jit)."""
    return tsmm(V, X, V, alpha=alpha, beta=beta)


def _acc_dtype(a, b):
    r = jnp.result_type(a, b)
    if r == jnp.bfloat16 or r == jnp.float16:
        return jnp.float32
    return r


# ---------------------------------------------------------------- BLAS-1(.5)
def axpy(y, x, a=1.0):
    return y + a * x


def axpby(y, x, a=1.0, b=1.0):
    return b * y + a * x


def scal(x, a):
    return a * x


def dot(x, y) -> jax.Array:
    """Column-wise <x, y> (conjugated first argument)."""
    xc = jnp.conj(x) if jnp.iscomplexobj(x) else x
    return jnp.sum(xc * y, axis=0)


def vaxpy(y, x, a):
    """Per-column scalars a: (b,)."""
    return y + jnp.asarray(a)[None, :] * x


def vaxpby(y, x, a, b):
    return jnp.asarray(b)[None, :] * y + jnp.asarray(a)[None, :] * x


def vscal(x, a):
    return jnp.asarray(a)[None, :] * x


# -------------------------------------------------------------------- Kahan
def _kahan_reduce(terms: jax.Array) -> jax.Array:
    """Compensated (Kahan) summation over axis 0 via lax.scan."""
    def step(carry, t):
        s, c = carry
        yk = t - c
        tk = s + yk
        c = (tk - s) - yk
        return (tk, c), None

    zero = jnp.zeros(terms.shape[1:], terms.dtype)
    (s, _), _ = jax.lax.scan(step, (zero, zero), terms)
    return s


def dot_kahan(x, y, *, block: int = 256) -> jax.Array:
    """Kahan-compensated column-wise dot.

    Blocks of ``block`` rows are reduced pairwise (exact in the roofline
    sense: still one sweep over memory), and the block partials are combined
    with Kahan compensation — matching GHOST's compensated tsmttsm whose
    extra flops are negligible for wide-enough blocks.
    """
    n = x.shape[0]
    nb = max(1, -(-n // block))
    pad = nb * block - n
    xc = jnp.conj(x) if jnp.iscomplexobj(x) else x
    t = (xc * y)
    if pad:
        t = jnp.pad(t, ((0, pad),) + ((0, 0),) * (t.ndim - 1))
    t = t.reshape(nb, block, *t.shape[1:]).sum(axis=1)
    return _kahan_reduce(t)


def tsmttsm_kahan(V: jax.Array, W: jax.Array, *, block: int = 256) -> jax.Array:
    """Kahan-compensated V^H W (paper's compensated inner product)."""
    n, m = V.shape
    k = W.shape[1]
    nb = max(1, -(-n // block))
    pad = nb * block - n
    Vh = jnp.conj(V) if jnp.iscomplexobj(V) else V
    if pad:
        Vh = jnp.pad(Vh, ((0, pad), (0, 0)))
        W = jnp.pad(W, ((0, pad), (0, 0)))
    Vb = Vh.reshape(nb, block, m)
    Wb = W.reshape(nb, block, k)
    partials = jnp.einsum("zbm,zbk->zmk", Vb, Wb,
                          preferred_element_type=_acc_dtype(V.dtype, W.dtype))
    return _kahan_reduce(partials)
