"""Trainer: jitted sharded train step, fault tolerance, elasticity.

Fault-tolerance model (designed for 1000+ nodes, exercised on CPU):

* checkpoint/restart: CheckpointManager with atomic writes; the data
  pipeline is stateless-in-step so a restart resumes exactly;
* node failure: on a real pod the runtime re-schedules and the trainer
  re-enters ``fit`` — which is a pure function of (checkpoint, step), so
  recovery == restart; tests kill a trainer mid-run and restart it;
* elastic scaling: restore re-lays-out the logical arrays onto whatever
  mesh the restarted job has (checkpoint stores unsharded arrays);
* straggler mitigation: a step-time EWMA monitor flags slow steps; on a
  heterogeneous/degraded fleet the same weighted partitioner that drives
  the solver distribution (core/partition.py) re-weights the batch shares
  (hook: ``rebalance_cb``).

Distributed-optimization knobs: gradient accumulation (microbatching),
bf16 params with f32 optimizer, global-norm clip, warmup+cosine schedule,
optional int8-compressed inter-pod gradient sync.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import SyntheticLM, make_global_batch
from repro.models import sharding as SH
from repro.models import transformer as T
from repro.train import optimizer as OPT
from repro.train.checkpoint import CheckpointManager

__all__ = ["TrainConfig", "Trainer"]


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    clip_norm: float = 1.0
    optimizer: str = "adamw"          # adamw | adafactor
    weight_decay: float = 0.1
    grad_accum: int = 1
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_thresh: float = 2.0     # x EWMA step time -> flagged


class Trainer:
    def __init__(self, cfg: T.ModelConfig, tc: TrainConfig, mesh: Mesh,
                 *, seq_len: int, global_batch: int,
                 rebalance_cb: Optional[Callable] = None):
        self.cfg = cfg
        self.tc = tc
        self.mesh = mesh
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.rebalance_cb = rebalance_cb
        self.opt = OPT.make_optimizer(
            tc.optimizer, weight_decay=tc.weight_decay
        ) if tc.optimizer == "adamw" else OPT.make_optimizer(tc.optimizer)
        self.lr_fn = OPT.warmup_cosine(tc.lr, tc.warmup, tc.total_steps)
        self.ckpt = CheckpointManager(tc.ckpt_dir, every=tc.ckpt_every,
                                      keep=tc.ckpt_keep)
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, mesh = self.cfg, self.mesh
        key = jax.random.PRNGKey(self.tc.seed)

        p_shape = jax.eval_shape(lambda: T.init_params(cfg, key))
        self.pspecs = SH.param_specs(cfg, p_shape, mesh)
        self.pshard = SH.named(mesh, self.pspecs)
        o_shape = jax.eval_shape(lambda: self.opt.init(p_shape))
        self.ospecs = SH.opt_specs(self.pspecs, o_shape, mesh)
        self.oshard = SH.named(mesh, self.ospecs)

        batch_shape = {
            "tokens": jax.ShapeDtypeStruct(
                (self.global_batch, self.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (self.global_batch, self.seq_len), jnp.int32),
        }
        self.bspecs = SH.batch_specs(cfg, batch_shape, mesh)
        self.bshard = SH.named(mesh, self.bspecs)

        tc = self.tc

        def train_step(params, opt_state, batch, step):
            accum = tc.grad_accum

            def loss(p, b):
                return T.loss_fn(cfg, p, b)

            if accum == 1:
                (l, metrics), grads = jax.value_and_grad(
                    loss, has_aux=True)(params, batch)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), batch)

                def acc_body(carry, b):
                    gsum, lsum = carry
                    (l, m), g = jax.value_and_grad(loss, has_aux=True)(params, b)
                    gsum = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gsum, g)
                    return (gsum, lsum + l), m

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, lsum), ms = jax.lax.scan(acc_body, (g0, 0.0), mb)
                grads = jax.tree.map(lambda g: g / accum, grads)
                l = lsum / accum
                metrics = jax.tree.map(lambda x: x[-1], ms)

            grads, gnorm = OPT.clip_by_global_norm(grads, tc.clip_norm)
            lr = self.lr_fn(step)
            params, opt_state = self.opt.update(grads, opt_state, params, lr)
            metrics = dict(metrics, loss=l, gnorm=gnorm, lr=lr)
            return params, opt_state, metrics

        self.train_step = jax.jit(
            train_step,
            in_shardings=(self.pshard, self.oshard, self.bshard, None),
            out_shardings=(self.pshard, self.oshard, None),
            donate_argnums=(0, 1),
        )

    # ------------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.tc.seed)
        params = jax.jit(lambda: T.init_params(self.cfg, key),
                         out_shardings=self.pshard)()
        opt_state = jax.jit(lambda p: self.opt.init(p),
                            out_shardings=self.oshard)(params)
        return params, opt_state

    def fit(self, steps: int, *, data: Optional[SyntheticLM] = None,
            log: Callable = print) -> Dict[str, Any]:
        data = data or SyntheticLM(self.cfg.vocab_size, self.seq_len,
                                   self.global_batch, seed=self.tc.seed)
        state_like = jax.eval_shape(self.init_state)
        restored, start = self.ckpt.resume(
            state_like, shardings=(self.pshard, self.oshard))
        if restored is None:
            params, opt_state = self.init_state()
            start = 0
        else:
            params, opt_state = restored
            log(f"[trainer] resumed from step {start}")

        ewma = None
        losses = []
        for step in range(start, steps):
            b = make_global_batch(data.batch(step), self.mesh, self.bspecs)
            t0 = time.perf_counter()
            params, opt_state, m = self.train_step(
                params, opt_state, b, jnp.asarray(step))
            loss = float(m["loss"])
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.tc.straggler_thresh * ewma and step > start + 2:
                log(f"[trainer] straggler step {step}: {dt:.3f}s vs "
                    f"EWMA {ewma:.3f}s")
                if self.rebalance_cb:
                    self.rebalance_cb(step, dt, ewma)
            losses.append(loss)
            if step % self.tc.log_every == 0:
                log(f"[trainer] step {step} loss {loss:.4f} "
                    f"gnorm {float(m['gnorm']):.3f} ({dt * 1e3:.0f} ms)")
            self.ckpt.maybe_save(step + 1, (params, opt_state),
                                 extra={"loss": loss})
        self.ckpt.maybe_save(steps, (params, opt_state), force=True)
        return {"params": params, "opt_state": opt_state, "losses": losses}
