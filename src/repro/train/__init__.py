"""Training substrate: optimizers, checkpointing, data, trainer loop."""
