"""Fault-tolerant checkpointing (save / restore / resume).

Design (single-controller; scales to multi-host by per-host shard files):

* a checkpoint is a directory ``step_<N>/`` containing one ``.npz`` per
  top-level pytree group plus a ``manifest.json`` (step, tree structure,
  shapes/dtypes, mesh shape at save time);
* writes are atomic: ``step_<N>.tmp`` -> fsync -> rename, so a crash
  mid-write can never corrupt the latest checkpoint;
* restore re-lays-out arrays onto the *current* mesh shardings — elastic
  restarts onto a different mesh shape work because the on-disk format is
  the logical (unsharded) array;
* a retention policy keeps the last ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(_seg(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _unflatten_into(tree_like, flat: Dict[str, np.ndarray]):
    def one(path, leaf):
        key = "/".join(_seg(p) for p in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {leaf.shape}")
        return arr
    return jax.tree_util.tree_map_with_path(one, tree_like)


def save_checkpoint(directory: str, step: int, tree, *, extra: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": int(step),
        "time": time.time(),
        "keys": sorted(flat),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _valid(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json"))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            p = os.path.join(directory, name)
            if _valid(p):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like, *,
                       shardings=None):
    """Restore into the structure of ``tree_like``; optionally device_put
    with a sharding tree (elastic re-layout onto the current mesh)."""
    path = os.path.join(directory, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(tree_like, flat)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return tree, manifest


class CheckpointManager:
    """Periodic save + retention + resume (the trainer's FT backbone)."""

    def __init__(self, directory: str, *, every: int = 50, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree, *, extra=None, force=False):
        if not force and (step == 0 or step % self.every != 0):
            return None
        path = save_checkpoint(self.directory, step, tree, extra=extra)
        self._retain()
        return path

    def _retain(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        for s in sorted(steps)[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def resume(self, tree_like, *, shardings=None):
        """(tree, step) from the latest valid checkpoint, or (None, 0)."""
        step = latest_step(self.directory)
        if step is None:
            return None, 0
        tree, _ = restore_checkpoint(self.directory, step, tree_like,
                                     shardings=shardings)
        return tree, step
