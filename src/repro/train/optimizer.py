"""Optimizers (pure-pytree, no optax dependency) + distributed-optimization
utilities.

* AdamW — default for <=100B-class models.
* Adafactor (factored second moment, no first moment) — default for the
  300-400B MoE archs so optimizer state fits the 16 GB/chip budget.
* Gradient compression for the *inter-pod* (DCN) all-reduce: int8 or bf16
  quantization with per-tensor scales (paper C5 spirit: spend arithmetic to
  save the slow link).  XLA already reduces bf16 grads in bf16; the explicit
  int8 path is used by the trainer's hierarchical pod sync.
* Global-norm clipping and a warmup+cosine schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "clip_by_global_norm", "warmup_cosine", "make_optimizer",
           "quantize_int8", "dequantize_int8", "compressed_psum"]


# ---------------------------------------------------------------- schedules
def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5
                         * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ------------------------------------------------------------------- AdamW
def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    c = state["count"] + 1
    cf = c.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / (1 - b1 ** cf)
        vhat = v / (1 - b2 ** cf)
        step = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:                       # no decay on norms/bias
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "count": c}


# --------------------------------------------------------------- Adafactor
def adafactor_init(params):
    def one(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"slots": jax.tree.map(one, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)
                                  or hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, lr, *, decay=0.8, eps=1e-30,
                     clip_thresh=1.0, weight_decay=0.0):
    c = state["count"] + 1
    beta = 1.0 - c.astype(jnp.float32) ** (-decay)

    def upd(g, slot, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if p.ndim >= 2:
            vr = beta * slot["vr"] + (1 - beta) * g2.mean(axis=-1)
            vc = beta * slot["vc"] + (1 - beta) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            prec = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            step = gf / jnp.sqrt(jnp.maximum(prec, eps))
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta * slot["v"] + (1 - beta) * g2
            step = gf / jnp.sqrt(jnp.maximum(v, eps))
            new_slot = {"v": v}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(step * step) + 1e-12)
        step = step / jnp.maximum(1.0, rms / clip_thresh)
        if weight_decay and p.ndim >= 2:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_slot

    leaves_is = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    out = jax.tree.map(upd, grads, state["slots"], params, is_leaf=None)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_s = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"slots": new_s, "count": c}


# ------------------------------------------------------------- compression
def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, *, bits: int = 8):
    """Quantized all-reduce over the (slow, inter-pod) axis: each shard
    quantizes, reduces int-summed values in int32, and dequantizes with the
    max scale — 4x (int8) / 2x (bf16) less DCN traffic than f32."""
    if bits == 16:
        return jax.lax.psum(x.astype(jnp.bfloat16), axis_name
                            ).astype(x.dtype)
    q, scale = quantize_int8(x)
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int32)
    s = jax.lax.psum(q, axis_name)
    return (s.astype(jnp.float32) * scale).astype(x.dtype)


# ------------------------------------------------------------------ facade
@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    name: str


def make_optimizer(kind: str, **kw) -> Optimizer:
    if kind == "adamw":
        return Optimizer(adamw_init,
                         lambda g, s, p, lr: adamw_update(g, s, p, lr, **kw),
                         "adamw")
    if kind == "adafactor":
        return Optimizer(adafactor_init,
                         lambda g, s, p, lr: adafactor_update(g, s, p, lr, **kw),
                         "adafactor")
    raise ValueError(kind)
