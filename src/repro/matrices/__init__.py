"""Test matrices: generators for the paper's application domains + MM IO."""
from repro.matrices import generators, mmio
from repro.matrices.generators import (
    anderson3d, anisotropic_laplace2d, banded_random, graphene, laplace2d,
    laplace3d, matpde, spin_chain_xx,
)
from repro.matrices.mmio import read_matrix_market, write_matrix_market

__all__ = [
    "generators", "mmio", "matpde", "anderson3d", "graphene", "laplace2d",
    "laplace3d", "anisotropic_laplace2d", "banded_random", "spin_chain_xx",
    "read_matrix_market", "write_matrix_market",
]
