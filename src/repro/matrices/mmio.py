"""Matrix Market exchange format IO (paper section 3.1: GHOST reads MM).

Supports coordinate real/integer/complex/pattern, general/symmetric/
skew-symmetric/hermitian. Host-side numpy; no scipy dependency.

Fidelity notes: ``integer`` fields are parsed with ``int`` (no float
round-trip, so 64-bit values survive exactly) and written back with an
``integer`` header, so a write->read roundtrip preserves dtype; blank
lines anywhere after the header are tolerated, as the format spec asks.
"""
from __future__ import annotations

import gzip
from typing import Optional, Tuple

import numpy as np

__all__ = ["read_matrix_market", "write_matrix_market"]

_FIELDS = ("real", "integer", "complex", "pattern")
_SYMMETRIES = ("general", "symmetric", "skew-symmetric", "hermitian")


def _open(path, mode="rt"):
    if str(path).endswith(".gz"):
        return gzip.open(path, mode)
    return open(path, mode)


def _next_data_line(f, what: str):
    """Next non-blank line (data section tolerates blanks and comments)."""
    while True:
        line = f.readline()
        if not line:
            raise ValueError(f"unexpected end of file while reading {what}")
        if line.strip() and not line.startswith("%"):
            return line.split()


def read_matrix_market(path) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Tuple[int, int]]:
    """Returns (rows, cols, vals, (nrows, ncols)) with symmetry expanded.

    ``vals`` dtype follows the field: real -> float64, integer -> int64
    (parsed exactly, no float truncation), complex -> complex128,
    pattern -> float64 ones.
    """
    with _open(path) as f:
        header = f.readline().strip().split()
        if len(header) < 5 or header[0] != "%%MatrixMarket":
            raise ValueError(f"not a MatrixMarket file: {header}")
        _, obj, fmt, field, sym = [h.lower() for h in header[:5]]
        if obj != "matrix" or fmt != "coordinate":
            raise ValueError(f"only coordinate matrices supported, got {obj}/{fmt}")
        if field not in _FIELDS:
            raise ValueError(f"unknown field {field!r} (expected one of {_FIELDS})")
        if sym not in _SYMMETRIES:
            raise ValueError(f"unknown symmetry {sym!r} (expected one of {_SYMMETRIES})")
        nr, nc, nnz = map(int, _next_data_line(f, "size line"))
        rows = np.empty(nnz, np.int64)
        cols = np.empty(nnz, np.int64)
        if field == "complex":
            vals = np.empty(nnz, np.complex128)
        elif field == "integer":
            vals = np.empty(nnz, np.int64)
        elif field == "pattern":
            vals = np.ones(nnz, np.float64)
        else:
            vals = np.empty(nnz, np.float64)
        for k in range(nnz):
            parts = _next_data_line(f, f"entry {k + 1}/{nnz}")
            rows[k] = int(parts[0]) - 1
            cols[k] = int(parts[1]) - 1
            if field == "complex":
                vals[k] = float(parts[2]) + 1j * float(parts[3])
            elif field == "integer":
                vals[k] = int(parts[2])       # exact: no float truncation
            elif field == "pattern":
                pass
            else:
                vals[k] = float(parts[2])

    if sym in ("symmetric", "hermitian", "skew-symmetric"):
        off = rows != cols
        r2, c2 = cols[off], rows[off]
        if sym == "hermitian":
            v2 = np.conj(vals[off])
        elif sym == "skew-symmetric":
            v2 = -vals[off]
        else:
            v2 = vals[off]
        rows = np.concatenate([rows, r2])
        cols = np.concatenate([cols, c2])
        vals = np.concatenate([vals, v2])
    return rows, cols, vals, (nr, nc)


def write_matrix_market(path, rows, cols, vals, shape, *,
                        field: Optional[str] = None,
                        symmetry: str = "general") -> None:
    """Write COO triplets as a coordinate MatrixMarket file.

    ``field=None`` derives the header from the values' dtype (complex /
    integer / real), so integer matrices round-trip as ``integer`` rather
    than silently becoming ``real``.  Pass ``field="pattern"`` to write
    structure only.  ``symmetry`` is written to the header verbatim; for
    anything but ``general`` the caller must pass only the stored (lower)
    triangle, exactly as :func:`read_matrix_market` would re-expand it.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    if field is None:
        if np.iscomplexobj(vals):
            field = "complex"
        elif np.issubdtype(vals.dtype, np.integer):
            field = "integer"
        else:
            field = "real"
    if field not in _FIELDS:
        raise ValueError(f"unknown field {field!r} (expected one of {_FIELDS})")
    if symmetry not in _SYMMETRIES:
        raise ValueError(
            f"unknown symmetry {symmetry!r} (expected one of {_SYMMETRIES})")
    with _open(path, "wt") as f:
        f.write(f"%%MatrixMarket matrix coordinate {field} {symmetry}\n")
        f.write(f"{shape[0]} {shape[1]} {len(vals)}\n")
        for r, c, v in zip(rows, cols, vals):
            if field == "pattern":
                f.write(f"{r + 1} {c + 1}\n")
            elif field == "complex":
                f.write(f"{r + 1} {c + 1} {v.real:.17g} {v.imag:.17g}\n")
            elif field == "integer":
                f.write(f"{r + 1} {c + 1} {int(v)}\n")
            else:
                f.write(f"{r + 1} {c + 1} {v:.17g}\n")
