"""Matrix Market exchange format IO (paper section 3.1: GHOST reads MM).

Supports coordinate real/integer/complex/pattern, general/symmetric/
skew-symmetric/hermitian. Host-side numpy; no scipy dependency.
"""
from __future__ import annotations

import gzip
from typing import Tuple

import numpy as np

__all__ = ["read_matrix_market", "write_matrix_market"]


def _open(path, mode="rt"):
    if str(path).endswith(".gz"):
        return gzip.open(path, mode)
    return open(path, mode)


def read_matrix_market(path) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Tuple[int, int]]:
    """Returns (rows, cols, vals, (nrows, ncols)) with symmetry expanded."""
    with _open(path) as f:
        header = f.readline().strip().split()
        if len(header) < 5 or header[0] != "%%MatrixMarket":
            raise ValueError(f"not a MatrixMarket file: {header}")
        _, obj, fmt, field, sym = [h.lower() for h in header[:5]]
        if obj != "matrix" or fmt != "coordinate":
            raise ValueError(f"only coordinate matrices supported, got {obj}/{fmt}")
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        nr, nc, nnz = map(int, line.split())
        rows = np.empty(nnz, np.int64)
        cols = np.empty(nnz, np.int64)
        if field == "complex":
            vals = np.empty(nnz, np.complex128)
        elif field == "integer":
            vals = np.empty(nnz, np.int64)
        elif field == "pattern":
            vals = np.ones(nnz, np.float64)
        else:
            vals = np.empty(nnz, np.float64)
        for k in range(nnz):
            parts = f.readline().split()
            rows[k] = int(parts[0]) - 1
            cols[k] = int(parts[1]) - 1
            if field == "complex":
                vals[k] = float(parts[2]) + 1j * float(parts[3])
            elif field == "pattern":
                pass
            else:
                vals[k] = float(parts[2])

    if sym in ("symmetric", "hermitian", "skew-symmetric"):
        off = rows != cols
        r2, c2 = cols[off], rows[off]
        if sym == "hermitian":
            v2 = np.conj(vals[off])
        elif sym == "skew-symmetric":
            v2 = -vals[off]
        else:
            v2 = vals[off]
        rows = np.concatenate([rows, r2])
        cols = np.concatenate([cols, c2])
        vals = np.concatenate([vals, v2])
    return rows, cols, vals, (nr, nc)


def write_matrix_market(path, rows, cols, vals, shape) -> None:
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    cplx = np.iscomplexobj(vals)
    field = "complex" if cplx else "real"
    with _open(path, "wt") as f:
        f.write(f"%%MatrixMarket matrix coordinate {field} general\n")
        f.write(f"{shape[0]} {shape[1]} {len(vals)}\n")
        for r, c, v in zip(rows, cols, vals):
            if cplx:
                f.write(f"{r + 1} {c + 1} {v.real:.17g} {v.imag:.17g}\n")
            else:
                f.write(f"{r + 1} {c + 1} {v:.17g}\n")
