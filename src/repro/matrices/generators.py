"""Sparse test-matrix generators (host-side COO).

Mirrors the paper's application domains: MATPDE (section 6.1 case study),
quantum Hamiltonians from the ESSEX project (Anderson disorder, graphene
tight-binding, spin chains — sections 1.1/1.3), plus generic banded/Laplace
operators standing in for the SuiteSparse test cases (ML_Geer, cage15,
3Dspectralwave) that cannot be shipped offline.

All generators return ``(rows, cols, vals, n)`` numpy COO.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "matpde", "anderson3d", "graphene", "laplace2d", "laplace3d",
    "anisotropic_laplace2d", "banded_random", "spin_chain_xx",
]

Coo = Tuple[np.ndarray, np.ndarray, np.ndarray, int]


def _collect(entries) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    r = np.concatenate([e[0] for e in entries])
    c = np.concatenate([e[1] for e in entries])
    v = np.concatenate([e[2] for e in entries])
    return r, c, v


def matpde(nx: int, ny: int | None = None, *, beta_c: float = 20.0,
           gamma_c: float = 0.0) -> Coo:
    """MATPDE-style non-symmetric 2D elliptic operator (paper section 6.1).

    Five-point central FD discretization of
        -(a u_x)_x - (b u_y)_y + beta*p u_x + gamma*q u_y
    with variable coefficients on an nx x ny grid, Dirichlet boundaries.
    """
    ny = nx if ny is None else ny
    hx, hy = 1.0 / (nx + 1), 1.0 / (ny + 1)
    ix = np.arange(1, nx + 1)
    iy = np.arange(1, ny + 1)
    X, Y = np.meshgrid(ix * hx, iy * hy, indexing="ij")          # (nx, ny)

    def a(x, y):
        return np.exp(-x * y)

    def b(x, y):
        return np.exp(x * y)

    def p(x, y):
        return beta_c * (x + y)

    def q(x, y):
        return gamma_c * (x * y)

    aE = a(X + hx / 2, Y) / hx**2
    aW = a(X - hx / 2, Y) / hx**2
    bN = b(X, Y + hy / 2) / hy**2
    bS = b(X, Y - hy / 2) / hy**2
    pc = p(X, Y) / (2 * hx)
    qc = q(X, Y) / (2 * hy)

    idx = (np.arange(nx)[:, None] * ny + np.arange(ny)[None, :])

    entries = []
    # center
    entries.append((idx.ravel(), idx.ravel(), (aE + aW + bN + bS).ravel()))
    # east (x+1)
    m = np.zeros((nx, ny), bool)
    m[:-1, :] = True
    entries.append((idx[m], idx[m] + ny, (-aE + pc)[m]))
    # west
    m = np.zeros((nx, ny), bool)
    m[1:, :] = True
    entries.append((idx[m], idx[m] - ny, (-aW - pc)[m]))
    # north (y+1)
    m = np.zeros((nx, ny), bool)
    m[:, :-1] = True
    entries.append((idx[m], idx[m] + 1, (-bN + qc)[m]))
    # south
    m = np.zeros((nx, ny), bool)
    m[:, 1:] = True
    entries.append((idx[m], idx[m] - 1, (-bS - qc)[m]))
    r, c, v = _collect(entries)
    return r, c, v, nx * ny


def laplace2d(nx: int, ny: int | None = None) -> Coo:
    return matpde(nx, ny, beta_c=0.0, gamma_c=0.0)


def anisotropic_laplace2d(nx: int, ny: int | None = None, *,
                          epsilon: float = 1e-2) -> Coo:
    """Anisotropic 2D Laplacian ``-eps u_xx - u_yy`` (5-point, Dirichlet).

    The canonical ill-conditioned SPD preconditioning benchmark: for
    ``epsilon << 1`` the strong coupling runs along grid lines in ``y``
    (the fast index — ``idx = ix * ny + iy``), so plain CG converges
    slowly while block-Jacobi with ``block_size = ny`` (line Jacobi over
    contiguous index blocks) captures the dominant coupling exactly.
    """
    ny = nx if ny is None else ny
    if epsilon <= 0:
        raise ValueError(f"epsilon must be positive, got {epsilon}")
    hx, hy = 1.0 / (nx + 1), 1.0 / (ny + 1)
    ax = epsilon / hx**2
    by = 1.0 / hy**2
    idx = (np.arange(nx)[:, None] * ny + np.arange(ny)[None, :])

    entries = [(idx.ravel(), idx.ravel(),
                np.full(nx * ny, 2.0 * ax + 2.0 * by))]
    # x-neighbors (stride ny), both triangles
    m = idx[:-1, :].ravel()
    entries.append((m, m + ny, np.full(m.size, -ax)))
    entries.append((m + ny, m, np.full(m.size, -ax)))
    # y-neighbors (stride 1), both triangles
    m = idx[:, :-1].ravel()
    entries.append((m, m + 1, np.full(m.size, -by)))
    entries.append((m + 1, m, np.full(m.size, -by)))
    r, c, v = _collect(entries)
    return r, c, v, nx * ny


def laplace3d(nx: int) -> Coo:
    """Standard 7-point 3D Laplacian on nx^3 grid."""
    n = nx**3
    i = np.arange(n)
    x, y, z = i // (nx * nx), (i // nx) % nx, i % nx
    entries = [(i, i, np.full(n, 6.0))]
    for (coord, stride) in ((x, nx * nx), (y, nx), (z, 1)):
        m = coord < nx - 1
        entries.append((i[m], i[m] + stride, np.full(m.sum(), -1.0)))
        entries.append((i[m] + stride, i[m], np.full(m.sum(), -1.0)))
    r, c, v = _collect(entries)
    return r, c, v, n


def anderson3d(nx: int, disorder: float = 16.5, seed: int = 0) -> Coo:
    """3D Anderson-localization Hamiltonian: hopping + random on-site
    disorder in [-W/2, W/2] (ESSEX application, topological disorder
    physics of section 1.1)."""
    rng = np.random.default_rng(seed)
    n = nx**3
    i = np.arange(n)
    x, y, z = i // (nx * nx), (i // nx) % nx, i % nx
    entries = [(i, i, rng.uniform(-disorder / 2, disorder / 2, n))]
    for (coord, stride) in ((x, nx * nx), (y, nx), (z, 1)):
        m = coord < nx - 1
        entries.append((i[m], i[m] + stride, np.full(m.sum(), -1.0)))
        entries.append((i[m] + stride, i[m], np.full(m.sum(), -1.0)))
    r, c, v = _collect(entries)
    return r, c, v, n


def graphene(nx: int, ny: int, *, t: float = -2.7, onsite_disorder: float = 0.0,
             seed: int = 0) -> Coo:
    """Honeycomb-lattice tight-binding Hamiltonian (graphene; paper 1.1).

    Brick-wall mapping of the honeycomb lattice onto an nx x ny grid with
    two-atom unit cells; nearest-neighbor hopping ``t``.
    """
    rng = np.random.default_rng(seed)
    n = nx * ny * 2

    def site(ix, iy, s):
        return (ix * ny + iy) * 2 + s

    rr, cc, vv = [], [], []
    for ix in range(nx):
        for iy in range(ny):
            a_ = site(ix, iy, 0)
            b_ = site(ix, iy, 1)
            # intra-cell bond
            rr += [a_, b_]
            cc += [b_, a_]
            vv += [t, t]
            # inter-cell bonds
            if iy + 1 < ny:
                nb = site(ix, iy + 1, 0)
                rr += [b_, nb]
                cc += [nb, b_]
                vv += [t, t]
            if ix + 1 < nx:
                nb = site(ix + 1, iy, 0)
                rr += [b_, nb]
                cc += [nb, b_]
                vv += [t, t]
    if onsite_disorder:
        i = np.arange(n)
        rr += i.tolist()
        cc += i.tolist()
        vv += rng.uniform(-onsite_disorder / 2, onsite_disorder / 2, n).tolist()
    return (np.asarray(rr, np.int64), np.asarray(cc, np.int64),
            np.asarray(vv, np.float64), n)


def spin_chain_xx(L: int, jz: float = 1.0) -> Coo:
    """XXZ spin-1/2 chain in the Sz=0-free full basis (2^L), sparse
    Hamiltonian — the 'no mesh interpretation, indefinite' matrix class the
    paper emphasizes (section 1.3)."""
    n = 1 << L
    states = np.arange(n, dtype=np.int64)
    rr, cc, vv = [], [], []
    diag = np.zeros(n)
    for i in range(L - 1):
        bi = (states >> i) & 1
        bj = (states >> (i + 1)) & 1
        # S^z_i S^z_{i+1}
        diag += jz * 0.25 * np.where(bi == bj, 1.0, -1.0)
        # flip-flop (S+S- + S-S+)/2
        m = bi != bj
        flipped = states[m] ^ ((1 << i) | (1 << (i + 1)))
        rr.append(states[m])
        cc.append(flipped)
        vv.append(np.full(m.sum(), 0.5))
    rr.append(states)
    cc.append(states)
    vv.append(diag)
    return (np.concatenate(rr), np.concatenate(cc), np.concatenate(vv), n)


def banded_random(n: int, bw: int = 16, density: float = 0.4,
                  seed: int = 0, *, sym: bool = False) -> Coo:
    """Random banded matrix (cage15 stand-in): ~density filled band."""
    rng = np.random.default_rng(seed)
    i = np.arange(n)
    rows, cols, vals = [i], [i], [rng.standard_normal(n) + bw]
    for d in range(1, bw + 1):
        m = rng.random(n - d) < density
        idx = i[: n - d][m]
        v = rng.standard_normal(m.sum())
        rows += [idx, idx + d]
        cols += [idx + d, idx]
        vals += [v, v if sym else rng.standard_normal(m.sum())]
    r, c, v = _collect(list(zip(rows, cols, vals)))
    return r, c, v, n
