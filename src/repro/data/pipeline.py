"""Deterministic synthetic LM data pipeline.

Stateless: batch ``i`` is a pure function of (seed, i), so a restarted
trainer resumes mid-stream without data loss or duplication — the data-side
half of fault tolerance.  Tokens follow a Zipf-ish distribution with
injected local structure (skip-gram copies) so the loss has signal to
descend.

``make_global_batch`` builds sharded ``jax.Array``s on the mesh via
``jax.make_array_from_callback`` (per-shard materialization: on a real pod
each host only touches its addressable slice).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["SyntheticLM", "make_global_batch"]


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, structure: float = 0.5):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.structure = structure

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # Zipf-ish marginals
        u = rng.random((B, S + 1))
        toks = np.minimum((u ** 3 * V).astype(np.int64), V - 1)
        # local structure: with prob `structure`, copy the token 2 back
        # (sequential, so copy chains persist and the skip-gram signal is
        # exactly `structure` at every position)
        if S + 1 >= 3:
            copy = rng.random((B, S - 1)) < self.structure
            for j in range(2, S + 1):
                m = copy[:, j - 2]
                toks[m, j] = toks[m, j - 2]
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_global_batch(batch: Dict[str, np.ndarray], mesh: Mesh,
                      specs) -> Dict[str, jax.Array]:
    """Host batch -> sharded global jax.Arrays (per-shard callbacks)."""
    out = {}
    for k, v in batch.items():
        sharding = NamedSharding(mesh, specs[k]) if not isinstance(
            specs[k], NamedSharding) else specs[k]
        out[k] = jax.make_array_from_callback(
            v.shape, sharding, lambda idx, v=v: v[idx])
    return out
