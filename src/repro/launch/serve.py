"""Batched serving driver: prefill + decode loop with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --smoke \
        --batch 4 --prompt-len 16 --gen 16

Runs the real serve path (the same ``decode_step`` the dry-run lowers for
the decode_32k / long_500k cells): prefill the prompt token-by-token into
the cache, then greedy-decode ``--gen`` tokens.  On a pod, drop ``--smoke``
for the full config + production mesh with the cache sharded per
``models/sharding.cache_specs``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B = args.batch
    max_len = args.prompt_len + args.gen
    cache = T.init_cache(cfg, B, max_len)
    prompts = jax.random.randint(key, (B, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    enc_out = None
    if cfg.enc_dec:
        enc_out = jax.random.normal(key, (B, args.prompt_len * 2,
                                          cfg.d_model), cfg.dtype)

    step = jax.jit(
        lambda p, c, t, n, e=None: T.decode_step(cfg, p, c, t, n, enc_out=e),
        static_argnames=())

    # prefill (token-by-token through the same decode path; a production
    # deployment fuses this into one forward — see dryrun prefill cells)
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, t:t + 1],
                             jnp.asarray(t), enc_out)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # greedy decode
    tok = jnp.argmax(logits[:, :, : cfg.vocab_size], -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for t in range(args.prompt_len, max_len - 1):
        logits, cache = step(params, cache, tok, jnp.asarray(t), enc_out)
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0

    gen = np.concatenate(out, axis=1)
    n_dec = max(max_len - 1 - args.prompt_len, 1)
    print(f"arch={cfg.name} B={B} prompt={args.prompt_len} gen={gen.shape[1]}")
    print(f"prefill: {t_prefill * 1e3:.0f} ms | decode: "
          f"{t_dec / n_dec * 1e3:.1f} ms/token")
    print("sample generations:", gen[:2, :10].tolist())
    if not np.isfinite(gen).all():
        raise RuntimeError("serve smoke: non-finite values in generations")
    print("serve OK")


if __name__ == "__main__":
    main()
