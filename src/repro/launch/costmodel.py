"""Analytic per-device cost model (FLOPs / HBM bytes / collective bytes).

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, not x trip-count (verified: a 10-step scanned matmul reports exactly
1/10th the flops of its unrolled twin).  All our large models scan over
layer periods and stream attention/SSM over sequence blocks, so raw
cost_analysis under-counts by 1-2 orders of magnitude.  The roofline terms
in EXPERIMENTS.md therefore come from this structural model; the dry-run's
HLO artifacts remain the ground truth for *which* collectives run and for
the per-device memory footprint, and ``dryrun.parse_collectives`` applies
trip-count multipliers parsed from the while tree as the measured
cross-check.

Conventions:
  * per-device quantities; compute assumed evenly sharded over the mesh.
  * bf16 params/activations (2 B), f32 optimizer state (4 B).
  * attention is counted at the *computed* cost of our streamed kernel
    (full masked blocks, i.e. no causal skip — see §Perf for the
    optimization that halves it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ShapeSpec
from repro.models.transformer import ModelConfig

BF16 = 2
F32 = 4


# ---------------------------------------------------------------------------
# SpMV roofline terms (GHOST sections 2 and 4.1).
#
# These feed ``repro.runtime.devicepool.DevicePool``: the heterogeneous
# engine weights each device class by its *attainable SpMV throughput*,
# which for a bandwidth-bound kernel is  bw / code_balance.  The paper's
# reference point: double precision values + 32-bit indices give the
# minimum code balance of (8 + 4) / 2 = 6 bytes/flop, hence the predicted
# aggregate 350/6 = 58 Gflop/s for the full CPU+GPU+PHI node (Table 1).
# ---------------------------------------------------------------------------

def spmv_code_balance(*, val_bytes: int = 8, idx_bytes: int = 4,
                      nvecs: int = 1, nnzr: float = float("inf"),
                      rhs_reload: float = 0.0) -> float:
    """Bytes of HBM traffic per flop of a SELL-C-sigma SpM(M)V.

    Per nonzero and right-hand-side column: matrix value + column index are
    streamed once (amortized over ``nvecs`` block-vector columns), the
    output row is written (and read for beta-accumulation) once per row —
    i.e. ``2 * val_bytes / nnzr`` per nonzero — and ``rhs_reload`` accounts
    for x-gather traffic beyond the first load (0 = perfect cache/VMEM
    residency, 1 = every gather misses).  Flops per nonzero per column: 2.
    """
    mat = (val_bytes + idx_bytes) / nvecs
    vec = 2.0 * val_bytes / max(nnzr, 1.0) + rhs_reload * val_bytes
    return (mat + vec) / 2.0


def spmv_cost(nnz: int, nrows: int, *, val_bytes: int = 8,
              idx_bytes: int = 4, nvecs: int = 1,
              rhs_reload: float = 0.0) -> Cost:
    """Structural roofline inputs for one SpM(M)V over ``nnz`` nonzeros."""
    c = Cost()
    nnzr = nnz / max(nrows, 1)
    cb = spmv_code_balance(val_bytes=val_bytes, idx_bytes=idx_bytes,
                           nvecs=nvecs, nnzr=nnzr, rhs_reload=rhs_reload)
    flops = 2.0 * nnz * nvecs
    c.add("spmv", flops=flops, hbm=flops * cb)
    return c


@dataclasses.dataclass
class Cost:
    flops: float = 0.0            # per device
    hbm_bytes: float = 0.0        # per device
    coll_bytes: float = 0.0       # per device (ICI wire bytes)
    detail: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, key, flops=0.0, hbm=0.0, coll=0.0):
        self.flops += flops
        self.hbm_bytes += hbm
        self.coll_bytes += coll
        self.detail[key] = self.detail.get(key, 0.0) + flops


def _layer_param_counts(cfg: ModelConfig) -> Dict[str, float]:
    """Matmul parameters per *instance* of each sub-layer kind."""
    d, hd = cfg.d_model, cfg.hd
    H, KV, ff = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    out = {}
    out["attn"] = d * (H * hd) * 2 + d * (KV * hd) * 2        # q,o + k,v
    out["xattn"] = out["attn"]
    out["mlp"] = d * ff * (3 if cfg.act == "swiglu" else 2)
    if cfg.moe is not None:
        E, K = cfg.moe.n_experts, cfg.moe.top_k
        per_exp = d * ff * (3 if cfg.act == "swiglu" else 2)
        out["moe_active"] = per_exp * K                        # per token
        out["moe_total"] = per_exp * E
        out["router"] = d * E
    di = cfg.ssm.inner(d)
    out["mamba"] = (d * 2 * di + di * (cfg.ssm.rank(d) + 2 * cfg.ssm.d_state)
                    + cfg.ssm.rank(d) * di + di * d)
    dix = cfg.xlstm.expand * d
    out["mlstm"] = d * 2 * dix + 3 * dix * dix + dix * d
    out["slstm"] = d * 4 * d + 4 * (d // cfg.xlstm.n_heads) * d + d * d
    return out


def _pattern_counts(cfg: ModelConfig, layers: int) -> Dict[str, int]:
    """How many instances of each sub-layer kind in `layers` layers."""
    counts: Dict[str, int] = {}
    full = (list(cfg.pattern) * ((layers + cfg.period - 1) // cfg.period))[:layers]
    for mix, ffn in full:
        counts[mix] = counts.get(mix, 0) + 1
        if ffn != "none":
            counts[ffn] = counts.get(ffn, 0) + 1
    return counts


def analytic_cost(cfg: ModelConfig, shape: ShapeSpec, n_dev: int,
                  *, dp: int, tp: int, causal_skip: bool = False,
                  zero1: bool = False,
                  train_flop_mult: float = 3.0) -> Cost:
    """Per-device roofline inputs for one (arch x shape) cell."""
    c = Cost()
    S = shape.seq_len
    B = shape.global_batch
    kind = shape.kind
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    V = cfg.padded_vocab
    pc = _layer_param_counts(cfg)

    # tokens processed globally this step
    if kind == "decode":
        T = B                                  # one token per sequence
        S_dec = 1
    elif cfg.enc_dec:
        S_dec = S // cfg.dec_len_ratio
        T = B * S_dec
        T_enc = B * S
    else:
        S_dec = S
        T = B * S

    mult = train_flop_mult if kind == "train" else 1.0
    attn_mult = 0.5 if causal_skip else 1.0

    def stack_cost(layers: int, T_stack: float, S_ctx: float, causal: bool):
        """Matmul + mixer flops for a stack over T_stack tokens with
        context length S_ctx."""
        n = _pattern_counts(cfg, layers)
        f = 0.0
        # projections / FFN / MoE: 2 flops per param per token
        f += n.get("attn", 0) * 2 * T_stack * pc["attn"]
        f += n.get("mlp", 0) * 2 * T_stack * pc["mlp"]
        if cfg.moe is not None and n.get("moe"):
            f += n["moe"] * 2 * T_stack * (pc["moe_active"] + pc["router"])
        f += n.get("mamba", 0) * (2 * T_stack * pc["mamba"]
                                  + T_stack * cfg.ssm.inner(d)
                                  * cfg.ssm.d_state * 6)
        f += n.get("mlstm", 0) * (2 * T_stack * pc["mlstm"]
                                  + T_stack * cfg.xlstm.n_heads
                                  * (cfg.xlstm.expand * d // cfg.xlstm.n_heads) ** 2 * 4)
        f += n.get("slstm", 0) * (2 * T_stack * pc["slstm"])
        # attention score+value flops: 4 * T * S_ctx * H * hd
        am = attn_mult if causal else 1.0
        f += n.get("attn", 0) * 4 * T_stack * S_ctx * H * hd * am
        return f

    # ---- compute -----------------------------------------------------
    if cfg.enc_dec and kind != "decode":
        c.add("encoder", flops=mult * stack_cost(cfg.n_enc_layers, T_enc, S,
                                                 causal=False) / n_dev)
        f_dec = stack_cost(cfg.n_layers, T, S_dec, causal=True)
        f_dec += cfg.n_layers * (2 * T * pc["xattn"] / 2                 # kv proj on enc side
                                 + 2 * T_enc * pc["xattn"] / 2
                                 + 4 * T * S * H * hd)                   # cross attn
        c.add("decoder", flops=mult * f_dec / n_dev)
    elif cfg.enc_dec and kind == "decode":
        f_dec = stack_cost(cfg.n_layers, T, S, causal=True)              # self on cache S
        f_dec += cfg.n_layers * (2 * T_enc_dec_kv(cfg, B, S)             # enc kv proj
                                 + 4 * T * S * H * hd)                   # cross attn
        c.add("decoder", flops=mult * f_dec / n_dev)
    else:
        S_ctx = S if kind != "decode" else S                             # decode: cache len S
        c.add("decoder", flops=mult * stack_cost(cfg.n_layers, T, S_ctx,
                                                 causal=True) / n_dev)
    # lm head + embed
    c.add("head", flops=mult * 2 * T * d * V / n_dev)

    # ---- HBM bytes -----------------------------------------------------
    n_params = _total_params(cfg)
    # per-device weight bytes touched per step: the FSDP all-gather leaves a
    # full copy along 'data' but still sharded 1/tp along 'model'
    p_gathered = n_params * BF16 / tp
    if kind == "train":
        big = n_params > 50e9
        # optimizer touches the 1/n_dev shard: adam ~6 f32 arrays r+w,
        # adafactor ~3
        opt_bytes = (3 if big else 6) * n_params * F32 / n_dev
        if zero1:
            # params resident (replicated): read fwd + bwd, grads written
            weight_traffic = 3 * n_params * BF16
        else:
            weight_traffic = 3 * p_gathered              # fwd + remat + bwd
        act = _act_bytes(cfg, T, dp, tp, train=True)
        rec = _recurrent_state_bytes(cfg, B / dp, S_dec, train=True)
        c.hbm_bytes = weight_traffic + opt_bytes + act + rec
    elif kind == "prefill":
        weight_traffic = p_gathered
        act = _act_bytes(cfg, T, dp, tp, train=False)
        rec = _recurrent_state_bytes(cfg, B / dp, S_dec, train=False)
        c.hbm_bytes = weight_traffic + act + rec
    else:  # decode
        weight_traffic = p_gathered                   # every param read once
        cache = _cache_bytes(cfg, B, S) / n_dev       # cache read once
        c.hbm_bytes = weight_traffic + cache

    # ---- collective bytes ----------------------------------------------
    L = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)
    T_loc = (T if kind == "decode" else T) / dp
    if kind == "train":
        # FSDP: all-gather params fwd + bwd, reduce-scatter grads (bf16)
        fsdp = 3 * (n_params * BF16 / tp) * (dp - 1) / dp
        # TP: 2 all-reduces per layer fwd, 2 bwd, on (T_loc, d) bf16
        tpc = 4 * L * T_loc * d * BF16 * 2 * (tp - 1) / tp if tp > 1 else 0
        c.coll_bytes = fsdp + tpc
    elif kind == "prefill":
        fsdp = (n_params * BF16 / tp) * (dp - 1) / dp
        tpc = 2 * L * T_loc * d * BF16 * 2 * (tp - 1) / tp if tp > 1 else 0
        c.coll_bytes = fsdp + tpc
    else:
        fsdp = (n_params * BF16 / tp) * (dp - 1) / dp
        tpc = 2 * L * T_loc * d * BF16 * 2 * (tp - 1) / tp if tp > 1 else 0
        c.coll_bytes = fsdp + tpc
    return c


def T_enc_dec_kv(cfg, B, S):
    return B * S * cfg.d_model * cfg.n_kv_heads * cfg.hd // cfg.d_model


def _total_params(cfg: ModelConfig) -> float:
    pc = _layer_param_counts(cfg)
    n = _pattern_counts(cfg, cfg.n_layers)
    total = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    total += n.get("attn", 0) * pc["attn"]
    total += n.get("mlp", 0) * pc["mlp"]
    if cfg.moe is not None and n.get("moe"):
        total += n["moe"] * (pc["moe_total"] + pc["router"])
    total += n.get("mamba", 0) * pc["mamba"]
    total += n.get("mlstm", 0) * pc["mlstm"]
    total += n.get("slstm", 0) * pc["slstm"]
    if cfg.enc_dec:
        ne = _pattern_counts(cfg, cfg.n_enc_layers)
        total += ne.get("attn", 0) * pc["attn"] * 2      # + cross attn
        total += ne.get("mlp", 0) * pc["mlp"]
    return total


def _recurrent_state_bytes(cfg: ModelConfig, B_loc: float, S: int,
                           *, train: bool) -> float:
    """HBM traffic of recurrent state streaming (the term that dominates
    SSM/xLSTM training and that chunkwise/fused forms attack — §Perf H2/H3).

    recurrent mLSTM: the (H, dh, dh) f32 matrix memory is read+written
    every timestep; chunkwise: once per chunk + intra-chunk (W x W) tiles.
    mamba (materialized): dA/dBx (B, S, di, N) f32 are written + read
    (+ re-read in backward); fused: recomputed in-register from (B, S, di).
    """
    n = _pattern_counts(cfg, cfg.n_layers)
    mult = 3.0 if train else 1.0          # fwd + bwd re-traffic
    total = 0.0
    if n.get("mlstm"):
        H = cfg.xlstm.n_heads
        dh = cfg.xlstm.expand * cfg.d_model // H
        state = B_loc * H * dh * dh * F32
        if cfg.xlstm.chunkwise:
            W = cfg.xlstm.chunk
            steps = (S + W - 1) // W
            intra = B_loc * S * W * H * F32 * 2          # D/score tiles
            total += n["mlstm"] * (2 * state * steps + intra) * mult
        else:
            total += n["mlstm"] * 2 * state * S * mult
    if n.get("slstm"):
        total += n["slstm"] * 2 * (B_loc * 4 * cfg.d_model * F32) * S * mult
    if n.get("mamba"):
        di = cfg.ssm.inner(cfg.d_model)
        N = cfg.ssm.d_state
        impl = getattr(cfg.ssm, "scan_impl", "materialized")
        if impl == "pallas":
            # state VMEM-resident; only the (B, S, di) inputs stream
            total += n["mamba"] * 4 * (B_loc * S * di * F32) * mult
        elif impl == "chunked":
            # dA/dBx recomputed per step; state (B, di, N) r/w per step
            total += n["mamba"] * 2 * (B_loc * di * N * F32) * S * mult
        else:
            # materialized dA/dBx (B, S, di, N): write + read (+bwd)
            total += n["mamba"] * 2 * (B_loc * S * di * N * F32) * 2 * mult
    return total


def _act_bytes(cfg: ModelConfig, T: float, dp: int, tp: int,
               *, train: bool) -> float:
    """Activation traffic per device.

    Residual-stream tensors (norms, adds, projections in d_model) are
    sharded on dp only (~6 sweeps/layer); wide internals (d_ff / head
    tensors) are additionally tp-sharded (~8 sweeps/layer of the widest
    dim).  Remat'ed backward re-reads ~2.5x."""
    L = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)
    wide = max(cfg.d_ff, cfg.n_heads * cfg.hd,
               cfg.ssm.inner(cfg.d_model) if any(
                   m == "mamba" for m, _ in cfg.pattern) else 0,
               cfg.xlstm.expand * cfg.d_model if any(
                   m in ("mlstm", "slstm") for m, _ in cfg.pattern) else 0)
    mult = 2.5 if train else 1.0
    resid = 6 * T * cfg.d_model / dp
    inner = 8 * T * wide / (dp * tp)
    return L * (resid + inner) * BF16 * mult


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    n_attn = _pattern_counts(cfg, cfg.n_layers).get("attn", 0)
    kv = 2 * n_attn * B * S * cfg.n_kv_heads * cfg.hd * BF16
    # recurrent states are O(1) in S
    n = _pattern_counts(cfg, cfg.n_layers)
    di = cfg.ssm.inner(cfg.d_model)
    kv += n.get("mamba", 0) * B * di * cfg.ssm.d_state * F32
    dh = cfg.xlstm.expand * cfg.d_model // cfg.xlstm.n_heads
    kv += n.get("mlstm", 0) * B * cfg.xlstm.n_heads * dh * dh * F32
    return kv
