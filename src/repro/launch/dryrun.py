import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell on the production mesh and extract the roofline terms.

THE first two lines of this file force 512 host-platform placeholder
devices and MUST run before any other import (jax locks the device count on
first init).

Per cell this produces (written to experiments/dryrun/*.json):
    * compiled.memory_analysis()  — proves the cell fits per-device HBM
    * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
    * collective byte counts parsed from the post-SPMD HLO text
      (all-gather / all-reduce / reduce-scatter / all-to-all /
      collective-permute), since cost_analysis does not expose them.

Usage:
    python -m repro.launch.dryrun --arch qwen2_5_3b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single
    python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import json
import re
import time
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import (ARCHS, SHAPES, dryrun_cells, get_config,
                           input_specs)
from repro.configs.base import shape_applicable
from repro.launch.mesh import HW, make_production_mesh
from repro.models import sharding as SH
from repro.models import transformer as T
from repro.train import optimizer as OPT

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(txt: str) -> int:
    """Wire bytes of one HLO result shape like 'bf16[128,1024]'.

    Async collectives ('-start') produce a (operand, result) tuple; the
    on-the-wire volume is ~the larger element (all-gather result, reduce-
    scatter operand), so tuples contribute max(elements), not the sum.
    """
    sizes = []
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes.append(n * _DTYPE_BYTES[dt])
    if not sizes:
        return 0
    if txt.lstrip().startswith("("):
        return max(sizes)
    return sizes[0]


_COLL_RE = re.compile(
    r".*= ((?:\([^)]*\)|\S+)) (all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(")


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum output-shape bytes of every collective op in the HLO (raw —
    while bodies counted once; see parse_collectives_weighted)."""
    out = {c: {"bytes": 0, "count": 0} for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _COLL_RE.match(ls)
        if not m or "-done(" in ls:
            continue
        shape_txt, kind = m.groups()
        out[kind]["bytes"] += _shape_bytes(shape_txt)
        out[kind]["count"] += 1
    return out


# -- while-tree weighting: XLA cost/byte parses count a while body ONCE; we
# recover execution counts by walking the while tree with parsed trip counts
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str):
    comps, entry, cur = {}, None, None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines) -> int:
    """Scan conditions compare the counter against a constant bound."""
    consts = [int(m.group(1)) for ln in cond_lines
              for m in _CONST_RE.finditer(ln)]
    return max(consts) if consts else 1


def parse_collectives_weighted(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Collective bytes with while-body trip-count multipliers applied."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return parse_collectives(hlo_text)

    # comp -> [(body, trips)] edges
    edges = {}
    for name, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                cond, body = m.groups()
                trips = _trip_count(comps.get(cond, []))
                edges.setdefault(name, []).append((body, trips))

    mult = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # propagate (the while tree is a DAG; fixed-point over a few passes)
    for _ in range(len(comps)):
        changed = False
        for src, outs in edges.items():
            for body, trips in outs:
                want = mult.get(src, 0.0) * trips
                if want > mult.get(body, 0.0):
                    mult[body] = want
                    changed = True
        if not changed:
            break

    out = {c: {"bytes": 0.0, "count": 0.0} for c in COLLECTIVES}
    for name, lines in comps.items():
        m_ = mult.get(name, 0.0)
        if m_ <= 0:
            # collectives in unreached comps (conservative: count once)
            m_ = 1.0 if any(_COLL_RE.match(ln.strip()) for ln in lines) else 0.0
            if m_ == 0:
                continue
        for ln in lines:
            ls = ln.strip()
            mm = _COLL_RE.match(ls)
            if not mm or "-done(" in ls:
                continue
            shape_txt, kind = mm.groups()
            out[kind]["bytes"] += _shape_bytes(shape_txt) * m_
            out[kind]["count"] += m_
    return out


# ---------------------------------------------------------------------------
# step functions per cell kind
# ---------------------------------------------------------------------------

def pick_optimizer(n_params: int) -> str:
    return "adafactor" if n_params > 50e9 else "adamw"


def build_cell(arch: str, shape_name: str, mesh: Mesh, cfg=None):
    """Returns (fn, abstract_args, in_shardings, out_shardings, meta)."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SystemExit(f"{arch} x {shape_name}: {why}")

    key = jax.random.PRNGKey(0)
    p_shape = jax.eval_shape(lambda: T.init_params(cfg, key))
    n_params = sum(x.size for x in jax.tree.leaves(p_shape))
    pspecs = SH.param_specs(cfg, p_shape, mesh)
    pshard = SH.named(mesh, pspecs)
    dp = SH.dp_axes(mesh)

    batch = input_specs(cfg, shape)
    bspecs = SH.batch_specs(cfg, batch, mesh)
    bshard = SH.named(mesh, bspecs)

    n_active = T.active_param_count(cfg, p_shape)
    tokens_processed = (shape.global_batch *
                        (1 if shape.kind == "decode" else shape.seq_len))
    if cfg.enc_dec and shape.kind != "decode":
        tokens_processed = shape.global_batch * (
            shape.seq_len + shape.seq_len // cfg.dec_len_ratio)
    # MODEL_FLOPS: 6ND train (fwd+bwd), 2ND inference (fwd only)
    mf = (6 if shape.kind == "train" else 2) * n_active * tokens_processed
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "n_params": int(n_params), "n_active_params": int(n_active),
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "model_flops_global": float(mf)}

    if shape.kind == "train":
        opt_kind = pick_optimizer(n_params)
        opt = OPT.make_optimizer(opt_kind)
        o_shape = jax.eval_shape(lambda: opt.init(p_shape))
        ospecs = SH.opt_specs(pspecs, o_shape, mesh)
        oshard = SH.named(mesh, ospecs)
        meta["optimizer"] = opt_kind

        def train_step(params, opt_state, batch):
            (l, m), grads = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
            grads, gnorm = OPT.clip_by_global_norm(grads, 1.0)
            params, opt_state = opt.update(grads, opt_state, params, 3e-4)
            return params, opt_state, l

        return (train_step, (p_shape, o_shape, batch),
                (pshard, oshard, bshard), (pshard, oshard, None), meta)

    if shape.kind == "prefill":
        def prefill(params, batch):
            logits, _ = T.forward(cfg, params, batch, remat=False)
            # return only the last-position logits (serving prefill)
            return logits[:, -1, :]

        return (prefill, (p_shape, batch), (pshard, bshard), None, meta)

    # decode
    B = shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, B, shape.seq_len))
    seq_shard = shape.name == "long_500k"
    cspecs = SH.cache_specs(cfg, cache_shape, mesh, seq_shard=seq_shard)
    cshard = SH.named(mesh, cspecs)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tshard = NamedSharding(mesh, SH.guard_spec(P(dp, None), (B, 1), mesh))
    cur_len = jax.ShapeDtypeStruct((), jnp.int32)

    if cfg.enc_dec:
        enc = jax.ShapeDtypeStruct((B, shape.seq_len, cfg.d_model),
                                   jnp.bfloat16)
        eshard = NamedSharding(mesh, SH.guard_spec(
            P(dp, None, None), enc.shape, mesh))

        def serve_step(params, cache, tokens, cur_len, enc_out):
            return T.decode_step(cfg, params, cache, tokens, cur_len,
                                 enc_out=enc_out)

        return (serve_step, (p_shape, cache_shape, tokens, cur_len, enc),
                (pshard, cshard, tshard, None, eshard),
                (None, cshard), meta)

    def serve_step(params, cache, tokens, cur_len):
        return T.decode_step(cfg, params, cache, tokens, cur_len)

    return (serve_step, (p_shape, cache_shape, tokens, cur_len),
            (pshard, cshard, tshard, None), (None, cshard), meta)


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh: Mesh, mesh_name: str,
             *, save: bool = True, verbose: bool = True,
             cfg=None, tag: str = "") -> Dict:
    n_dev = mesh.size
    fn, args, in_sh, out_sh, meta = build_cell(arch, shape_name, mesh,
                                               cfg=cfg)
    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll_raw = parse_collectives(hlo)
    coll_w = parse_collectives_weighted(hlo)

    flops_hlo = float(cost.get("flops", 0.0))
    bytes_hlo = float(cost.get("bytes accessed", 0.0))
    coll_bytes_raw = sum(c["bytes"] for c in coll_raw.values())
    coll_bytes = sum(c["bytes"] for c in coll_w.values())

    # analytic structural model (XLA cost_analysis counts while bodies
    # once; see launch/costmodel.py docstring)
    from repro.configs import SHAPES as _SHAPES
    from repro.launch.costmodel import analytic_cost
    from repro.models import sharding as _SH
    if cfg is None:
        cfg = get_config(arch)
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh.shape.get(ax, 1)
    tp = mesh.shape.get("model", 1)
    if _SH.get_layout() in ("fsdp", "zero1"):   # model axis became DP
        dp, tp = dp * tp, 1
    from repro.models.layers import CAUSAL_SKIP as _cskip
    ac = analytic_cost(cfg, _SHAPES[shape_name], n_dev, dp=dp, tp=tp,
                       causal_skip=_cskip,
                       zero1=_SH.get_layout() == "zero1")

    result = dict(meta)
    result.update({
        "mesh": mesh_name,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # raw HLO numbers (while bodies counted once — see costmodel.py)
        "flops_per_device_hlo": flops_hlo,
        "bytes_per_device_hlo": bytes_hlo,
        "collective_bytes_raw": coll_bytes_raw,
        # trip-count-weighted HLO collectives (measured, corrected)
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll_w,
        "collectives_raw": coll_raw,
        # analytic structural model
        "flops_per_device": ac.flops,
        "bytes_per_device": ac.hbm_bytes,
        "collective_bytes_analytic": ac.coll_bytes,
        "memory": _mem_dict(mem),
        "hlo_bytes": len(hlo),
    })
    # roofline terms (seconds): analytic compute/memory; measured
    # (trip-weighted) collectives
    result["t_compute"] = ac.flops / HW["peak_flops_bf16"]
    result["t_memory"] = ac.hbm_bytes / HW["hbm_bw"]
    result["t_collective"] = coll_bytes / HW["ici_bw"]
    result["t_collective_analytic"] = ac.coll_bytes / HW["ici_bw"]
    terms = {"compute": result["t_compute"], "memory": result["t_memory"],
             "collective": result["t_collective"]}
    result["bottleneck"] = max(terms, key=terms.get)
    mf_dev = meta["model_flops_global"] / n_dev
    result["useful_flops_ratio"] = (mf_dev / ac.flops) if ac.flops else 0.0
    # roofline fraction: useful model flops over the time the dominant
    # term implies (how close the cell is to the compute roofline)
    t_dom = max(terms.values())
    result["roofline_fraction"] = (
        (mf_dev / HW["peak_flops_bf16"]) / t_dom if t_dom else 0.0)

    if verbose:
        print(f"[{arch} x {shape_name} @ {mesh_name}] "
              f"compile {t_compile:.1f}s | flops/dev {ac.flops:.3e} "
              f"(hlo {flops_hlo:.2e}) | bytes/dev {ac.hbm_bytes:.3e} | "
              f"coll/dev {coll_bytes:.3e} (raw {coll_bytes_raw:.2e}, "
              f"analytic {ac.coll_bytes:.2e}) | "
              f"bottleneck {result['bottleneck']}")
        if mem is not None:
            print(f"  memory_analysis: {_mem_dict(mem)}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(OUT_DIR,
                            f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def _mem_dict(mem) -> Optional[Dict[str, float]]:
    if mem is None:
        return None
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    if args.all:
        for arch, shape in dryrun_cells():
            run_cell(arch, shape, mesh, args.mesh)
    else:
        run_cell(args.arch, args.shape, mesh, args.mesh)


if __name__ == "__main__":
    main()
