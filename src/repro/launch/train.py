"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_3b --smoke \
        --steps 100 --seq 128 --batch 8

On real hardware drop ``--smoke`` and the full config + production mesh are
used; on this CPU container the smoke config with a host mesh runs a ~real
training loop (loss descends, checkpoints, resumes).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--optimizer", default="adamw")
    args = ap.parse_args()

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_host_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh()

    tc = TrainConfig(lr=args.lr, warmup=max(args.steps // 10, 1),
                     total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, optimizer=args.optimizer)
    tr = Trainer(cfg, tc, mesh, seq_len=args.seq, global_batch=args.batch)
    out = tr.fit(args.steps)
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(from {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
