"""Recompute the analytic roofline terms for saved dry-run JSONs (no
recompilation; the measured HLO collectives/memory are kept as-is).  Used
when the cost model is refined after a sweep.

    python -m repro.launch.refresh_costs
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.costmodel import analytic_cost
from repro.launch.dryrun import OUT_DIR
from repro.launch.mesh import HW


def refresh(path: str) -> None:
    with open(path) as f:
        r = json.load(f)
    tag = os.path.basename(path).split("__")
    if len(tag) > 3:
        return                      # hillclimb variants: produced fresh
    cfg = get_config(r["arch"])
    shape = SHAPES[r["shape"]]
    n_dev = r["n_devices"]
    if r["mesh"] == "multi":
        dp, tp = 2 * 16, 16
    else:
        dp, tp = 16, 16
    ac = analytic_cost(cfg, shape, n_dev, dp=dp, tp=tp)
    r["flops_per_device"] = ac.flops
    r["bytes_per_device"] = ac.hbm_bytes
    r["collective_bytes_analytic"] = ac.coll_bytes
    r["t_compute"] = ac.flops / HW["peak_flops_bf16"]
    r["t_memory"] = ac.hbm_bytes / HW["hbm_bw"]
    coll = r["collective_bytes_per_device"]
    r["t_collective"] = coll / HW["ici_bw"]
    terms = {"compute": r["t_compute"], "memory": r["t_memory"],
             "collective": r["t_collective"]}
    r["bottleneck"] = max(terms, key=terms.get)
    mf_dev = r["model_flops_global"] / n_dev
    r["useful_flops_ratio"] = mf_dev / ac.flops if ac.flops else 0.0
    t_dom = max(terms.values())
    r["roofline_fraction"] = ((mf_dev / HW["peak_flops_bf16"]) / t_dom
                              if t_dom else 0.0)
    with open(path, "w") as f:
        json.dump(r, f, indent=1)


def main():
    for p in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        try:
            refresh(p)
        except (OSError, KeyError, ValueError) as e:
            # unreadable file / missing field / malformed JSON
            print(f"skip {os.path.basename(p)}: {e}")
    print("refreshed")


if __name__ == "__main__":
    main()
