"""§Perf hillclimbing driver: run one dry-run cell with named optimization
variants and log the roofline-term deltas, plus the reusable
``proportional_step`` weight-update rule.

Importing this module is side-effect free (the heterogeneous runtime's
rebalance loop pulls ``proportional_step`` from here); the 512-device
XLA flag and the dry-run machinery load only inside ``main()``.

    python -m repro.launch.hillclimb --arch qwen2_5_3b --shape train_4k \
        --variant fsdp_layout

Variants (composable, comma-separated):
    baseline       — paper-faithful defaults (TP layout, masked-full attn)
    fsdp_layout    — treat 'model' as extra FSDP/data parallelism (H1)
    causal_skip    — process only unmasked causal attention tiles (H-causal)
    chunkwise      — chunkwise-parallel mLSTM (H2)
    dense_moe      — conventional one-hot MoE dispatch (ablation: paper's
                     sparse dispatch OFF)
Each run writes experiments/dryrun/<cell>__<variant>.json.
"""
import argparse
import dataclasses

import numpy as np


def proportional_step(weights, costs, *, step: float = 0.5,
                      floor: float = 1e-3):
    """One multiplicative hill-climb step on a weight vector.

    ``costs[i]`` is the measured (or modeled) per-shard time under the
    current ``weights``.  A shard slower than the mean is overloaded for
    its device, so its weight shrinks by ``(mean/cost)^step``; a faster
    shard grows.  ``step=1`` jumps straight to the perfectly-balanced
    weights *if* time were exactly proportional to assigned work; smaller
    steps damp measurement noise.  The fixed point is equal per-shard time
    — GHOST's bandwidth-weighted ideal (section 4.1) discovered online.

    Used by ``repro.runtime.split.SplitPlan.rebalance`` (one step per
    solver outer-iteration) and reusable for any weight-tuning loop.
    Returns weights with the input sum preserved, floored at ``floor``
    of the total (capped at the equal share so the floor is always
    feasible) so no shard starves irrecoverably.

    A zero cost means the shard did no work (e.g. it holds no rows), so
    it carries no signal about its device: such entries keep their
    weight instead of exploding toward infinite speed.
    """
    w = np.asarray(weights, np.float64)
    t = np.asarray(costs, np.float64)
    if w.shape != t.shape or (w <= 0).any() or (t < 0).any():
        raise ValueError("weights/costs must be matching vectors, "
                         "weights positive, costs non-negative")
    total = w.sum()
    pos = t > 0
    if not pos.any():
        return w.copy()
    factor = np.ones_like(w)
    factor[pos] = (t[pos].mean() / t[pos]) ** step
    base = w * factor
    base = base / base.sum() * total

    # water-filling floor: pin every entry that lands below the floor and
    # rescale the rest, repeating because the rescale can push further
    # entries under — terminates in <= len(w) rounds
    lo = min(floor, 1.0 / len(w)) * total
    clipped = np.zeros(len(base), bool)
    while True:
        if clipped.all():
            return np.full_like(w, total / len(w))
        excess = total - lo * clipped.sum()
        scaled = np.where(clipped, lo,
                          base * excess / base[~clipped].sum())
        newly = (~clipped) & (scaled < lo)
        if not newly.any():
            return scaled
        clipped |= newly


def apply_variants(arch: str, variants):
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.models import sharding as SH
    cfg = get_config(arch)
    SH.set_layout("tp")
    L.set_causal_skip(False)
    for v in variants:
        if v == "baseline":
            continue
        elif v == "fsdp_layout":
            SH.set_layout("fsdp")
        elif v == "zero1_layout":
            SH.set_layout("zero1")
        elif v == "causal_skip":
            L.set_causal_skip(True)
        elif v == "chunkwise":
            cfg = dataclasses.replace(
                cfg, xlstm=dataclasses.replace(cfg.xlstm, chunkwise=True))
        elif v == "chunked_mamba":
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, scan_impl="chunked"))
        elif v == "dense_moe":
            if cfg.moe is None:
                raise ValueError("variant 'dense_moe' needs a MoE config")
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, ghost_dispatch=False))
        else:
            raise SystemExit(f"unknown variant {v}")
    return cfg


def main():
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    args = ap.parse_args()

    variants = args.variant.split(",")
    cfg = apply_variants(args.arch, variants)
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    tag = "+".join(v for v in variants if v != "baseline") or "baseline"
    r = run_cell(args.arch, args.shape, mesh, args.mesh, cfg=cfg, tag=tag)
    print(f"\n== {args.arch} x {args.shape} [{tag}] ==")
    for k in ("t_compute", "t_memory", "t_collective", "bottleneck",
              "roofline_fraction", "useful_flops_ratio"):
        print(f"  {k}: {r[k]}")


if __name__ == "__main__":
    main()
