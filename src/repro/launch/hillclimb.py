import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: run one dry-run cell with named optimization
variants and log the roofline-term deltas.

    python -m repro.launch.hillclimb --arch qwen2_5_3b --shape train_4k \
        --variant fsdp_layout

Variants (composable, comma-separated):
    baseline       — paper-faithful defaults (TP layout, masked-full attn)
    fsdp_layout    — treat 'model' as extra FSDP/data parallelism (H1)
    causal_skip    — process only unmasked causal attention tiles (H-causal)
    chunkwise      — chunkwise-parallel mLSTM (H2)
    dense_moe      — conventional one-hot MoE dispatch (ablation: paper's
                     sparse dispatch OFF)
Each run writes experiments/dryrun/<cell>__<variant>.json.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh


def apply_variants(arch: str, variants):
    from repro.models import layers as L
    from repro.models import sharding as SH
    cfg = get_config(arch)
    SH.set_layout("tp")
    L.set_causal_skip(False)
    for v in variants:
        if v == "baseline":
            continue
        elif v == "fsdp_layout":
            SH.set_layout("fsdp")
        elif v == "zero1_layout":
            SH.set_layout("zero1")
        elif v == "causal_skip":
            L.set_causal_skip(True)
        elif v == "chunkwise":
            cfg = dataclasses.replace(
                cfg, xlstm=dataclasses.replace(cfg.xlstm, chunkwise=True))
        elif v == "chunked_mamba":
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, scan_impl="chunked"))
        elif v == "dense_moe":
            assert cfg.moe is not None
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, ghost_dispatch=False))
        else:
            raise SystemExit(f"unknown variant {v}")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    args = ap.parse_args()

    variants = args.variant.split(",")
    cfg = apply_variants(args.arch, variants)
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    tag = "+".join(v for v in variants if v != "baseline") or "baseline"
    r = run_cell(args.arch, args.shape, mesh, args.mesh, cfg=cfg, tag=tag)
    print(f"\n== {args.arch} x {args.shape} [{tag}] ==")
    for k in ("t_compute", "t_memory", "t_collective", "bottleneck",
              "roofline_fraction", "useful_flops_ratio"):
        print(f"  {k}: {r[k]}")


if __name__ == "__main__":
    main()
