"""Roofline aggregation: reads the dry-run JSONs and renders the
EXPERIMENTS.md tables (one row per arch x shape x mesh).

    python -m repro.launch.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def load(mesh: str = None) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if mesh is None or r.get("mesh") == mesh:
            rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(rows: List[Dict], *, md: bool = True) -> str:
    hdr = ["arch", "shape", "mesh", "t_comp", "t_mem", "t_coll",
           "bottleneck", "useful", "roofline", "mem/dev(GB)"]
    lines = []
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in rows:
        mem = r.get("memory") or {}
        total_mem = sum(mem.get(k, 0) for k in
                        ("argument_size_in_bytes", "temp_size_in_bytes",
                         "output_size_in_bytes"))
        row = [r["arch"], r["shape"], r["mesh"],
               fmt_s(r["t_compute"]), fmt_s(r["t_memory"]),
               fmt_s(r["t_collective"]), r["bottleneck"],
               f"{r.get('useful_flops_ratio', 0):.2f}",
               f"{r.get('roofline_fraction', 0):.3f}",
               f"{total_mem / 1e9:.1f}"]
        if md:
            lines.append("| " + " | ".join(row) + " |")
        else:
            lines.append(",".join(row))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh)
    print(table(rows, md=not args.csv))


if __name__ == "__main__":
    main()
