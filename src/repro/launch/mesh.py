"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
*before* the first jax initialization.

Production target: TPU v5e pods, 256 chips each (16x16), 2 pods for the
multi-pod dry-run.  Axes:
    pod    — data parallelism across DCN-connected pods
    data   — FSDP/batch within a pod
    model  — tensor/expert parallelism within a pod
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "HW"]


# TPU v5e hardware constants (per chip) for the roofline analysis
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    import numpy as np
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))
