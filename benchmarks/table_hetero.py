"""Paper section 4.1 (Table 1 + the SpMV listings): heterogeneous
bandwidth-weighted work distribution.

Reproduces the paper's reasoning: device weights = attainable memory
bandwidths (CPU socket 50, GPU 150, PHI 150 GB/s), SpMV at the minimum
code balance of 6 bytes/flop (double + 32-bit index), so predicted
aggregate Gflop/s = sum(bw)/6.  The paper measured 16.4 (2 CPU sockets),
45 (CPU+GPU) and ~55 Gflop/s (full node, pseudo-SpMV) for ML_Geer; we
recompute those predictions from our partitioner on an ML_Geer-like
band matrix and report the nnz shares each device receives."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core import partition as pt
from repro.matrices import banded_random

CB = 6.0  # bytes/flop, paper's minimum SpMV code balance


def predict(bws):
    return sum(bws) / CB


def main():
    # ML_Geer-like: n=1.5M, ~74 nnz/row band
    n = 150_000                                  # scaled 10x down for CPU
    r, c, v, _ = banded_random(n, bw=37, density=1.0, seed=0)
    rowlen = np.zeros(n, np.int64)
    np.add.at(rowlen, r, 1)

    cases = {
        "2xCPU": [50, 50],
        "CPU+GPU": [50 - 5, 150],                 # GPU host core subtracted
        "CPU+GPU+PHI": [45, 150, 150],
    }
    measured = {"2xCPU": 16.4, "CPU+GPU": 45.0, "CPU+GPU+PHI": 55.0}
    for name, bws in cases.items():
        ranges = pt.weighted_nnz_partition(rowlen, bws)
        shares = [float(rowlen[s:e].sum()) / len(r) for s, e in ranges]
        pred = predict(bws)
        meas = measured[name]
        row(f"hetero_{name}", 0.0,
            f"pred_gflops={pred:.1f};paper_measured={meas};"
            f"agreement={meas / pred:.2f};"
            f"nnz_shares={'/'.join(f'{s:.2f}' for s in shares)}")


if __name__ == "__main__":
    main()
