"""Paper section 4.1 (Table 1 + the SpMV listings): heterogeneous
bandwidth-weighted work distribution, driven by the execution engine.

Reproduces the paper's reasoning through the runtime path: a
``DevicePool`` holds the device classes (CPU socket 50, GPU 150, PHI 150
GB/s attainable), the pool's roofline turns those into split weights
(SpMV at the minimum code balance of 6 bytes/flop -> predicted aggregate
Gflop/s = sum(bw)/6), and ``plan_split`` apportions an ML_Geer-like band
matrix into C-aligned nnz-proportional shards.  The paper measured 16.4
(2 CPU sockets), 45 (CPU+GPU) and ~55 Gflop/s (full node, pseudo-SpMV)
for ML_Geer; we report prediction/measurement agreement plus the nnz
share each device receives, and one modeled rebalance step to show the
hill-climb is a no-op when the model already matches (fixed point)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import policy_row, row
from repro.matrices import banded_random
from repro.runtime import DevicePool, plan_split


def main():
    policy_row("table_hetero")
    # ML_Geer-like: n=1.5M, ~74 nnz/row band
    n = 150_000                                  # scaled 10x down for CPU
    r, c, v, _ = banded_random(n, bw=37, density=1.0, seed=0)
    rowlen = np.zeros(n, np.int64)
    np.add.at(rowlen, r, 1)

    cases = {
        "2xCPU": [50, 50],
        "CPU+GPU": [50 - 5, 150],                 # GPU host core subtracted
        "CPU+GPU+PHI": [45, 150, 150],
    }
    measured = {"2xCPU": 16.4, "CPU+GPU": 45.0, "CPU+GPU+PHI": 55.0}
    for name, bws in cases.items():
        pool = DevicePool.from_bandwidths(bws)
        w = pool.device_weights()                 # roofline-proportional
        plan = plan_split(n, w, align=32, rowlen=rowlen)
        shares = plan.shard_nnz() / len(r)
        pred = pool.aggregate_spmv_gflops(nnzr=1e9)  # min code balance (6)
        meas = measured[name]

        # one modeled rebalance step: with per-shard time = share / bw the
        # plan is already at the hill-climb fixed point -> weights move < 1%
        times = shares / (np.asarray(bws, float) / sum(bws))
        drift = np.abs(np.asarray(plan.rebalance(times).weights)
                       - np.asarray(plan.weights)).max()

        row(f"hetero_{name}", 0.0,
            f"pred_gflops={pred:.1f};paper_measured={meas};"
            f"agreement={meas / pred:.2f};"
            f"nnz_shares={'/'.join(f'{s:.2f}' for s in shares)};"
            f"rebalance_drift={drift:.4f}")


if __name__ == "__main__":
    main()
