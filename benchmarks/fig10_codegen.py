"""Paper Fig. 10: hard-coded block-vector width (compile-time codegen) vs
a generic-width kernel.

In JAX the tracer IS the code generator (DESIGN.md C6): jitting with a
static width b produces a fully specialized kernel, the analogue of
GHOST's #GHOST_UNROLL expansion.  The 'generic' baseline processes one
vector at a time through the same matrix (what a width-1 library kernel
without SpMMV support would do)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import from_coo
from repro.core.spmv import spmv_ref
from repro.matrices import banded_random


def main():
    policy_row("fig10_codegen")
    r, c, v, n = banded_random(150_000, bw=10, density=0.6, seed=0)
    m = from_coo(r, c, v, (n, n), C=32, sigma=256, dtype=np.float32)
    rng = np.random.default_rng(1)
    for b in (1, 2, 4, 8):
        x = rng.standard_normal((n, b)).astype(np.float32)
        xp = m.permute(x)
        spec = jax.jit(lambda xp: spmv_ref(m, xp)[0])     # specialized on b
        t_spec = time_fn(spec, xp)

        one = jax.jit(lambda xc: spmv_ref(m, xc)[0])      # width-1 kernel

        def generic(xp):
            return jnp.stack([one(xp[:, i:i + 1])[:, 0]
                              for i in range(b)], axis=1)

        t_gen = time_fn(generic, xp)
        gf = 2 * m.nnz * b / t_spec / 1e9
        row(f"fig10_width{b}", t_spec * 1e6,
            f"specialized_gflops={gf:.2f};"
            f"speedup_vs_generic={t_gen / t_spec:.2f}x")


if __name__ == "__main__":
    main()
