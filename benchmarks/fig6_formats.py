"""Paper Fig. 6: SpMV with the unified SELL-C-sigma format vs the
device-specific baseline format (CRS == SELL-1-1).

Reported: wall time per SpMV (CPU sanity), plus the derived quantities the
paper's model predicts from — storage efficiency beta and the code balance
(bytes per flop; the paper's 1 Gflop/s == 6 GB/s relation for double +
32-bit indices)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import from_coo
from repro.core.spmv import spmv_ref
from repro.matrices import banded_random, matpde


def code_balance(m, dtype_bytes=4, idx_bytes=4, nvecs=1):
    """Bytes moved per flop for SpMMV (paper section 4.1 / Gropp model)."""
    nnz = m.nnz
    n = m.nrows
    flops = 2 * nnz * nvecs
    bytes_ = (nnz / m.beta) * (dtype_bytes + idx_bytes) \
        + n * nvecs * dtype_bytes * 2 + n * nvecs * dtype_bytes
    return bytes_ / flops


def main():
    policy_row("fig6_formats")
    r, c, v, n = matpde(380)                       # ~144k rows, ~720k nnz
    x = np.random.default_rng(0).standard_normal((n, 1)).astype(np.float32)

    results = {}
    for name, C, sigma in [("SELL-1-1(CRS)", 1, 1),
                           ("SELL-32-1", 32, 1),
                           ("SELL-32-256", 32, 256)]:
        m = from_coo(r, c, v, (n, n), C=C, sigma=sigma, dtype=np.float32)
        xp = m.permute(x)
        f = jax.jit(lambda xp, m=m: spmv_ref(m, xp)[0])
        t = time_fn(f, xp)
        gflops = 2 * m.nnz / t / 1e9
        cb = code_balance(m)
        results[name] = (t, m.beta, gflops)
        row(f"fig6_spmv_{name}", t * 1e6,
            f"beta={m.beta:.3f};gflops_cpu={gflops:.2f};code_balance={cb:.2f}B/F")

    # paper claim: SELL-C-sigma is on par with / better than CRS
    t_crs = results["SELL-1-1(CRS)"][0]
    t_sell = results["SELL-32-256"][0]
    row("fig6_sell_vs_crs_ratio", 0.0, f"ratio={t_crs / t_sell:.2f}x")


if __name__ == "__main__":
    main()
