"""Paper Fig. 9: impact of vectorization width on the SpMV kernel.

Without real TPU wall-clock this is the structural study the §Perf
methodology prescribes: sweep the kernel width tile (w_tile, the analogue
of SSE/AVX/MIC width) and report
  * beta (padding overhead grows with alignment),
  * slab loads per chunk (fewer, wider loads as w_tile grows),
  * CPU wall time of the ref path at the matching alignment (sanity).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import from_coo
from repro.core.spmv import spmv_ref
from repro.matrices import matpde


def main():
    policy_row("fig9_vectorization")
    r, c, v, n = matpde(256)
    x = np.random.default_rng(0).standard_normal((n, 1)).astype(np.float32)
    for wt in (1, 2, 4, 8, 16):
        m = from_coo(r, c, v, (n, n), C=32, sigma=256, w_align=wt,
                     dtype=np.float32)
        slabs = int(np.asarray(m.chunk_len).sum()) // wt
        xp = m.permute(x)
        f = jax.jit(lambda xp, m=m: spmv_ref(m, xp)[0])
        t = time_fn(f, xp)
        row(f"fig9_wtile{wt}", t * 1e6,
            f"beta={m.beta:.3f};slab_loads={slabs};"
            f"bytes_padded={int(m.cap * 8)}")


if __name__ == "__main__":
    main()
