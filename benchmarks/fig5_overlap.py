"""Paper Fig. 5: SpMV runtime — no overlap vs overlapped communication.

Runs the pipelined SpMV of the heterogeneous execution engine (8 simulated
shards in a subprocess, cage15-like band matrix) in its two schedules:
  * overlap=False — "No Overlap": optimization barrier forces the halo
    exchange to complete before local compute starts;
  * overlap=True  — "GHOST task mode": local compute is data-independent of
    the exchange, so the scheduler may overlap them; the chained run uses
    the double-buffered halo staging so successive SpMVs can pipeline.
Also reports the derived quantities that matter at scale: halo volume per
shard (compressed remote columns, Fig. 3) and the local/remote nnz split."""
from __future__ import annotations

import subprocess
import sys
import os

from benchmarks.common import policy_row, row

CODE = r"""
import time, numpy as np, jax
from jax.sharding import Mesh
from repro.matrices import banded_random
from repro.runtime import DevicePool, HeterogeneousEngine

r, c, v, n = banded_random(120_000, bw=16, density=0.6, seed=0)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
eng = HeterogeneousEngine(r, c, v, n, mesh=mesh,
                          pool=DevicePool.from_bandwidths([1.0] * 8),
                          C=32, sigma=256, w_align=4, dtype=np.float32)
D = eng.A
rng = np.random.default_rng(0)
x = rng.standard_normal((n, 1)).astype(np.float32)
xs = D.distribute_vec(x)

for name, ov, db in (("no_overlap", False, False),
                     ("overlap", True, False),
                     ("overlap_dbuf", True, True)):
    run = eng.make_matvec(overlap=ov, nvecs=1, double_buffer=db)
    stg = eng.init_staging(1, np.float32) if db else None
    y, _, _ = run(xs, staging=stg); jax.block_until_ready(y)
    ts = []
    for _ in range(20):
        t0 = time.perf_counter()
        y, _, s = run(xs, staging=stg)
        if db:
            stg = s
        jax.block_until_ready(y); ts.append(time.perf_counter() - t0)
    t = float(np.median(ts))
    print(f"RES,{name},{t*1e6:.1f}")

lm = int(np.asarray(D.l_vals != 0).sum()); rm = int(np.asarray(D.r_vals != 0).sum())
print(f"RES,halo,{0:.1f},max_msg={D.max_msg};h_max={D.h_max};"
      f"local_nnz={lm};remote_nnz={rm};remote_frac={rm/(lm+rm):.4f}")
"""


def main():
    policy_row("fig5_overlap")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=560)
    if out.returncode != 0:
        row("fig5_overlap", 0.0, f"FAILED:{out.stderr[-200:]}")
        return
    res = {}
    for line in out.stdout.splitlines():
        if line.startswith("RES,"):
            parts = line.split(",", 3)
            res[parts[1]] = parts[2:]
    t_no = float(res["no_overlap"][0])
    t_ov = float(res["overlap"][0])
    t_db = float(res["overlap_dbuf"][0])
    row("fig5_spmv_no_overlap", t_no, "mode=barrier")
    row("fig5_spmv_overlap", t_ov,
        f"mode=task;speedup={t_no / max(t_ov, 1e-9):.2f}x")
    # the staging array is structural (RDMA landing-buffer hook); its cost
    # is the buffer-rotation copy, reported as overhead vs plain task mode
    row("fig5_spmv_overlap_dbuf", t_db,
        f"mode=task+staging;staging_overhead={t_db / max(t_ov, 1e-9):.2f}x")
    row("fig5_halo", 0.0, res["halo"][1])


if __name__ == "__main__":
    main()
