"""Paper Fig. 5: SpMV runtime — no overlap vs overlapped communication.

Runs the distributed SpMV (8 simulated shards in a subprocess, cage15-like
band matrix) in the two modes ``core.distributed`` provides:
  * overlap=False — "No Overlap": optimization barrier forces the halo
    exchange to complete before local compute starts;
  * overlap=True  — "GHOST task mode": local compute is data-independent of
    the exchange, so the scheduler may overlap them.
Also reports the derived quantities that matter at scale: halo volume per
shard (compressed remote columns, Fig. 3) and the local/remote nnz split."""
from __future__ import annotations

import subprocess
import sys
import os

from benchmarks.common import row

CODE = r"""
import time, numpy as np, jax
from jax.sharding import Mesh
from repro.core.distributed import dist_from_coo, make_dist_spmv
from repro.matrices import banded_random

r, c, v, n = banded_random(120_000, bw=16, density=0.6, seed=0)
D = dist_from_coo(r, c, v, n, nshards=8, C=32, sigma=256, w_align=4,
                  dtype=np.float32)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(0)
x = rng.standard_normal((n, 1)).astype(np.float32)
xs = D.distribute_vec(x)

for name, ov in (("no_overlap", False), ("overlap", True)):
    run = make_dist_spmv(D, mesh, overlap=ov, nvecs=1)
    y, _ = run(xs); jax.block_until_ready(y)
    ts = []
    for _ in range(20):
        t0 = time.perf_counter(); y, _ = run(xs)
        jax.block_until_ready(y); ts.append(time.perf_counter() - t0)
    t = float(np.median(ts))
    print(f"RES,{name},{t*1e6:.1f}")

lm = int(np.asarray(D.l_vals != 0).sum()); rm = int(np.asarray(D.r_vals != 0).sum())
print(f"RES,halo,{0:.1f},max_msg={D.max_msg};h_max={D.h_max};"
      f"local_nnz={lm};remote_nnz={rm};remote_frac={rm/(lm+rm):.4f}")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=560)
    if out.returncode != 0:
        row("fig5_overlap", 0.0, f"FAILED:{out.stderr[-200:]}")
        return
    res = {}
    for line in out.stdout.splitlines():
        if line.startswith("RES,"):
            parts = line.split(",", 3)
            res[parts[1]] = parts[2:]
    t_no = float(res["no_overlap"][0])
    t_ov = float(res["overlap"][0])
    row("fig5_spmv_no_overlap", t_no, "mode=barrier")
    row("fig5_spmv_overlap", t_ov,
        f"mode=task;speedup={t_no / max(t_ov, 1e-9):.2f}x")
    row("fig5_halo", 0.0, res["halo"][1])


if __name__ == "__main__":
    main()
