"""Paper section 5.1: matrix construction cost in SpMV-equivalents.

The paper measures: initial CRS->SELL-C-sigma construction (including
communication buffers) ~ 48 SpMVs; subsequent value-only updates ~ 2 SpMVs
(read CRS vals + write-allocate SELL vals = 3 x nnz transfers)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import from_coo
from repro.core.spmv import spmv_ref
from repro.matrices import banded_random


def main():
    policy_row("table_construction")
    r, c, v, n = banded_random(120_000, bw=16, density=0.7, seed=0)
    t0 = time.perf_counter()
    m = from_coo(r, c, v, (n, n), C=32, sigma=256, dtype=np.float32)
    t_build = time.perf_counter() - t0

    x = m.permute(np.random.default_rng(0).standard_normal(n).astype(np.float32))
    f = jax.jit(lambda xp: spmv_ref(m, xp)[0])
    t_spmv = time_fn(f, x)

    # value-only update (same pattern): scatter new values into slots
    vals2 = (v * 2).astype(np.float32)
    t0 = time.perf_counter()
    m2 = from_coo(r, c, vals2, (n, n), C=32, sigma=256, dtype=np.float32)
    t_update = time.perf_counter() - t0     # upper bound (full rebuild)

    row("construction_initial", t_build * 1e6,
        f"spmv_equivalents={t_build / t_spmv:.1f};paper=48")
    row("construction_value_update", t_update * 1e6,
        f"spmv_equivalents={t_update / t_spmv:.1f};paper=2(min_3nnz_transfers)")


if __name__ == "__main__":
    main()
