"""Paper Fig. 8: SpMMV with row-major vs column-major block vectors.

Row-major (interleaved, (n, b) minor-last) gives unit-stride access to all
b vector entries of a gathered row — the paper's preferred layout.  The
column-major variant strides by n per vector."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import from_coo
from repro.matrices import banded_random


def main():
    policy_row("fig8_layout")
    r, c, v, n = banded_random(200_000, bw=12, density=0.5, seed=0)
    m = from_coo(r, c, v, (n, n), C=32, sigma=256, dtype=np.float32)
    rng = np.random.default_rng(1)

    for b in (1, 2, 4, 8, 16):
        x = rng.standard_normal((n, b)).astype(np.float32)
        xp = m.permute(x)

        # row-major: gather (cap, b) rows — unit stride in b
        f_row = jax.jit(lambda xp: jax.ops.segment_sum(
            m.vals[:, None] * xp[m.cols], m.rowids,
            num_segments=m.nrows_pad))
        # col-major: (b, n) layout, gather along the minor axis
        xc = jnp.asarray(xp.T)
        f_col = jax.jit(lambda xc: jax.ops.segment_sum(
            (m.vals[None, :] * xc[:, m.cols]).T, m.rowids,
            num_segments=m.nrows_pad))
        t_r = time_fn(f_row, xp)
        t_c = time_fn(f_col, xc)
        gf_r = 2 * m.nnz * b / t_r / 1e9
        gf_c = 2 * m.nnz * b / t_c / 1e9
        row(f"fig8_spmmv_b{b}", t_r * 1e6,
            f"rowmajor_gflops={gf_r:.2f};colmajor_gflops={gf_c:.2f};"
            f"row_vs_col={t_c / t_r:.2f}x")


if __name__ == "__main__":
    main()
