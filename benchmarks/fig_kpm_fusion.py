"""Paper section 5.3 / [24]: kernel fusion + block vectors in KPM.

The paper reports a 2.5x solver-level gain for the kernel polynomial
method from (a) fusing the shifted SpMV with the two moment dots and (b)
processing R probe vectors at once.  We measure the CPU wall-clock ratio
of the fused vs naive moment iteration and report the derived traffic
model:

    naive:  SpMV sweep + 2 dot sweeps + axpby sweep over (n,R) vectors
    fused:  one sweep (matrix + 3 vectors in, 1 vector + 2 scalars out)
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import from_coo
from repro.matrices import anderson3d
from repro.solvers import make_operator
from repro.solvers.kpm import kpm_dos_moments


def traffic_ratio(nnz, n, R, beta=1.0):
    mat = (nnz / beta) * 8
    vec = n * R * 4
    naive = mat + 2 * vec + 2 * 2 * vec + 2 * vec      # spmv + dots + axpby
    fused = mat + 3 * vec
    return naive / fused


def main():
    policy_row("fig_kpm_fusion")
    r, c, v, n = anderson3d(24, disorder=8.0, seed=0)   # 13824 sites
    A = from_coo(r, c, v, (n, n), C=32, sigma=128, dtype=np.float32)
    op = make_operator(A)
    spectrum = (-8.0, 8.0)
    for R in (1, 4, 8):
        f_f = lambda: kpm_dos_moments(op, 64, n_probes=R,
                                      spectrum=spectrum, fused=True)
        f_n = lambda: kpm_dos_moments(op, 64, n_probes=R,
                                      spectrum=spectrum, fused=False)
        t_f = time_fn(f_f, iters=3)
        t_n = time_fn(f_n, iters=3)
        tr = traffic_ratio(A.nnz, n, R, A.beta)
        row(f"kpm_R{R}_fused", t_f * 1e6,
            f"speedup_vs_naive={t_n / t_f:.2f}x;"
            f"traffic_model_bound={tr:.2f}x;paper_solver_gain=2.5x")


if __name__ == "__main__":
    main()
