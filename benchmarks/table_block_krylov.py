"""Block-Krylov (shared Krylov space) vs. column-independent steppers.

The GHOST block-vector thesis taken to its conclusion (C2 + C5): once
independent solve requests ride one ``(n, b)`` block, the solver itself
can couple the columns — block CG (Dubrulle's BCGrQ) and block MINRES
(block Lanczos + band QR) search ONE Krylov space for the whole block,
so each column benefits from every other column's directions.  On
operators with clustered small eigenvalues the block method deflates
that cluster after ~b sweeps, which the column-independent recurrences
must each grind through alone.

Workload: anisotropic 2-D Laplacian (epsilon = 1e-2, the preconditioner
table's hard case) with a width-16 rhs block.

* ``monolithic`` rows — one ``cg``/``minres`` call per mode on the same
  16-wide block; the metric is block iterations (== SpMV sweeps, both
  modes sweep the matrix once per iteration) until EVERY column
  converged.  Acceptance (asserted): block CG needs >= 1.5x fewer
  sweeps per converged request than column CG.
* ``service`` rows — the same comparison end-to-end through
  :class:`SolverService` with ``submit(..., block=True)``: 32 requests
  through width-16 batches, block batches warm-restart on refill.

Run: ``python -m benchmarks.table_block_krylov`` (or benchmarks/run.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import policy_row, row
from repro.core import from_coo
from repro.matrices import anisotropic_laplace2d
from repro.runtime import MatrixRegistry, SolverService
from repro.solvers import cg, make_operator, minres

GRID = 32
EPSILON = 1e-2
WIDTH = 16
N_REQUESTS = 32
CHUNK_ITERS = 8
MAXITER = 4000
TOLS = {"cg": 1e-6, "minres": 1e-5}
SOLVES = {"cg": cg, "minres": minres}

#: acceptance bar (asserted): block CG retires the width-16 request
#: block in >= 1.5x fewer SpMV sweeps than column CG
MIN_CG_SWEEP_RATIO = 1.5


def _monolithic(op, b, solver):
    """(column_iters, block_iters) for one solve of the shared block."""
    fn, tol = SOLVES[solver], TOLS[solver]
    col = fn(op, b, tol=tol, maxiter=MAXITER)
    blk = fn(op, b, tol=tol, maxiter=MAXITER, block=True)
    assert bool(np.all(np.asarray(col.converged))), f"column {solver} diverged"
    assert bool(np.all(np.asarray(blk.converged))), f"block {solver} diverged"
    return int(col.iters), int(blk.iters)


def _service(reg, Ad, n, rng, block):
    """Drain N_REQUESTS through a service; mean per-ticket sweeps."""
    svc = SolverService(reg, block_width=WIDTH, chunk_iters=CHUNK_ITERS)
    tickets = []
    for i in range(N_REQUESTS):
        bvec = rng.standard_normal(n).astype(np.float32)
        solver = "minres" if i % 4 == 3 else "cg"
        tickets.append(svc.submit("ani", bvec, solver=solver,
                                  tol=TOLS[solver], maxiter=MAXITER,
                                  block=block))
    svc.drain()
    for t in tickets:
        assert t.result.converged, f"service request diverged: {t}"
        rel = (np.abs(Ad @ t.result.x - np.asarray(t.b)).max()
               / np.abs(np.asarray(t.b)).max())
        assert rel < 1e-3, (t, rel)
    iters = [t.result.iters for t in tickets]
    return float(np.mean(iters)), svc.stats


def main():
    policy_row("table_block_krylov")
    r, c, v, n = anisotropic_laplace2d(GRID, epsilon=EPSILON)
    Ad = np.zeros((n, n), np.float32)
    Ad[r, c] += v.astype(np.float32)
    A = from_coo(r, c, v, (n, n), C=16, sigma=1, w_align=4,
                 dtype=np.float32)
    op = make_operator(A)
    rng = np.random.default_rng(7)
    b = A.permute(rng.standard_normal((n, WIDTH)).astype(np.float32))

    # ---- monolithic block solves: sweeps until every column converged
    ratios = {}
    for solver in ("cg", "minres"):
        col_it, blk_it = _monolithic(op, b, solver)
        ratios[solver] = col_it / max(blk_it, 1)
        row(f"block_krylov_{solver}", 0.0,
            f"column_sweeps={col_it};block_sweeps={blk_it};"
            f"sweep_ratio={ratios[solver]:.2f}x;width={WIDTH};"
            f"n={n};epsilon={EPSILON};tol={TOLS[solver]:g}")
    assert ratios["cg"] >= MIN_CG_SWEEP_RATIO, (
        f"block CG sweep reduction {ratios['cg']:.2f}x is below the "
        f"{MIN_CG_SWEEP_RATIO}x acceptance bar")

    # ---- the same claim end-to-end through the SolverService
    reg = MatrixRegistry()
    reg.register("ani", rows=r, cols=c, vals=v, shape=(n, n), C=16,
                 sigma=1, w_align=4, dtype=np.float32)
    col_mean, col_stats = _service(reg, Ad, n,
                                   np.random.default_rng(11), block=False)
    blk_mean, blk_stats = _service(reg, Ad, n,
                                   np.random.default_rng(11), block=True)
    row("block_krylov_service", 0.0,
        f"column_mean_ticket_sweeps={col_mean:.1f};"
        f"block_mean_ticket_sweeps={blk_mean:.1f};"
        f"requests={N_REQUESTS};width={WIDTH};"
        f"column_refills={col_stats['refills']};"
        f"block_refills={blk_stats['refills']};"
        f"block_warm_restarts={blk_stats['refills']}")


if __name__ == "__main__":
    main()
