"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Wall-clock is CPU sanity
only; the graded roofline numbers come from the dry-run artifacts
(EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m benchmarks.run [--only fig6,fig7]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

BENCHES = [
    "fig5_overlap",        # task-mode overlap (Fig. 5)
    "fig6_formats",        # SELL-C-sigma vs CRS SpMV (Fig. 6)
    "fig7_tsm",            # tall-skinny kernels vs GEMM (Fig. 7)
    "fig8_layout",         # row- vs col-major block vectors (Fig. 8)
    "fig9_vectorization",  # width-tile sweep (Fig. 9)
    "fig10_codegen",       # hard-coded block width (Fig. 10)
    "fig11_scaling",       # Krylov case study + scaling model (Fig. 11)
    "table_hetero",        # heterogeneous weighted SpMV (section 4.1)
    "table_construction",  # construction cost (section 5.1)
    "fig_kpm_fusion",      # KPM fusion gain (section 5.3 / [24])
    "table_serving",       # continuous-batching SolverService (C2+C5)
    "table_precond",       # block-Jacobi / Chebyshev preconditioned CG
    "table_mixed_precision",  # bf16/f32 storage vs f32/f64 accumulate (C6)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench name filter")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    from repro.core import execution
    print(f"execution_policy,0.0,{execution.describe()}")
    failed = []
    for name in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
        except Exception as e:                            # noqa: BLE001
            failed.append(name)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
