"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes a
``BENCH_<utc-date>.json`` artifact (per-bench rows plus execution-policy
and backend metadata) so the perf trajectory is tracked across PRs as
committed files instead of living in CI grep bars.  Wall-clock is CPU
sanity only; the graded roofline numbers come from the dry-run
artifacts (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m benchmarks.run [--only fig6,fig7]
    PYTHONPATH=src python -m benchmarks.run --artifact out/bench.json
    PYTHONPATH=src python -m benchmarks.run --no-artifact
"""
from __future__ import annotations

import argparse
import datetime
import importlib
import json
import os
import platform
import sys
import traceback

from benchmarks import common

BENCHES = [
    "fig5_overlap",        # task-mode overlap (Fig. 5)
    "fig6_formats",        # SELL-C-sigma vs CRS SpMV (Fig. 6)
    "fig7_tsm",            # tall-skinny kernels vs GEMM (Fig. 7)
    "fig8_layout",         # row- vs col-major block vectors (Fig. 8)
    "fig9_vectorization",  # width-tile sweep (Fig. 9)
    "fig10_codegen",       # hard-coded block width (Fig. 10)
    "fig11_scaling",       # Krylov case study + scaling model (Fig. 11)
    "table_hetero",        # heterogeneous weighted SpMV (section 4.1)
    "table_construction",  # construction cost (section 5.1)
    "fig_kpm_fusion",      # KPM fusion gain (section 5.3 / [24])
    "table_serving",       # continuous-batching SolverService (C2+C5)
    "table_precond",       # block-Jacobi / Chebyshev preconditioned CG
    "table_mixed_precision",  # bf16/f32 storage vs f32/f64 accumulate (C6)
    "table_block_krylov",  # shared-Krylov block CG/MINRES vs column steppers
]


def _default_artifact_path() -> str:
    date = datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d")
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), f"BENCH_{date}.json")


def _metadata() -> dict:
    import jax
    from repro.core import execution
    return {
        "utc_time": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "execution_policy": execution.describe(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }


def write_artifact(path: str, *, benches: dict, failed: list,
                   metadata: dict) -> None:
    data = {
        "comment": ("benchmark trajectory artifact; regenerate with "
                    "PYTHONPATH=src python -m benchmarks.run.  Wall-"
                    "clock rows are CPU sanity numbers — the derived "
                    "column carries the roofline model quantities."),
        "metadata": metadata,
        "benches": benches,
        "failed": failed,
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench name filter")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="artifact path (default: BENCH_<utc-date>.json "
                         "at the repo root)")
    ap.add_argument("--no-artifact", action="store_true",
                    help="skip writing the JSON artifact")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    from repro.core import execution
    print(f"execution_policy,0.0,{execution.describe()}")
    benches: dict = {}
    failed = []
    for name in BENCHES:
        if only and not any(name.startswith(o) for o in only):
            continue
        common.reset_rows()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            benches[name] = list(common.ROWS)
        except Exception as e:                            # noqa: BLE001
            failed.append(name)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if not args.no_artifact:
        path = args.artifact or _default_artifact_path()
        write_artifact(path, benches=benches, failed=failed,
                       metadata=_metadata())
        print(f"artifact,0.0,{path}")
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
