"""Paper Fig. 11 / section 6.1: the Krylov eigensolver case study —
scaling behaviour of the GHOST building blocks.

CPU analogue of the Anasazi/Krylov-Schur study: a Krylov solve on MATPDE
through the GHOST operator stack, plus the *derived* strong-scaling model
from the distributed partitioner: per-shard work and halo volume as the
shard count grows (parallel efficiency = work / (work + comm) under the
Table-1 bandwidth model)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import from_coo
from repro.core.distributed import dist_from_coo
from repro.matrices import matpde
from repro.solvers import cg, make_operator


def main():
    policy_row("fig11_scaling")
    r, c, v, n = matpde(128, beta_c=0.0)
    A = from_coo(r, c, v, (n, n), C=32, sigma=128, w_align=4,
                 dtype=np.float32)
    op = make_operator(A)
    b = np.random.default_rng(0).standard_normal((n, 4)).astype(np.float32)
    bp = A.permute(b)

    def solve():
        return cg(op, bp, tol=1e-6, maxiter=800)

    t = time_fn(solve, iters=3)
    res = solve()
    row("fig11_matpde_blockcg", t * 1e6,
        f"iters={int(res.iters)};converged={bool(np.asarray(res.converged).all())}")

    # strong scaling model: halo volume growth vs per-shard work
    hbm, ici = 819e9, 50e9                      # v5e bytes/s
    for P in (2, 4, 8, 16):
        D = dist_from_coo(r, c, v, n, nshards=P, C=32, sigma=128,
                          w_align=4, dtype=np.float32)
        work_bytes = (D.l_vals.size + D.r_vals.size) * 8 / P
        halo_bytes = D.comm_volume * 4
        t_work = work_bytes / hbm
        t_comm = halo_bytes / ici
        eff_overlap = t_work / max(t_work, t_comm)       # comm hidden
        eff_seq = t_work / (t_work + t_comm)             # no overlap
        row(f"fig11_scaling_P{P}", 0.0,
            f"halo_words={D.comm_volume};eff_no_overlap={eff_seq:.3f};"
            f"eff_overlap={eff_overlap:.3f}")


if __name__ == "__main__":
    main()
