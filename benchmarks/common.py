"""Benchmark utilities.

This container is CPU-only, so wall-clock numbers are sanity signals, not
the graded metric; each bench also reports the *derived* model quantity
(bytes moved, code balance, beta, comm volume) that the paper's roofline
methodology actually predicts performance from.  The TPU-facing numbers
live in EXPERIMENTS.md §Roofline (from the dry-run artifacts).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


#: rows emitted since the last reset_rows(); benchmarks/run.py drains
#: this into the BENCH_<date>.json artifact so the perf trajectory is a
#: committed file, not a CI log grep
ROWS: list = []


def reset_rows() -> None:
    ROWS.clear()


def row(name: str, us: float, derived: str):
    ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def policy_row(bench: str) -> None:
    """Report the resolved kernel execution mode for this benchmark run.

    Every bench prints this first, so BENCH numbers can never again
    silently come from the Pallas interpreter without saying so.
    """
    from repro.core import execution
    row(f"{bench}_execution_policy", 0.0, execution.describe())
