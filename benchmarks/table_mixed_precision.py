"""Mixed-precision SELL-C-sigma storage: SpMV + CG across storage dtypes.

The paper's C6 argument applied to *data types*: SpMV is memory-bandwidth
bound (section 5.1, Fig. 6), so narrowing the value stream is a direct
speedup — GHOST generates kernels per dtype for exactly this reason.  This
bench runs the same 3D Laplacian at three storage configurations:

    f64          — f64 values, f64 accumulate (requires x64)
    f32          — f32 values, f32 accumulate (the classic single dtype)
    bf16_store   — bf16 *stored* values, f32 accumulate (store_dtype=)

and reports, per variant: bytes moved per nonzero (value + column index,
beta-adjusted), SpMV wall time, CG iterations to tolerance, and the final
residual.  The acceptance bar — bf16 storage >= 1.3x faster than f32
storage for SpMV — is asserted only when the *compiled* Pallas path
actually ran (on CPU/interpret runs the value-stream width does not bound
throughput, so the ratio is reported but not asserted).

CG must converge at every storage dtype; the iteration delta vs f32 is the
price of the narrower values (typically 0-15% on a Laplacian at 1e-6).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import policy_row, row, time_fn
from repro.core import execution
from repro.core.sellcs import from_coo
from repro.kernels import ops
from repro.matrices import laplace3d
from repro.solvers import cg, make_operator

NX = 12                    # n = 1728
TOL = 1e-6
MAXITER = 2000
NVECS = 4                  # block vector: the high-intensity sweep (C2)


def _bytes_per_nnz(m) -> float:
    """HBM bytes per nonzero of one SpMV value+index stream (beta-adj)."""
    vb = jnp.dtype(m.store_dtype).itemsize
    ib = jnp.dtype(m.cols.dtype).itemsize
    return (vb + ib) * m.cap / max(1, m.nnz)


def _run_variant(name, r, c, v, n, *, dtype, store_dtype, impl):
    m = from_coo(r, c, v, (n, n), C=16, sigma=32, w_align=4,
                 dtype=dtype, store_dtype=store_dtype)
    op = make_operator(m, impl=impl)
    rng = np.random.default_rng(7)
    x = m.permute(jnp.asarray(rng.standard_normal((n, NVECS)), m.dtype))
    spmv_t = time_fn(lambda: op.mv(x), warmup=2, iters=5)

    b = m.permute(jnp.asarray(rng.standard_normal(n), m.dtype))
    res = cg(op, b, tol=TOL, maxiter=MAXITER)
    conv = bool(np.all(np.asarray(res.converged)))
    assert conv, f"CG did not converge at storage variant {name!r}"
    row(f"mixed_precision_spmv_{name}", spmv_t * 1e6,
        f"n={n};nvecs={NVECS};store={m.store_dtype};compute={m.dtype};"
        f"bytes_per_nnz={_bytes_per_nnz(m):.2f};beta={m.beta:.3f}")
    row(f"mixed_precision_cg_{name}", 0.0,
        f"iters={int(res.iters)};tol={TOL:g};"
        f"resnorm={float(np.max(res.resnorm)):.3e};converged={conv}")
    return spmv_t, int(res.iters)


def main():
    policy_row("table_mixed_precision")
    r, c, v, n = laplace3d(NX)
    # the raw stencil values (+-1, 6) are exactly representable in bf16,
    # which would make the accuracy leg vacuous; an irrational uniform
    # scale keeps the matrix SPD while every stored value genuinely
    # rounds at the storage width
    v = v * np.e

    # compiled Pallas when the backend takes it, jnp reference otherwise
    # (an interpret-mode Pallas sweep would time the interpreter, not the
    # value stream)
    pol = execution.current_policy()
    compiled = (not pol.interpret) and execution.compiled_available()
    impl = "pallas" if compiled else "ref"

    times, iters = {}, {}
    try:
        from jax.experimental import enable_x64
        with enable_x64():
            times["f64"], iters["f64"] = _run_variant(
                "f64", r, c, v, n, dtype=np.float64, store_dtype=None,
                impl=impl)
    except Exception as e:                               # noqa: BLE001
        row("mixed_precision_spmv_f64", 0.0, f"SKIPPED:{type(e).__name__}")
    times["f32"], iters["f32"] = _run_variant(
        "f32", r, c, v, n, dtype=np.float32, store_dtype=None, impl=impl)
    times["bf16_store"], iters["bf16_store"] = _run_variant(
        "bf16_store", r, c, v, n, dtype=np.float32,
        store_dtype=jnp.bfloat16, impl=impl)

    speedup = times["f32"] / times["bf16_store"]
    delta = iters["bf16_store"] - iters["f32"]
    row("mixed_precision_speedup", 0.0,
        f"bf16_store_vs_f32={speedup:.2f}x;cg_iter_delta={delta:+d};"
        f"compiled={compiled};asserted={compiled}")
    if compiled:
        # the tentpole acceptance bar: narrower values must pay off when
        # the bandwidth-bound compiled kernel actually runs
        assert speedup >= 1.3, (
            f"bf16-store SpMV speedup {speedup:.2f}x < 1.3x acceptance "
            f"bar in compiled mode")


if __name__ == "__main__":
    main()
