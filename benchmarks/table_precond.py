"""Preconditioned vs plain CG on an ill-conditioned anisotropic Laplacian.

The canonical preconditioned-Krylov serving workload (mixed-mode PETSc
benchmarking, Lange et al. 2013) on GHOST building blocks: a 2D
anisotropic Laplacian ``-eps u_xx - u_yy`` whose strong coupling runs
along contiguous grid lines.  Plain CG crawls (condition number scales
with ``1/eps``); block-Jacobi with line-sized aligned blocks (extracted
straight from SELL-C-sigma storage, factorized host-side once, applied
via the Pallas batched block-diagonal kernel) captures the dominant
coupling, and a degree-4 Chebyshev polynomial (from the registry-cached
Lanczos bounds) trades extra SpMVs for far fewer global reductions.

Reported per variant: iterations to tol, wall-clock per solve, setup
cost, and the iteration/time reduction vs plain CG.  The acceptance bar
(checked here and by the CI `precond-smoke` grep) is a >= 2x
iteration-count reduction for block-Jacobi PCG.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.matrices import anisotropic_laplace2d
from repro.runtime import MatrixRegistry
from repro.solvers import cg

NX = 48                    # n = 2304; block_size = NX -> line Jacobi
EPSILON = 1e-2
TOL = 1e-6
MAXITER = 4000


def _solve(op, b, M=None):
    res = cg(op, b, tol=TOL, maxiter=MAXITER, M=M)
    assert bool(np.all(np.asarray(res.converged))), \
        f"CG(M={M}) did not converge in {MAXITER} iterations"
    return res


def main():
    policy_row("table_precond")
    r, c, v, n = anisotropic_laplace2d(NX, epsilon=EPSILON)
    reg = MatrixRegistry()
    # sigma=1 keeps the permutation trivial so the aligned blocks are the
    # grid lines (see docs/preconditioning.md on the sigma/bs interplay)
    reg.register("ani", rows=r, cols=c, vals=v, shape=(n, n), C=16,
                 sigma=1, w_align=4, dtype=np.float32)
    op = reg.operator("ani")
    rng = np.random.default_rng(11)
    b = op.to_op_space(rng.standard_normal(n).astype(np.float32))

    t0 = time.perf_counter()
    M_bj = reg.preconditioner("ani", f"block_jacobi:{NX}")
    bj_setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    M_ch = reg.preconditioner("ani", "chebyshev:4")   # Lanczos bounds + poly
    ch_setup = time.perf_counter() - t0

    variants = [
        ("plain_cg", None, 0.0),
        ("block_jacobi_cg", M_bj, bj_setup),
        ("chebyshev_cg", M_ch, ch_setup),
    ]
    iters = {}
    walls = {}
    for name, M, setup in variants:
        res = _solve(op, b, M)                        # warm (trace+compile)
        iters[name] = int(res.iters)
        walls[name] = time_fn(lambda: _solve(op, b, M).x, warmup=1, iters=3)
        row(f"precond_{name}", walls[name] * 1e6,
            f"n={n};iters={iters[name]};tol={TOL:g};"
            f"setup_s={setup:.4f};resnorm={float(np.max(res.resnorm)):.3e}")

    it_red = iters["plain_cg"] / max(1, iters["block_jacobi_cg"])
    ch_red = iters["plain_cg"] / max(1, iters["chebyshev_cg"])
    t_red = walls["plain_cg"] / walls["block_jacobi_cg"]
    row("precond_iter_reduction", 0.0,
        f"block_jacobi_vs_plain={it_red:.2f}x;"
        f"chebyshev_vs_plain={ch_red:.2f}x;"
        f"block_jacobi_wallclock={t_red:.2f}x;"
        f"epsilon={EPSILON:g};block_size={NX}")
    assert it_red >= 2.0, (
        f"block-Jacobi PCG iteration reduction {it_red:.2f}x < 2x "
        f"acceptance bar")


if __name__ == "__main__":
    main()
