"""Paper Fig. 7: custom tall & skinny kernels vs general GEMM.

GHOST's claim: tsmttsm/tsmm specialized for m,k << n are memory-bound and
beat a generic BLAS call.  We compare the specialized reduction (f32
accumulate, fused scale) against the generic dot path across the paper's
m,k sweep, and report the derived traffic model (bytes/flop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import policy_row, row, time_fn
from repro.core import blockvec as bv


def main():
    policy_row("fig7_tsm")
    n = 1 << 19                                    # 524288 rows
    rng = np.random.default_rng(0)
    for m in (1, 2, 4, 8, 16, 32):
        k = m
        V = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
        W = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)

        spec = jax.jit(lambda V, W: bv.tsmttsm(V, W))
        gen = jax.jit(lambda V, W: jnp.dot(V.T, W))
        t_s = time_fn(spec, V, W)
        t_g = time_fn(gen, V, W)
        flops = 2 * n * m * k
        traffic = 4 * n * (m + k)                   # one sweep, f32
        row(f"fig7_tsmttsm_m{m}k{k}", t_s * 1e6,
            f"speedup_vs_generic={t_g / t_s:.2f}x;"
            f"bytes_per_flop={traffic / flops:.2f};"
            f"gbs_cpu={traffic / t_s / 1e9:.1f}")

        X = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        spec2 = jax.jit(lambda V, X: bv.tsmm(V, X))
        gen2 = jax.jit(lambda V, X: jnp.dot(V, X))
        t_s2 = time_fn(spec2, V, X)
        t_g2 = time_fn(gen2, V, X)
        row(f"fig7_tsmm_m{m}k{k}", t_s2 * 1e6,
            f"speedup_vs_generic={t_g2 / t_s2:.2f}x")


if __name__ == "__main__":
    main()
