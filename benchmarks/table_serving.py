"""Continuous-batching SolverService vs. solve-one-at-a-time baseline.

The GHOST thesis applied to serving: many independent sparse solves
should be fed through one block-vector kernel stream (C2) with the
runtime retiring and refilling columns (C5) instead of running each
request as its own solver call.  This table measures that claim on a
mixed 32-request workload (CG + MINRES, tolerances 1e-5/1e-6/1e-7, all
requests arriving at t=0):

* ``baseline`` — sequential monolithic ``cg``/``minres`` calls, one per
  request (runs at block width 1; ``lax.while_loop`` re-traces on every
  call — inherent to the monolithic API);
* ``service``  — :class:`SolverService` at block width 8, chunked
  steppers, converged columns retired between chunks and freed slots
  refilled from the queue; chunk/init/merge programs compile once and
  serve every subsequent request.

Both paths are warmed with a small prologue workload first (serving
throughput is a steady-state metric), and the cold first-contact numbers
are reported as separate rows.  Reported per phase: requests/s and
per-request p50/p99 latency (submit->result, queue wait included), plus
the steady-state throughput speedup.  The acceptance bar for this
workload is >= 2x service throughput.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import policy_row, row
from repro.matrices import laplace3d
from repro.runtime import MatrixRegistry, SolverService
from repro.solvers import cg, minres

N_REQUESTS = 32
BLOCK_WIDTH = 8
CHUNK_ITERS = 16
MAXITER = 600


def _workload(n, rng):
    tols = [1e-5, 1e-6, 1e-7]
    reqs = []
    for i in range(N_REQUESTS):
        b = rng.standard_normal(n).astype(np.float32)
        solver = "minres" if i % 4 == 3 else "cg"
        reqs.append((solver, b, tols[i % len(tols)]))
    return reqs


def _stats(name, latencies, wall):
    lat = np.asarray(latencies)
    rps = len(lat) / wall
    row(f"serving_{name}", wall * 1e6 / len(lat),
        f"requests={len(lat)};wall_s={wall:.3f};reqs_per_s={rps:.2f};"
        f"p50_ms={np.percentile(lat, 50) * 1e3:.1f};"
        f"p99_ms={np.percentile(lat, 99) * 1e3:.1f}")
    return rps


def _run_baseline(op, reqs):
    solvers = {"cg": cg, "minres": minres}
    t0 = time.perf_counter()
    lat = []
    for solver, b, tol in reqs:
        res = solvers[solver](op, op.to_op_space(b), tol=tol, maxiter=MAXITER)
        np.asarray(res.x)                       # materialize like a response
        lat.append(time.perf_counter() - t0)
        assert bool(res.converged), f"baseline {solver} tol={tol} diverged"
    return lat, time.perf_counter() - t0


def _run_service(svc, reqs):
    t0 = time.perf_counter()
    tickets = [svc.submit("lap", b, solver=solver, tol=tol, maxiter=MAXITER)
               for solver, b, tol in reqs]
    svc.drain()
    wall = time.perf_counter() - t0
    assert all(t.result.converged for t in tickets), "service request diverged"
    return [t.latency for t in tickets], wall


def main():
    policy_row("table_serving")
    r, c, v, n = laplace3d(8)
    reg = MatrixRegistry()
    reg.register("lap", rows=r, cols=c, vals=v, shape=(n, n), C=16,
                 sigma=32, w_align=4, dtype=np.float32)
    op = reg.operator("lap")
    rng = np.random.default_rng(7)
    warm_reqs = _workload(n, rng)               # trace-warming prologue:
    reqs = _workload(n, rng)                    # full mix incl. refill/merge

    svc = SolverService(reg, block_width=BLOCK_WIDTH, chunk_iters=CHUNK_ITERS)

    # ---- cold first contact (trace/compile included) ---------------------
    lat, wall = _run_baseline(op, warm_reqs)
    _stats("baseline_cold", lat, wall)
    lat, wall = _run_service(svc, warm_reqs)
    _stats("service_cold", lat, wall)

    # ---- steady state: mixed 32-request workload -------------------------
    base_lat, base_wall = _run_baseline(op, reqs)
    base_rps = _stats("baseline", base_lat, base_wall)
    svc_lat, svc_wall = _run_service(svc, reqs)
    svc_rps = _stats("service", svc_lat, svc_wall)

    speedup = svc_rps / base_rps
    row("serving_speedup", 0.0,
        f"service_vs_baseline={speedup:.2f}x;block_width={BLOCK_WIDTH};"
        f"chunk_iters={CHUNK_ITERS};"
        f"chunks={svc.stats['chunks']};refills={svc.stats['refills']}")


if __name__ == "__main__":
    main()
